#!/usr/bin/env bash
# refresh_baselines.sh — re-measure the committed bench baselines.
#
# Runs the two bench smokes at the same --tiny sizes CI pins
# (.github/workflows/ci.yml), then copies the freshly-written
# rust/BENCH_*.json over the repo-root baselines WITHOUT the
# "committed-unverified-baseline" provenance marker — from then on
# scripts/perf_compare.sh enforces (>30% drift on non-wall-clock keys
# fails CI) instead of downgrading every failure to a warning.
#
# Run it on the reference machine (the CI runner class, so the numbers
# gate the machines that actually check them), eyeball the diff, commit
# the two JSON files it rewrites. That's the whole refresh.
set -euo pipefail
cd "$(dirname "$0")/.."

(cd rust && cargo bench --bench perf_micro -- --tiny --json)
(cd rust && cargo bench --bench dist_ship -- --tiny --json)

for name in BENCH_perf_micro.json BENCH_dist_ship.json; do
    python3 - "$name" <<'PYEOF'
import json
import sys

name = sys.argv[1]
with open(f"rust/{name}") as f:
    doc = json.load(f)
doc.pop("provenance", None)
with open(name, "w") as f:
    json.dump(doc, f, indent=2)
    f.write("\n")
print(f"refreshed {name} (provenance marker dropped; perf gate armed)")
PYEOF
done
