#!/usr/bin/env bash
# perf_compare.sh <baseline.json> <current.json>
#
# Compare a bench JSON report against its committed baseline: every
# numeric leaf (walked recursively, dotted paths) is checked for drift.
#   >10%  -> warning
#   >30%  -> failure (exit 1)
#
# Wall-clock keys (*_secs) are warn-only by default — CI runners are too
# noisy to gate on time — unless PERF_COMPARE_STRICT=1.  A baseline whose
# "provenance" is "committed-unverified-baseline" (hand-pinned, never
# measured on the reference machine) downgrades every failure to a
# warning: the first verified run should refresh the baseline and drop
# the provenance marker.
set -euo pipefail

if [ "$#" -ne 2 ]; then
    echo "usage: $0 <baseline.json> <current.json>" >&2
    exit 2
fi

BASELINE="$1" CURRENT="$2" python3 - <<'PYEOF'
import json
import os
import sys

baseline_path = os.environ["BASELINE"]
current_path = os.environ["CURRENT"]
strict = os.environ.get("PERF_COMPARE_STRICT") == "1"

with open(baseline_path) as f:
    baseline = json.load(f)
with open(current_path) as f:
    current = json.load(f)


def leaves(doc, prefix=""):
    """Flatten to {dotted.path: number} over the numeric leaves."""
    out = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            out.update(leaves(v, f"{prefix}{k}." if not prefix else f"{prefix}{k}."))
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            out.update(leaves(v, f"{prefix}{i}."))
    elif isinstance(doc, (int, float)) and not isinstance(doc, bool):
        out[prefix.rstrip(".")] = float(doc)
    return out


base = leaves(baseline)
cur = leaves(current)
unverified = baseline.get("provenance") == "committed-unverified-baseline"

warnings, failures = [], []
for path in sorted(set(base) | set(cur)):
    if path not in base:
        warnings.append(f"{path}: only in current ({cur[path]:g})")
        continue
    if path not in cur:
        warnings.append(f"{path}: only in baseline ({base[path]:g})")
        continue
    b, c = base[path], cur[path]
    drift = abs(c - b) / max(abs(b), 1e-12)
    if drift <= 0.10:
        continue
    msg = f"{path}: {b:g} -> {c:g} ({drift:+.0%} drift)"
    time_key = path.endswith("_secs") or "_secs." in path
    if time_key and not strict:
        warnings.append(msg + " [wall-clock, warn-only]")
    elif drift > 0.30:
        failures.append(msg)
    else:
        warnings.append(msg)

name = os.path.basename(current_path)
for w in warnings:
    print(f"perf_compare WARN  {name}: {w}")
if failures and unverified:
    for f_ in failures:
        print(f"perf_compare WARN  {name}: {f_} [baseline unverified, downgraded]")
    print(f"perf_compare: {name}: baseline is provenance-marked unverified; "
          "run scripts/refresh_baselines.sh on the reference machine to arm the gate")
elif failures:
    for f_ in failures:
        print(f"perf_compare FAIL  {name}: {f_}")
    sys.exit(1)
if not failures and not warnings:
    print(f"perf_compare OK    {name}: all numeric leaves within 10% of baseline")
PYEOF
