//! The GREEDY algorithm (Algorithm 2.1) and the Lazy Greedy variant.
//!
//! Both maximize a monotone submodular [`Oracle`] subject to a hereditary
//! [`Constraint`] over an explicit candidate list (the distributed
//! algorithms call this on partitions and on unions of child solutions).
//! Both report the number of gain queries ("function calls") and their
//! total abstract cost — the paper's primary performance metric (§5: "the
//! number of function calls in the critical path ... represents the
//! parallel runtime").

use crate::constraint::Constraint;
use crate::objective::Oracle;
use crate::ElemId;

pub mod lazy;
pub mod naive;
pub mod sieve;
pub mod stochastic;

pub use lazy::greedy_lazy;
pub use naive::greedy_naive;
pub use sieve::{sieve_coreset, sieve_streaming, SieveCoreset};
pub use stochastic::greedy_stochastic;

/// Result of one GREEDY run.
#[derive(Clone, Debug, Default)]
pub struct GreedyOutcome {
    /// Selected elements in selection order.
    pub solution: Vec<ElemId>,
    /// Objective value `f(solution)` (w.r.t. the evaluation view used).
    pub value: f64,
    /// Number of marginal-gain queries performed.
    pub calls: u64,
    /// Σ of `call_cost` over those queries (the δ-weighted cost of Table 1).
    pub cost: u64,
}

/// Which greedy implementation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GreedyKind {
    /// Algorithm 2.1 verbatim: rescan every feasible candidate per round.
    Naive,
    /// Minoux's lazy evaluation (same output guarantees, far fewer calls;
    /// the paper's implementation choice, §5 "MPI Implementation").
    Lazy,
}

/// Dispatch on [`GreedyKind`].
pub fn greedy(
    kind: GreedyKind,
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    candidates: &[ElemId],
    view: Option<&[ElemId]>,
) -> GreedyOutcome {
    match kind {
        GreedyKind::Naive => greedy_naive(oracle, constraint, candidates, view),
        GreedyKind::Lazy => greedy_lazy(oracle, constraint, candidates, view),
    }
}

/// Deduplicate candidates preserving first-seen order (unions of child
/// solutions routinely overlap).  §Perf P4: a dense bool mask beats hashing
/// — ids are dense `0..n` and the mask allocation is one memset.
pub(crate) fn dedup_candidates(candidates: &[ElemId]) -> Vec<ElemId> {
    let n = candidates.iter().copied().max().map_or(0, |m| m as usize + 1);
    let mut seen = vec![false; n];
    candidates
        .iter()
        .copied()
        .filter(|&e| !std::mem::replace(&mut seen[e as usize], true))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraint::Cardinality;
    use crate::objective::{FacilityLocation, KCover, Modular};
    use std::sync::Arc;

    #[test]
    fn modular_greedy_is_optimal() {
        // Greedy on a modular function picks the k largest weights exactly.
        let o = Modular::new(vec![0.3, 0.9, 0.1, 0.7, 0.5]);
        let c = Cardinality::new(2);
        let cands: Vec<ElemId> = (0..5).collect();
        for kind in [GreedyKind::Naive, GreedyKind::Lazy] {
            let out = greedy(kind, &o, &c, &cands, None);
            let mut sol = out.solution.clone();
            sol.sort_unstable();
            assert_eq!(sol, vec![1, 3], "{kind:?}");
            assert!((out.value - 1.6).abs() < 1e-12);
        }
    }

    #[test]
    fn lazy_matches_naive_value() {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 120,
                num_items: 80,
                mean_size: 6.0,
                zipf_s: 0.9,
            },
            5,
        );
        let o = KCover::new(Arc::new(data));
        let c = Cardinality::new(10);
        let cands: Vec<ElemId> = (0..o.n() as u32).collect();
        let a = greedy_naive(&o, &c, &cands, None);
        let b = greedy_lazy(&o, &c, &cands, None);
        assert!((a.value - b.value).abs() < 1e-9, "naive {} vs lazy {}", a.value, b.value);
        assert!(
            b.calls <= a.calls,
            "lazy ({}) should not use more calls than naive ({})",
            b.calls,
            a.calls
        );
    }

    #[test]
    fn lazy_matches_naive_solution_with_distinct_gains() {
        // FacilityLocation with random weights: ties have measure ~0.
        let o = FacilityLocation::random(15, 25, 9);
        let c = Cardinality::new(6);
        let cands: Vec<ElemId> = (0..25).collect();
        let a = greedy_naive(&o, &c, &cands, None);
        let b = greedy_lazy(&o, &c, &cands, None);
        assert_eq!(a.solution, b.solution);
    }

    #[test]
    fn duplicate_candidates_are_harmless() {
        let o = Modular::new(vec![1.0, 2.0]);
        let c = Cardinality::new(2);
        for kind in [GreedyKind::Naive, GreedyKind::Lazy] {
            let out = greedy(kind, &o, &c, &[1, 1, 0, 1, 0], None);
            assert!((out.value - 3.0).abs() < 1e-12, "{kind:?}");
            assert_eq!(out.solution.len(), 2);
        }
    }

    #[test]
    fn stops_at_zero_gain() {
        // Only 2 distinct useful sets; k allows 4 — greedy must stop early
        // (Algorithm 2.1 line 6: break when marginal gain is zero).
        let data = crate::data::itemsets::ItemsetCollection::from_sets(&[
            vec![0, 1],
            vec![1, 0],
            vec![2],
            vec![],
        ]);
        let o = KCover::new(Arc::new(data));
        let c = Cardinality::new(4);
        for kind in [GreedyKind::Naive, GreedyKind::Lazy] {
            let out = greedy(kind, &o, &c, &[0, 1, 2, 3], None);
            assert_eq!(out.value, 3.0, "{kind:?}");
            assert_eq!(out.solution.len(), 2, "{kind:?} must stop at zero gain");
        }
    }

    #[test]
    fn empty_candidates() {
        let o = Modular::new(vec![1.0]);
        let c = Cardinality::new(3);
        for kind in [GreedyKind::Naive, GreedyKind::Lazy] {
            let out = greedy(kind, &o, &c, &[], None);
            assert!(out.solution.is_empty());
            assert_eq!(out.value, 0.0);
            assert_eq!(out.calls, 0);
        }
    }
}
