//! Sieve-Streaming (Badanidiyuru et al., KDD 2014): single-pass streaming
//! submodular maximization under a cardinality constraint with a
//! `(1/2 − ε)` guarantee and `O((k/ε)·log k)` memory.
//!
//! Motivation here: the paper positions GreedyML for *edge computing*
//! (§6.2.1) where machines cannot hold their partition at once; sieve
//! streaming is the natural leaf-level alternative in that regime and a
//! baseline the ablation bench compares against (calls are 1 per element
//! per live threshold, memory is O(k·log(k)/ε) elements instead of the
//! whole partition).

use super::GreedyOutcome;
use crate::constraint::Cardinality;
use crate::objective::{GainState, Oracle};
use crate::ElemId;

/// One threshold's candidate solution.
struct Sieve<'a> {
    threshold: f64,
    state: Box<dyn GainState + 'a>,
}

/// All live sieves after one pass, plus the query accounting.
struct SievePass<'a> {
    sieves: Vec<Sieve<'a>>,
    calls: u64,
    cost: u64,
}

/// The union of every sieve's candidate set after one pass — the streaming
/// *coreset* of Lucic et al. ("Horizontally Scalable Submodular
/// Maximization", PAPERS.md).  It contains the winning sieve's solution, so
/// running greedy over it preserves the `(1/2 − ε)` certificate, and its
/// size is at most `sieves × k = O(k·log(k)/ε)` elements — the quantity the
/// coreset dist mode ships instead of whole `O(n/m)` shards.
pub struct SieveCoreset {
    /// Union of sieve candidate sets, in stream order.
    pub elems: Vec<ElemId>,
    /// The best single sieve (the classic Sieve-Streaming answer).
    pub best: GreedyOutcome,
}

/// Run Sieve-Streaming over `stream` with budget `k` and accuracy `epsilon`.
///
/// Only cardinality constraints are supported (the algorithm's analysis is
/// specific to them), which is also the only family the paper evaluates.
pub fn sieve_streaming(
    oracle: &dyn Oracle,
    constraint: &Cardinality,
    stream: &[ElemId],
    view: Option<&[ElemId]>,
    epsilon: f64,
) -> GreedyOutcome {
    let pass = run_pass(oracle, constraint.k().max(1), stream, view, epsilon);
    best_outcome(&pass)
}

/// Run the sieve pass and keep *every* sieve's candidates: the coreset
/// consumed by `--coreset` dist runs (leaves sieve their shard; accumulation
/// nodes re-sieve the union of their children's coresets).
pub fn sieve_coreset(
    oracle: &dyn Oracle,
    constraint: &Cardinality,
    stream: &[ElemId],
    view: Option<&[ElemId]>,
    epsilon: f64,
) -> SieveCoreset {
    let pass = run_pass(oracle, constraint.k().max(1), stream, view, epsilon);
    let best = best_outcome(&pass);
    let mut member: std::collections::HashSet<ElemId> = std::collections::HashSet::new();
    for s in &pass.sieves {
        member.extend(s.state.solution().iter().copied());
    }
    // Stream order keeps the coreset deterministic and re-sieveable.
    let elems: Vec<ElemId> = stream.iter().copied().filter(|e| member.remove(e)).collect();
    SieveCoreset { elems, best }
}

fn best_outcome(pass: &SievePass<'_>) -> GreedyOutcome {
    let best = pass
        .sieves
        .iter()
        .max_by(|a, b| a.state.value().partial_cmp(&b.state.value()).unwrap());
    match best {
        None => GreedyOutcome { solution: Vec::new(), value: 0.0, calls: pass.calls, cost: pass.cost },
        Some(s) => GreedyOutcome {
            solution: s.state.solution().to_vec(),
            value: s.state.value(),
            calls: pass.calls,
            cost: pass.cost,
        },
    }
}

fn run_pass<'a>(
    oracle: &'a dyn Oracle,
    k: usize,
    stream: &[ElemId],
    view: Option<&[ElemId]>,
    epsilon: f64,
) -> SievePass<'a> {
    assert!(epsilon > 0.0 && epsilon < 1.0);
    let mut calls = 0u64;
    let mut cost = 0u64;

    // First pass fragment: track max singleton value m; thresholds are
    // (1+ε)^j in [m, 2·k·m]. We lazily instantiate sieves as m grows (the
    // standard SIEVE-STREAMING++ -style trick, done in one pass).
    let mut max_singleton = 0.0f64;
    let mut sieves: Vec<Sieve> = Vec::new();
    let ratio = 1.0 + epsilon;

    for &e in stream {
        // Singleton value via a throwaway gain query on an empty state is
        // expensive; use a shared empty state instead.
        // (f(∅ ∪ e) − f(∅) = f({e}).)
        let singleton = {
            let empty = oracle.new_state(view);
            calls += 1;
            cost += empty.call_cost(e);
            empty.gain(e)
        };
        if singleton > max_singleton {
            max_singleton = singleton;
            // (Re)instantiate thresholds covering [m/(2k)… 2km]; keep
            // existing sieves whose thresholds remain in range.
            let lo = max_singleton / (2.0 * k as f64);
            let hi = 2.0 * k as f64 * max_singleton;
            sieves.retain(|s| s.threshold >= lo / ratio && s.threshold <= hi * ratio);
            let mut t = lo;
            while t <= hi {
                let exists = sieves.iter().any(|s| (s.threshold / t - 1.0).abs() < 1e-9);
                if !exists {
                    sieves.push(Sieve { threshold: t, state: oracle.new_state(view) });
                }
                t *= ratio;
            }
        }
        for sieve in &mut sieves {
            if sieve.state.solution().len() >= k {
                continue;
            }
            calls += 1;
            cost += sieve.state.call_cost(e);
            let gain = sieve.state.gain(e);
            // Admit when the marginal gain clears the water level
            // (threshold/2 − f(S))/(k − |S|)… the classic simplified rule:
            // gain ≥ (threshold/2 − f(S)) / (k − |S|).
            let need = (sieve.threshold / 2.0 - sieve.state.value())
                / (k - sieve.state.solution().len()) as f64;
            if gain >= need && gain > 0.0 {
                sieve.state.commit(e);
            }
        }
    }

    SievePass { sieves, calls, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::greedy::greedy_lazy;
    use crate::objective::{KCover, Oracle};
    use std::sync::Arc;

    fn oracle(n: usize, seed: u64) -> KCover {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: n,
                num_items: n / 2,
                mean_size: 6.0,
                zipf_s: 0.9,
            },
            seed,
        );
        KCover::new(Arc::new(data))
    }

    #[test]
    fn clears_half_minus_eps_empirically() {
        let o = oracle(1500, 3);
        let c = Cardinality::new(25);
        let stream: Vec<u32> = (0..1500).collect();
        let lazy = greedy_lazy(&o, &c, &stream, None);
        let sieve = sieve_streaming(&o, &c, &stream, None, 0.2);
        assert!(
            sieve.value >= 0.5 * lazy.value,
            "sieve {} vs lazy {}",
            sieve.value,
            lazy.value
        );
        assert!(sieve.solution.len() <= 25);
        assert!((sieve.value - o.eval(&sieve.solution)).abs() < 1e-9);
    }

    #[test]
    fn single_pass_is_order_sensitive_but_feasible() {
        let o = oracle(800, 9);
        let c = Cardinality::new(12);
        let fwd: Vec<u32> = (0..800).collect();
        let mut rev = fwd.clone();
        rev.reverse();
        let a = sieve_streaming(&o, &c, &fwd, None, 0.25);
        let b = sieve_streaming(&o, &c, &rev, None, 0.25);
        for out in [&a, &b] {
            assert!(out.solution.len() <= 12);
            assert!(out.value > 0.0);
        }
    }

    #[test]
    fn coreset_contains_the_best_sieve_and_stays_small() {
        let o = oracle(1200, 7);
        let c = Cardinality::new(20);
        let stream: Vec<u32> = (0..1200).collect();
        let cs = sieve_coreset(&o, &c, &stream, None, 0.2);
        // The winning sieve's solution is a subset of the union.
        for e in &cs.best.solution {
            assert!(cs.elems.contains(e), "coreset lost best-sieve element {e}");
        }
        // Union in stream order, no duplicates.
        let mut sorted = cs.elems.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), cs.elems.len());
        assert!(cs.elems.windows(2).all(|w| w[0] < w[1]));
        // Far smaller than the stream; greedy over it clears the sieve value.
        assert!(cs.elems.len() < 1200 / 2, "coreset {} too large", cs.elems.len());
        let over = greedy_lazy(&o, &c, &cs.elems, None);
        assert!(over.value >= cs.best.value - 1e-9);
        // And matches a plain sieve_streaming run exactly.
        let plain = sieve_streaming(&o, &c, &stream, None, 0.2);
        assert_eq!(plain.solution, cs.best.solution);
        assert_eq!(plain.value.to_bits(), cs.best.value.to_bits());
    }

    #[test]
    fn empty_stream() {
        let o = oracle(50, 1);
        let c = Cardinality::new(5);
        let out = sieve_streaming(&o, &c, &[], None, 0.2);
        assert!(out.solution.is_empty());
        assert_eq!(out.value, 0.0);
    }

    #[test]
    fn k_one_picks_a_near_best_singleton() {
        let o = oracle(300, 5);
        let c = Cardinality::new(1);
        let stream: Vec<u32> = (0..300).collect();
        let out = sieve_streaming(&o, &c, &stream, None, 0.1);
        let best = (0..300u32).map(|e| o.eval(&[e])).fold(0.0f64, f64::max);
        assert!(out.value >= 0.5 * best, "{} vs best {best}", out.value);
    }
}
