//! Algorithm 2.1 verbatim: every round, evaluate the marginal gain of every
//! feasible candidate and pick the argmax (ties broken toward the lower
//! element id for determinism).  `O(nk)` gain queries — the baseline whose
//! call counts Table 1's `nk` row describes.

use super::{dedup_candidates, GreedyOutcome};
use crate::constraint::Constraint;
use crate::objective::Oracle;
use crate::ElemId;

/// Run the naive GREEDY.
pub fn greedy_naive(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    candidates: &[ElemId],
    view: Option<&[ElemId]>,
) -> GreedyOutcome {
    let candidates = dedup_candidates(candidates);
    let mut state = oracle.new_state(view);
    let mut cstate = constraint.new_state();
    let mut in_solution = vec![false; oracle.n()];
    let mut calls = 0u64;
    let mut cost = 0u64;
    let mut gains = Vec::with_capacity(candidates.len());

    loop {
        if cstate.full() {
            break;
        }
        // E ← {e ∈ V \ S : S ∪ {e} ∈ C}
        let feasible: Vec<ElemId> = candidates
            .iter()
            .copied()
            .filter(|&e| !in_solution[e as usize] && cstate.can_add(e))
            .collect();
        if feasible.is_empty() {
            break;
        }
        // e' ← argmax f(S ∪ {e}); batched so accelerated oracles can tile
        // and an active executor can fan the round's scan over idle cores.
        crate::dist::pool::par_gain_batch(&*state, &feasible, &mut gains);
        calls += feasible.len() as u64;
        cost += feasible.iter().map(|&e| state.call_cost(e)).sum::<u64>();
        let mut best = 0usize;
        for i in 1..feasible.len() {
            if gains[i] > gains[best] {
                best = i;
            }
        }
        // Break when the best marginal gain is zero (line 6).
        if gains[best] <= 0.0 {
            break;
        }
        let e = feasible[best];
        state.commit(e);
        cstate.commit(e);
        in_solution[e as usize] = true;
    }

    GreedyOutcome { value: state.value(), solution: state.solution().to_vec(), calls, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraint::Cardinality;
    use crate::objective::KCover;
    use std::sync::Arc;

    #[test]
    fn call_count_matches_nk_shape() {
        // n candidates, k rounds with no early stop → calls = Σ_{i} (n − i).
        let data = crate::data::itemsets::ItemsetCollection::from_sets(
            &(0..20).map(|i| vec![i as u32 * 2, i as u32 * 2 + 1]).collect::<Vec<_>>(),
        );
        let o = KCover::new(Arc::new(data));
        let c = Cardinality::new(5);
        let out = greedy_naive(&o, &c, &(0..20).collect::<Vec<_>>(), None);
        assert_eq!(out.solution.len(), 5);
        assert_eq!(out.calls, 20 + 19 + 18 + 17 + 16);
        assert_eq!(out.cost, out.calls * 2, "every set has δ=2");
    }

    #[test]
    fn deterministic_tie_breaking() {
        // All elements identical; must pick lowest ids first.
        let data = crate::data::itemsets::ItemsetCollection::from_sets(&[
            vec![0],
            vec![1],
            vec![2],
        ]);
        let o = KCover::new(Arc::new(data));
        let c = Cardinality::new(2);
        let out = greedy_naive(&o, &c, &[2, 1, 0], None);
        // Candidate order [2,1,0]: argmax with strict > keeps the first max,
        // i.e. candidate 2 then 1 — deterministic across runs.
        assert_eq!(out.solution, vec![2, 1]);
    }

    #[test]
    fn respects_matroid() {
        let o = crate::objective::Modular::new(vec![5.0, 4.0, 3.0, 2.0]);
        let m = crate::constraint::PartitionMatroid::new(vec![0, 0, 1, 1], vec![1, 1]);
        let out = greedy_naive(&o, &m, &[0, 1, 2, 3], None);
        let mut sol = out.solution.clone();
        sol.sort_unstable();
        assert_eq!(sol, vec![0, 2], "one per group, highest weights");
        assert!((out.value - 8.0).abs() < 1e-12);
    }
}
