//! Stochastic Greedy (Mirzasoleiman et al., "Lazier than lazy greedy",
//! AAAI 2015): each round evaluates only a random sample of
//! `⌈(n/k)·ln(1/ε)⌉` candidates and picks the best, giving a
//! `(1 − 1/e − ε)` expected guarantee at `O(n·ln(1/ε))` total gain queries
//! — independent of k.
//!
//! Included as an optional leaf-level accelerator: the paper's leaf cost is
//! `O(nk/m)` (Table 1); with stochastic greedy a leaf drops to
//! `O((n/m)·ln(1/ε))`, which matters in exactly the large-k regimes the
//! memory experiments (§6.2.1) explore.  The `ablation` bench quantifies
//! the quality/calls trade-off.

use super::{dedup_candidates, GreedyOutcome};
use crate::constraint::Constraint;
use crate::objective::Oracle;
use crate::util::rng::Rng;
use crate::ElemId;

/// Run Stochastic Greedy with accuracy parameter `epsilon` and a seed.
pub fn greedy_stochastic(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    candidates: &[ElemId],
    view: Option<&[ElemId]>,
    epsilon: f64,
    seed: u64,
) -> GreedyOutcome {
    assert!(epsilon > 0.0 && epsilon < 1.0, "epsilon must be in (0,1)");
    let candidates = dedup_candidates(candidates);
    let mut state = oracle.new_state(view);
    let mut cstate = constraint.new_state();
    let mut rng = Rng::new(seed);
    let mut calls = 0u64;
    let mut cost = 0u64;
    let k = constraint.rank().max(1);
    let n = candidates.len();
    let sample_size = (((n as f64 / k as f64) * (1.0 / epsilon).ln()).ceil() as usize)
        .clamp(1, n.max(1));

    // Remaining-candidate pool with O(1) removal (swap_remove).
    let mut pool: Vec<ElemId> = candidates;
    let mut gains = Vec::with_capacity(sample_size);
    while !pool.is_empty() && !cstate.full() {
        // Draw the round's sample from the feasible pool.
        let mut sample_idx = Vec::with_capacity(sample_size.min(pool.len()));
        let want = sample_size.min(pool.len());
        let distinct = rng.sample_distinct(pool.len(), want);
        for i in distinct {
            if cstate.can_add(pool[i]) {
                sample_idx.push(i);
            }
        }
        if sample_idx.is_empty() {
            // All sampled were infeasible: prune them and retry; if the
            // whole pool is infeasible we are done.
            pool.retain(|&e| cstate.can_add(e));
            if pool.is_empty() {
                break;
            }
            continue;
        }
        let sample: Vec<ElemId> = sample_idx.iter().map(|&i| pool[i]).collect();
        crate::dist::pool::par_gain_batch(&*state, &sample, &mut gains);
        calls += sample.len() as u64;
        cost += sample.iter().map(|&e| state.call_cost(e)).sum::<u64>();
        let mut best = 0usize;
        for i in 1..sample.len() {
            if gains[i] > gains[best] {
                best = i;
            }
        }
        if gains[best] <= 0.0 {
            // The sampled max is 0; with diminishing returns the whole pool
            // is *likely* exhausted, but not certainly — fall back to a full
            // feasibility-pruned scan once to decide (same as the paper's
            // termination handling).
            pool.retain(|&e| cstate.can_add(e));
            crate::dist::pool::par_gain_batch(&*state, &pool, &mut gains);
            calls += pool.len() as u64;
            cost += pool.iter().map(|&e| state.call_cost(e)).sum::<u64>();
            match gains
                .iter()
                .enumerate()
                .filter(|(_, &g)| g > 0.0)
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            {
                None => break,
                Some((i, _)) => {
                    let e = pool.swap_remove(i);
                    state.commit(e);
                    cstate.commit(e);
                }
            }
            continue;
        }
        let e = pool.swap_remove(sample_idx[best]);
        state.commit(e);
        cstate.commit(e);
    }

    GreedyOutcome { value: state.value(), solution: state.solution().to_vec(), calls, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraint::Cardinality;
    use crate::greedy::greedy_lazy;
    use crate::objective::{KCover, Oracle};
    use std::sync::Arc;

    fn oracle(n: usize) -> KCover {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: n,
                num_items: n / 2,
                mean_size: 8.0,
                zipf_s: 0.9,
            },
            13,
        );
        KCover::new(Arc::new(data))
    }

    #[test]
    fn quality_close_to_lazy_with_fewer_calls_than_naive() {
        let o = oracle(3000);
        let c = Cardinality::new(60);
        let cands: Vec<u32> = (0..3000).collect();
        let lazy = greedy_lazy(&o, &c, &cands, None);
        let naive = crate::greedy::greedy_naive(&o, &c, &cands, None);
        let stoch = greedy_stochastic(&o, &c, &cands, None, 0.1, 7);
        assert!(
            stoch.value >= 0.85 * lazy.value,
            "stochastic {} vs lazy {}",
            stoch.value,
            lazy.value
        );
        // Stochastic greedy's guarantee is O(n·ln(1/ε)) total calls — far
        // below naive's O(nk). (Lazy greedy is already near-linear on easy
        // coverage instances, so it is not the right yardstick.)
        assert!(
            (stoch.calls as f64) < 0.1 * naive.calls as f64,
            "stochastic {} calls vs naive {}",
            stoch.calls,
            naive.calls
        );
    }

    #[test]
    fn respects_constraint_and_dedups() {
        let o = oracle(400);
        let c = Cardinality::new(10);
        let mut cands: Vec<u32> = (0..400).collect();
        cands.extend(0..200); // duplicates
        let out = greedy_stochastic(&o, &c, &cands, None, 0.2, 3);
        assert!(out.solution.len() <= 10);
        let set: std::collections::HashSet<_> = out.solution.iter().collect();
        assert_eq!(set.len(), out.solution.len());
        assert!((out.value - o.eval(&out.solution)).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let o = oracle(500);
        let c = Cardinality::new(15);
        let cands: Vec<u32> = (0..500).collect();
        let a = greedy_stochastic(&o, &c, &cands, None, 0.1, 5);
        let b = greedy_stochastic(&o, &c, &cands, None, 0.1, 5);
        assert_eq!(a.solution, b.solution);
    }

    #[test]
    fn stops_on_exhausted_coverage() {
        // 3 distinct useful sets, k = 8: must stop early.
        let data = crate::data::itemsets::ItemsetCollection::from_sets(&[
            vec![0],
            vec![1],
            vec![2],
            vec![0, 1],
            vec![],
        ]);
        let o = KCover::new(Arc::new(data));
        let c = Cardinality::new(8);
        let out = greedy_stochastic(&o, &c, &[0, 1, 2, 3, 4], None, 0.3, 1);
        assert_eq!(out.value, 3.0);
        assert!(out.solution.len() <= 3);
    }
}
