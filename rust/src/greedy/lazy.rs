//! Lazy Greedy (Minoux 1978) — the paper's implementation choice (§5).
//!
//! By submodularity, an element's marginal gain only *decreases* as the
//! solution grows, so stale upper bounds in a max-heap are safe: pop the
//! top, and if its bound was computed against the current solution it is
//! the true argmax; otherwise recompute, push back, and continue.  Output
//! is identical to naive GREEDY (up to ties); the number of gain queries
//! drops dramatically — which is precisely why the paper's "function calls
//! in the critical path" metric is dominated by the *first* full scan of a
//! node's input.
//!
//! The initial scan is issued through [`crate::dist::pool::par_gain_batch`]:
//! tiled oracles (CPU k-medoid, PJRT) evaluate whole candidate tiles per
//! call, and when a [`crate::dist::pool::with_pool`] executor is active the
//! scan additionally fans out over idle workers — exactly the scan that
//! dominates the accumulation node's critical path (§5).  `calls`/`cost`
//! accounting is computed from the candidate list itself, so it is
//! identical however the scan was executed.

use super::{dedup_candidates, GreedyOutcome};
use crate::constraint::Constraint;
use crate::objective::Oracle;
use crate::ElemId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry: gain upper bound for `elem`, stamped with the solution size
/// it was computed at.
struct Entry {
    gain: f64,
    elem: ElemId,
    stamp: u32,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.gain == other.gain && self.elem == other.elem
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap by gain; tie-break toward the smaller id so lazy and
        // naive agree on fully-tied inputs.
        self.gain
            .partial_cmp(&other.gain)
            .expect("NaN gain from oracle")
            .then_with(|| other.elem.cmp(&self.elem))
    }
}

/// Run Lazy Greedy.
pub fn greedy_lazy(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    candidates: &[ElemId],
    view: Option<&[ElemId]>,
) -> GreedyOutcome {
    let candidates = dedup_candidates(candidates);
    let mut state = oracle.new_state(view);
    let mut cstate = constraint.new_state();
    let mut calls = 0u64;
    let mut cost = 0u64;

    // Initial full scan (batched; fans out over idle executor workers).
    let mut gains = Vec::with_capacity(candidates.len());
    crate::dist::pool::par_gain_batch(&*state, &candidates, &mut gains);
    calls += candidates.len() as u64;
    cost += candidates.iter().map(|&e| state.call_cost(e)).sum::<u64>();
    let mut heap: BinaryHeap<Entry> = candidates
        .iter()
        .zip(&gains)
        .map(|(&elem, &gain)| Entry { gain, elem, stamp: 0 })
        .collect();

    let mut round: u32 = 0;
    while let Some(top) = heap.pop() {
        if cstate.full() {
            break;
        }
        if top.gain <= 0.0 {
            // Submodularity: every other bound is ≤ this one; all gains are
            // ≤ 0 now and forever. Algorithm 2.1 line 6 → stop.
            break;
        }
        if !cstate.can_add(top.elem) {
            // Infeasible under the current solution. For matroids,
            // feasibility of an uncommitted element can only decrease as S
            // grows, so dropping it permanently is safe.
            continue;
        }
        if top.stamp == round {
            // Fresh bound → true argmax. Select it.
            state.commit(top.elem);
            cstate.commit(top.elem);
            round += 1;
        } else {
            // Stale → recompute against the current solution and re-insert.
            let gain = state.gain(top.elem);
            calls += 1;
            cost += state.call_cost(top.elem);
            heap.push(Entry { gain, elem: top.elem, stamp: round });
        }
    }

    GreedyOutcome { value: state.value(), solution: state.solution().to_vec(), calls, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraint::Cardinality;
    use crate::objective::{KCover, KMedoid, Modular};
    use std::sync::Arc;

    #[test]
    fn modular_uses_minimum_calls() {
        // On a modular function gains never change, so each selection after
        // the first costs exactly one refresh of the top entry:
        // n initial calls + (k − 1) refreshes.
        let o = Modular::random(50, 3);
        let c = Cardinality::new(10);
        let out = greedy_lazy(&o, &c, &(0..50).collect::<Vec<_>>(), None);
        assert_eq!(out.calls, 50 + 9);
        assert_eq!(out.solution.len(), 10);
    }

    #[test]
    fn fewer_calls_than_naive_on_coverage() {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 300,
                num_items: 150,
                mean_size: 8.0,
                zipf_s: 1.0,
            },
            11,
        );
        let o = KCover::new(Arc::new(data));
        let c = Cardinality::new(20);
        let cands: Vec<ElemId> = (0..300).collect();
        let lazy = greedy_lazy(&o, &c, &cands, None);
        let naive = super::super::greedy_naive(&o, &c, &cands, None);
        assert!((lazy.value - naive.value).abs() < 1e-9);
        assert!(
            (lazy.calls as f64) < 0.5 * naive.calls as f64,
            "lazy {} vs naive {}",
            lazy.calls,
            naive.calls
        );
    }

    #[test]
    fn kmedoid_lazy_equals_naive() {
        let (vs, _) = crate::data::gen::gaussian_mixture(
            crate::data::gen::GaussianParams { n: 60, dim: 8, classes: 4, noise: 0.3 },
            2,
        );
        let o = KMedoid::new(Arc::new(vs));
        let c = Cardinality::new(6);
        let cands: Vec<ElemId> = (0..60).collect();
        let lazy = greedy_lazy(&o, &c, &cands, None);
        let naive = super::super::greedy_naive(&o, &c, &cands, None);
        assert!((lazy.value - naive.value).abs() < 1e-9);
        assert_eq!(lazy.solution, naive.solution, "distinct gains → identical picks");
    }

    #[test]
    fn respects_view() {
        let (vs, _) = crate::data::gen::gaussian_mixture(
            crate::data::gen::GaussianParams { n: 30, dim: 6, classes: 3, noise: 0.3 },
            4,
        );
        let o = KMedoid::new(Arc::new(vs));
        let c = Cardinality::new(3);
        let view: Vec<u32> = (0..10).collect();
        let out = greedy_lazy(&o, &c, &(0..30).collect::<Vec<_>>(), Some(&view));
        let manual = {
            let mut st = o.new_state(Some(&view));
            for &e in &out.solution {
                st.commit(e);
            }
            st.value()
        };
        assert!((out.value - manual).abs() < 1e-9);
    }
}
