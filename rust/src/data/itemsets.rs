//! Transaction / itemset collections for the k-cover experiments.
//!
//! The paper's k-cover datasets (webdocs, kosarak, retail) come from the
//! Frequent Itemset Mining repository: each line of a FIMI file is one
//! transaction — a list of item ids.  The k-cover ground set is the set of
//! *transactions*; the objective counts the union of *items* covered.
//!
//! Storage is CSR-like: all itemsets concatenated into one arena with
//! per-transaction offsets, so a machine's memory charge is exact and
//! per-call cost is a linear scan of δ items (Table 1).

use crate::ElemId;

/// A collection of itemsets over an item universe `0..num_items`.
#[derive(Clone, Debug)]
pub struct ItemsetCollection {
    offsets: Vec<u64>,
    items: Vec<u32>,
    num_items: usize,
}

impl ItemsetCollection {
    /// Build from explicit per-transaction item lists. Item ids are used as
    /// given; `num_items` is inferred as max+1.
    pub fn from_sets(sets: &[Vec<u32>]) -> Self {
        let mut offsets = Vec::with_capacity(sets.len() + 1);
        offsets.push(0u64);
        let total: usize = sets.iter().map(|s| s.len()).sum();
        let mut items = Vec::with_capacity(total);
        let mut num_items = 0usize;
        for set in sets {
            let mut s = set.clone();
            s.sort_unstable();
            s.dedup();
            for &i in &s {
                num_items = num_items.max(i as usize + 1);
            }
            items.extend_from_slice(&s);
            offsets.push(items.len() as u64);
        }
        Self { offsets, items, num_items }
    }

    /// Number of transactions (the ground set size `n`).
    pub fn num_sets(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Size of the item universe.
    pub fn num_items(&self) -> usize {
        self.num_items
    }

    /// Items of transaction `t` (sorted, deduped).
    #[inline]
    pub fn set(&self, t: ElemId) -> &[u32] {
        let s = self.offsets[t as usize] as usize;
        let e = self.offsets[t as usize + 1] as usize;
        &self.items[s..e]
    }

    /// Cardinality of transaction `t` (the paper's δ(u) for k-cover).
    #[inline]
    pub fn set_size(&self, t: ElemId) -> usize {
        (self.offsets[t as usize + 1] - self.offsets[t as usize]) as usize
    }

    /// Total item occurrences (Σδ(u), Table 2).
    pub fn total_items(&self) -> u64 {
        self.items.len() as u64
    }

    /// Average itemset size.
    pub fn avg_set_size(&self) -> f64 {
        if self.num_sets() == 0 {
            0.0
        } else {
            self.items.len() as f64 / self.num_sets() as f64
        }
    }

    /// Max itemset size.
    pub fn max_set_size(&self) -> usize {
        (0..self.num_sets())
            .map(|t| self.set_size(t as ElemId))
            .max()
            .unwrap_or(0)
    }

    /// Heap bytes.
    pub fn mem_bytes(&self) -> usize {
        self.offsets.len() * 8 + self.items.len() * 4
    }

    /// Bytes to hold/ship one transaction (id + length + items).
    pub fn elem_bytes(&self, t: ElemId) -> usize {
        8 + 4 * self.set_size(t)
    }

    /// Re-index a subset of transactions into a fresh CSR pair
    /// `(offsets, items)` — transaction `i` of the slice is `elems[i]`,
    /// with offsets renumbered from zero.  The partition-shipping slice
    /// primitive: a shard payload is exactly this pair plus the id map.
    pub fn slice_sets(&self, elems: &[ElemId]) -> (Vec<u64>, Vec<u32>) {
        let mut offsets = Vec::with_capacity(elems.len() + 1);
        offsets.push(0u64);
        let total: usize = elems.iter().map(|&t| self.set_size(t)).sum();
        let mut items = Vec::with_capacity(total);
        for &t in elems {
            items.extend_from_slice(self.set(t));
            offsets.push(items.len() as u64);
        }
        (offsets, items)
    }

    /// Parse FIMI format: one transaction per line, whitespace-separated
    /// item ids.  A blank line is an *empty transaction* (so `to_fimi` ∘
    /// `parse_fimi` round-trips); real FIMI files contain none.
    pub fn parse_fimi(text: &str) -> crate::Result<Self> {
        let mut sets: Vec<Vec<u32>> = Vec::new();
        for line in text.lines() {
            let set: Result<Vec<u32>, _> =
                line.split_whitespace().map(|w| w.parse()).collect();
            sets.push(set.map_err(|e| anyhow::anyhow!("bad FIMI line '{line}': {e}"))?);
        }
        Ok(Self::from_sets(&sets))
    }

    /// Load a FIMI file.
    pub fn load_fimi(path: &str) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
        Self::parse_fimi(&text)
    }

    /// Serialise to FIMI text.
    pub fn to_fimi(&self) -> String {
        let mut out = String::new();
        for t in 0..self.num_sets() as ElemId {
            let strs: Vec<String> = self.set(t).iter().map(|i| i.to_string()).collect();
            out.push_str(&strs.join(" "));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ItemsetCollection {
        ItemsetCollection::from_sets(&[vec![1, 2, 3], vec![3, 4], vec![], vec![0, 4, 4]])
    }

    #[test]
    fn structure() {
        let c = sample();
        assert_eq!(c.num_sets(), 4);
        assert_eq!(c.num_items(), 5);
        assert_eq!(c.set(0), &[1, 2, 3]);
        assert_eq!(c.set(2), &[] as &[u32]);
        assert_eq!(c.set(3), &[0, 4], "duplicates removed");
        assert_eq!(c.set_size(1), 2);
        assert_eq!(c.total_items(), 7);
        assert_eq!(c.max_set_size(), 3);
        assert!((c.avg_set_size() - 7.0 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn fimi_roundtrip() {
        let c = sample();
        let text = c.to_fimi();
        let c2 = ItemsetCollection::parse_fimi(&text).unwrap();
        assert_eq!(c2.num_sets(), 4);
        for t in 0..4 {
            assert_eq!(c.set(t), c2.set(t));
        }
    }

    #[test]
    fn fimi_parse_errors() {
        assert!(ItemsetCollection::parse_fimi("1 2 x\n").is_err());
    }

    #[test]
    fn elem_bytes() {
        let c = sample();
        assert_eq!(c.elem_bytes(0), 8 + 12);
        assert_eq!(c.elem_bytes(2), 8);
    }
}
