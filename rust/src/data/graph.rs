//! Compressed-sparse-row graphs.
//!
//! The k-dominating-set experiments run on graphs (Friendster, road_usa,
//! road_central, belgium_osm in the paper).  We store undirected graphs in
//! CSR form: `offsets[v]..offsets[v+1]` indexes into `neighbors`.  CSR keeps
//! the per-element adjacency scan (`δ(u)`, the paper's per-call cost unit)
//! cache-friendly and lets the memory accountant charge each partition its
//! true byte footprint.

use crate::ElemId;

/// An undirected graph in CSR form. Vertices are `0..n`.
#[derive(Clone, Debug)]
pub struct CsrGraph {
    offsets: Vec<u64>,
    neighbors: Vec<ElemId>,
}

impl CsrGraph {
    /// Build from an edge list (duplicates and self-loops are removed).
    /// Edges are undirected: `(u, v)` produces adjacency in both rows.
    pub fn from_edges(n: usize, edges: &[(ElemId, ElemId)]) -> Self {
        let mut degree = vec![0u64; n];
        let mut clean: Vec<(ElemId, ElemId)> = edges
            .iter()
            .copied()
            .filter(|&(u, v)| u != v && (u as usize) < n && (v as usize) < n)
            .map(|(u, v)| if u < v { (u, v) } else { (v, u) })
            .collect();
        clean.sort_unstable();
        clean.dedup();
        for &(u, v) in &clean {
            degree[u as usize] += 1;
            degree[v as usize] += 1;
        }
        let mut offsets = vec![0u64; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + degree[v];
        }
        let mut cursor = offsets.clone();
        let mut neighbors = vec![0 as ElemId; offsets[n] as usize];
        for &(u, v) in &clean {
            neighbors[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
            neighbors[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        // Sort each adjacency row for deterministic iteration & binary search.
        for v in 0..n {
            let (s, e) = (offsets[v] as usize, offsets[v + 1] as usize);
            neighbors[s..e].sort_unstable();
        }
        Self { offsets, neighbors }
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    /// Neighbors of `v` (sorted).
    #[inline]
    pub fn neighbors(&self, v: ElemId) -> &[ElemId] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.neighbors[s..e]
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: ElemId) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Sum of degrees (the paper's Σδ(u) column in Table 2).
    pub fn total_degree(&self) -> u64 {
        self.neighbors.len() as u64
    }

    /// Average degree.
    pub fn avg_degree(&self) -> f64 {
        if self.num_vertices() == 0 {
            0.0
        } else {
            self.neighbors.len() as f64 / self.num_vertices() as f64
        }
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices())
            .map(|v| self.degree(v as ElemId))
            .max()
            .unwrap_or(0)
    }

    /// Heap bytes (memory accounting).
    pub fn mem_bytes(&self) -> usize {
        self.offsets.len() * 8 + self.neighbors.len() * 4
    }

    /// Bytes charged for holding the adjacency data of one vertex — what a
    /// leaf machine stores per element of its partition, and what one
    /// solution element costs when shipped up the accumulation tree
    /// (id + length + adjacency list; cf. §4.2 "Communication Complexity").
    pub fn elem_bytes(&self, v: ElemId) -> usize {
        // 4 (id) + 4 (list length) + 4 per neighbour.
        8 + 4 * self.degree(v)
    }

    /// Re-index a subset of vertices into a fresh CSR pair
    /// `(offsets, neighbors)`: row `i` of the slice is `vertices[i]`, with
    /// offsets renumbered from zero and neighbour lists kept in **global**
    /// vertex ids (the dominating-set item universe stays the whole
    /// graph).  The partition-shipping slice primitive for graph data.
    pub fn neighborhoods(&self, vertices: &[ElemId]) -> (Vec<u64>, Vec<u32>) {
        let mut offsets = Vec::with_capacity(vertices.len() + 1);
        offsets.push(0u64);
        let total: usize = vertices.iter().map(|&v| self.degree(v)).sum();
        let mut targets = Vec::with_capacity(total);
        for &v in vertices {
            targets.extend_from_slice(self.neighbors(v));
            offsets.push(targets.len() as u64);
        }
        (offsets, targets)
    }

    /// Parse an edge-list text format: one `u v` pair per line, `#` or `%`
    /// comment lines ignored (covers SNAP and Matrix-Market-ish headers).
    /// Vertex ids may be arbitrary u32s; they are compacted to `0..n`.
    pub fn parse_edge_list(text: &str) -> crate::Result<Self> {
        let mut edges: Vec<(u32, u32)> = Vec::new();
        let mut max_id = 0u32;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
                continue;
            }
            let mut it = line.split_whitespace();
            let (Some(a), Some(b)) = (it.next(), it.next()) else {
                anyhow::bail!("bad edge line: '{line}'");
            };
            let u: u32 = a.parse().map_err(|e| anyhow::anyhow!("bad vertex '{a}': {e}"))?;
            let v: u32 = b.parse().map_err(|e| anyhow::anyhow!("bad vertex '{b}': {e}"))?;
            max_id = max_id.max(u).max(v);
            edges.push((u, v));
        }
        let n = if edges.is_empty() { 0 } else { max_id as usize + 1 };
        Ok(Self::from_edges(n, &edges))
    }

    /// Load an edge-list file.
    pub fn load_edge_list(path: &str) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
        Self::parse_edge_list(&text)
    }

    /// Write as edge-list text (for golden tests and dataset export).
    pub fn to_edge_list(&self) -> String {
        let mut out = String::new();
        out.push_str("# greedyml edge list\n");
        for u in 0..self.num_vertices() as ElemId {
            for &v in self.neighbors(u) {
                if u < v {
                    out.push_str(&format!("{u} {v}\n"));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> CsrGraph {
        // 0 - 1 - 2, plus isolated 3
        CsrGraph::from_edges(4, &[(0, 1), (1, 2)])
    }

    #[test]
    fn basic_structure() {
        let g = path3();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.neighbors(3), &[] as &[ElemId]);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.max_degree(), 2);
        assert!((g.avg_degree() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dedup_and_self_loops() {
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 0), (0, 1), (2, 2)]);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.neighbors(2), &[] as &[ElemId]);
    }

    #[test]
    fn out_of_range_edges_dropped() {
        let g = CsrGraph::from_edges(2, &[(0, 1), (0, 5)]);
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = CsrGraph::from_edges(5, &[(0, 1), (1, 2), (3, 4), (0, 4)]);
        let text = g.to_edge_list();
        let g2 = CsrGraph::parse_edge_list(&text).unwrap();
        assert_eq!(g2.num_vertices(), 5);
        assert_eq!(g2.num_edges(), 4);
        for v in 0..5 {
            assert_eq!(g.neighbors(v), g2.neighbors(v));
        }
    }

    #[test]
    fn parse_with_comments_and_errors() {
        let g = CsrGraph::parse_edge_list("# hi\n% there\n0 1\n1 2\n").unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert!(CsrGraph::parse_edge_list("0\n").is_err());
        assert!(CsrGraph::parse_edge_list("a b\n").is_err());
    }

    #[test]
    fn elem_bytes_scale_with_degree() {
        let g = path3();
        assert_eq!(g.elem_bytes(1), 8 + 8);
        assert_eq!(g.elem_bytes(3), 8);
    }

    #[test]
    fn mem_bytes_positive() {
        let g = path3();
        assert!(g.mem_bytes() >= g.num_vertices() * 8);
    }
}
