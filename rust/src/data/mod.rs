//! Datasets: storage (CSR graphs, itemset collections, dense vectors),
//! text/binary IO, and seeded synthetic generators reproducing the shape of
//! the paper's testbed (Table 2).

pub mod gen;
pub mod graph;
pub mod itemsets;
pub mod vectors;

pub use graph::CsrGraph;
pub use itemsets::ItemsetCollection;
pub use vectors::VectorSet;

/// Summary row matching the paper's Table 2 ("Properties of Datasets").
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetSummary {
    /// Dataset label.
    pub name: String,
    /// Ground-set size n = |V|.
    pub n: usize,
    /// Σ_u δ(u): total neighbours / items / vector length.
    pub total_delta: u64,
    /// Average δ(u).
    pub avg_delta: f64,
}

impl DatasetSummary {
    /// Summarise a graph (k-dominating-set row).
    pub fn of_graph(name: &str, g: &CsrGraph) -> Self {
        Self {
            name: name.to_string(),
            n: g.num_vertices(),
            total_delta: g.total_degree(),
            avg_delta: g.avg_degree(),
        }
    }

    /// Summarise an itemset collection (k-cover row).
    pub fn of_itemsets(name: &str, c: &ItemsetCollection) -> Self {
        Self {
            name: name.to_string(),
            n: c.num_sets(),
            total_delta: c.total_items(),
            avg_delta: c.avg_set_size(),
        }
    }

    /// Summarise a vector set (k-medoid row; δ = dim as in the paper).
    pub fn of_vectors(name: &str, v: &VectorSet) -> Self {
        Self {
            name: name.to_string(),
            n: v.len(),
            total_delta: (v.len() * v.dim()) as u64,
            avg_delta: v.dim() as f64,
        }
    }

    /// One fixed-width table row (Table 2 shape).
    pub fn row(&self) -> String {
        format!(
            "{:<18} {:>12} {:>16} {:>10.2}",
            self.name,
            crate::util::fmt_count(self.n as u64),
            crate::util::fmt_count(self.total_delta),
            self.avg_delta
        )
    }

    /// Table header matching [`row`](Self::row).
    pub fn header() -> String {
        format!("{:<18} {:>12} {:>16} {:>10}", "Dataset", "n=|V|", "sum delta(u)", "avg delta")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summaries() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let s = DatasetSummary::of_graph("g", &g);
        assert_eq!(s.n, 4);
        assert_eq!(s.total_delta, 6);
        assert!((s.avg_delta - 1.5).abs() < 1e-12);

        let c = ItemsetCollection::from_sets(&[vec![0, 1], vec![2]]);
        let s = DatasetSummary::of_itemsets("c", &c);
        assert_eq!((s.n, s.total_delta), (2, 3));

        let v = VectorSet::from_flat(vec![0.0; 12], 3).unwrap();
        let s = DatasetSummary::of_vectors("v", &v);
        assert_eq!((s.n, s.total_delta), (4, 12));
        assert!(s.row().contains("v"));
        assert!(DatasetSummary::header().contains("Dataset"));
    }
}
