//! Synthetic dataset generators standing in for the paper's testbed
//! (Table 2).  Each generator is seeded and deterministic; DESIGN.md §2
//! documents the paper-dataset → generator mapping and why each
//! substitution preserves the behaviour the experiments measure.

pub mod ba;
pub mod gaussian;
pub mod rmat;
pub mod road;
pub mod transactions;

pub use ba::barabasi_albert;
pub use gaussian::{gaussian_mixture, GaussianParams};
pub use rmat::{rmat, RmatParams};
pub use road::{road, RoadParams};
pub use transactions::{transactions, TransactionParams, Zipf};
