//! Gaussian-mixture vector generator for the k-medoid experiments.
//!
//! Stands in for Tiny ImageNet (100k images, 200 classes, 64×64 px →
//! 12,288-d).  Exemplar clustering only cares that the data has cluster
//! structure in a metric space: we draw `classes` centers on the unit
//! sphere and sample class members around them with per-class noise, then
//! apply the paper's preprocessing (center + L2-normalize).

use crate::data::vectors::VectorSet;
use crate::util::rng::Rng;

/// Parameters for the Gaussian-mixture generator.
#[derive(Clone, Copy, Debug)]
pub struct GaussianParams {
    /// Number of vectors.
    pub n: usize,
    /// Dimensionality (paper: 12,288; we default lower for CI speed).
    pub dim: usize,
    /// Number of mixture components ("classes"; paper: 200).
    pub classes: usize,
    /// Noise scale relative to inter-center distance.
    pub noise: f64,
}

impl Default for GaussianParams {
    fn default() -> Self {
        Self { n: 2048, dim: 128, classes: 16, noise: 0.35 }
    }
}

impl GaussianParams {
    /// Tiny-ImageNet-like shape, scaled down.
    pub fn tiny_imagenet_like(n: usize, dim: usize) -> Self {
        Self { n, dim, classes: (n / 500).max(2), noise: 0.35 }
    }
}

/// Generate the mixture. Returns the vectors (already centered/normalized
/// per the paper's §6.4 preprocessing) and the class label of each row
/// (used by tests to verify exemplar diversity).
pub fn gaussian_mixture(params: GaussianParams, seed: u64) -> (VectorSet, Vec<u32>) {
    assert!(params.classes >= 1 && params.dim >= 2 && params.n >= 1);
    let mut rng = Rng::new(seed);
    // Class centers: random unit vectors.
    let mut centers = vec![0f32; params.classes * params.dim];
    for c in centers.chunks_mut(params.dim) {
        let mut norm = 0.0f64;
        for x in c.iter_mut() {
            let v = rng.normal();
            *x = v as f32;
            norm += v * v;
        }
        let norm = norm.sqrt().max(1e-12) as f32;
        for x in c.iter_mut() {
            *x /= norm;
        }
    }
    let mut data = vec![0f32; params.n * params.dim];
    let mut labels = Vec::with_capacity(params.n);
    for (i, row) in data.chunks_mut(params.dim).enumerate() {
        // Round-robin class assignment keeps classes balanced like the
        // paper's 500-images-per-class structure.
        let class = i % params.classes;
        labels.push(class as u32);
        let center = &centers[class * params.dim..(class + 1) * params.dim];
        for (x, &c) in row.iter_mut().zip(center) {
            *x = c + (params.noise * rng.normal()) as f32 / (params.dim as f32).sqrt();
        }
    }
    let mut vs = VectorSet::from_flat(data, params.dim).expect("generator produced flat buffer");
    vs.normalize_rows();
    (vs, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vectors::dist_sq;

    #[test]
    fn shapes_and_labels() {
        let p = GaussianParams { n: 100, dim: 16, classes: 5, noise: 0.2 };
        let (vs, labels) = gaussian_mixture(p, 3);
        assert_eq!(vs.len(), 100);
        assert_eq!(vs.dim(), 16);
        assert_eq!(labels.len(), 100);
        assert!(labels.iter().all(|&l| l < 5));
        // Balanced classes.
        for c in 0..5u32 {
            assert_eq!(labels.iter().filter(|&&l| l == c).count(), 20);
        }
    }

    #[test]
    fn rows_are_normalized() {
        let (vs, _) = gaussian_mixture(GaussianParams::default(), 5);
        for i in (0..vs.len()).step_by(97) {
            let norm: f32 = vs.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-4, "row {i} norm {norm}");
        }
    }

    #[test]
    fn cluster_structure_exists() {
        let p = GaussianParams { n: 300, dim: 32, classes: 3, noise: 0.15 };
        let (vs, labels) = gaussian_mixture(p, 11);
        // Average intra-class distance should be well below inter-class.
        let (mut intra, mut inter) =
            (crate::util::stats::Running::new(), crate::util::stats::Running::new());
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..2000 {
            let i = rng.below(300) as usize;
            let j = rng.below(300) as usize;
            if i == j {
                continue;
            }
            let d = dist_sq(vs.row(i), vs.row(j));
            if labels[i] == labels[j] {
                intra.push(d);
            } else {
                inter.push(d);
            }
        }
        assert!(
            intra.mean() * 1.5 < inter.mean(),
            "intra {} vs inter {}",
            intra.mean(),
            inter.mean()
        );
    }

    #[test]
    fn deterministic() {
        let p = GaussianParams { n: 50, dim: 8, classes: 2, noise: 0.3 };
        let (a, _) = gaussian_mixture(p, 9);
        let (b, _) = gaussian_mixture(p, 9);
        assert_eq!(a.flat(), b.flat());
    }
}
