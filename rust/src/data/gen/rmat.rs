//! R-MAT / Kronecker power-law graph generator.
//!
//! Stands in for the Friendster social network (65.6M vertices, avg degree
//! 27.5) in the k-dominating-set experiments.  R-MAT reproduces the heavy
//! tail and community skew that make social graphs behave the way the paper
//! observes (large dominating sets live in the low-degree fringe; a few
//! hubs dominate quickly).  Parameters follow the Graph500 convention
//! (a=0.57, b=0.19, c=0.19, d=0.05).

use crate::data::graph::CsrGraph;
use crate::util::rng::Rng;

/// R-MAT parameters.
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    /// log2 of the number of vertices.
    pub scale: u32,
    /// Average number of undirected edges per vertex.
    pub edge_factor: f64,
    /// Quadrant probabilities (must sum to 1).
    pub a: f64,
    pub b: f64,
    pub c: f64,
}

impl Default for RmatParams {
    fn default() -> Self {
        Self { scale: 14, edge_factor: 14.0, a: 0.57, b: 0.19, c: 0.19 }
    }
}

impl RmatParams {
    /// Friendster-like skew, scaled to `scale` (the real graph is scale≈26).
    pub fn friendster_like(scale: u32) -> Self {
        Self { scale, edge_factor: 13.8, a: 0.57, b: 0.19, c: 0.19 }
    }
}

/// Generate an undirected R-MAT graph (duplicate edges and self-loops are
/// dropped by the CSR builder, so the realized edge factor is slightly
/// below the nominal one — same convention as Graph500).
pub fn rmat(params: RmatParams, seed: u64) -> CsrGraph {
    let n = 1usize << params.scale;
    let m = (n as f64 * params.edge_factor / 2.0).round() as usize;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(m);
    let d = 1.0 - params.a - params.b - params.c;
    assert!(d >= -1e-9, "rmat quadrant probs exceed 1");
    for _ in 0..m {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..params.scale {
            let r = rng.f64();
            let (du, dv) = if r < params.a {
                (0, 0)
            } else if r < params.a + params.b {
                (0, 1)
            } else if r < params.a + params.b + params.c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        edges.push((u as u32, v as u32));
    }
    CsrGraph::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let p = RmatParams { scale: 8, edge_factor: 8.0, ..Default::default() };
        let g1 = rmat(p, 42);
        let g2 = rmat(p, 42);
        assert_eq!(g1.num_edges(), g2.num_edges());
        assert_eq!(g1.neighbors(5), g2.neighbors(5));
        let g3 = rmat(p, 43);
        assert_ne!(
            (g1.num_edges(), g1.total_degree()),
            (g3.num_edges(), g3.total_degree()),
            "different seeds should differ"
        );
    }

    #[test]
    fn size_and_density_plausible() {
        let p = RmatParams { scale: 10, edge_factor: 14.0, ..Default::default() };
        let g = rmat(p, 1);
        assert_eq!(g.num_vertices(), 1024);
        // Dedup loses some edges; expect within [30%, 100%] of nominal.
        let nominal = 1024.0 * 14.0 / 2.0;
        assert!(g.num_edges() as f64 > 0.3 * nominal, "{} edges", g.num_edges());
        assert!(g.num_edges() as f64 <= nominal);
    }

    #[test]
    fn degree_distribution_is_skewed() {
        let p = RmatParams { scale: 11, edge_factor: 14.0, ..Default::default() };
        let g = rmat(p, 7);
        // Power-law-ish: max degree should dwarf the average.
        assert!(
            g.max_degree() as f64 > 8.0 * g.avg_degree(),
            "max {} avg {}",
            g.max_degree(),
            g.avg_degree()
        );
    }
}
