//! Synthetic transaction (itemset) generator for the k-cover experiments.
//!
//! Stands in for webdocs / kosarak / retail.  What matters for k-cover
//! behaviour (DESIGN.md §2) is (a) the itemset-size distribution and (b)
//! item popularity skew — overlapping popular items are what create the
//! submodular "diminishing returns" structure.  We sample itemset sizes
//! from a clipped lognormal around a target mean and items from a Zipf
//! distribution over the item universe.

use crate::data::itemsets::ItemsetCollection;
use crate::util::rng::Rng;

/// Parameters for the transaction generator.
#[derive(Clone, Copy, Debug)]
pub struct TransactionParams {
    /// Number of transactions (the ground set size).
    pub num_sets: usize,
    /// Item universe size.
    pub num_items: usize,
    /// Target mean itemset size (paper: webdocs 177.2, kosarak 8.1, retail 10.3).
    pub mean_size: f64,
    /// Zipf skew exponent for item popularity (≈0.8–1.2 for real baskets).
    pub zipf_s: f64,
}

impl TransactionParams {
    /// webdocs-like (very large itemsets over a big dictionary).
    pub fn webdocs_like(num_sets: usize) -> Self {
        Self { num_sets, num_items: num_sets * 3, mean_size: 177.2, zipf_s: 1.0 }
    }

    /// kosarak-like (click streams: small sets, strong skew).
    pub fn kosarak_like(num_sets: usize) -> Self {
        Self { num_sets, num_items: num_sets / 24, mean_size: 8.1, zipf_s: 1.1 }
    }

    /// retail-like (market baskets).
    pub fn retail_like(num_sets: usize) -> Self {
        Self { num_sets, num_items: num_sets / 5, mean_size: 10.3, zipf_s: 0.9 }
    }
}

/// Precomputed Zipf sampler over `0..n` via inverse-CDF binary search.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build for universe size `n` and exponent `s`.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    /// Sample one rank (0 = most popular).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // partition_point: first index with cdf[i] >= u.
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// Generate a synthetic transaction collection.
pub fn transactions(params: TransactionParams, seed: u64) -> ItemsetCollection {
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(params.num_items, params.zipf_s);
    // Shuffle ranks → item ids so popular items are spread over the id space
    // (real datasets don't have popularity sorted by id).
    let mut rank_to_item: Vec<u32> = (0..params.num_items as u32).collect();
    rng.shuffle(&mut rank_to_item);
    // Lognormal size: choose sigma so the distribution has a plausible tail,
    // then scale to hit the mean: E[lognormal(mu, sigma)] = exp(mu + sigma^2/2).
    let sigma = 0.7f64;
    let mu = params.mean_size.max(1.0).ln() - sigma * sigma / 2.0;
    let mut sets = Vec::with_capacity(params.num_sets);
    for _ in 0..params.num_sets {
        let raw = (mu + sigma * rng.normal()).exp();
        let size = raw.round().clamp(1.0, params.num_items as f64) as usize;
        let mut set = std::collections::HashSet::with_capacity(size);
        let mut guard = 0;
        while set.len() < size && guard < size * 30 {
            set.insert(rank_to_item[zipf.sample(&mut rng)]);
            guard += 1;
        }
        sets.push(set.into_iter().collect::<Vec<u32>>());
    }
    ItemsetCollection::from_sets(&sets)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_size_near_target() {
        let p = TransactionParams { num_sets: 4000, num_items: 2000, mean_size: 10.3, zipf_s: 0.9 };
        let c = transactions(p, 42);
        assert_eq!(c.num_sets(), 4000);
        let avg = c.avg_set_size();
        // Zipf collisions shave the realized mean a bit; wide band.
        assert!((6.0..=13.0).contains(&avg), "avg itemset size {avg}");
    }

    #[test]
    fn zipf_is_skewed() {
        let z = Zipf::new(1000, 1.1);
        let mut rng = Rng::new(9);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Top rank should be sampled far more than the median rank.
        assert!(counts[0] > 20 * counts[500].max(1), "top {} mid {}", counts[0], counts[500]);
    }

    #[test]
    fn deterministic() {
        let p = TransactionParams::retail_like(500);
        let a = transactions(p, 7);
        let b = transactions(p, 7);
        assert_eq!(a.total_items(), b.total_items());
        assert_eq!(a.set(17), b.set(17));
    }

    #[test]
    fn presets_have_distinct_shapes() {
        let kos = transactions(TransactionParams::kosarak_like(2400), 1);
        let ret = transactions(TransactionParams::retail_like(2400), 1);
        assert!(kos.avg_set_size() < 12.0);
        assert!(ret.avg_set_size() < 14.0);
        let web = transactions(
            TransactionParams { num_sets: 200, num_items: 4000, mean_size: 177.2, zipf_s: 1.0 },
            1,
        );
        assert!(web.avg_set_size() > 60.0, "webdocs-like avg {}", web.avg_set_size());
    }
}
