//! Barabási–Albert preferential-attachment generator.
//!
//! A second scale-free family used for the ablation benches (DESIGN.md):
//! BA gives a *guaranteed-connected* power-law graph, unlike R-MAT, which
//! isolates the effect of disconnected fringe vertices on dominating-set
//! quality.

use crate::data::graph::CsrGraph;
use crate::util::rng::Rng;

/// Generate a BA graph: start from a clique of `m0 = m_attach` vertices,
/// then attach each new vertex to `m_attach` existing vertices sampled
/// proportionally to degree (via the repeated-endpoints trick).
pub fn barabasi_albert(n: usize, m_attach: usize, seed: u64) -> CsrGraph {
    assert!(m_attach >= 1, "need at least one attachment edge");
    assert!(n > m_attach, "n must exceed m_attach");
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * m_attach);
    // `endpoints` holds every edge endpoint; sampling uniformly from it is
    // sampling proportional to degree.
    let mut endpoints: Vec<u32> = Vec::with_capacity(2 * n * m_attach);
    // Seed clique.
    for u in 0..m_attach as u32 {
        for v in (u + 1)..m_attach as u32 {
            edges.push((u, v));
            endpoints.push(u);
            endpoints.push(v);
        }
    }
    if m_attach == 1 {
        // Degenerate clique: single vertex, no endpoints yet — bootstrap.
        endpoints.push(0);
    }
    for u in m_attach as u32..n as u32 {
        let mut targets = std::collections::HashSet::with_capacity(m_attach);
        let mut guard = 0;
        while targets.len() < m_attach && guard < 50 * m_attach {
            let t = endpoints[rng.below(endpoints.len() as u64) as usize];
            if t != u {
                targets.insert(t);
            }
            guard += 1;
        }
        // Fall back to uniform if degree sampling stalls (tiny graphs).
        while targets.len() < m_attach {
            let t = rng.below(u as u64) as u32;
            targets.insert(t);
        }
        for &t in &targets {
            edges.push((u, t));
            endpoints.push(u);
            endpoints.push(t);
        }
    }
    CsrGraph::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connected_and_sized() {
        let g = barabasi_albert(2000, 2, 17);
        assert_eq!(g.num_vertices(), 2000);
        // Every vertex beyond the clique attaches with >= 1 edge.
        for v in 0..2000u32 {
            assert!(g.degree(v) >= 1, "vertex {v} isolated");
        }
        // BFS connectivity check.
        let mut seen = vec![false; 2000];
        let mut queue = std::collections::VecDeque::from([0u32]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in g.neighbors(u) {
                if !seen[v as usize] {
                    seen[v as usize] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        assert_eq!(count, 2000, "graph not connected");
    }

    #[test]
    fn heavy_tail() {
        let g = barabasi_albert(5000, 3, 23);
        assert!(g.max_degree() > 20 * 3, "max degree {} not heavy-tailed", g.max_degree());
    }

    #[test]
    fn deterministic() {
        let g1 = barabasi_albert(500, 2, 5);
        let g2 = barabasi_albert(500, 2, 5);
        assert_eq!(g1.num_edges(), g2.num_edges());
    }

    #[test]
    fn m_attach_one() {
        let g = barabasi_albert(100, 1, 9);
        assert_eq!(g.num_vertices(), 100);
        assert_eq!(g.num_edges(), 99, "m=1 BA is a tree");
    }
}
