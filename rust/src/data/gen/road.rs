//! Road-network-like graph generator.
//!
//! Stands in for road_usa / road_central / belgium_osm (avg degree ≈ 2.1 —
//! 2.4, near-planar, huge diameter).  These properties — *not* the exact
//! topology — drive the paper's observations: tiny δ makes per-call cost
//! cheap, and low degree forces very large dominating sets, which is why
//! the paper can push k to 1,024,000.
//!
//! Construction: place vertices on a √n×√n grid, connect each to its right
//! and down neighbour with probability `p_keep` (thinning creates dead ends
//! and varying degree like a real road network), then add a sparse set of
//! random "highway" shortcuts.

use crate::data::graph::CsrGraph;
use crate::util::rng::Rng;

/// Parameters for the road-like generator.
#[derive(Clone, Copy, Debug)]
pub struct RoadParams {
    /// Number of vertices (rounded down to a full grid).
    pub n: usize,
    /// Probability of keeping each grid edge.
    pub p_keep: f64,
    /// Fraction of n added as long-range shortcut edges.
    pub shortcut_frac: f64,
}

impl Default for RoadParams {
    fn default() -> Self {
        // Tuned to land near avg degree 2.4 (road_usa / road_central).
        Self { n: 1 << 14, p_keep: 0.62, shortcut_frac: 0.01 }
    }
}

impl RoadParams {
    /// road_usa-like at a given size.
    pub fn usa_like(n: usize) -> Self {
        Self { n, ..Default::default() }
    }

    /// belgium_osm-like (slightly sparser, avg degree ≈ 2.14).
    pub fn belgium_like(n: usize) -> Self {
        Self { n, p_keep: 0.55, shortcut_frac: 0.005 }
    }
}

/// Generate a road-like graph.
pub fn road(params: RoadParams, seed: u64) -> CsrGraph {
    let side = (params.n as f64).sqrt().floor() as usize;
    let n = side * side;
    assert!(side >= 2, "road generator needs at least a 2x2 grid");
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity((2.0 * n as f64) as usize);
    let idx = |r: usize, c: usize| (r * side + c) as u32;
    for r in 0..side {
        for c in 0..side {
            if c + 1 < side && rng.bool(params.p_keep) {
                edges.push((idx(r, c), idx(r, c + 1)));
            }
            if r + 1 < side && rng.bool(params.p_keep) {
                edges.push((idx(r, c), idx(r + 1, c)));
            }
        }
    }
    let shortcuts = (n as f64 * params.shortcut_frac) as usize;
    for _ in 0..shortcuts {
        let u = rng.below(n as u64) as u32;
        let v = rng.below(n as u64) as u32;
        edges.push((u, v));
    }
    CsrGraph::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_degree_near_target() {
        let g = road(RoadParams { n: 1 << 14, ..Default::default() }, 3);
        let avg = g.avg_degree();
        assert!(
            (2.1..=2.7).contains(&avg),
            "avg degree {avg} outside road-like band"
        );
    }

    #[test]
    fn belgium_variant_is_sparser() {
        let a = road(RoadParams::usa_like(1 << 12), 5);
        let b = road(RoadParams::belgium_like(1 << 12), 5);
        assert!(b.avg_degree() < a.avg_degree());
    }

    #[test]
    fn max_degree_is_small() {
        let g = road(RoadParams { n: 1 << 12, ..Default::default() }, 9);
        // Grid degree ≤ 4 plus a few shortcuts.
        assert!(g.max_degree() <= 10, "max degree {}", g.max_degree());
    }

    #[test]
    fn deterministic() {
        let p = RoadParams { n: 4096, ..Default::default() };
        let g1 = road(p, 11);
        let g2 = road(p, 11);
        assert_eq!(g1.num_edges(), g2.num_edges());
        assert_eq!(g1.neighbors(100), g2.neighbors(100));
    }
}
