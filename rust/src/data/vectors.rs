//! Dense vector datasets for the k-medoid (exemplar clustering) experiments.
//!
//! The paper flattens Tiny ImageNet images to 12,288-d vectors, subtracts
//! the mean and normalizes (§6.4).  We store row-major `f32` with fixed
//! dimensionality, support the same preprocessing, and read/write a simple
//! fvecs-like binary format (`[u32 dim][f32 × dim]` per row) so real data
//! can drop in.

/// A dense row-major `f32` matrix: `n` vectors of dimension `d`.
#[derive(Clone, Debug)]
pub struct VectorSet {
    data: Vec<f32>,
    dim: usize,
    /// Per-row squared L2 norms, built lazily on first use (the norm-trick
    /// kernels need them: ‖u−v‖² = ‖u‖² + ‖v‖² − 2·u·v) and invalidated by
    /// [`VectorSet::normalize_rows`].
    norms_sq: std::sync::OnceLock<Vec<f64>>,
}

impl VectorSet {
    /// Build from a flat buffer (length must be a multiple of `dim`).
    pub fn from_flat(data: Vec<f32>, dim: usize) -> crate::Result<Self> {
        anyhow::ensure!(dim > 0, "dimension must be positive");
        anyhow::ensure!(
            data.len() % dim == 0,
            "buffer length {} is not a multiple of dim {dim}",
            data.len()
        );
        Ok(Self { data, dim, norms_sq: std::sync::OnceLock::new() })
    }

    /// Number of vectors.
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    /// True if there are no vectors.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Dimensionality (the paper's δ for k-medoid, Table 1).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Flat data (PJRT bridge).
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    /// Squared Euclidean distance between rows `i` and `j`.
    #[inline]
    pub fn dist_sq(&self, i: usize, j: usize) -> f64 {
        dist_sq(self.row(i), self.row(j))
    }

    /// Squared Euclidean distance between row `i` and an external vector.
    #[inline]
    pub fn dist_sq_to(&self, i: usize, v: &[f32]) -> f64 {
        dist_sq(self.row(i), v)
    }

    /// Per-row squared L2 norms, computed once and cached.  The norm values
    /// use the same lane-parallel kernel as [`dot_fast`], so the norm-trick
    /// distance `‖u‖² + ‖v‖² − 2·u·v` is symmetric and consistent between
    /// the gain and commit paths.
    pub fn norms_sq(&self) -> &[f64] {
        self.norms_sq
            .get_or_init(|| (0..self.len()).map(|i| sq_norm_fast(self.row(i))).collect())
    }

    /// Paper preprocessing: subtract the per-vector mean and L2-normalize
    /// each row (§6.4). Zero rows are left as zeros.
    pub fn normalize_rows(&mut self) {
        self.norms_sq = std::sync::OnceLock::new();
        let d = self.dim;
        for r in self.data.chunks_mut(d) {
            let mean = r.iter().sum::<f32>() / d as f32;
            for x in r.iter_mut() {
                *x -= mean;
            }
            let norm = r.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 0.0 {
                for x in r.iter_mut() {
                    *x /= norm;
                }
            }
        }
    }

    /// Heap bytes.
    pub fn mem_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Bytes to hold/ship one vector (id + dim + payload).
    pub fn elem_bytes(&self) -> usize {
        8 + 4 * self.dim
    }

    /// Serialise to fvecs bytes: per row, little-endian `u32 dim` then
    /// `dim` little-endian `f32`s.
    pub fn to_fvecs(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len() * (4 + 4 * self.dim));
        for i in 0..self.len() {
            out.extend_from_slice(&(self.dim as u32).to_le_bytes());
            for &x in self.row(i) {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out
    }

    /// Parse fvecs bytes.
    pub fn parse_fvecs(bytes: &[u8]) -> crate::Result<Self> {
        anyhow::ensure!(bytes.len() >= 4, "fvecs: truncated header");
        let dim = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        anyhow::ensure!(dim > 0, "fvecs: zero dimension");
        let row_bytes = 4 + 4 * dim;
        anyhow::ensure!(
            bytes.len() % row_bytes == 0,
            "fvecs: {} bytes is not a multiple of row size {row_bytes}",
            bytes.len()
        );
        let n = bytes.len() / row_bytes;
        let mut data = Vec::with_capacity(n * dim);
        for r in 0..n {
            let base = r * row_bytes;
            let d = u32::from_le_bytes(bytes[base..base + 4].try_into().unwrap()) as usize;
            anyhow::ensure!(d == dim, "fvecs: row {r} has dim {d}, expected {dim}");
            for c in 0..dim {
                let off = base + 4 + 4 * c;
                data.push(f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
            }
        }
        Self::from_flat(data, dim)
    }

    /// Load an fvecs file.
    pub fn load_fvecs(path: &str) -> crate::Result<Self> {
        let bytes =
            std::fs::read(path).map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
        Self::parse_fvecs(&bytes)
    }

    /// Subset by row indices (builds a new set — used for partitions).
    pub fn subset(&self, rows: &[crate::ElemId]) -> Self {
        Self {
            data: self.gather_flat(rows),
            dim: self.dim,
            norms_sq: std::sync::OnceLock::new(),
        }
    }

    /// Re-index a subset of rows into a fresh flat buffer (row `i` of the
    /// result is `rows[i]`) — the partition-shipping slice primitive; a
    /// vector shard payload is exactly this buffer plus the id map.
    pub fn gather_flat(&self, rows: &[crate::ElemId]) -> Vec<f32> {
        let mut data = Vec::with_capacity(rows.len() * self.dim);
        for &r in rows {
            data.extend_from_slice(self.row(r as usize));
        }
        data
    }
}

/// Squared Euclidean distance between two equal-length slices.
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc
}

/// Squared Euclidean distance with 4-lane f32 accumulation (lanes summed in
/// f64 at the end).  The per-element f32→f64 widening in [`dist_sq`] defeats
/// autovectorization; this version keeps the inner loop in f32 so LLVM emits
/// packed SIMD, at a worst-case relative error of ~d·2⁻²⁴ — negligible
/// against the kernels' own f32 math (§Perf P1).
#[inline]
pub fn dist_sq_fast(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    const LANES: usize = 4;
    let mut lanes = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for c in 0..chunks {
        let i = c * LANES;
        // Bounds are checked once by the slice indexing below; the pattern
        // is simple enough for LLVM to lift the checks and vectorize
        // (packed sub + FMA; 4 lanes measured faster than 8 here — §Perf P1).
        let (a4, b4) = (&a[i..i + LANES], &b[i..i + LANES]);
        for l in 0..LANES {
            let d = a4[l] - b4[l];
            lanes[l] += d * d;
        }
    }
    let mut acc = lanes.iter().map(|&l| l as f64).sum::<f64>();
    for i in chunks * LANES..a.len() {
        let d = (a[i] - b[i]) as f64;
        acc += d * d;
    }
    acc
}

/// Lane width of the dot-product kernels.  Shared by [`dot_fast`] and
/// [`dot4_fast`] so single-candidate and register-blocked paths accumulate
/// in the same order and agree bit-for-bit (§Perf P6).
const DOT_LANES: usize = 8;

/// Dot product with 8-lane f32 accumulation (lanes summed in f64 at the
/// end).  The norm-trick inner loop: a pure mul-add chain that LLVM lowers
/// to packed multiply-accumulate, higher arithmetic density than the
/// subtract-square loop of [`dist_sq_fast`], at the same worst-case
/// relative error of ~d·2⁻²⁴.
#[inline]
pub fn dot_fast(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; DOT_LANES];
    let chunks = a.len() / DOT_LANES;
    for c in 0..chunks {
        let i = c * DOT_LANES;
        let (a8, b8) = (&a[i..i + DOT_LANES], &b[i..i + DOT_LANES]);
        for l in 0..DOT_LANES {
            lanes[l] += a8[l] * b8[l];
        }
    }
    let mut acc = lanes.iter().map(|&l| l as f64).sum::<f64>();
    for i in chunks * DOT_LANES..a.len() {
        acc += (a[i] as f64) * (b[i] as f64);
    }
    acc
}

/// Squared L2 norm via the [`dot_fast`] kernel.
#[inline]
pub fn sq_norm_fast(a: &[f32]) -> f64 {
    dot_fast(a, a)
}

/// Four dot products against one shared left row — the register-blocked
/// inner kernel of the tiled k-medoid scan: each element of `x` is loaded
/// once and reused across the four candidates, quartering the load traffic
/// of four [`dot_fast`] calls.  Per candidate, the lane layout and
/// summation order match [`dot_fast`] exactly, so the blocked and unblocked
/// paths return bit-identical values.
#[inline]
pub fn dot4_fast(x: &[f32], c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32]) -> [f64; 4] {
    debug_assert!(c0.len() == x.len() && c1.len() == x.len());
    debug_assert!(c2.len() == x.len() && c3.len() == x.len());
    let mut l0 = [0.0f32; DOT_LANES];
    let mut l1 = [0.0f32; DOT_LANES];
    let mut l2 = [0.0f32; DOT_LANES];
    let mut l3 = [0.0f32; DOT_LANES];
    let chunks = x.len() / DOT_LANES;
    for c in 0..chunks {
        let i = c * DOT_LANES;
        let x8 = &x[i..i + DOT_LANES];
        let (a8, b8, c8, d8) = (
            &c0[i..i + DOT_LANES],
            &c1[i..i + DOT_LANES],
            &c2[i..i + DOT_LANES],
            &c3[i..i + DOT_LANES],
        );
        for l in 0..DOT_LANES {
            let xv = x8[l];
            l0[l] += xv * a8[l];
            l1[l] += xv * b8[l];
            l2[l] += xv * c8[l];
            l3[l] += xv * d8[l];
        }
    }
    let mut out = [
        l0.iter().map(|&l| l as f64).sum::<f64>(),
        l1.iter().map(|&l| l as f64).sum::<f64>(),
        l2.iter().map(|&l| l as f64).sum::<f64>(),
        l3.iter().map(|&l| l as f64).sum::<f64>(),
    ];
    for i in chunks * DOT_LANES..x.len() {
        let xv = x[i] as f64;
        out[0] += xv * (c0[i] as f64);
        out[1] += xv * (c1[i] as f64);
        out[2] += xv * (c2[i] as f64);
        out[3] += xv * (c3[i] as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> VectorSet {
        VectorSet::from_flat(vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0], 2).unwrap()
    }

    #[test]
    fn structure_and_distance() {
        let v = sample();
        assert_eq!(v.len(), 3);
        assert_eq!(v.dim(), 2);
        assert_eq!(v.row(1), &[3.0, 4.0]);
        assert!((v.dist_sq(0, 1) - 25.0).abs() < 1e-9);
        assert!((v.dist_sq_to(0, &[1.0, 1.0]) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn from_flat_validates() {
        assert!(VectorSet::from_flat(vec![1.0; 5], 2).is_err());
        assert!(VectorSet::from_flat(vec![], 0).is_err());
    }

    #[test]
    fn normalize_rows_zero_mean_unit_norm() {
        let mut v = VectorSet::from_flat(vec![1.0, 3.0, 5.0, 5.0, 5.0, 5.0], 3).unwrap();
        v.normalize_rows();
        // Row 0: mean 3 -> [-2,0,2], norm sqrt(8).
        let r = v.row(0);
        assert!((r[0] + 2.0 / 8f32.sqrt()).abs() < 1e-6);
        let norm: f32 = r.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-6);
        // Row 1 is constant -> zero after centering; stays zero.
        assert_eq!(v.row(1), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn fvecs_roundtrip() {
        let v = sample();
        let bytes = v.to_fvecs();
        let v2 = VectorSet::parse_fvecs(&bytes).unwrap();
        assert_eq!(v2.len(), 3);
        assert_eq!(v2.dim(), 2);
        for i in 0..3 {
            assert_eq!(v.row(i), v2.row(i));
        }
    }

    #[test]
    fn fvecs_rejects_garbage() {
        assert!(VectorSet::parse_fvecs(&[1, 2]).is_err());
        // dim=2 header but short payload
        let mut bad = 2u32.to_le_bytes().to_vec();
        bad.extend_from_slice(&[0; 4]);
        assert!(VectorSet::parse_fvecs(&bad).is_err());
    }

    #[test]
    fn norms_cache_and_invalidate() {
        let mut v = VectorSet::from_flat(vec![3.0, 4.0, 1.0, 0.0], 2).unwrap();
        assert_eq!(v.norms_sq(), &[25.0, 1.0]);
        v.normalize_rows();
        // Rows are centered then unit-normalized; row 1 = (1,0) → (0.5,−0.5)
        // centered → unit → norm 1.  The cache must rebuild.
        let n = v.norms_sq();
        assert!((n[1] - 1.0).abs() < 1e-6, "{n:?}");
    }

    #[test]
    fn dot_kernels_agree_bitwise() {
        // Odd length exercises both the lane body and the scalar tail.
        let mut rng = crate::util::rng::Rng::new(9);
        let n = 37;
        let gen = |rng: &mut crate::util::rng::Rng| -> Vec<f32> {
            (0..n).map(|_| (rng.below(1000) as f32 - 500.0) / 250.0).collect()
        };
        let x = gen(&mut rng);
        let cands: Vec<Vec<f32>> = (0..4).map(|_| gen(&mut rng)).collect();
        let blocked = dot4_fast(&x, &cands[0], &cands[1], &cands[2], &cands[3]);
        for j in 0..4 {
            let single = dot_fast(&x, &cands[j]);
            assert_eq!(single.to_bits(), blocked[j].to_bits(), "candidate {j}");
            // And both agree with a plain f64 reference to f32 accuracy.
            let reference: f64 =
                x.iter().zip(&cands[j]).map(|(&a, &b)| (a as f64) * (b as f64)).sum();
            assert!((single - reference).abs() < 1e-4, "{single} vs {reference}");
        }
        assert!((sq_norm_fast(&x) - dot_fast(&x, &x)).abs() == 0.0);
    }

    #[test]
    fn subset_copies_rows() {
        let v = sample();
        let s = v.subset(&[2, 0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.row(0), &[1.0, 1.0]);
        assert_eq!(s.row(1), &[0.0, 0.0]);
    }
}
