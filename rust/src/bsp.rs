//! BSP cost model — the closed forms of Table 1.
//!
//! These are the paper's *analytic* complexity expressions for GREEDY,
//! RandGreeDI and GreedyML under cardinality constraints.  The
//! `table1_complexity` bench validates the *measured* call counts from the
//! simulator against these formulas (shape, not constants), and the
//! coordinator uses them to predict whether a configuration will fit in
//! memory before running it.

/// Problem/machine parameters for the model.
#[derive(Clone, Copy, Debug)]
pub struct BspParams {
    /// Ground-set size n.
    pub n: u64,
    /// Solution size k.
    pub k: u64,
    /// Number of machines m.
    pub m: u64,
    /// Accumulation levels L (1 for RandGreeDI).
    pub levels: u64,
    /// Per-element δ (avg. neighbours / itemset size / feature count).
    pub delta: f64,
}

impl BspParams {
    /// `⌈m^{1/L}⌉` — the per-node fan-in of a balanced L-level tree.
    pub fn fan_in(&self) -> u64 {
        if self.levels == 0 {
            return 1;
        }
        let root = (self.m as f64).powf(1.0 / self.levels as f64);
        // Round carefully: powf(8, 1/3) can come out at 1.9999….
        let r = root.ceil();
        if ((r - 1.0).powi(self.levels as i32) >= self.m as f64 - 1e-9) && r > 1.0 {
            (r - 1.0) as u64
        } else {
            r as u64
        }
    }

    /// GREEDY total function calls: `n·k`.
    pub fn greedy_calls(&self) -> u64 {
        self.n * self.k
    }

    /// RandGreeDI calls per machine: `k(n/m + k·m)`.
    pub fn randgreedi_calls(&self) -> u64 {
        self.k * (self.n / self.m + self.k * self.m)
    }

    /// GreedyML calls per machine: `k(n/m + L·k·⌈m^{1/L}⌉)`.
    pub fn greedyml_calls(&self) -> u64 {
        self.k * (self.n / self.m + self.levels * self.k * self.fan_in())
    }

    /// Elements held by an interior node: `k·m` (RandGreeDI) vs
    /// `k·⌈m^{1/L}⌉` (GreedyML).
    pub fn interior_elems_randgreedi(&self) -> u64 {
        self.k * self.m
    }

    /// See [`interior_elems_randgreedi`](Self::interior_elems_randgreedi).
    pub fn interior_elems_greedyml(&self) -> u64 {
        self.k * self.fan_in()
    }

    /// Communication cost: `δ·k·m` (RandGreeDI).
    pub fn comm_randgreedi(&self) -> f64 {
        self.delta * (self.k * self.m) as f64
    }

    /// Communication cost: `δ·k·L·⌈m^{1/L}⌉` (GreedyML).
    pub fn comm_greedyml(&self) -> f64 {
        self.delta * (self.k * self.levels * self.fan_in()) as f64
    }

    /// k-cover / k-dominating-set computation: `δ·k·(n/m + k·m)` for
    /// RandGreeDI.
    pub fn coverage_comp_randgreedi(&self) -> f64 {
        self.delta * self.randgreedi_calls() as f64
    }

    /// k-cover / k-dominating-set computation for GreedyML.
    pub fn coverage_comp_greedyml(&self) -> f64 {
        self.delta * self.greedyml_calls() as f64
    }

    /// k-medoid computation: `δ·k((n/m)² + (k·m)²)` for RandGreeDI.
    pub fn kmedoid_comp_randgreedi(&self) -> f64 {
        let leaf = (self.n / self.m) as f64;
        let interior = (self.k * self.m) as f64;
        self.delta * self.k as f64 * (leaf * leaf + interior * interior)
    }

    /// k-medoid computation: `δ·k((n/m)² + L(k·⌈m^{1/L}⌉)²)` for GreedyML.
    pub fn kmedoid_comp_greedyml(&self) -> f64 {
        let leaf = (self.n / self.m) as f64;
        let interior = (self.k * self.fan_in()) as f64;
        self.delta * self.k as f64 * (leaf * leaf + self.levels as f64 * interior * interior)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(n: u64, k: u64, m: u64, levels: u64) -> BspParams {
        BspParams { n, k, m, levels, delta: 2.0 }
    }

    #[test]
    fn fan_in_exact_powers() {
        assert_eq!(p(0, 1, 8, 3).fan_in(), 2);
        assert_eq!(p(0, 1, 8, 1).fan_in(), 8);
        assert_eq!(p(0, 1, 8, 2).fan_in(), 3, "ceil(sqrt 8) = 3");
        assert_eq!(p(0, 1, 27, 3).fan_in(), 3);
        assert_eq!(p(0, 1, 16, 2).fan_in(), 4);
        assert_eq!(p(0, 1, 16, 4).fan_in(), 2);
    }

    #[test]
    fn randgreedi_is_greedyml_with_l1() {
        let a = p(1_000_000, 100, 32, 1);
        assert_eq!(a.randgreedi_calls(), a.greedyml_calls());
        assert_eq!(a.interior_elems_randgreedi(), a.interior_elems_greedyml());
        assert!((a.comm_randgreedi() - a.comm_greedyml()).abs() < 1e-9);
    }

    #[test]
    fn multilevel_reduces_interior_cost() {
        // The paper's claim: for large k the k²·m accumulation dominates
        // and L > 1 cuts it to L·k²·m^{1/L}.
        let rg = p(1_000_000, 10_000, 32, 1);
        let gml = p(1_000_000, 10_000, 32, 5);
        assert!(gml.greedyml_calls() < rg.randgreedi_calls());
        assert!(gml.interior_elems_greedyml() < rg.interior_elems_randgreedi());
        assert!(gml.kmedoid_comp_greedyml() < rg.kmedoid_comp_randgreedi());
    }

    #[test]
    fn comm_grows_linearly_vs_logarithmically() {
        // Fig. 6: RandGreeDI comm is O(km); GreedyML (b=2) is O(k log m).
        let mut prev_ratio = 0.0;
        for m in [8u64, 16, 32, 64, 128] {
            let levels = (m as f64).log2() as u64;
            let rg = p(1 << 20, 50, m, 1);
            let gml = p(1 << 20, 50, m, levels);
            let ratio = rg.comm_randgreedi() / gml.comm_greedyml();
            assert!(ratio > prev_ratio, "ratio should widen with m");
            prev_ratio = ratio;
        }
        assert!(prev_ratio > 4.0, "at m=128 the gap should be substantial");
    }

    #[test]
    fn greedy_baseline() {
        assert_eq!(p(1000, 10, 4, 1).greedy_calls(), 10_000);
    }
}
