//! BSP cost model — the closed forms of Table 1.
//!
//! These are the paper's *analytic* complexity expressions for GREEDY,
//! RandGreeDI and GreedyML under cardinality constraints.  The
//! `table1_complexity` bench validates the *measured* call counts from the
//! simulator against these formulas (shape, not constants), and the
//! coordinator uses them to predict whether a configuration will fit in
//! memory before running it.

/// Problem/machine parameters for the model.
#[derive(Clone, Copy, Debug)]
pub struct BspParams {
    /// Ground-set size n.
    pub n: u64,
    /// Solution size k.
    pub k: u64,
    /// Number of machines m.
    pub m: u64,
    /// Accumulation levels L (1 for RandGreeDI).
    pub levels: u64,
    /// Per-element δ (avg. neighbours / itemset size / feature count).
    pub delta: f64,
}

impl BspParams {
    /// `⌈m^{1/L}⌉` — the per-node fan-in of a balanced L-level tree,
    /// computed exactly as the smallest integer `b` with `b^L ≥ m`
    /// (floating-point `powf` rounding is wrong for large `m`).
    pub fn fan_in(&self) -> u64 {
        if self.levels == 0 {
            return 1;
        }
        if self.m <= 1 {
            return self.m;
        }
        // Binary search the minimal b in [1, m]; b = m always satisfies
        // m^L ≥ m for L ≥ 1.
        let (mut lo, mut hi) = (1u64, self.m);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if pow_sat(mid, self.levels) >= self.m {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo
    }

    /// GREEDY total function calls: `n·k`.
    pub fn greedy_calls(&self) -> u64 {
        self.n * self.k
    }

    /// RandGreeDI calls per machine: `k(n/m + k·m)`.
    pub fn randgreedi_calls(&self) -> u64 {
        self.k * (self.n / self.m + self.k * self.m)
    }

    /// GreedyML calls per machine: `k(n/m + L·k·⌈m^{1/L}⌉)`.
    pub fn greedyml_calls(&self) -> u64 {
        self.k * (self.n / self.m + self.levels * self.k * self.fan_in())
    }

    /// Elements held by an interior node: `k·m` (RandGreeDI) vs
    /// `k·⌈m^{1/L}⌉` (GreedyML).
    pub fn interior_elems_randgreedi(&self) -> u64 {
        self.k * self.m
    }

    /// See [`interior_elems_randgreedi`](Self::interior_elems_randgreedi).
    pub fn interior_elems_greedyml(&self) -> u64 {
        self.k * self.fan_in()
    }

    /// Communication cost: `δ·k·m` (RandGreeDI).
    pub fn comm_randgreedi(&self) -> f64 {
        self.delta * (self.k * self.m) as f64
    }

    /// Communication cost: `δ·k·L·⌈m^{1/L}⌉` (GreedyML).
    pub fn comm_greedyml(&self) -> f64 {
        self.delta * (self.k * self.levels * self.fan_in()) as f64
    }

    /// k-cover / k-dominating-set computation: `δ·k·(n/m + k·m)` for
    /// RandGreeDI.
    pub fn coverage_comp_randgreedi(&self) -> f64 {
        self.delta * self.randgreedi_calls() as f64
    }

    /// k-cover / k-dominating-set computation for GreedyML.
    pub fn coverage_comp_greedyml(&self) -> f64 {
        self.delta * self.greedyml_calls() as f64
    }

    /// k-medoid computation: `δ·k((n/m)² + (k·m)²)` for RandGreeDI.
    pub fn kmedoid_comp_randgreedi(&self) -> f64 {
        let leaf = (self.n / self.m) as f64;
        let interior = (self.k * self.m) as f64;
        self.delta * self.k as f64 * (leaf * leaf + interior * interior)
    }

    /// k-medoid computation: `δ·k((n/m)² + L(k·⌈m^{1/L}⌉)²)` for GreedyML.
    pub fn kmedoid_comp_greedyml(&self) -> f64 {
        let leaf = (self.n / self.m) as f64;
        let interior = (self.k * self.fan_in()) as f64;
        self.delta * self.k as f64 * (leaf * leaf + self.levels as f64 * interior * interior)
    }
}

/// `b^e`, saturating at `u64::MAX`.  Terminates quickly for any input: for
/// `b ≥ 2` the product saturates within 64 steps and the loop breaks.
fn pow_sat(b: u64, e: u64) -> u64 {
    if e == 0 {
        return 1;
    }
    if b <= 1 {
        return b;
    }
    let mut r = 1u64;
    for _ in 0..e {
        r = r.saturating_mul(b);
        if r == u64::MAX {
            break;
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(n: u64, k: u64, m: u64, levels: u64) -> BspParams {
        BspParams { n, k, m, levels, delta: 2.0 }
    }

    #[test]
    fn fan_in_exact_powers() {
        assert_eq!(p(0, 1, 8, 3).fan_in(), 2);
        assert_eq!(p(0, 1, 8, 1).fan_in(), 8);
        assert_eq!(p(0, 1, 8, 2).fan_in(), 3, "ceil(sqrt 8) = 3");
        assert_eq!(p(0, 1, 27, 3).fan_in(), 3);
        assert_eq!(p(0, 1, 16, 2).fan_in(), 4);
        assert_eq!(p(0, 1, 16, 4).fan_in(), 2);
    }

    #[test]
    fn randgreedi_is_greedyml_with_l1() {
        let a = p(1_000_000, 100, 32, 1);
        assert_eq!(a.randgreedi_calls(), a.greedyml_calls());
        assert_eq!(a.interior_elems_randgreedi(), a.interior_elems_greedyml());
        assert!((a.comm_randgreedi() - a.comm_greedyml()).abs() < 1e-9);
    }

    #[test]
    fn multilevel_reduces_interior_cost() {
        // The paper's claim: for large k the k²·m accumulation dominates
        // and L > 1 cuts it to L·k²·m^{1/L}.
        let rg = p(1_000_000, 10_000, 32, 1);
        let gml = p(1_000_000, 10_000, 32, 5);
        assert!(gml.greedyml_calls() < rg.randgreedi_calls());
        assert!(gml.interior_elems_greedyml() < rg.interior_elems_randgreedi());
        assert!(gml.kmedoid_comp_greedyml() < rg.kmedoid_comp_randgreedi());
    }

    #[test]
    fn comm_grows_linearly_vs_logarithmically() {
        // Fig. 6: RandGreeDI comm is O(km); GreedyML (b=2) is O(k log m).
        let mut prev_ratio = 0.0;
        for m in [8u64, 16, 32, 64, 128] {
            let levels = (m as f64).log2() as u64;
            let rg = p(1 << 20, 50, m, 1);
            let gml = p(1 << 20, 50, m, levels);
            let ratio = rg.comm_randgreedi() / gml.comm_greedyml();
            assert!(ratio > prev_ratio, "ratio should widen with m");
            prev_ratio = ratio;
        }
        assert!(prev_ratio > 4.0, "at m=128 the gap should be substantial");
    }

    #[test]
    fn greedy_baseline() {
        assert_eq!(p(1000, 10, 4, 1).greedy_calls(), 10_000);
    }

    #[test]
    fn fan_in_covers_and_is_minimal() {
        use crate::check::{ensure, forall, pair, Gen};
        forall(
            "fan_in(m,L)^L >= m, minimally",
            500,
            pair(Gen::u64(1..100_000), Gen::u64(1..12)),
            |&(m, levels)| {
                let b = p(0, 1, m, levels).fan_in();
                ensure(
                    pow_sat(b, levels) >= m,
                    format!("{b}^{levels} = {} < m = {m}", pow_sat(b, levels)),
                )?;
                if b > 1 {
                    ensure(
                        pow_sat(b - 1, levels) < m,
                        format!("{b} is not minimal for m={m}, L={levels}"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fan_in_monotone_in_m() {
        use crate::check::{ensure, forall, pair, Gen};
        forall(
            "fan_in monotone in m",
            300,
            pair(Gen::u64(1..50_000), Gen::u64(1..10)),
            |&(m, levels)| {
                let a = p(0, 1, m, levels).fan_in();
                let b = p(0, 1, m + 1, levels).fan_in();
                ensure(a <= b, format!("fan_in({m})={a} > fan_in({})={b} at L={levels}", m + 1))
            },
        );
    }

    #[test]
    fn fan_in_single_level_is_m() {
        use crate::check::{ensure, forall, Gen};
        forall("fan_in(m, 1) == m", 300, Gen::u64(1..1_000_000), |&m| {
            let b = p(0, 1, m, 1).fan_in();
            ensure(b == m, format!("fan_in({m}, 1) = {b}"))
        });
    }

    #[test]
    fn fan_in_huge_m_does_not_overflow() {
        // The old powf-based rounding went wrong far earlier than this.
        assert_eq!(p(0, 1, u64::MAX, 64).fan_in(), 2);
        assert_eq!(p(0, 1, u64::MAX, 1).fan_in(), u64::MAX);
        assert_eq!(p(0, 1, 1 << 62, 31).fan_in(), 4);
        assert_eq!(pow_sat(2, 64), u64::MAX);
        assert_eq!(pow_sat(3, 0), 1);
        assert_eq!(pow_sat(1, 1_000_000), 1);
        assert_eq!(pow_sat(0, 5), 0);
    }
}
