//! Run-level metric reporting: turning [`DistOutcome`]s into the rows the
//! paper's tables and figures print, plus JSON export for machine-readable
//! results — and the gateway daemon's live counters
//! ([`GatewayCounters`] / [`GatewaySnapshot`]).

use crate::algo::DistOutcome;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// A named experiment measurement (one table row / figure point).
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Algorithm label (Greedy / RG / GML(L,b) …).
    pub algo: String,
    /// Dataset label.
    pub dataset: String,
    /// Solution-size parameter k.
    pub k: usize,
    /// Machines.
    pub machines: u32,
    /// Branching factor (0 for sequential).
    pub branching: u32,
    /// Accumulation levels.
    pub levels: u32,
    /// Objective value.
    pub value: f64,
    /// Objective value relative to a baseline (percent), if known.
    pub rel_value_pct: Option<f64>,
    /// Function calls on the critical path.
    pub critical_calls: u64,
    /// Total function calls.
    pub total_calls: u64,
    /// Modeled computation seconds.
    pub comp_secs: f64,
    /// Modeled communication seconds.
    pub comm_secs: f64,
    /// Peak per-machine memory in bytes.
    pub peak_mem: u64,
    /// Human-readable fault accounting when the run saw worker faults
    /// (`None` = fault-free).  A report mentioning dropped machines
    /// marks a **degraded** answer — computed without the lost
    /// machines' data (see docs/failure-model.md).
    pub faults: Option<String>,
}

impl RunReport {
    /// Build from a distributed outcome.
    pub fn from_outcome(
        algo: &str,
        dataset: &str,
        k: usize,
        out: &DistOutcome,
        machines: u32,
        branching: u32,
        levels: u32,
    ) -> Self {
        Self {
            algo: algo.to_string(),
            dataset: dataset.to_string(),
            k,
            machines,
            branching,
            levels,
            value: out.value,
            rel_value_pct: None,
            critical_calls: out.critical_calls,
            total_calls: out.total_calls,
            comp_secs: out.comp_secs,
            comm_secs: out.comm_secs,
            peak_mem: out.peak_mem(),
            faults: (!out.faults.is_empty()).then(|| out.faults.to_string()),
        }
    }

    /// Set the relative function value against a baseline value.
    pub fn with_baseline(mut self, baseline_value: f64) -> Self {
        if baseline_value > 0.0 {
            self.rel_value_pct = Some(100.0 * self.value / baseline_value);
        }
        self
    }

    /// Fixed-width human row.
    pub fn row(&self) -> String {
        format!(
            "{:<14} {:<14} {:>9} {:>4} {:>3} {:>3} {:>14.2} {:>8} {:>12} {:>10.4} {:>10.4} {:>12}",
            self.algo,
            self.dataset,
            self.k,
            self.machines,
            self.branching,
            self.levels,
            self.value,
            self.rel_value_pct.map_or("-".to_string(), |p| format!("{p:.2}%")),
            crate::util::fmt_count(self.critical_calls),
            self.comp_secs,
            self.comm_secs,
            crate::util::fmt_bytes(self.peak_mem),
        )
    }

    /// Header matching [`row`](Self::row).
    pub fn header() -> String {
        format!(
            "{:<14} {:<14} {:>9} {:>4} {:>3} {:>3} {:>14} {:>8} {:>12} {:>10} {:>10} {:>12}",
            "algo", "dataset", "k", "m", "b", "L", "f(S)", "rel", "crit.calls", "comp(s)",
            "comm(s)", "peak mem"
        )
    }

    /// JSON export.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("algo", Json::from(self.algo.clone())),
            ("dataset", Json::from(self.dataset.clone())),
            ("k", Json::from(self.k)),
            ("machines", Json::from(self.machines as u64)),
            ("branching", Json::from(self.branching as u64)),
            ("levels", Json::from(self.levels as u64)),
            ("value", Json::from(self.value)),
            (
                "rel_value_pct",
                self.rel_value_pct.map_or(Json::Null, Json::from),
            ),
            ("critical_calls", Json::from(self.critical_calls)),
            ("total_calls", Json::from(self.total_calls)),
            ("comp_secs", Json::from(self.comp_secs)),
            ("comm_secs", Json::from(self.comm_secs)),
            ("peak_mem", Json::from(self.peak_mem)),
            ("faults", self.faults.clone().map_or(Json::Null, Json::from)),
        ])
    }
}

/// Live counters of a running gateway daemon, bumped lock-free by its
/// connection and worker threads (relaxed ordering — each counter is an
/// independent tally, not a synchronization point).  `queued`/`running`
/// are gauges of in-flight work; the rest are monotone totals.  Exported
/// over the wire as a [`GatewaySnapshot`].
#[derive(Debug, Default)]
pub struct GatewayCounters {
    /// Jobs accepted and waiting for a worker thread.
    pub queued: AtomicU64,
    /// Jobs currently executing on a worker thread.
    pub running: AtomicU64,
    /// Jobs that reached a `result` frame (fresh or cached).
    pub completed: AtomicU64,
    /// Completed jobs served by a reused warm fleet.
    pub warm: AtomicU64,
    /// Completed jobs answered from the solution cache.
    pub cached: AtomicU64,
    /// Jobs refused by admission control (post-accept rejections only —
    /// malformed specs bounce before they are queued and are not
    /// counted).
    pub rejected: AtomicU64,
    /// Jobs that errored in flight.
    pub failed: AtomicU64,
    /// Completed jobs whose run survived worker faults.
    pub faulted: AtomicU64,
}

impl GatewayCounters {
    /// A point-in-time copy.  The queue-level fields (`submitted`,
    /// `sessions`, `init_bytes`) are zero here — the daemon fills them
    /// from its [`JobQueue`](crate::coordinator::JobQueue) before
    /// answering a `stats` request, since they live in the queue and the
    /// session pool rather than in these counters.
    pub fn snapshot(&self) -> GatewaySnapshot {
        GatewaySnapshot {
            queued: self.queued.load(Ordering::Relaxed),
            running: self.running.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            warm: self.warm.load(Ordering::Relaxed),
            cached: self.cached.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            faulted: self.faulted.load(Ordering::Relaxed),
            submitted: 0,
            sessions: 0,
            init_bytes: 0,
        }
    }
}

/// A point-in-time view of a gateway daemon's counters: what a `stats`
/// frame carries and what `submit --json` prints as queue totals.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GatewaySnapshot {
    /// Jobs accepted and waiting for a worker thread.
    pub queued: u64,
    /// Jobs currently executing.
    pub running: u64,
    /// Jobs that reached a `result` frame.
    pub completed: u64,
    /// Completed jobs served by a reused warm fleet.
    pub warm: u64,
    /// Completed jobs answered from the solution cache.
    pub cached: u64,
    /// Jobs refused by admission control.
    pub rejected: u64,
    /// Jobs that errored in flight.
    pub failed: u64,
    /// Completed jobs whose run survived worker faults.
    pub faulted: u64,
    /// Jobs the shared queue has seen (including cache hits).
    pub submitted: u64,
    /// Worker sessions the pool established over its lifetime.
    pub sessions: u64,
    /// Bytes of problem data shipped establishing those sessions.
    pub init_bytes: u64,
}

impl GatewaySnapshot {
    /// JSON export (`submit --json` queue block, dashboards).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("queued", Json::from(self.queued)),
            ("running", Json::from(self.running)),
            ("completed", Json::from(self.completed)),
            ("warm", Json::from(self.warm)),
            ("cached", Json::from(self.cached)),
            ("rejected", Json::from(self.rejected)),
            ("failed", Json::from(self.failed)),
            ("faulted", Json::from(self.faulted)),
            ("submitted", Json::from(self.submitted)),
            ("sessions", Json::from(self.sessions)),
            ("init_bytes", Json::from(self.init_bytes)),
        ])
    }
}

/// Write a list of reports to a JSON file.
pub fn write_reports(path: &str, reports: &[RunReport]) -> crate::Result<()> {
    let arr = Json::Arr(reports.iter().map(|r| r.to_json()).collect());
    std::fs::write(path, arr.to_pretty())
        .map_err(|e| anyhow::anyhow!("cannot write {path}: {e}"))?;
    Ok(())
}

/// Quote a CSV field if it contains a delimiter (algorithm labels carry
/// commas: `GML(m=8,b=2,L=3)`).
fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Write the figure-regeneration CSVs from long-form sweep rows into
/// `dir`: one file per paper figure, each in tidy long form (one run per
/// row) so the plot scripts only select and pivot.  Returns the paths
/// written.
///
/// * `fig4_tree_params.csv` — solution quality vs tree shape (m, b, L).
/// * `fig5_memory_vary_k.csv` — peak per-machine memory vs k.
/// * `fig6_strong_scaling.csv` — computation/communication seconds and
///   critical-path calls vs machine count.
pub fn write_sweep_csvs(dir: &str, reports: &[RunReport]) -> crate::Result<Vec<String>> {
    std::fs::create_dir_all(dir).map_err(|e| anyhow::anyhow!("cannot create {dir}: {e}"))?;
    let mut written = Vec::new();
    let mut emit = |name: &str, header: &str, rows: Vec<String>| -> crate::Result<()> {
        let path = format!("{}/{name}", dir.trim_end_matches('/'));
        let mut text = String::from(header);
        text.push('\n');
        for row in rows {
            text.push_str(&row);
            text.push('\n');
        }
        std::fs::write(&path, text).map_err(|e| anyhow::anyhow!("cannot write {path}: {e}"))?;
        written.push(path);
        Ok(())
    };
    emit(
        "fig4_tree_params.csv",
        "algo,dataset,k,machines,branching,levels,value,rel_value_pct,critical_calls",
        reports
            .iter()
            .map(|r| {
                format!(
                    "{},{},{},{},{},{},{},{},{}",
                    csv_field(&r.algo),
                    csv_field(&r.dataset),
                    r.k,
                    r.machines,
                    r.branching,
                    r.levels,
                    r.value,
                    r.rel_value_pct.map_or(String::new(), |p| format!("{p}")),
                    r.critical_calls,
                )
            })
            .collect(),
    )?;
    emit(
        "fig5_memory_vary_k.csv",
        "algo,dataset,k,machines,branching,levels,peak_mem_bytes",
        reports
            .iter()
            .map(|r| {
                format!(
                    "{},{},{},{},{},{},{}",
                    csv_field(&r.algo),
                    csv_field(&r.dataset),
                    r.k,
                    r.machines,
                    r.branching,
                    r.levels,
                    r.peak_mem,
                )
            })
            .collect(),
    )?;
    emit(
        "fig6_strong_scaling.csv",
        "algo,dataset,k,machines,levels,comp_secs,comm_secs,total_secs,critical_calls",
        reports
            .iter()
            .map(|r| {
                format!(
                    "{},{},{},{},{},{},{},{},{}",
                    csv_field(&r.algo),
                    csv_field(&r.dataset),
                    r.k,
                    r.machines,
                    r.levels,
                    r.comp_secs,
                    r.comm_secs,
                    r.comp_secs + r.comm_secs,
                    r.critical_calls,
                )
            })
            .collect(),
    )?;
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy() -> RunReport {
        RunReport {
            algo: "GML".into(),
            dataset: "road".into(),
            k: 100,
            machines: 8,
            branching: 2,
            levels: 3,
            value: 1234.5,
            rel_value_pct: None,
            critical_calls: 999,
            total_calls: 4000,
            comp_secs: 0.5,
            comm_secs: 0.01,
            peak_mem: 2048,
            faults: None,
        }
    }

    #[test]
    fn baseline_percentage() {
        let r = dummy().with_baseline(2469.0);
        assert!((r.rel_value_pct.unwrap() - 50.0).abs() < 0.01);
        let r2 = dummy().with_baseline(0.0);
        assert!(r2.rel_value_pct.is_none());
    }

    #[test]
    fn row_and_header_align() {
        let h = RunReport::header();
        let r = dummy().with_baseline(1234.5).row();
        assert!(h.contains("crit.calls"));
        assert!(r.contains("100.00%"));
        assert!(r.contains("GML"));
    }

    #[test]
    fn json_roundtrip() {
        let j = dummy().with_baseline(1234.5).to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("k").unwrap().as_u64(), Some(100));
        assert_eq!(parsed.get("algo").unwrap().as_str(), Some("GML"));
    }

    #[test]
    fn sweep_csvs_are_long_form_and_comma_safe() {
        let dir = std::env::temp_dir().join("greedyml_csv_test");
        let dir = dir.to_str().unwrap().to_string();
        let mut r2 = dummy();
        r2.algo = "GML(m=8,b=2,L=3)".into();
        r2.k = 200;
        let written = write_sweep_csvs(&dir, &[dummy().with_baseline(1234.5), r2]).unwrap();
        assert_eq!(written.len(), 3);
        for path in &written {
            let text = std::fs::read_to_string(path).unwrap();
            assert_eq!(text.lines().count(), 3, "header + 2 rows in {path}");
            assert!(
                text.contains("\"GML(m=8,b=2,L=3)\""),
                "comma-bearing label must be quoted in {path}:\n{text}"
            );
        }
        let fig5 = std::fs::read_to_string(format!("{dir}/fig5_memory_vary_k.csv")).unwrap();
        assert!(fig5.starts_with("algo,dataset,k,"));
        assert!(fig5.contains(",2048"), "peak_mem column present");
        let fig6 = std::fs::read_to_string(format!("{dir}/fig6_strong_scaling.csv")).unwrap();
        assert!(fig6.contains(",0.51,"), "total_secs = comp + comm");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gateway_counters_snapshot_copies_every_tally() {
        let c = GatewayCounters::default();
        c.queued.fetch_add(3, Ordering::Relaxed);
        c.running.fetch_add(2, Ordering::Relaxed);
        c.completed.fetch_add(9, Ordering::Relaxed);
        c.warm.fetch_add(5, Ordering::Relaxed);
        c.cached.fetch_add(4, Ordering::Relaxed);
        c.rejected.fetch_add(1, Ordering::Relaxed);
        c.failed.fetch_add(1, Ordering::Relaxed);
        c.faulted.fetch_add(1, Ordering::Relaxed);
        let s = c.snapshot();
        assert_eq!(s.queued, 3);
        assert_eq!(s.running, 2);
        assert_eq!(s.completed, 9);
        assert_eq!(s.warm, 5);
        assert_eq!(s.cached, 4);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.failed, 1);
        assert_eq!(s.faulted, 1);
        assert_eq!(s.submitted, 0, "queue-level fields are filled by the daemon");
        let j = s.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("completed").unwrap().as_u64(), Some(9));
        assert_eq!(parsed.get("init_bytes").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn write_reports_to_file() {
        let path = std::env::temp_dir().join("greedyml_metrics_test.json");
        let path = path.to_str().unwrap().to_string();
        write_reports(&path, &[dummy(), dummy()]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let arr = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(arr.as_arr().unwrap().len(), 2);
        std::fs::remove_file(&path).ok();
    }
}
