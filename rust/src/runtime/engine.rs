//! PJRT execution engine: load AOT HLO-text artifacts, compile them once on
//! the CPU PJRT client, and execute them from the solve path.
//!
//! Threading: the `xla` crate's wrappers hold raw pointers and are not
//! `Send`/`Sync`, but the underlying PJRT CPU client *is* thread-safe for
//! compilation and execution (PJRT C API contract).  We still serialize all
//! launches behind one mutex — the distributed simulator calls in from many
//! superstep threads, and exclusive access is the conservatively correct
//! choice (and matches the paper's one-core-per-machine setup, where
//! objective evaluation is serial per machine anyway).

use super::manifest::{Entry, Manifest};
use std::collections::HashMap;
use std::sync::Mutex;

struct Inner {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

// SAFETY: `Inner` is only reachable behind `Engine`'s Mutex, so all access
// is exclusive; the PJRT CPU client itself is thread-safe per the PJRT API
// contract, we just never rely on that.
unsafe impl Send for Inner {}

/// A loaded artifact bundle. Cheap to share via `Arc<Engine>`.
pub struct Engine {
    inner: Mutex<Inner>,
    manifest: Manifest,
    dir: String,
}

impl Engine {
    /// Load `manifest.json` from `dir` and compile every listed entry.
    pub fn load(dir: &str) -> crate::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        let mut executables = HashMap::new();
        for entry in &manifest.entries {
            let path = format!("{dir}/{}", entry.file);
            let exe = compile_one(&client, &path)
                .map_err(|e| anyhow::anyhow!("compiling {path}: {e}"))?;
            executables.insert(entry.name.clone(), exe);
        }
        Ok(Self {
            inner: Mutex::new(Inner { client, executables }),
            manifest,
            dir: dir.to_string(),
        })
    }

    /// The manifest the artifacts were described by.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The artifact directory.
    pub fn dir(&self) -> &str {
        &self.dir
    }

    /// Entry lookup (validated names).
    pub fn entry(&self, name: &str) -> crate::Result<&Entry> {
        self.manifest.entry(name)
    }

    /// PJRT platform name (reporting).
    pub fn platform(&self) -> String {
        self.inner.lock().unwrap().client.platform_name()
    }

    /// Execute entry `name` with positional literals; returns the
    /// decomposed output tuple.  Arguments are borrowed — the PJRT call
    /// copies host literals to device buffers itself, so cloning on the
    /// Rust side would only duplicate host memory (§Perf P5).
    pub fn execute(&self, name: &str, args: &[&xla::Literal]) -> crate::Result<Vec<xla::Literal>> {
        let entry = self.manifest.entry(name)?;
        anyhow::ensure!(
            args.len() == entry.inputs.len(),
            "entry '{name}' wants {} args, got {}",
            entry.inputs.len(),
            args.len()
        );
        for (i, (arg, spec)) in args.iter().zip(&entry.inputs).enumerate() {
            let got = arg.element_count();
            anyhow::ensure!(
                got == spec.elems(),
                "entry '{name}' arg {i}: {got} elements, spec wants {:?}",
                spec.shape
            );
        }
        let inner = self.inner.lock().unwrap();
        let exe = inner
            .executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("entry '{name}' not compiled"))?;
        let result = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing '{name}': {e}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching '{name}' result: {e}"))?;
        root.to_tuple().map_err(|e| anyhow::anyhow!("detupling '{name}': {e}"))
    }
}

impl Engine {
    /// Upload a host buffer to a persistent device buffer (§Perf P5: X view
    /// chunks are immutable for a state's lifetime — upload them once and
    /// launch with `execute_buffers` instead of re-copying per call).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> crate::Result<xla::PjRtBuffer> {
        let inner = self.inner.lock().unwrap();
        inner
            .client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("device upload: {e}"))
    }

    /// Execute entry `name` with pre-uploaded device buffers.
    pub fn execute_buffers(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> crate::Result<Vec<xla::Literal>> {
        let inner = self.inner.lock().unwrap();
        let exe = inner
            .executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("entry '{name}' not compiled"))?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .map_err(|e| anyhow::anyhow!("executing '{name}': {e}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching '{name}' result: {e}"))?;
        root.to_tuple().map_err(|e| anyhow::anyhow!("detupling '{name}': {e}"))
    }
}

fn compile_one(
    client: &xla::PjRtClient,
    path: &str,
) -> Result<xla::PjRtLoadedExecutable, xla::Error> {
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp)
}

/// Build an `f32` literal of the given logical shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> crate::Result<xla::Literal> {
    let want: usize = dims.iter().product();
    anyhow::ensure!(data.len() == want, "literal_f32: {} elems for shape {dims:?}", data.len());
    let l = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(l);
    }
    let dims64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    l.reshape(&dims64).map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

/// Build a `u32` literal of the given logical shape from a flat slice.
pub fn literal_u32(data: &[u32], dims: &[usize]) -> crate::Result<xla::Literal> {
    let want: usize = dims.iter().product();
    anyhow::ensure!(data.len() == want, "literal_u32: {} elems for shape {dims:?}", data.len());
    let l = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(l);
    }
    let dims64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    l.reshape(&dims64).map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        // Tests are skipped when artifacts have not been generated yet
        // (CI runs `make artifacts` first; `make test` depends on it).
        Engine::load("artifacts").ok()
    }

    #[test]
    fn loads_and_lists_entries() {
        let Some(e) = engine() else { return };
        assert!(e.platform().to_lowercase().contains("cpu") || !e.platform().is_empty());
        assert!(e.entry("coverage_gains").is_ok());
        assert!(e.entry("missing_entry").is_err());
    }

    #[test]
    fn coverage_gains_executes_and_matches_bit_math() {
        let Some(e) = engine() else { return };
        let m = e.manifest();
        let (c, w) = (m.c_tile, m.w_tile);
        // Candidate 0 covers bits {0,1}; covered has bit 0 → gain 1.
        let mut masks = vec![0u32; c * w];
        masks[0] = 0b11;
        masks[w] = 0xFFFF_0000; // candidate 1: 16 bits, none covered
        let mut covered = vec![0u32; w];
        covered[0] = 0b1;
        let masks_l = literal_u32(&masks, &[c, w]).unwrap();
        let covered_l = literal_u32(&covered, &[w]).unwrap();
        let out = e.execute("coverage_gains", &[&masks_l, &covered_l]).unwrap();
        let gains: Vec<i32> = out[0].to_vec().unwrap();
        assert_eq!(gains[0], 1);
        assert_eq!(gains[1], 16);
        assert!(gains[2..].iter().all(|&g| g == 0));
    }

    #[test]
    fn kmedoid_gains_match_rust_oracle_math() {
        let Some(e) = engine() else { return };
        let m = e.manifest();
        let (nt, ct) = (m.n_tile, m.c_tile);
        let d = 64usize;
        let mut rng = crate::util::rng::Rng::new(3);
        let x: Vec<f32> = (0..nt * d).map(|_| rng.f32() - 0.5).collect();
        let mind: Vec<f32> = (0..nt).map(|_| rng.f32() * 2.0).collect();
        let mut c = vec![0f32; ct * d];
        for v in c.iter_mut().take(3 * d) {
            *v = rng.f32() - 0.5;
        }
        let x_l = literal_f32(&x, &[nt, d]).unwrap();
        let mind_l = literal_f32(&mind, &[nt]).unwrap();
        let c_l = literal_f32(&c, &[ct, d]).unwrap();
        let out = e.execute("kmedoid_gains_d64", &[&x_l, &mind_l, &c_l]).unwrap();
        let gains: Vec<f32> = out[0].to_vec().unwrap();
        // Reference math in f64 (mirrors objective::kmedoid).
        for j in 0..3 {
            let mut want = 0f64;
            for i in 0..nt {
                let mut d2 = 0f64;
                for t in 0..d {
                    let diff = (x[i * d + t] - c[j * d + t]) as f64;
                    d2 += diff * diff;
                }
                let dist = d2.sqrt();
                if (mind[i] as f64) > dist {
                    want += mind[i] as f64 - dist;
                }
            }
            assert!(
                (gains[j] as f64 - want).abs() < 1e-2 * want.max(1.0),
                "candidate {j}: pjrt {} vs rust {want}",
                gains[j]
            );
        }
    }

    #[test]
    fn argument_validation() {
        let Some(e) = engine() else { return };
        let bad = literal_u32(&[0u32; 4], &[4]).unwrap();
        assert!(e.execute("coverage_gains", &[&bad]).is_err());
    }
}
