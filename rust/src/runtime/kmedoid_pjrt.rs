//! PJRT-accelerated k-medoid oracle.
//!
//! Drop-in [`Oracle`] implementation whose marginal-gain math runs in the
//! AOT-compiled Pallas kernel (`kmedoid_gains_d*` / `kmedoid_update_d*`)
//! instead of the scalar Rust loop.  Semantics match
//! [`crate::objective::KMedoid`] exactly up to f32-vs-f64 accumulation;
//! the integration tests cross-check the two.
//!
//! View handling: the kernel has a static shape `[n_tile, d]`, so a state
//! splits its view into `⌈n'/n_tile⌉` chunks, pads the last one with zero
//! rows (`mind = 0` rows contribute zero gain by construction — see
//! `python/compile/kernels/ref.py`), uploads each chunk's X once at state
//! construction, and sums per-chunk kernel gains.  Candidate tiles are
//! padded to `c_tile` the same way.

use super::engine::Engine;
use xla::PjRtBuffer;
use crate::data::vectors::VectorSet;
use crate::objective::{GainState, Oracle};
use crate::ElemId;
use std::sync::Arc;

/// k-medoid oracle executing gains/updates through PJRT.
pub struct KMedoidPjrt {
    data: Arc<VectorSet>,
    engine: Arc<Engine>,
    gains_entry: String,
    update_entry: String,
}

impl KMedoidPjrt {
    /// Wrap a vector set; fails if no artifact was compiled for its
    /// dimensionality (`aot.py --dims` controls which exist).
    pub fn new(data: Arc<VectorSet>, engine: Arc<Engine>) -> crate::Result<Self> {
        let d = data.dim();
        let gains_entry = format!("kmedoid_gains_d{d}");
        let update_entry = format!("kmedoid_update_d{d}");
        engine.entry(&gains_entry)?;
        engine.entry(&update_entry)?;
        Ok(Self { data, engine, gains_entry, update_entry })
    }

    /// The underlying vectors.
    pub fn data(&self) -> &Arc<VectorSet> {
        &self.data
    }

    fn d0(&self, i: usize) -> f64 {
        self.data.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

impl Oracle for KMedoidPjrt {
    fn n(&self) -> usize {
        self.data.len()
    }

    fn name(&self) -> &'static str {
        "k-medoid-pjrt"
    }

    fn new_state<'a>(&'a self, view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        let view: Vec<ElemId> = match view {
            Some(v) => v.to_vec(),
            None => (0..self.data.len() as ElemId).collect(),
        };
        let nt = self.engine.manifest().n_tile;
        let d = self.data.dim();
        let nchunks = view.len().div_ceil(nt).max(1);
        // Upload padded X chunks once; they are immutable for the state's
        // lifetime.  mind stays host-side (it changes every commit).
        let mut x_chunks = Vec::with_capacity(nchunks);
        let mut mind = vec![0f32; nchunks * nt];
        let mut base_loss_sum = 0f64;
        for ci in 0..nchunks {
            let rows = &view[ci * nt..view.len().min((ci + 1) * nt)];
            let mut flat = vec![0f32; nt * d];
            for (r, &e) in rows.iter().enumerate() {
                flat[r * d..(r + 1) * d].copy_from_slice(self.data.row(e as usize));
                let d0 = self.d0(e as usize);
                mind[ci * nt + r] = d0 as f32;
                base_loss_sum += d0;
            }
            // §Perf P5: upload once; every gain/commit launch reuses the
            // device-resident chunk instead of re-copying ~n_tile·d floats.
            x_chunks
                .push(DeviceBuf(self.engine.upload_f32(&flat, &[nt, d]).expect("chunk upload")));
        }
        Box::new(KMedoidPjrtState {
            oracle: self,
            view,
            x_chunks,
            mind,
            base_loss_sum,
            solution: Vec::new(),
        })
    }

    fn elem_bytes(&self, _e: ElemId) -> usize {
        self.data.elem_bytes()
    }
}

/// Device-resident buffer shared across superstep threads.
struct DeviceBuf(PjRtBuffer);

// SAFETY: a buffer is written once at upload and only read afterwards, and
// every PJRT launch that touches it is serialized behind the engine's
// mutex (see `engine.rs`); the `xla` wrapper is `!Send`/`!Sync` only
// because it holds a raw pointer.
unsafe impl Send for DeviceBuf {}
unsafe impl Sync for DeviceBuf {}

struct KMedoidPjrtState<'a> {
    oracle: &'a KMedoidPjrt,
    view: Vec<ElemId>,
    /// Padded `[n_tile, d]` device-resident X buffers, one per view chunk.
    x_chunks: Vec<DeviceBuf>,
    /// Host copy of the padded min-distance vector (len = chunks · n_tile).
    mind: Vec<f32>,
    base_loss_sum: f64,
    solution: Vec<ElemId>,
}

impl KMedoidPjrtState<'_> {
    fn nv(&self) -> f64 {
        self.view.len().max(1) as f64
    }

    fn nt(&self) -> usize {
        self.oracle.engine.manifest().n_tile
    }

    /// Run the gains kernel for a padded candidate tile; returns per-tile
    /// gain *sums* (caller divides by n').
    fn tile_gains(&self, c_flat: &[f32], live: usize) -> Vec<f64> {
        let eng = &self.oracle.engine;
        let m = eng.manifest();
        let d = self.oracle.data.dim();
        let c_buf = eng.upload_f32(c_flat, &[m.c_tile, d]).expect("candidate upload");
        let nt = self.nt();
        let mut acc = vec![0f64; live];
        for (ci, x_buf) in self.x_chunks.iter().enumerate() {
            let mind_buf = eng
                .upload_f32(&self.mind[ci * nt..(ci + 1) * nt], &[nt])
                .expect("mind upload");
            let out = eng
                .execute_buffers(&self.oracle.gains_entry, &[&x_buf.0, &mind_buf, &c_buf])
                .expect("gains kernel launch");
            let gains: Vec<f32> = out[0].to_vec().expect("gains output");
            for (a, &g) in acc.iter_mut().zip(gains.iter().take(live)) {
                *a += g as f64;
            }
        }
        acc
    }
}

impl GainState for KMedoidPjrtState<'_> {
    fn value(&self) -> f64 {
        (self.base_loss_sum - self.mind.iter().map(|&v| v as f64).sum::<f64>()) / self.nv()
    }

    fn gain(&self, e: ElemId) -> f64 {
        let d = self.oracle.data.dim();
        let m = self.oracle.engine.manifest();
        let mut c_flat = vec![0f32; m.c_tile * d];
        c_flat[..d].copy_from_slice(self.oracle.data.row(e as usize));
        self.tile_gains(&c_flat, 1)[0] / self.nv()
    }

    fn gain_batch(&self, es: &[ElemId], out: &mut Vec<f64>) {
        out.clear();
        let d = self.oracle.data.dim();
        let m = self.oracle.engine.manifest();
        for tile in es.chunks(m.c_tile) {
            let mut c_flat = vec![0f32; m.c_tile * d];
            for (r, &e) in tile.iter().enumerate() {
                c_flat[r * d..(r + 1) * d].copy_from_slice(self.oracle.data.row(e as usize));
            }
            for g in self.tile_gains(&c_flat, tile.len()) {
                out.push(g / self.nv());
            }
        }
    }

    fn commit(&mut self, e: ElemId) {
        let eng = &self.oracle.engine;
        let d = self.oracle.data.dim();
        let nt = self.nt();
        let cand = eng
            .upload_f32(self.oracle.data.row(e as usize), &[d])
            .expect("cand upload");
        for (ci, x_buf) in self.x_chunks.iter().enumerate() {
            let mind_buf = eng
                .upload_f32(&self.mind[ci * nt..(ci + 1) * nt], &[nt])
                .expect("mind upload");
            let out = eng
                .execute_buffers(&self.oracle.update_entry, &[&x_buf.0, &mind_buf, &cand])
                .expect("update kernel launch");
            let new_mind: Vec<f32> = out[0].to_vec().expect("update output");
            self.mind[ci * nt..(ci + 1) * nt].copy_from_slice(&new_mind);
        }
        // Re-zero pad rows: padded X rows are all-zero vectors whose
        // distance to cand is ‖cand‖, and min(0, ‖cand‖) = 0 keeps them 0 —
        // nothing to fix, but assert the invariant in debug builds.
        debug_assert!(self
            .mind
            .iter()
            .skip(self.view.len() % nt + (self.x_chunks.len() - 1) * nt)
            .all(|&v| v >= 0.0));
        self.solution.push(e);
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, _e: ElemId) -> u64 {
        (self.view.len() * self.oracle.data.dim()) as u64
    }

    fn parallel_scan(&self) -> bool {
        // Launches serialize behind the engine mutex and readback is not
        // thread-safe; splitting would only multiply padded c_tile launches.
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::KMedoid;

    fn setup(n: usize, d: usize) -> Option<(Arc<VectorSet>, Arc<Engine>)> {
        let engine = Engine::load("artifacts").ok()?;
        let (vs, _) = crate::data::gen::gaussian_mixture(
            crate::data::gen::GaussianParams { n, dim: d, classes: 4, noise: 0.3 },
            17,
        );
        Some((Arc::new(vs), Arc::new(engine)))
    }

    #[test]
    fn matches_cpu_oracle_gains() {
        let Some((vs, eng)) = setup(300, 64) else { return };
        let cpu = KMedoid::new(vs.clone());
        let pjrt = KMedoidPjrt::new(vs, eng).unwrap();
        let st_cpu = cpu.new_state(None);
        let st_pjrt = pjrt.new_state(None);
        let mut got = Vec::new();
        st_pjrt.gain_batch(&[0, 5, 99, 211], &mut got);
        for (i, &e) in [0u32, 5, 99, 211].iter().enumerate() {
            let want = st_cpu.gain(e);
            assert!(
                (got[i] - want).abs() < 1e-3 * want.max(1e-3),
                "elem {e}: pjrt {} vs cpu {want}",
                got[i]
            );
        }
    }

    #[test]
    fn commit_and_value_track_cpu() {
        let Some((vs, eng)) = setup(520, 64) else { return };
        let cpu = KMedoid::new(vs.clone());
        let pjrt = KMedoidPjrt::new(vs, eng).unwrap();
        let mut a = cpu.new_state(None);
        let mut b = pjrt.new_state(None);
        for e in [3u32, 77, 401] {
            b.commit(e);
            a.commit(e);
            assert!(
                (a.value() - b.value()).abs() < 1e-3 * a.value().max(1e-3),
                "after {e}: cpu {} vs pjrt {}",
                a.value(),
                b.value()
            );
        }
    }

    #[test]
    fn respects_views_and_missing_dim_fails() {
        let Some((vs, eng)) = setup(100, 64) else { return };
        let pjrt = KMedoidPjrt::new(vs.clone(), eng.clone()).unwrap();
        let view: Vec<u32> = (0..10).collect();
        let st = pjrt.new_state(Some(&view));
        assert_eq!(st.call_cost(0), 10 * 64);
        // A dimension with no compiled artifact is rejected.
        let odd = VectorSet::from_flat(vec![0.0; 30], 3).unwrap();
        assert!(KMedoidPjrt::new(Arc::new(odd), eng).is_err());
    }

    #[test]
    fn greedy_over_pjrt_matches_cpu_quality() {
        let Some((vs, eng)) = setup(256, 64) else { return };
        let cpu = KMedoid::new(vs.clone());
        let pjrt = KMedoidPjrt::new(vs, eng).unwrap();
        let c = crate::constraint::Cardinality::new(5);
        let cands: Vec<u32> = (0..256).collect();
        let a = crate::greedy::greedy_lazy(&cpu, &c, &cands, None);
        let b = crate::greedy::greedy_lazy(&pjrt, &c, &cands, None);
        assert!(
            (a.value - b.value).abs() < 1e-3 * a.value,
            "cpu {} vs pjrt {}",
            a.value,
            b.value
        );
    }
}
