//! API-compatible stand-ins for the PJRT runtime, compiled when the `pjrt`
//! cargo feature is off.
//!
//! The contract: [`Engine::load`] always fails with a clear message, and
//! none of the types can be constructed (each holds an
//! [`std::convert::Infallible`]), so the methods that would need a real
//! engine are statically unreachable.  Call sites
//! don't change between builds — benches and e2e tests already gate their
//! PJRT sections on `Engine::load(..)` succeeding, and the coordinator's
//! `backend = pjrt` path only runs with a loaded engine in hand.

use super::manifest::{Entry, Manifest};
use crate::data::{ItemsetCollection, VectorSet};
use crate::objective::{GainState, Oracle};
use crate::ElemId;
use std::convert::Infallible;
use std::sync::Arc;

const NO_PJRT: &str = "built without the `pjrt` cargo feature; \
                       rebuild with `cargo build --features pjrt` (requires the XLA toolchain)";

/// Uninhabited stand-in for the artifact engine.
pub struct Engine {
    #[allow(dead_code)] // uninhabitedness is the point; never read
    never: Infallible,
}

impl Engine {
    /// Always fails: there is no PJRT client in this build.
    pub fn load(dir: &str) -> crate::Result<Self> {
        anyhow::bail!("cannot load artifacts from {dir}: {NO_PJRT}")
    }

    /// The manifest the artifacts were described by.
    pub fn manifest(&self) -> &Manifest {
        unreachable!("stub cannot be constructed (see `never` field)")
    }

    /// The artifact directory.
    pub fn dir(&self) -> &str {
        unreachable!("stub cannot be constructed (see `never` field)")
    }

    /// Entry lookup (validated names).
    pub fn entry(&self, _name: &str) -> crate::Result<&Entry> {
        unreachable!("stub cannot be constructed (see `never` field)")
    }

    /// PJRT platform name (reporting).
    pub fn platform(&self) -> String {
        unreachable!("stub cannot be constructed (see `never` field)")
    }
}

/// Uninhabited stand-in for the PJRT k-cover oracle.
pub struct KCoverPjrt {
    #[allow(dead_code)]
    never: Infallible,
}

impl KCoverPjrt {
    /// Always fails in a non-`pjrt` build.
    pub fn new(_data: Arc<ItemsetCollection>, _engine: Arc<Engine>) -> crate::Result<Self> {
        anyhow::bail!("k-cover PJRT oracle unavailable: {NO_PJRT}")
    }
}

impl Oracle for KCoverPjrt {
    fn n(&self) -> usize {
        unreachable!("stub cannot be constructed (see `never` field)")
    }

    fn name(&self) -> &'static str {
        unreachable!("stub cannot be constructed (see `never` field)")
    }

    fn new_state<'a>(&'a self, _view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        unreachable!("stub cannot be constructed (see `never` field)")
    }

    fn elem_bytes(&self, _e: ElemId) -> usize {
        unreachable!("stub cannot be constructed (see `never` field)")
    }
}

/// Uninhabited stand-in for the PJRT k-medoid oracle.
pub struct KMedoidPjrt {
    #[allow(dead_code)]
    never: Infallible,
}

impl KMedoidPjrt {
    /// Always fails in a non-`pjrt` build.
    pub fn new(_data: Arc<VectorSet>, _engine: Arc<Engine>) -> crate::Result<Self> {
        anyhow::bail!("k-medoid PJRT oracle unavailable: {NO_PJRT}")
    }
}

impl Oracle for KMedoidPjrt {
    fn n(&self) -> usize {
        unreachable!("stub cannot be constructed (see `never` field)")
    }

    fn name(&self) -> &'static str {
        unreachable!("stub cannot be constructed (see `never` field)")
    }

    fn new_state<'a>(&'a self, _view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        unreachable!("stub cannot be constructed (see `never` field)")
    }

    fn elem_bytes(&self, _e: ElemId) -> usize {
        unreachable!("stub cannot be constructed (see `never` field)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_load_reports_missing_feature() {
        let err = Engine::load("artifacts").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("artifacts"), "{msg}");
    }
}
