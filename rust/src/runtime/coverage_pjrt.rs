//! PJRT-accelerated coverage oracle (k-cover over packed bitmaps).
//!
//! Demonstrates the dense-bitmap path of the coverage kernel: candidate
//! itemsets are packed into `[c_tile, w_tile]` uint32 tiles and scored by
//! the AOT `coverage_gains` executable.  For sparse data (road networks,
//! δ ≈ 2) the host's sparse scan wins — the packing cost is Θ(universe) per
//! call — but for dense itemsets (webdocs-like, δ ≈ 177) the bitmap path
//! amortizes; the `ablation_pjrt` bench quantifies the crossover.
//! Commits stay host-side: updating `covered |= mask` is a trivial OR.

use super::engine::{literal_u32, Engine};
use crate::data::itemsets::ItemsetCollection;
use crate::objective::{GainState, Oracle};
use crate::ElemId;
use std::sync::Arc;

/// k-cover oracle whose batched gains run through PJRT.
pub struct KCoverPjrt {
    data: Arc<ItemsetCollection>,
    engine: Arc<Engine>,
    /// Words in the (padded) universe bitmap.
    words: usize,
}

impl KCoverPjrt {
    /// Wrap a collection; the universe is padded to a multiple of `w_tile`
    /// 32-bit words.
    pub fn new(data: Arc<ItemsetCollection>, engine: Arc<Engine>) -> crate::Result<Self> {
        engine.entry("coverage_gains")?;
        let w_tile = engine.manifest().w_tile;
        let raw_words = data.num_items().div_ceil(32).max(1);
        let words = raw_words.div_ceil(w_tile) * w_tile;
        Ok(Self { data, engine, words })
    }

    /// The underlying collection.
    pub fn data(&self) -> &ItemsetCollection {
        &self.data
    }

    fn pack_into(&self, t: ElemId, mask: &mut [u32]) {
        for &item in self.data.set(t) {
            mask[(item >> 5) as usize] |= 1 << (item & 31);
        }
    }
}

impl Oracle for KCoverPjrt {
    fn n(&self) -> usize {
        self.data.num_sets()
    }

    fn name(&self) -> &'static str {
        "k-cover-pjrt"
    }

    fn new_state<'a>(&'a self, _view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        Box::new(KCoverPjrtState {
            oracle: self,
            covered: vec![0u32; self.words],
            covered_count: 0,
            solution: Vec::new(),
        })
    }

    fn elem_bytes(&self, e: ElemId) -> usize {
        self.data.elem_bytes(e)
    }
}

struct KCoverPjrtState<'a> {
    oracle: &'a KCoverPjrt,
    covered: Vec<u32>,
    covered_count: usize,
    solution: Vec<ElemId>,
}

impl GainState for KCoverPjrtState<'_> {
    fn value(&self) -> f64 {
        self.covered_count as f64
    }

    fn gain(&self, e: ElemId) -> f64 {
        // Single-candidate queries stay host-side: a sparse scan is strictly
        // cheaper than packing a full tile for one row.
        self.oracle
            .data
            .set(e)
            .iter()
            .filter(|&&i| self.covered[(i >> 5) as usize] & (1 << (i & 31)) == 0)
            .count() as f64
    }

    fn gain_batch(&self, es: &[ElemId], out: &mut Vec<f64>) {
        out.clear();
        let eng = &self.oracle.engine;
        let m = eng.manifest();
        let (ct, wt) = (m.c_tile, m.w_tile);
        let words = self.oracle.words;
        for tile in es.chunks(ct) {
            // Pack the candidate tile once; stream w_tile-word slices.
            let mut masks = vec![0u32; ct * words];
            for (r, &e) in tile.iter().enumerate() {
                self.oracle.pack_into(e, &mut masks[r * words..(r + 1) * words]);
            }
            let mut acc = vec![0i64; tile.len()];
            for wchunk in 0..words / wt {
                let mut tile_masks = vec![0u32; ct * wt];
                for r in 0..ct {
                    let src = r * words + wchunk * wt;
                    tile_masks[r * wt..(r + 1) * wt]
                        .copy_from_slice(&masks[src..src + wt]);
                }
                let covered = &self.covered[wchunk * wt..(wchunk + 1) * wt];
                let masks_l = literal_u32(&tile_masks, &[ct, wt]).expect("masks literal");
                let covered_l = literal_u32(covered, &[wt]).expect("covered literal");
                let res = eng
                    .execute("coverage_gains", &[&masks_l, &covered_l])
                    .expect("coverage launch");
                let gains: Vec<i32> = res[0].to_vec().expect("coverage output");
                for (a, &g) in acc.iter_mut().zip(gains.iter().take(tile.len())) {
                    *a += g as i64;
                }
            }
            out.extend(acc.into_iter().map(|g| g as f64));
        }
    }

    fn commit(&mut self, e: ElemId) {
        for &item in self.oracle.data.set(e) {
            let w = &mut self.covered[(item >> 5) as usize];
            let bit = 1u32 << (item & 31);
            self.covered_count += (*w & bit == 0) as usize;
            *w |= bit;
        }
        self.solution.push(e);
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, e: ElemId) -> u64 {
        self.oracle.data.set_size(e) as u64
    }

    fn parallel_scan(&self) -> bool {
        // Launches serialize behind the engine mutex and readback is not
        // thread-safe; splitting would only multiply padded c_tile launches.
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::KCover;

    fn setup() -> Option<(Arc<ItemsetCollection>, Arc<Engine>)> {
        let engine = Engine::load("artifacts").ok()?;
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 150,
                num_items: 400,
                mean_size: 12.0,
                zipf_s: 0.9,
            },
            29,
        );
        Some((Arc::new(data), Arc::new(engine)))
    }

    #[test]
    fn batch_matches_cpu_oracle() {
        let Some((data, eng)) = setup() else { return };
        let cpu = KCover::new(data.clone());
        let pjrt = KCoverPjrt::new(data, eng).unwrap();
        let mut st_cpu = cpu.new_state(None);
        let mut st_pjrt = pjrt.new_state(None);
        for e in [3u32, 60] {
            st_cpu.commit(e);
            st_pjrt.commit(e);
        }
        assert_eq!(st_cpu.value(), st_pjrt.value());
        let es: Vec<u32> = (0..100).collect();
        let mut want = Vec::new();
        let mut got = Vec::new();
        st_cpu.gain_batch(&es, &mut want);
        st_pjrt.gain_batch(&es, &mut got);
        assert_eq!(want, got, "pjrt coverage gains must be bit-exact");
    }

    #[test]
    fn single_gain_is_hostside_and_exact() {
        let Some((data, eng)) = setup() else { return };
        let cpu = KCover::new(data.clone());
        let pjrt = KCoverPjrt::new(data, eng).unwrap();
        let a = cpu.new_state(None);
        let b = pjrt.new_state(None);
        for e in (0..150).step_by(13) {
            assert_eq!(a.gain(e), b.gain(e));
        }
    }

    #[test]
    fn greedy_end_to_end_identical_values() {
        let Some((data, eng)) = setup() else { return };
        let cpu = KCover::new(data.clone());
        let pjrt = KCoverPjrt::new(data, eng).unwrap();
        let c = crate::constraint::Cardinality::new(8);
        let cands: Vec<u32> = (0..150).collect();
        let a = crate::greedy::greedy_lazy(&cpu, &c, &cands, None);
        let b = crate::greedy::greedy_lazy(&pjrt, &c, &cands, None);
        assert_eq!(a.value, b.value, "integer objective must agree exactly");
    }
}
