//! The PJRT runtime: Python is build-time only; this module is how the
//! solve path executes the AOT-compiled Layer-1/Layer-2 artifacts.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, dtypes, tiles).
//! * [`engine`] — `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `compile` → `execute` (the /opt/xla-example/load_hlo pattern), plus
//!   literal helpers.
//! * [`kmedoid_pjrt`] / [`coverage_pjrt`] — drop-in [`crate::objective::Oracle`]
//!   implementations backed by the kernels, interchangeable with the pure
//!   Rust oracles everywhere (greedy, distributed runs, benches).

pub mod coverage_pjrt;
pub mod engine;
pub mod kmedoid_pjrt;
pub mod manifest;

pub use coverage_pjrt::KCoverPjrt;
pub use engine::{literal_f32, literal_u32, Engine};
pub use kmedoid_pjrt::KMedoidPjrt;
pub use manifest::{Entry, Manifest, TensorSpec};

/// Default artifact directory, overridable via `GREEDYML_ARTIFACTS`.
pub fn artifact_dir() -> String {
    std::env::var("GREEDYML_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

#[cfg(test)]
mod tests {
    #[test]
    fn artifact_dir_default() {
        // Don't mutate the environment (tests run in parallel); just check
        // the default path shape.
        if std::env::var("GREEDYML_ARTIFACTS").is_err() {
            assert_eq!(super::artifact_dir(), "artifacts");
        }
    }
}
