//! The PJRT runtime: Python is build-time only; this module is how the
//! solve path executes the AOT-compiled Layer-1/Layer-2 artifacts.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, dtypes, tiles).
//! * [`engine`] — `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `compile` → `execute` (the /opt/xla-example/load_hlo pattern), plus
//!   literal helpers.
//! * [`kmedoid_pjrt`] / [`coverage_pjrt`] — drop-in [`crate::objective::Oracle`]
//!   implementations backed by the kernels, interchangeable with the pure
//!   Rust oracles everywhere (greedy, distributed runs, benches).
//!
//! Everything that touches the `xla` crate is gated behind the off-by-default
//! `pjrt` cargo feature, so offline builds need no XLA toolchain.  Without
//! the feature, [`stub`] provides API-compatible stand-ins whose
//! `Engine::load` always fails — every PJRT-gated call site (CLI `--pjrt`,
//! benches, e2e tests) already treats a failed load as "artifacts not
//! available" and degrades to the pure-Rust oracles or a clean skip.

#[cfg(feature = "pjrt")]
pub mod coverage_pjrt;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod kmedoid_pjrt;
pub mod manifest;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use coverage_pjrt::KCoverPjrt;
#[cfg(feature = "pjrt")]
pub use engine::{literal_f32, literal_u32, Engine};
#[cfg(feature = "pjrt")]
pub use kmedoid_pjrt::KMedoidPjrt;
pub use manifest::{Entry, Manifest, TensorSpec};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Engine, KCoverPjrt, KMedoidPjrt};

/// Default artifact directory, overridable via `GREEDYML_ARTIFACTS`.
pub fn artifact_dir() -> String {
    std::env::var("GREEDYML_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

#[cfg(test)]
mod tests {
    #[test]
    fn artifact_dir_default() {
        // Don't mutate the environment (tests run in parallel); just check
        // the default path shape.
        if std::env::var("GREEDYML_ARTIFACTS").is_err() {
            assert_eq!(super::artifact_dir(), "artifacts");
        }
    }
}
