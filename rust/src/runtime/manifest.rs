//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  `manifest.json` lists every AOT entry point with its
//! input/output shapes and dtypes plus the tile sizes the kernels were
//! compiled for; the engine validates arguments against it before launch.

use crate::util::json::Json;

/// Shape + dtype of one argument or result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimensions, row-major.
    pub shape: Vec<usize>,
    /// Numpy dtype name ("float32", "uint32", "int32", …).
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> crate::Result<Self> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest entry missing shape"))?
            .iter()
            .map(|d| d.as_u64().map(|v| v as usize))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow::anyhow!("bad shape dims"))?;
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .ok_or_else(|| anyhow::anyhow!("manifest entry missing dtype"))?
            .to_string();
        Ok(Self { shape, dtype })
    }
}

/// One AOT entry point.
#[derive(Clone, Debug)]
pub struct Entry {
    /// Entry-point name (e.g. `kmedoid_gains_d128`).
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Input specs in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output specs (the HLO root is a tuple of these).
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Rows per k-medoid grid step (views are padded to multiples of this).
    pub n_tile: usize,
    /// Candidate-tile width shared by the gain kernels.
    pub c_tile: usize,
    /// uint32 words per coverage grid step.
    pub w_tile: usize,
    /// All entry points.
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> crate::Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        anyhow::ensure!(
            j.get("format").and_then(|f| f.as_str()) == Some("hlo-text"),
            "manifest format must be hlo-text (got {:?})",
            j.get("format")
        );
        let grab = |k: &str| -> crate::Result<usize> {
            j.get(k)
                .and_then(|v| v.as_u64())
                .map(|v| v as usize)
                .ok_or_else(|| anyhow::anyhow!("manifest missing {k}"))
        };
        let entries = j
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing entries"))?
            .iter()
            .map(|e| -> crate::Result<Entry> {
                let name = e
                    .get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| anyhow::anyhow!("entry missing name"))?
                    .to_string();
                let file = e
                    .get("file")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| anyhow::anyhow!("entry missing file"))?
                    .to_string();
                let specs = |k: &str| -> crate::Result<Vec<TensorSpec>> {
                    e.get(k)
                        .and_then(|v| v.as_arr())
                        .ok_or_else(|| anyhow::anyhow!("entry missing {k}"))?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect()
                };
                Ok(Entry { name, file, inputs: specs("inputs")?, outputs: specs("outputs")? })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Self {
            n_tile: grab("n_tile")?,
            c_tile: grab("c_tile")?,
            w_tile: grab("w_tile")?,
            entries,
        })
    }

    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: &str) -> crate::Result<Self> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path}: {e} (run `make artifacts`)"))?;
        Self::parse(&text)
    }

    /// Look up an entry by name.
    pub fn entry(&self, name: &str) -> crate::Result<&Entry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow::anyhow!("no artifact entry named '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": "hlo-text", "n_tile": 256, "c_tile": 64, "w_tile": 1024,
        "entries": [
            {"name": "kmedoid_gains_d8", "file": "kmedoid_gains_d8.hlo.txt",
             "inputs": [{"shape": [256, 8], "dtype": "float32"},
                         {"shape": [256], "dtype": "float32"},
                         {"shape": [64, 8], "dtype": "float32"}],
             "outputs": [{"shape": [64], "dtype": "float32"}]}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!((m.n_tile, m.c_tile, m.w_tile), (256, 64, 1024));
        let e = m.entry("kmedoid_gains_d8").unwrap();
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].shape, vec![256, 8]);
        assert_eq!(e.inputs[0].elems(), 2048);
        assert_eq!(e.outputs[0].dtype, "float32");
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // Integration-level check against the actual artifacts directory;
        // skipped silently when `make artifacts` has not run.
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.entry("coverage_gains").is_ok());
            assert!(m.entries.iter().all(|e| e.file.ends_with(".hlo.txt")));
        }
    }
}
