//! `greedyml` — the launcher.
//!
//! Subcommands:
//!   run       — run an experiment config:   greedyml run --config configs/fig4.toml [--set k=v]…
//!   sweep     — run an experiment grid (k values × algorithms)
//!   submit    — drive a [jobs] batch through the warm-session job queue
//!               (add --gateway <addr> to ship it to a gateway daemon instead)
//!   serve     — host tcp-backend worker sessions: greedyml serve --bind 0.0.0.0:7401
//!   gateway   — network front door: greedyml gateway --bind 0.0.0.0:7500
//!               accepts concurrent `submit --gateway` clients and schedules
//!               their jobs onto one shared warm-session pool
//!   tree      — inspect an accumulation tree: greedyml tree --machines 8 --branching 2
//!   datasets  — print Table-2-style summaries of the synthetic presets
//!   artifacts — validate the AOT artifact bundle and report entry points
//!   model     — print the BSP cost model (Table 1) for given parameters

use greedyml::cli::Args;
use greedyml::coordinator::gateway::FromGateway;
use greedyml::coordinator::{
    render_table, Experiment, GatewayClient, JobBatch, JobQueue, JobSpec, Submission,
};
use greedyml::metrics::write_reports;
use greedyml::runtime::Engine;
use greedyml::tree::AccumulationTree;
use greedyml::util::config::Config;
use greedyml::util::json::Json;
use std::sync::Arc;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn real_main() -> greedyml::Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("submit") => cmd_submit(&args),
        Some("serve") => cmd_serve(&args),
        Some("gateway") => cmd_gateway(&args),
        Some("tree") => cmd_tree(&args),
        Some("datasets") => cmd_datasets(),
        Some("artifacts") => cmd_artifacts(&args),
        Some("model") => cmd_model(&args),
        // Hidden: the process-backend worker protocol endpoint.  Spawned by
        // ProcessBackend, one per simulated machine; speaks length-prefixed
        // JSON frames on stdin/stdout (rust/src/dist/wire.rs).
        Some("worker") => greedyml::dist::proc::run_worker(),
        Some(other) => anyhow::bail!("unknown command '{other}'\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str =
    "usage: greedyml <run|sweep|submit|serve|gateway|tree|datasets|artifacts|model> [flags]
  run       --config <file> [--set key=value]… [--json <out.json>] [--pjrt]
            [--backend thread|process|tcp] [--hosts h1:port,h2:port] [--ship spec|partition]
            [--on-fault fail|retry|degrade] [--wire json|binary] [--coreset auto|on|off]
  sweep     --config <file> (with a [sweep] section) [--set key=value]… [--json <out.json>]
            [--csv <dir>] [--backend thread|process|tcp] [--hosts h1:port,h2:port]
            [--ship spec|partition] [--on-fault fail|retry|degrade] [--wire json|binary]
            [--coreset auto|on|off]
  submit    --config <file> (with a [jobs] section) [--set key=value]… [--json]
            [--gateway <addr>] [--backend thread|process|tcp] [--hosts h1:port,h2:port]
            [--ship spec|partition] [--on-fault fail|retry|degrade] [--wire json|binary]
            [--coreset auto|on|off] [--deltas <file>] (re-solves the batch after each delta)
  serve     --bind <addr>   (tcp-backend worker daemon; --bind 127.0.0.1:0 picks a free port)
  gateway   --bind <addr> [--workers <n>] [--mem-budget <bytes>] [--cache-entries <n>]
            (job-service daemon: schedules concurrent submit clients onto warm fleets)
  tree      --machines <m> --branching <b>
  datasets  (no flags)
  artifacts [--dir <artifacts/>]
  model     --n <n> --k <k> --machines <m> --levels <L> [--delta <d>]";

fn cmd_run(args: &Args) -> greedyml::Result<()> {
    args.check_known(&[
        "config", "set", "json", "pjrt", "trace", "backend", "hosts", "ship", "on-fault", "wire",
        "coreset",
    ])?;
    let mut cfg = Config::load(args.require("config")?)?;
    for kv in args.get_all("set") {
        cfg.set_kv(kv)?;
    }
    if let Some(backend) = args.get("backend") {
        cfg.set("run.backend", backend);
    }
    if let Some(hosts) = args.get("hosts") {
        cfg.set("run.hosts", hosts);
    }
    if let Some(ship) = args.get("ship") {
        cfg.set("run.ship", ship);
    }
    if let Some(on_fault) = args.get("on-fault") {
        cfg.set("run.on_fault", on_fault);
    }
    if let Some(wire) = args.get("wire") {
        cfg.set("run.wire", wire);
    }
    if let Some(coreset) = args.get("coreset") {
        cfg.set("run.coreset", coreset);
    }
    let engine = if args.has("pjrt") || cfg.str_or("objective.backend", "cpu") == "pjrt" {
        if args.has("pjrt") {
            cfg.set("objective.backend", "pjrt");
        }
        Some(Arc::new(Engine::load(&greedyml::runtime::artifact_dir())?))
    } else {
        None
    };
    let exp = Experiment::from_config(&cfg, engine)?;
    println!(
        "experiment '{}' — {} on {} (n={}, k={})",
        exp.name,
        exp.problem.objective,
        exp.problem.summary.name,
        greedyml::util::fmt_count(exp.problem.summary.n as u64),
        exp.k
    );
    let (reports, failures) = exp.run();
    print!("{}", render_table(&reports, &failures));
    if let Some(path) = args.get("json") {
        write_reports(path, &reports)?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("trace") {
        // Re-run the first distributed variant with tracing and export a
        // Chrome-trace timeline (open in chrome://tracing or Perfetto).
        if let Some(spec) = exp.algos.iter().find_map(|a| match *a {
            greedyml::coordinator::AlgoSpec::GreedyMl { m, b } => Some((m, b, false)),
            greedyml::coordinator::AlgoSpec::RandGreedi { m } => Some((m, m, true)),
            _ => None,
        }) {
            let (m, b, all) = spec;
            let cfg = exp.dist_config(AccumulationTree::new(m, b), all);
            let out = greedyml::algo::run_dist(
                exp.problem.oracle.as_ref(),
                exp.constraint.as_ref(),
                &cfg,
            )
            .map_err(|e| anyhow::anyhow!("{e}"))?;
            out.trace.write(path)?;
            println!(
                "wrote {path} ({} spans, makespan {:.4}s) — open in chrome://tracing",
                out.trace.steps().len(),
                out.trace.makespan()
            );
        } else {
            println!("--trace: no distributed variant in run.algos to trace");
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> greedyml::Result<()> {
    args.check_known(&[
        "config", "set", "json", "pjrt", "csv", "backend", "hosts", "ship", "on-fault", "wire",
        "coreset",
    ])?;
    let mut cfg = Config::load(args.require("config")?)?;
    for kv in args.get_all("set") {
        cfg.set_kv(kv)?;
    }
    if let Some(backend) = args.get("backend") {
        cfg.set("sweep.backend", backend);
    }
    if let Some(hosts) = args.get("hosts") {
        cfg.set("sweep.hosts", hosts);
    }
    if let Some(ship) = args.get("ship") {
        cfg.set("sweep.ship", ship);
    }
    if let Some(on_fault) = args.get("on-fault") {
        cfg.set("sweep.on_fault", on_fault);
    }
    if let Some(wire) = args.get("wire") {
        cfg.set("sweep.wire", wire);
    }
    if let Some(coreset) = args.get("coreset") {
        cfg.set("sweep.coreset", coreset);
    }
    let engine = if args.has("pjrt") || cfg.str_or("objective.backend", "cpu") == "pjrt" {
        Some(Arc::new(Engine::load(&greedyml::runtime::artifact_dir())?))
    } else {
        None
    };
    let problem = greedyml::coordinator::build_problem(&cfg, engine)?;
    let sweep = greedyml::coordinator::Sweep::from_config(&cfg)?;
    println!(
        "sweep on {} ({} ks × {} algos × {} reps)",
        problem.summary.name,
        sweep.ks.len(),
        sweep.algos.len(),
        sweep.reps
    );
    let (reports, failures) = sweep.run(&problem);
    print!("{}", render_table(&reports, &failures));
    if let Some(path) = args.get("json") {
        write_reports(path, &reports)?;
        println!("wrote {path}");
    }
    if let Some(dir) = args.get("csv") {
        for path in greedyml::metrics::write_sweep_csvs(dir, &reports)? {
            println!("wrote {path}");
        }
    }
    Ok(())
}

fn cmd_submit(args: &Args) -> greedyml::Result<()> {
    args.check_known(&[
        "config", "set", "backend", "hosts", "ship", "on-fault", "gateway", "json", "wire",
        "coreset", "deltas",
    ])?;
    let mut cfg = Config::load(args.require("config")?)?;
    for kv in args.get_all("set") {
        cfg.set_kv(kv)?;
    }
    if let Some(backend) = args.get("backend") {
        cfg.set("jobs.backend", backend);
    }
    if let Some(hosts) = args.get("hosts") {
        cfg.set("jobs.hosts", hosts);
    }
    if let Some(ship) = args.get("ship") {
        cfg.set("jobs.ship", ship);
    }
    if let Some(on_fault) = args.get("on-fault") {
        cfg.set("jobs.on_fault", on_fault);
    }
    if let Some(wire) = args.get("wire") {
        cfg.set("jobs.wire", wire);
    }
    if let Some(coreset) = args.get("coreset") {
        cfg.set("jobs.coreset", coreset);
    }
    // A deltas file turns the batch into a live-dataset replay: every
    // (seed, k) cell runs at epoch 0, then again after each delta, with
    // resident fleets advanced in place between passes.
    let deltas = match args.get("deltas") {
        None => Vec::new(),
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--deltas {path}: {e}"))?;
            greedyml::stream::parse_deltas(&text)
                .map_err(|e| anyhow::anyhow!("--deltas {path}: {e}"))?
        }
    };
    let batch = JobBatch::from_config(&cfg)?;
    let json = args.has("json");
    match args.get("gateway") {
        Some(addr) => submit_gateway(&cfg, &batch, &deltas, addr, json),
        None => submit_local(&cfg, &batch, &deltas, json),
    }
}

/// One `submit` table row as a JSON record (`--json` mode).  `value` is
/// null for jobs that produced none (rejected/failed); `faults` is the
/// run's fault summary (empty for a clean run); `detail` carries the
/// rejection reason or error text.  `epoch` is the dataset epoch the job
/// ran at — 0 unless `--deltas` advanced the corpus.
fn job_row(
    id: u64,
    epoch: u64,
    k: usize,
    seed: u64,
    status: &str,
    value: Option<f64>,
    faults: &str,
    detail: &str,
) -> Json {
    Json::obj([
        ("id", Json::from(id)),
        ("epoch", Json::from(epoch)),
        ("k", Json::from(k)),
        ("seed", Json::from(seed)),
        ("status", Json::from(status)),
        ("value", value.map_or(Json::Null, Json::from)),
        ("faults", Json::from(faults)),
        ("detail", Json::from(detail)),
    ])
}

/// The final queue counters of a `submit` run (`--json` mode).  Same six
/// keys whether the batch ran in-process or through a gateway daemon.
fn queue_counters(
    submitted: u64,
    cached: u64,
    rejected: u64,
    failed: u64,
    warm_jobs: u64,
    init_bytes_total: u64,
) -> Json {
    Json::obj([
        ("submitted", Json::from(submitted)),
        ("cached", Json::from(cached)),
        ("rejected", Json::from(rejected)),
        ("failed", Json::from(failed)),
        ("warm_jobs", Json::from(warm_jobs)),
        ("init_bytes_total", Json::from(init_bytes_total)),
    ])
}

/// Drive the batch through an in-process [`JobQueue`] — the historical
/// `submit` path, still the right tool when the fleet belongs to this
/// process alone.  With `deltas`, the batch re-runs after each delta as
/// an incremental re-solve over the advanced-in-place fleets.
fn submit_local(
    cfg: &Config,
    batch: &JobBatch,
    deltas: &[greedyml::objective::PartitionDelta],
    json: bool,
) -> greedyml::Result<()> {
    let problem = greedyml::coordinator::build_problem(cfg, None)?;
    let jobs = batch.jobs();
    let mut live = match deltas.is_empty() {
        true => None,
        false => Some(
            greedyml::stream::LiveProblem::new(problem.oracle.as_ref())
                .map_err(|e| anyhow::anyhow!("--deltas: {e}"))?,
        ),
    };
    if !json {
        println!(
            "submitting {} jobs against {} (n={}, fleet {}×b{}{})",
            jobs.len(),
            problem.summary.name,
            greedyml::util::fmt_count(problem.summary.n as u64),
            batch.machines,
            batch.branching,
            match deltas.len() {
                0 => String::new(),
                d => format!(", {} epochs", d + 1),
            }
        );
        println!("{:>6} {:>6} {:>6}  {:<8} {}", "epoch", "k", "seed", "status", "value");
    }
    let queue = JobQueue::with_cache_entries(batch.mem_budget, batch.cache_entries);
    let mut rows = Vec::new();
    for pass in 0..=deltas.len() {
        if pass > 0 {
            let l = live.as_mut().expect("deltas imply a live problem");
            l.apply(&deltas[pass - 1])
                .map_err(|e| anyhow::anyhow!("--deltas entry {}: {e}", pass - 1))?;
        }
        let ep = live.as_ref().map_or(0, |l| l.epoch());
        for (j, &(seed, k)) in jobs.iter().enumerate() {
            let id = (pass * jobs.len() + j) as u64;
            let mut dist = batch.dist_config(cfg, k, seed);
            dist.epoch = ep;
            // One job failing must not strand the rest of the batch — or
            // eat the final accounting.  Report the row, keep draining.
            let (status, value, faults, detail) =
                match queue.submit_live(&problem, &dist, live.as_ref()) {
                    Ok(Submission::Rejected { reason }) => {
                        if !json {
                            println!("{ep:>6} {k:>6} {seed:>6}  {:<8} — {reason}", "rejected");
                        }
                        ("rejected", None, String::new(), reason)
                    }
                    Ok(sub) => {
                        let value = sub.value();
                        if !json {
                            println!(
                                "{ep:>6} {k:>6} {seed:>6}  {:<8} {:.6}",
                                sub.status(),
                                value.unwrap()
                            );
                        }
                        let faults = match &sub {
                            Submission::Ran { faults, .. } => faults.clone(),
                            _ => String::new(),
                        };
                        if !json && !faults.is_empty() {
                            println!("{:>6} {:>6} {:>6}  faults: {faults}", "", "", "");
                        }
                        (sub.status(), value, faults, String::new())
                    }
                    Err(e) => {
                        if !json {
                            println!("{ep:>6} {k:>6} {seed:>6}  {:<8} — {e}", "failed");
                        }
                        ("failed", None, String::new(), format!("{e:#}"))
                    }
                };
            rows.push(job_row(id, ep, k, seed, status, value, &faults, &detail));
        }
    }
    let pool = queue.pool();
    if json {
        let counters = queue_counters(
            queue.submitted(),
            queue.cache_hits(),
            queue.rejected(),
            queue.failed(),
            pool.warm_jobs(),
            pool.init_bytes_total(),
        );
        let doc = Json::obj([("jobs", Json::Arr(rows)), ("queue", counters)]);
        println!("{}", doc.to_pretty());
    } else {
        println!(
            "queue: {} submitted, {} cached, {} rejected, {} failed; fleet: {} sessions \
             established, {} of {} pooled jobs warm, {} retried, {} init bytes shipped",
            queue.submitted(),
            queue.cache_hits(),
            queue.rejected(),
            queue.failed(),
            pool.sessions_established(),
            pool.warm_jobs(),
            pool.jobs_run(),
            pool.retried_jobs(),
            pool.init_bytes_total()
        );
    }
    // A batch with refused or failed work is not a success: exit nonzero
    // so CI and scripts notice, after the full accounting has printed.
    if queue.rejected() > 0 || queue.failed() > 0 {
        anyhow::bail!(
            "{} of {} jobs did not complete ({} rejected by admission, {} failed)",
            queue.rejected() + queue.failed(),
            queue.submitted(),
            queue.rejected(),
            queue.failed()
        );
    }
    Ok(())
}

/// Ship the batch to a `greedyml gateway` daemon and stream results back
/// as they complete — completion order, not submission order, because the
/// daemon runs admitted jobs concurrently.  The problem is built daemon-side
/// from the shipped spec, so this process never touches the dataset.  With
/// `deltas`, each pass is fully drained before the next `delta` frame goes
/// out — a delta overtaking an in-flight job would fail it as stale.
fn submit_gateway(
    cfg: &Config,
    batch: &JobBatch,
    deltas: &[greedyml::objective::PartitionDelta],
    addr: &str,
    json: bool,
) -> greedyml::Result<()> {
    let jobs = batch.jobs();
    if !json {
        println!(
            "submitting {} jobs to gateway {addr} (fleet {}×b{}{})",
            jobs.len(),
            batch.machines,
            batch.branching,
            match deltas.len() {
                0 => String::new(),
                d => format!(", {} epochs", d + 1),
            }
        );
        println!("{:>6} {:>6} {:>6}  {:<8} {}", "epoch", "k", "seed", "status", "value");
    }
    let mut client = GatewayClient::connect(addr)?;
    // The daemon keys its resident corpus by dataset fingerprint, which
    // ignores the per-job `problem.k` override — the bare spec addresses
    // the corpus every job in this batch runs against.
    let corpus_spec = greedyml::coordinator::problem_spec(cfg);
    let mut rows: Vec<Option<Json>> = vec![None; jobs.len() * (deltas.len() + 1)];
    let (mut rejected, mut failed) = (0u64, 0u64);
    let mut epoch_now = 0u64;
    for pass in 0..=deltas.len() {
        if pass > 0 {
            client.send_delta(&corpus_spec, &deltas[pass - 1])?;
            // The daemon's epoch is authoritative: another client may
            // have advanced the corpus since our last pass.
            epoch_now = loop {
                match client.next()? {
                    FromGateway::DeltaOk { epoch } => break epoch,
                    FromGateway::Accepted { .. } => continue,
                    other => anyhow::bail!("expected delta_ok from the gateway, got {other:?}"),
                }
            };
        }
        let base = pass * jobs.len();
        for (j, &(seed, k)) in jobs.iter().enumerate() {
            let mut dist = batch.dist_config(cfg, k, seed);
            dist.epoch = epoch_now;
            client.submit(&JobSpec::from_dist((base + j) as u64, &dist)?)?;
        }
        let mut pending = jobs.len();
        while pending > 0 {
            let (id, status, value, faults, detail) = match client.next()? {
                // Admission acks are bookkeeping, not terminal outcomes.
                FromGateway::Accepted { .. } => continue,
                FromGateway::Result { id, value, warm, cached, faults, .. } => {
                    let status = match (cached, warm) {
                        (true, _) => "cached",
                        (false, true) => "warm",
                        (false, false) => "cold",
                    };
                    (id, status, Some(value), faults, String::new())
                }
                FromGateway::Rejected { id, reason } => {
                    rejected += 1;
                    (id, "rejected", None, String::new(), reason)
                }
                FromGateway::Failed { id, error } => {
                    failed += 1;
                    (id, "failed", None, String::new(), error)
                }
                other => anyhow::bail!("unexpected gateway frame {other:?}"),
            };
            let j = (id as usize)
                .checked_sub(base)
                .filter(|j| *j < jobs.len())
                .ok_or_else(|| anyhow::anyhow!("gateway answered job id {id} outside this pass"))?;
            let (seed, k) = jobs[j];
            if !json {
                match value {
                    Some(v) => println!("{epoch_now:>6} {k:>6} {seed:>6}  {status:<8} {v:.6}"),
                    None => println!("{epoch_now:>6} {k:>6} {seed:>6}  {status:<8} — {detail}"),
                }
                if !faults.is_empty() {
                    println!("{:>6} {:>6} {:>6}  faults: {faults}", "", "", "");
                }
            }
            if rows[id as usize].is_none() {
                pending -= 1;
            }
            rows[id as usize] =
                Some(job_row(id, epoch_now, k, seed, status, value, &faults, &detail));
        }
    }
    // Daemon-wide tallies: they cover every client of this gateway, not
    // just the batch we shipped.
    client.request_stats()?;
    let snap = loop {
        match client.next()? {
            FromGateway::Stats(s) => break s,
            FromGateway::Accepted { .. } => continue,
            other => anyhow::bail!("expected stats from the gateway, got {other:?}"),
        }
    };
    let total = rows.len();
    if json {
        let counters = queue_counters(
            snap.submitted,
            snap.cached,
            snap.rejected,
            snap.failed,
            snap.warm,
            snap.init_bytes,
        );
        let jobs_json: Vec<Json> = rows.into_iter().flatten().collect();
        let doc = Json::obj([("jobs", Json::Arr(jobs_json)), ("queue", counters)]);
        println!("{}", doc.to_pretty());
    } else {
        println!(
            "gateway: {} submitted, {} cached, {} rejected, {} failed; fleet: {} sessions \
             established, {} warm jobs, {} init bytes shipped",
            snap.submitted,
            snap.cached,
            snap.rejected,
            snap.failed,
            snap.sessions,
            snap.warm,
            snap.init_bytes
        );
    }
    // Same contract as the local path: refused or failed work exits
    // nonzero after the accounting has printed.
    if rejected > 0 || failed > 0 {
        anyhow::bail!(
            "{} of {} jobs did not complete ({} rejected by admission, {} failed)",
            rejected + failed,
            total,
            rejected,
            failed
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> greedyml::Result<()> {
    args.check_known(&["bind"])?;
    // 127.0.0.1:0 binds an ephemeral port and prints it — handy for tests
    // and single-host smoke runs; production daemons pass an explicit
    // `--bind 0.0.0.0:<port>`.
    let bind = args.get("bind").unwrap_or("127.0.0.1:0");
    greedyml::dist::tcp::run_serve(bind)
}

fn cmd_gateway(args: &Args) -> greedyml::Result<()> {
    args.check_known(&["bind", "workers", "mem-budget", "cache-entries"])?;
    // Same ephemeral-port convention as `serve`: --bind 127.0.0.1:0 picks a
    // free port and the banner prints the resolved address.
    let bind = args.get("bind").unwrap_or("127.0.0.1:0").to_string();
    let workers = args.u64_or("workers", 4)? as usize;
    // No --mem-budget means unlimited admission, mirroring `jobs.mem_budget`.
    let mem_budget = match args.get("mem-budget") {
        None | Some("none") => None,
        Some(_) => Some(args.u64_or("mem-budget", 0)?),
    };
    let default_cache = greedyml::coordinator::jobs::DEFAULT_CACHE_ENTRIES as u64;
    let cache_entries = args.u64_or("cache-entries", default_cache)? as usize;
    let gc = greedyml::coordinator::GatewayConfig { bind, workers, mem_budget, cache_entries };
    greedyml::coordinator::run_gateway(&gc)
}

fn cmd_tree(args: &Args) -> greedyml::Result<()> {
    args.check_known(&["machines", "branching", "show"])?;
    let m = args.u64_or("machines", 8)? as u32;
    let b = args.u64_or("branching", 2)? as u32;
    let t = AccumulationTree::new(m, b);
    print!("{}", t.render());
    println!("max fan-in: {}", t.max_fan_in());
    Ok(())
}

fn cmd_datasets() -> greedyml::Result<()> {
    use greedyml::data::{gen, DatasetSummary};
    println!("{}", DatasetSummary::header());
    let road = gen::road(gen::RoadParams::usa_like(1 << 14), 1);
    println!("{}", DatasetSummary::of_graph("road-like", &road).row());
    let belg = gen::road(gen::RoadParams::belgium_like(1 << 13), 1);
    println!("{}", DatasetSummary::of_graph("belgium-like", &belg).row());
    let rmat = gen::rmat(gen::RmatParams::friendster_like(13), 1);
    println!("{}", DatasetSummary::of_graph("friendster-like", &rmat).row());
    let kos = gen::transactions(gen::TransactionParams::kosarak_like(4096), 1);
    println!("{}", DatasetSummary::of_itemsets("kosarak-like", &kos).row());
    let ret = gen::transactions(gen::TransactionParams::retail_like(4096), 1);
    println!("{}", DatasetSummary::of_itemsets("retail-like", &ret).row());
    let web = gen::transactions(
        gen::TransactionParams { num_sets: 512, num_items: 2048, mean_size: 177.2, zipf_s: 1.0 },
        1,
    );
    println!("{}", DatasetSummary::of_itemsets("webdocs-like", &web).row());
    let (vs, _) = gen::gaussian_mixture(gen::GaussianParams::tiny_imagenet_like(2048, 128), 1);
    println!("{}", DatasetSummary::of_vectors("tiny-imagenet-like", &vs).row());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> greedyml::Result<()> {
    args.check_known(&["dir"])?;
    let dir = args.get("dir").map(str::to_string).unwrap_or_else(greedyml::runtime::artifact_dir);
    let engine = Engine::load(&dir)?;
    let m = engine.manifest();
    println!(
        "artifacts ok: dir={dir} platform={} n_tile={} c_tile={} w_tile={}",
        engine.platform(),
        m.n_tile,
        m.c_tile,
        m.w_tile
    );
    for e in &m.entries {
        let ins: Vec<String> =
            e.inputs.iter().map(|s| format!("{:?}:{}", s.shape, s.dtype)).collect();
        println!("  {:<24} {} -> {} outputs", e.name, ins.join(", "), e.outputs.len());
    }
    Ok(())
}

fn cmd_model(args: &Args) -> greedyml::Result<()> {
    args.check_known(&["n", "k", "machines", "levels", "delta"])?;
    let p = greedyml::bsp::BspParams {
        n: args.u64_or("n", 1 << 20)?,
        k: args.u64_or("k", 1000)?,
        m: args.u64_or("machines", 32)?,
        levels: args.u64_or("levels", 2)?,
        delta: args.get("delta").map(|d| d.parse()).transpose()?.unwrap_or(8.0),
    };
    println!(
        "BSP model (Table 1) for n={} k={} m={} L={} delta={}",
        p.n, p.k, p.m, p.levels, p.delta
    );
    println!("  fan-in ceil(m^(1/L))      : {}", p.fan_in());
    println!("  Greedy total calls        : {}", greedyml::util::fmt_count(p.greedy_calls()));
    println!("  RandGreeDI calls/machine  : {}", greedyml::util::fmt_count(p.randgreedi_calls()));
    println!("  GreedyML calls/machine    : {}", greedyml::util::fmt_count(p.greedyml_calls()));
    println!(
        "  interior elems RG vs GML  : {} vs {}",
        greedyml::util::fmt_count(p.interior_elems_randgreedi()),
        greedyml::util::fmt_count(p.interior_elems_greedyml())
    );
    println!(
        "  comm cost RG vs GML       : {:.3e} vs {:.3e}",
        p.comm_randgreedi(),
        p.comm_greedyml()
    );
    println!(
        "  k-medoid comp RG vs GML   : {:.3e} vs {:.3e}",
        p.kmedoid_comp_randgreedi(),
        p.kmedoid_comp_greedyml()
    );
    Ok(())
}
