//! `greedyml` — the launcher.
//!
//! Subcommands:
//!   run       — run an experiment config:   greedyml run --config configs/fig4.toml [--set k=v]…
//!   sweep     — run an experiment grid (k values × algorithms)
//!   submit    — drive a [jobs] batch through the warm-session job queue
//!   serve     — host tcp-backend worker sessions: greedyml serve --bind 0.0.0.0:7401
//!   tree      — inspect an accumulation tree: greedyml tree --machines 8 --branching 2
//!   datasets  — print Table-2-style summaries of the synthetic presets
//!   artifacts — validate the AOT artifact bundle and report entry points
//!   model     — print the BSP cost model (Table 1) for given parameters

use greedyml::cli::Args;
use greedyml::coordinator::{render_table, Experiment};
use greedyml::metrics::write_reports;
use greedyml::runtime::Engine;
use greedyml::tree::AccumulationTree;
use greedyml::util::config::Config;
use std::sync::Arc;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn real_main() -> greedyml::Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("submit") => cmd_submit(&args),
        Some("serve") => cmd_serve(&args),
        Some("tree") => cmd_tree(&args),
        Some("datasets") => cmd_datasets(),
        Some("artifacts") => cmd_artifacts(&args),
        Some("model") => cmd_model(&args),
        // Hidden: the process-backend worker protocol endpoint.  Spawned by
        // ProcessBackend, one per simulated machine; speaks length-prefixed
        // JSON frames on stdin/stdout (rust/src/dist/wire.rs).
        Some("worker") => greedyml::dist::proc::run_worker(),
        Some(other) => anyhow::bail!("unknown command '{other}'\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage: greedyml <run|sweep|submit|serve|tree|datasets|artifacts|model> [flags]
  run       --config <file> [--set key=value]… [--json <out.json>] [--pjrt]
            [--backend thread|process|tcp] [--hosts h1:port,h2:port] [--ship spec|partition]
            [--on-fault fail|retry|degrade]
  sweep     --config <file> (with a [sweep] section) [--set key=value]… [--json <out.json>]
            [--csv <dir>] [--backend thread|process|tcp] [--hosts h1:port,h2:port]
            [--ship spec|partition] [--on-fault fail|retry|degrade]
  submit    --config <file> (with a [jobs] section) [--set key=value]…
            [--backend thread|process|tcp] [--hosts h1:port,h2:port] [--ship spec|partition]
            [--on-fault fail|retry|degrade]
  serve     --bind <addr>   (tcp-backend worker daemon; --bind 127.0.0.1:0 picks a free port)
  tree      --machines <m> --branching <b>
  datasets  (no flags)
  artifacts [--dir <artifacts/>]
  model     --n <n> --k <k> --machines <m> --levels <L> [--delta <d>]";

fn cmd_run(args: &Args) -> greedyml::Result<()> {
    args.check_known(&[
        "config", "set", "json", "pjrt", "trace", "backend", "hosts", "ship", "on-fault",
    ])?;
    let mut cfg = Config::load(args.require("config")?)?;
    for kv in args.get_all("set") {
        cfg.set_kv(kv)?;
    }
    if let Some(backend) = args.get("backend") {
        cfg.set("run.backend", backend);
    }
    if let Some(hosts) = args.get("hosts") {
        cfg.set("run.hosts", hosts);
    }
    if let Some(ship) = args.get("ship") {
        cfg.set("run.ship", ship);
    }
    if let Some(on_fault) = args.get("on-fault") {
        cfg.set("run.on_fault", on_fault);
    }
    let engine = if args.has("pjrt") || cfg.str_or("objective.backend", "cpu") == "pjrt" {
        if args.has("pjrt") {
            cfg.set("objective.backend", "pjrt");
        }
        Some(Arc::new(Engine::load(&greedyml::runtime::artifact_dir())?))
    } else {
        None
    };
    let exp = Experiment::from_config(&cfg, engine)?;
    println!(
        "experiment '{}' — {} on {} (n={}, k={})",
        exp.name,
        exp.problem.objective,
        exp.problem.summary.name,
        greedyml::util::fmt_count(exp.problem.summary.n as u64),
        exp.k
    );
    let (reports, failures) = exp.run();
    print!("{}", render_table(&reports, &failures));
    if let Some(path) = args.get("json") {
        write_reports(path, &reports)?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("trace") {
        // Re-run the first distributed variant with tracing and export a
        // Chrome-trace timeline (open in chrome://tracing or Perfetto).
        if let Some(spec) = exp.algos.iter().find_map(|a| match *a {
            greedyml::coordinator::AlgoSpec::GreedyMl { m, b } => Some((m, b, false)),
            greedyml::coordinator::AlgoSpec::RandGreedi { m } => Some((m, m, true)),
            _ => None,
        }) {
            let (m, b, all) = spec;
            let cfg = exp.dist_config(AccumulationTree::new(m, b), all);
            let out = greedyml::algo::run_dist(
                exp.problem.oracle.as_ref(),
                exp.constraint.as_ref(),
                &cfg,
            )
            .map_err(|e| anyhow::anyhow!("{e}"))?;
            out.trace.write(path)?;
            println!(
                "wrote {path} ({} spans, makespan {:.4}s) — open in chrome://tracing",
                out.trace.steps().len(),
                out.trace.makespan()
            );
        } else {
            println!("--trace: no distributed variant in run.algos to trace");
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> greedyml::Result<()> {
    args.check_known(&[
        "config", "set", "json", "pjrt", "csv", "backend", "hosts", "ship", "on-fault",
    ])?;
    let mut cfg = Config::load(args.require("config")?)?;
    for kv in args.get_all("set") {
        cfg.set_kv(kv)?;
    }
    if let Some(backend) = args.get("backend") {
        cfg.set("sweep.backend", backend);
    }
    if let Some(hosts) = args.get("hosts") {
        cfg.set("sweep.hosts", hosts);
    }
    if let Some(ship) = args.get("ship") {
        cfg.set("sweep.ship", ship);
    }
    if let Some(on_fault) = args.get("on-fault") {
        cfg.set("sweep.on_fault", on_fault);
    }
    let engine = if args.has("pjrt") || cfg.str_or("objective.backend", "cpu") == "pjrt" {
        Some(Arc::new(Engine::load(&greedyml::runtime::artifact_dir())?))
    } else {
        None
    };
    let problem = greedyml::coordinator::build_problem(&cfg, engine)?;
    let sweep = greedyml::coordinator::Sweep::from_config(&cfg)?;
    println!(
        "sweep on {} ({} ks × {} algos × {} reps)",
        problem.summary.name,
        sweep.ks.len(),
        sweep.algos.len(),
        sweep.reps
    );
    let (reports, failures) = sweep.run(&problem);
    print!("{}", render_table(&reports, &failures));
    if let Some(path) = args.get("json") {
        write_reports(path, &reports)?;
        println!("wrote {path}");
    }
    if let Some(dir) = args.get("csv") {
        for path in greedyml::metrics::write_sweep_csvs(dir, &reports)? {
            println!("wrote {path}");
        }
    }
    Ok(())
}

fn cmd_submit(args: &Args) -> greedyml::Result<()> {
    args.check_known(&["config", "set", "backend", "hosts", "ship", "on-fault"])?;
    let mut cfg = Config::load(args.require("config")?)?;
    for kv in args.get_all("set") {
        cfg.set_kv(kv)?;
    }
    if let Some(backend) = args.get("backend") {
        cfg.set("jobs.backend", backend);
    }
    if let Some(hosts) = args.get("hosts") {
        cfg.set("jobs.hosts", hosts);
    }
    if let Some(ship) = args.get("ship") {
        cfg.set("jobs.ship", ship);
    }
    if let Some(on_fault) = args.get("on-fault") {
        cfg.set("jobs.on_fault", on_fault);
    }
    let problem = greedyml::coordinator::build_problem(&cfg, None)?;
    let batch = greedyml::coordinator::JobBatch::from_config(&cfg)?;
    let jobs = batch.jobs();
    println!(
        "submitting {} jobs against {} (n={}, fleet {}×b{})",
        jobs.len(),
        problem.summary.name,
        greedyml::util::fmt_count(problem.summary.n as u64),
        batch.machines,
        batch.branching
    );
    let mut queue = greedyml::coordinator::JobQueue::new(batch.mem_budget);
    println!("{:>6} {:>6}  {:<8} {}", "k", "seed", "status", "value");
    for (seed, k) in jobs {
        let dist = batch.dist_config(&cfg, k, seed);
        // One job failing must not strand the rest of the batch — or eat
        // the final accounting.  Report the row, keep draining.
        match queue.submit(&problem, &dist) {
            Ok(greedyml::coordinator::Submission::Rejected { reason }) => {
                println!("{k:>6} {seed:>6}  {:<8} — {reason}", "rejected");
            }
            Ok(sub) => {
                println!("{k:>6} {seed:>6}  {:<8} {:.6}", sub.status(), sub.value().unwrap());
            }
            Err(e) => {
                println!("{k:>6} {seed:>6}  {:<8} — {e}", "failed");
            }
        }
    }
    let pool = queue.pool();
    println!(
        "queue: {} submitted, {} cached, {} rejected, {} failed; fleet: {} sessions \
         established, {} of {} pooled jobs warm, {} retried, {} init bytes shipped",
        queue.submitted(),
        queue.cache_hits(),
        queue.rejected(),
        queue.failed(),
        pool.sessions_established(),
        pool.warm_jobs(),
        pool.jobs_run(),
        pool.retried_jobs(),
        pool.init_bytes_total()
    );
    // A batch with refused or failed work is not a success: exit nonzero
    // so CI and scripts notice, after the full accounting has printed.
    if queue.rejected() > 0 || queue.failed() > 0 {
        anyhow::bail!(
            "{} of {} jobs did not complete ({} rejected by admission, {} failed)",
            queue.rejected() + queue.failed(),
            queue.submitted(),
            queue.rejected(),
            queue.failed()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> greedyml::Result<()> {
    args.check_known(&["bind"])?;
    // 127.0.0.1:0 binds an ephemeral port and prints it — handy for tests
    // and single-host smoke runs; production daemons pass an explicit
    // `--bind 0.0.0.0:<port>`.
    let bind = args.get("bind").unwrap_or("127.0.0.1:0");
    greedyml::dist::tcp::run_serve(bind)
}

fn cmd_tree(args: &Args) -> greedyml::Result<()> {
    args.check_known(&["machines", "branching", "show"])?;
    let m = args.u64_or("machines", 8)? as u32;
    let b = args.u64_or("branching", 2)? as u32;
    let t = AccumulationTree::new(m, b);
    print!("{}", t.render());
    println!("max fan-in: {}", t.max_fan_in());
    Ok(())
}

fn cmd_datasets() -> greedyml::Result<()> {
    use greedyml::data::{gen, DatasetSummary};
    println!("{}", DatasetSummary::header());
    let road = gen::road(gen::RoadParams::usa_like(1 << 14), 1);
    println!("{}", DatasetSummary::of_graph("road-like", &road).row());
    let belg = gen::road(gen::RoadParams::belgium_like(1 << 13), 1);
    println!("{}", DatasetSummary::of_graph("belgium-like", &belg).row());
    let rmat = gen::rmat(gen::RmatParams::friendster_like(13), 1);
    println!("{}", DatasetSummary::of_graph("friendster-like", &rmat).row());
    let kos = gen::transactions(gen::TransactionParams::kosarak_like(4096), 1);
    println!("{}", DatasetSummary::of_itemsets("kosarak-like", &kos).row());
    let ret = gen::transactions(gen::TransactionParams::retail_like(4096), 1);
    println!("{}", DatasetSummary::of_itemsets("retail-like", &ret).row());
    let web = gen::transactions(
        gen::TransactionParams { num_sets: 512, num_items: 2048, mean_size: 177.2, zipf_s: 1.0 },
        1,
    );
    println!("{}", DatasetSummary::of_itemsets("webdocs-like", &web).row());
    let (vs, _) = gen::gaussian_mixture(gen::GaussianParams::tiny_imagenet_like(2048, 128), 1);
    println!("{}", DatasetSummary::of_vectors("tiny-imagenet-like", &vs).row());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> greedyml::Result<()> {
    args.check_known(&["dir"])?;
    let dir = args.get("dir").map(str::to_string).unwrap_or_else(greedyml::runtime::artifact_dir);
    let engine = Engine::load(&dir)?;
    let m = engine.manifest();
    println!(
        "artifacts ok: dir={dir} platform={} n_tile={} c_tile={} w_tile={}",
        engine.platform(),
        m.n_tile,
        m.c_tile,
        m.w_tile
    );
    for e in &m.entries {
        let ins: Vec<String> =
            e.inputs.iter().map(|s| format!("{:?}:{}", s.shape, s.dtype)).collect();
        println!("  {:<24} {} -> {} outputs", e.name, ins.join(", "), e.outputs.len());
    }
    Ok(())
}

fn cmd_model(args: &Args) -> greedyml::Result<()> {
    args.check_known(&["n", "k", "machines", "levels", "delta"])?;
    let p = greedyml::bsp::BspParams {
        n: args.u64_or("n", 1 << 20)?,
        k: args.u64_or("k", 1000)?,
        m: args.u64_or("machines", 32)?,
        levels: args.u64_or("levels", 2)?,
        delta: args.get("delta").map(|d| d.parse()).transpose()?.unwrap_or(8.0),
    };
    println!(
        "BSP model (Table 1) for n={} k={} m={} L={} delta={}",
        p.n, p.k, p.m, p.levels, p.delta
    );
    println!("  fan-in ceil(m^(1/L))      : {}", p.fan_in());
    println!("  Greedy total calls        : {}", greedyml::util::fmt_count(p.greedy_calls()));
    println!("  RandGreeDI calls/machine  : {}", greedyml::util::fmt_count(p.randgreedi_calls()));
    println!("  GreedyML calls/machine    : {}", greedyml::util::fmt_count(p.greedyml_calls()));
    println!(
        "  interior elems RG vs GML  : {} vs {}",
        greedyml::util::fmt_count(p.interior_elems_randgreedi()),
        greedyml::util::fmt_count(p.interior_elems_greedyml())
    );
    println!(
        "  comm cost RG vs GML       : {:.3e} vs {:.3e}",
        p.comm_randgreedi(),
        p.comm_greedyml()
    );
    println!(
        "  k-medoid comp RG vs GML   : {:.3e} vs {:.3e}",
        p.kmedoid_comp_randgreedi(),
        p.kmedoid_comp_greedyml()
    );
    Ok(())
}
