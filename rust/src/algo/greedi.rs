//! GreeDI (Mirzasoleiman et al. 2013): like RandGreeDI but with an
//! *arbitrary* (here: contiguous-chunk) partition — the variant whose
//! worst-case guarantee degrades to `1/Θ(min(√k, m))`.  Included as the
//! paper's historical baseline and to let the benches demonstrate why the
//! random tape matters on adversarial orderings.

use super::{greedyml::run_dist, DistConfig, DistOutcome, PartitionScheme};
use crate::constraint::Constraint;
use crate::dist::DistError;
use crate::greedy::GreedyKind;
use crate::objective::Oracle;
use crate::tree::AccumulationTree;

/// The full engine config GreeDI runs as: contiguous partition, single
/// accumulation level, argmax over every child.  Public so the
/// coordinator can attach backend/problem settings before running.
pub fn greedi_config(machines: u32, mem_limit: Option<u64>) -> DistConfig {
    DistConfig {
        mem_limit,
        partition: PartitionScheme::Contiguous,
        compare_all_children: true,
        kind: GreedyKind::Lazy,
        // seed 0: no randomness used by the contiguous partition
        ..DistConfig::greedyml(AccumulationTree::randgreedi(machines), 0)
    }
}

/// Run GreeDI on `machines` with a contiguous partition.
pub fn run_greedi(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    machines: u32,
    mem_limit: Option<u64>,
) -> Result<DistOutcome, DistError> {
    run_dist(oracle, constraint, &greedi_config(machines, mem_limit))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraint::Cardinality;
    use crate::objective::KCover;
    use std::sync::Arc;

    #[test]
    fn contiguous_partition_covers_everything() {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 300,
                num_items: 200,
                mean_size: 5.0,
                zipf_s: 0.9,
            },
            3,
        );
        let o = KCover::new(Arc::new(data));
        let c = Cardinality::new(8);
        let out = run_greedi(&o, &c, 6, None).unwrap();
        assert!(out.value > 0.0);
        assert!(out.solution.len() <= 8);
        // Deterministic: no tape involved.
        let out2 = run_greedi(&o, &c, 6, None).unwrap();
        assert_eq!(out.solution, out2.solution);
    }

    #[test]
    fn random_partition_beats_adversarial_order_on_clustered_data() {
        // Construct data where contiguity is adversarial: identical
        // transactions are adjacent, so each GreeDI chunk is redundant.
        let mut sets = Vec::new();
        for block in 0..10u32 {
            for _ in 0..30 {
                sets.push(vec![block * 4, block * 4 + 1, block * 4 + 2, block * 4 + 3]);
            }
        }
        let o = KCover::new(Arc::new(crate::data::itemsets::ItemsetCollection::from_sets(&sets)));
        let c = Cardinality::new(10);
        let gd = run_greedi(&o, &c, 10, None).unwrap();
        let rg = crate::algo::run_randgreedi(
            &o,
            &c,
            crate::algo::randgreedi::RandGreediOpts::new(10, 5),
        )
        .unwrap();
        // Both should actually solve this easy instance; the point is that
        // the random partition is never *worse*.
        assert!(rg.value >= gd.value - 1e-9);
    }
}
