//! The sequential baseline: (Lazy) GREEDY over the whole dataset on one
//! machine, with the same memory accounting as the distributed runs so the
//! §6.2 "GREEDY cannot even hold the data" regime reproduces.

use crate::constraint::Constraint;
use crate::dist::{DistError, MemoryMeter};
use crate::greedy::{greedy, GreedyKind, GreedyOutcome};
use crate::objective::Oracle;
use crate::util::timer::timed;
use crate::ElemId;

/// Result of a sequential run.
#[derive(Clone, Debug)]
pub struct SeqOutcome {
    /// The greedy solution and its statistics.
    pub greedy: GreedyOutcome,
    /// Wall seconds.
    pub secs: f64,
    /// Peak memory (data + solution).
    pub peak_mem: u64,
}

/// Run sequential GREEDY with an optional memory limit.
pub fn run_sequential(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    kind: GreedyKind,
    mem_limit: Option<u64>,
) -> Result<SeqOutcome, DistError> {
    let mut meter = MemoryMeter::new(mem_limit);
    let candidates: Vec<ElemId> = (0..oracle.n() as ElemId).collect();
    let data_bytes: u64 = candidates.iter().map(|&e| oracle.elem_bytes(e) as u64).sum();
    meter.charge(data_bytes, 0, 0, "full dataset")?;
    let (out, secs) = timed(|| greedy(kind, oracle, constraint, &candidates, None));
    let sol_bytes: u64 = out.solution.iter().map(|&e| oracle.elem_bytes(e) as u64).sum();
    meter.charge(sol_bytes, 0, 0, "solution")?;
    Ok(SeqOutcome { greedy: out, secs, peak_mem: meter.peak() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraint::Cardinality;
    use crate::greedy::GreedyKind;
    use crate::objective::KCover;
    use std::sync::Arc;

    fn oracle() -> KCover {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 200,
                num_items: 120,
                mean_size: 5.0,
                zipf_s: 1.0,
            },
            8,
        );
        KCover::new(Arc::new(data))
    }

    #[test]
    fn runs_and_reports() {
        let o = oracle();
        let out = run_sequential(&o, &Cardinality::new(10), GreedyKind::Lazy, None).unwrap();
        assert!(out.greedy.value > 0.0);
        assert!(out.peak_mem > 0);
        assert!(out.secs >= 0.0);
    }

    #[test]
    fn memory_limit_blocks_whole_dataset() {
        let o = oracle();
        // Limit below the dataset footprint → the paper's "GREEDY fails".
        let data_bytes: u64 = (0..o.n() as u32).map(|e| o.elem_bytes(e) as u64).sum();
        let err = run_sequential(
            &o,
            &Cardinality::new(10),
            GreedyKind::Lazy,
            Some(data_bytes / 2),
        )
        .unwrap_err();
        assert!(matches!(err, DistError::OutOfMemory { machine: 0, .. }));
    }
}
