//! RandGreeDI (Barbosa et al. 2015, Algorithm 2.2): uniform random
//! partition, a single accumulation step on machine 0, and argmax over the
//! merged solution *and every local solution*.
//!
//! Implemented as the `b = m` (L = 1) special case of the GreedyML engine
//! with `compare_all_children` enabled — Theorem 4.4 with L = 1 recovers
//! its α/2 guarantee.

use super::{greedyml::run_dist, DistConfig, DistOutcome};
use crate::constraint::Constraint;
use crate::dist::DistError;
use crate::greedy::GreedyKind;
use crate::objective::Oracle;
use crate::tree::AccumulationTree;

/// Options for a RandGreeDI run (a subset of [`DistConfig`]).
#[derive(Clone, Copy, Debug)]
pub struct RandGreediOpts {
    /// Number of machines.
    pub machines: u32,
    /// Random-tape seed.
    pub seed: u64,
    /// Per-machine memory limit.
    pub mem_limit: Option<u64>,
    /// Greedy implementation.
    pub kind: GreedyKind,
    /// Machine-local objective evaluation (k-medoid scheme).
    pub local_view: bool,
    /// Extra random elements at the accumulation step (§6.4).
    pub added_elements: usize,
}

impl RandGreediOpts {
    /// Defaults for `m` machines.
    pub fn new(machines: u32, seed: u64) -> Self {
        Self {
            machines,
            seed,
            mem_limit: None,
            kind: GreedyKind::Lazy,
            local_view: false,
            added_elements: 0,
        }
    }

    /// Expand into the full engine config (backend settings at their
    /// defaults — the coordinator overrides them before running).
    pub fn to_config(self) -> DistConfig {
        DistConfig {
            kind: self.kind,
            mem_limit: self.mem_limit,
            local_view: self.local_view,
            added_elements: self.added_elements,
            compare_all_children: true,
            ..DistConfig::greedyml(AccumulationTree::randgreedi(self.machines), self.seed)
        }
    }
}

/// Run RandGreeDI.
pub fn run_randgreedi(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    opts: RandGreediOpts,
) -> Result<DistOutcome, DistError> {
    run_dist(oracle, constraint, &opts.to_config())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraint::Cardinality;
    use crate::objective::{KCover, Oracle};
    use std::sync::Arc;

    fn oracle() -> KCover {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 500,
                num_items: 250,
                mean_size: 7.0,
                zipf_s: 1.0,
            },
            21,
        );
        KCover::new(Arc::new(data))
    }

    #[test]
    fn single_level_tree() {
        let o = oracle();
        let c = Cardinality::new(10);
        let out = run_randgreedi(&o, &c, RandGreediOpts::new(8, 4)).unwrap();
        assert_eq!(out.levels.len(), 2, "leaves + one accumulation");
        assert_eq!(out.machines.len(), 8);
        assert!((out.value - o.eval(&out.solution)).abs() < 1e-9);
    }

    #[test]
    fn equals_greedyml_b_eq_m_up_to_argmax() {
        // With identical seeds the leaf solutions are identical; RandGreeDI
        // additionally argmaxes over the locals, so its value can only be ≥
        // the GreedyML(b=m) value.
        let o = oracle();
        let c = Cardinality::new(10);
        let rg = run_randgreedi(&o, &c, RandGreediOpts::new(8, 4)).unwrap();
        let gml = super::super::run_greedyml(
            &o,
            &c,
            &super::super::DistConfig::greedyml(AccumulationTree::randgreedi(8), 4),
        )
        .unwrap();
        assert!(rg.value >= gml.value - 1e-9);
        // Leaf work identical → identical leaf call totals.
        let rg_leaf: u64 = rg.levels[0].total_calls;
        let gml_leaf: u64 = gml.levels[0].total_calls;
        assert_eq!(rg_leaf, gml_leaf);
    }

    #[test]
    fn quality_beats_worst_case_bound() {
        // Empirically RandGreeDI is close to Greedy (paper: within ~6%);
        // we assert a loose 60% to be robust across seeds.
        let o = oracle();
        let c = Cardinality::new(12);
        let seq =
            crate::greedy::greedy_lazy(&o, &c, &(0..o.n() as u32).collect::<Vec<_>>(), None);
        let rg = run_randgreedi(&o, &c, RandGreediOpts::new(10, 77)).unwrap();
        assert!(
            rg.value >= 0.6 * seq.value,
            "rg {} vs seq {}",
            rg.value,
            seq.value
        );
    }
}
