//! The GreedyML engine (Algorithm 3.1) — also the substrate for GreeDI and
//! RandGreeDI, which are the single-level special case with different
//! partition/argmax settings.
//!
//! Execution is level-synchronous BSP: level 0 runs GREEDY on every leaf's
//! partition in parallel; each level ℓ ≥ 1 gathers the children's solutions
//! at their parents (charging the memory meter and the comm model), runs
//! GREEDY on the union, and keeps `argmax{f(merged), f(previous)}` per the
//! recurrence of Fig. 3.  Machine 0 participates at every level, so its
//! accumulated gain-query count is the paper's "function calls on the
//! critical path".

use super::{DistConfig, DistOutcome, LevelStats, PartitionScheme};
use crate::constraint::Constraint;
use crate::dist::pool;
use crate::dist::{DistError, Executor, MachineStats, MemoryMeter, NodeStep, Trace};
use crate::greedy::{greedy, GreedyOutcome};
use crate::objective::Oracle;
use crate::util::rng::{RandomTape, Rng};
use crate::util::timer::timed;
use crate::{ElemId, MachineId};

/// Rolling state of one machine between supersteps.
struct NodeCtx {
    stats: MachineStats,
    meter: MemoryMeter,
    /// S_prev: the machine's best solution so far.
    sol: Vec<ElemId>,
    /// f(S_prev) as evaluated at this machine's last active level.
    sol_value: f64,
    /// Bytes currently charged for holding `sol`.
    sol_bytes: u64,
}

/// What one machine did during a single superstep (level aggregation).
#[derive(Clone, Copy, Debug, Default)]
struct StepDelta {
    comp_secs: f64,
    comm_secs: f64,
    calls: u64,
    accum_elems: usize,
}

/// A child's shipped solution.
struct ChildMsg {
    sol: Vec<ElemId>,
    value: f64,
    bytes: u64,
}

/// Run GreedyML with the given config (Algorithm 3.1).
pub fn run_greedyml(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    cfg: &DistConfig,
) -> Result<DistOutcome, DistError> {
    run_dist(oracle, constraint, cfg)
}

/// The shared engine (see module docs). Public so the baselines reuse it.
///
/// Spawns the two-level executor once for the whole run (workers persist
/// across supersteps) and tears it down on return; `cfg.threads` /
/// `GREEDYML_THREADS` control its width, and `threads = 1` reproduces the
/// serial runtime bit-for-bit.
pub fn run_dist(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    cfg: &DistConfig,
) -> Result<DistOutcome, DistError> {
    let threads = cfg.threads.unwrap_or_else(pool::default_threads).max(1);
    pool::with_pool(threads, |exec| run_dist_on(exec, oracle, constraint, cfg))
}

/// One distributed run on an already-running executor.
fn run_dist_on(
    exec: &Executor<'_>,
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    cfg: &DistConfig,
) -> Result<DistOutcome, DistError> {
    let tree = cfg.tree;
    let m = tree.machines();
    let n = oracle.n();

    // ---- Line 2: partition the data over the leaves. ------------------
    let parts: Vec<Vec<ElemId>> = match cfg.partition {
        PartitionScheme::Random => RandomTape::draw(n, m, cfg.seed).partition(),
        PartitionScheme::Contiguous => {
            let mut parts = vec![Vec::new(); m as usize];
            for e in 0..n {
                parts[(e * m as usize / n.max(1)).min(m as usize - 1)].push(e as ElemId);
            }
            parts
        }
    };

    let mut levels: Vec<LevelStats> = Vec::with_capacity(tree.levels() as usize + 1);

    // ---- Level 0 superstep: GREEDY on every partition. -----------------
    let leaf_inputs: Vec<(MachineId, Vec<ElemId>)> =
        parts.into_iter().enumerate().map(|(i, p)| (i as MachineId, p)).collect();
    let leaf_results: Vec<Result<(NodeCtx, StepDelta), DistError>> =
        exec.map(leaf_inputs, |(id, part)| {
            let mut stats = MachineStats::new(id);
            let mut meter = MemoryMeter::new(cfg.mem_limit);
            let data_bytes: u64 = part.iter().map(|&e| oracle.elem_bytes(e) as u64).sum();
            meter.charge(data_bytes, id, 0, "partition data")?;
            let view = cfg.local_view.then_some(&part[..]);
            let (out, secs): (GreedyOutcome, f64) =
                timed(|| greedy(cfg.kind, oracle, constraint, &part, view));
            stats.calls = out.calls;
            stats.cost = out.cost;
            stats.comp_secs = secs;
            let sol_bytes: u64 =
                out.solution.iter().map(|&e| oracle.elem_bytes(e) as u64).sum();
            meter.charge(sol_bytes, id, 0, "local solution")?;
            // The partition itself is no longer needed once the local
            // solution exists (only S_prev crosses levels).
            meter.release(data_bytes);
            stats.peak_mem = meter.peak();
            let delta = StepDelta {
                comp_secs: secs,
                comm_secs: 0.0,
                calls: out.calls,
                accum_elems: 0,
            };
            Ok((
                NodeCtx { stats, meter, sol: out.solution, sol_value: out.value, sol_bytes },
                delta,
            ))
        });

    let mut ctxs: Vec<Option<NodeCtx>> = (0..m).map(|_| None).collect();
    let mut deltas0 = Vec::with_capacity(m as usize);
    let mut trace_steps: Vec<NodeStep> = Vec::new();
    for r in leaf_results {
        let (ctx, d) = r?;
        trace_steps.push(NodeStep {
            machine: ctx.stats.id,
            level: 0,
            comp_secs: d.comp_secs,
            comm_secs: d.comm_secs,
            calls: d.calls,
        });
        deltas0.push(d);
        let id = ctx.stats.id as usize;
        ctxs[id] = Some(ctx);
    }
    levels.push(aggregate_level(0, &deltas0));

    // Machines that have finished all their roles.
    let mut retired: Vec<Option<MachineStats>> = (0..m).map(|_| None).collect();
    let mut max_accum_elems = 0usize;

    // ---- Levels 1..=L: accumulate. -------------------------------------
    for level in 1..=tree.levels() {
        let active = tree.nodes_at_level(level);
        struct Task {
            id: MachineId,
            ctx: NodeCtx,
            children: Vec<ChildMsg>,
        }
        let mut tasks: Vec<Task> = Vec::with_capacity(active.len());
        for &id in &active {
            let ctx = ctxs[id as usize].take().expect("parent ctx missing");
            let mut children = Vec::new();
            for c in tree.children(level, id) {
                if c == id {
                    continue; // j = 0: the node's own S_prev stays in ctx.
                }
                let mut child = ctxs[c as usize].take().expect("child ctx missing");
                // `sol_bytes` already tracks Σ elem_bytes over the held
                // solution (charged at every level swap) — no rescan.
                let bytes = child.sol_bytes;
                child.stats.bytes_sent += bytes;
                // Child is done (Algorithm 3.1 lines 6-7: send & break).
                children.push(ChildMsg { sol: std::mem::take(&mut child.sol), value: child.sol_value, bytes });
                retired[c as usize] = Some(child.stats);
            }
            tasks.push(Task { id, ctx, children });
        }

        let results: Vec<Result<(NodeCtx, StepDelta), DistError>> =
            exec.map(tasks, |mut task| {
                let id = task.id;
                let ctx = &mut task.ctx;
                // Receive child solutions: comm model + memory charges.
                let msg_bytes: Vec<u64> = task.children.iter().map(|c| c.bytes).collect();
                let recv_bytes: u64 = msg_bytes.iter().sum();
                ctx.meter.charge(recv_bytes, id, level, "child solutions")?;
                let comm_secs = cfg.comm.gather_time(&msg_bytes);
                ctx.stats.comm_secs += comm_secs;
                ctx.stats.bytes_received += recv_bytes;

                // D ← S_prev ∪ child solutions (lines 8-13), plus the §6.4
                // optional random extra elements.  The union is built
                // *distinct*: solutions can overlap across levels, and
                // `sample_added` can re-draw elements already in D — blind
                // concatenation would inflate `accum_elems` and charge the
                // memory meter twice for the same resident element.
                // Membership is tracked in a |D|-sized set, not an O(n)
                // bitmap: the union is O(b·k + added) elements and this
                // runs once per active node per level.
                let cap = ctx.sol.len()
                    + task.children.iter().map(|c| c.sol.len()).sum::<usize>()
                    + cfg.added_elements;
                let mut seen = std::collections::HashSet::with_capacity(cap);
                let mut d: Vec<ElemId> = Vec::with_capacity(cap);
                for &e in ctx.sol.iter().chain(task.children.iter().flat_map(|c| c.sol.iter())) {
                    if seen.insert(e) {
                        d.push(e);
                    }
                }
                let added = sample_added(cfg, n, level, id);
                let mut add_bytes = 0u64;
                for &e in &added {
                    if seen.insert(e) {
                        add_bytes += oracle.elem_bytes(e) as u64;
                        d.push(e);
                    }
                }
                if add_bytes > 0 {
                    ctx.meter.charge(add_bytes, id, level, "added elements")?;
                }
                let accum_elems = d.len();

                // Run GREEDY on the union (line 14).
                let view = cfg.local_view.then_some(&d[..]);
                let (out, secs) = timed(|| greedy(cfg.kind, oracle, constraint, &d, view));
                let mut calls = out.calls;
                let mut cost = out.cost;

                // Line 15: S_prev ← argmax{f(S), f(S_prev)}.  Under a local
                // view the stored f(S_prev) was computed against different
                // data, so re-evaluate it against this node's view.
                let prev_value = if cfg.local_view {
                    let mut st = oracle.new_state(view);
                    for &e in &ctx.sol {
                        calls += 1;
                        cost += st.call_cost(e);
                        st.commit(e);
                    }
                    st.value()
                } else {
                    ctx.sol_value
                };

                let mut best_sol = out.solution;
                let mut best_val = out.value;
                if prev_value > best_val {
                    best_val = prev_value;
                    best_sol = ctx.sol.clone();
                }
                if cfg.compare_all_children {
                    // RandGreeDI (Algorithm 2.2 line 7): also compare every
                    // child's local solution.  Only the argmax winner is
                    // cloned — b can be as large as m.
                    let mut winner: Option<&ChildMsg> = None;
                    for c in &task.children {
                        if c.value > best_val {
                            best_val = c.value;
                            winner = Some(c);
                        }
                    }
                    if let Some(c) = winner {
                        best_sol = c.sol.clone();
                    }
                }

                ctx.stats.calls += calls;
                ctx.stats.cost += cost;
                ctx.stats.comp_secs += secs;
                ctx.stats.top_level = level;
                ctx.stats.max_accum_elems = ctx.stats.max_accum_elems.max(accum_elems);

                // Swap in the new solution. The merged solution is a subset
                // of D (greedy selects *from* the union), so its data is
                // already charged; release everything D-related first, then
                // re-charge just the retained solution.
                let new_bytes: u64 =
                    best_sol.iter().map(|&e| oracle.elem_bytes(e) as u64).sum();
                ctx.meter.release(recv_bytes + add_bytes + ctx.sol_bytes);
                ctx.meter.charge(new_bytes, id, level, "merged solution")?;
                ctx.sol = best_sol;
                ctx.sol_value = best_val;
                ctx.sol_bytes = new_bytes;
                ctx.stats.peak_mem = ctx.meter.peak();
                let delta = StepDelta { comp_secs: secs, comm_secs, calls, accum_elems };
                Ok((task.ctx, delta))
            });

        let mut step_deltas = Vec::with_capacity(active.len());
        for r in results {
            let (ctx, d) = r?;
            max_accum_elems = max_accum_elems.max(d.accum_elems);
            trace_steps.push(NodeStep {
                machine: ctx.stats.id,
                level,
                comp_secs: d.comp_secs,
                comm_secs: d.comm_secs,
                calls: d.calls,
            });
            step_deltas.push(d);
            let id = ctx.stats.id as usize;
            ctxs[id] = Some(ctx);
        }
        levels.push(aggregate_level(level, &step_deltas));
    }

    // ---- Collect the root and any never-retired machines. --------------
    let root = ctxs[0].take().expect("root ctx missing");
    let solution = root.sol.clone();
    let value = root.sol_value;
    retired[0] = Some(root.stats);
    for (i, slot) in ctxs.into_iter().enumerate() {
        if let Some(ctx) = slot {
            retired[i] = Some(ctx.stats);
        }
    }
    let machines: Vec<MachineStats> =
        retired.into_iter().map(|s| s.expect("machine stats missing")).collect();

    let critical_calls = machines[0].calls;
    let total_calls = machines.iter().map(|s| s.calls).sum();
    let comp_secs = levels.iter().map(|l| l.comp_secs).sum();
    let comm_secs = levels.iter().map(|l| l.comm_secs).sum();

    Ok(DistOutcome {
        solution,
        value,
        machines,
        levels,
        critical_calls,
        total_calls,
        comp_secs,
        comm_secs,
        max_accum_elems,
        trace: Trace::new(trace_steps),
    })
}

/// §6.4 "added images": extra random elements mixed into every
/// accumulation step, seeded per (level, node) for reproducibility.
fn sample_added(cfg: &DistConfig, n: usize, level: u32, id: MachineId) -> Vec<ElemId> {
    if cfg.added_elements == 0 {
        return Vec::new();
    }
    let count = cfg.added_elements.min(n);
    let mut rng = Rng::split(cfg.seed ^ 0xADDED, ((level as u64) << 32) | id as u64);
    rng.sample_distinct(n, count).into_iter().map(|e| e as ElemId).collect()
}

/// Fold one superstep's per-node deltas into a [`LevelStats`]: BSP
/// semantics — the superstep lasts as long as its slowest node.
fn aggregate_level(level: u32, deltas: &[StepDelta]) -> LevelStats {
    let mut out = LevelStats { level, ..Default::default() };
    for d in deltas {
        out.active_nodes += 1;
        out.comp_secs = out.comp_secs.max(d.comp_secs);
        out.comm_secs = out.comm_secs.max(d.comm_secs);
        out.max_calls = out.max_calls.max(d.calls);
        out.total_calls += d.calls;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraint::Cardinality;
    use crate::objective::{KCover, KDominatingSet, Oracle};
    use crate::tree::AccumulationTree;
    use std::sync::Arc;

    fn cover_oracle(n: usize, seed: u64) -> KCover {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: n,
                num_items: n / 2,
                mean_size: 6.0,
                zipf_s: 0.9,
            },
            seed,
        );
        KCover::new(Arc::new(data))
    }

    #[test]
    fn runs_and_produces_feasible_solution() {
        let o = cover_oracle(600, 3);
        let c = Cardinality::new(12);
        let cfg = DistConfig::greedyml(AccumulationTree::new(8, 2), 42);
        let out = run_greedyml(&o, &c, &cfg).unwrap();
        assert!(out.solution.len() <= 12);
        assert!(out.value > 0.0);
        assert!((out.value - o.eval(&out.solution)).abs() < 1e-9);
        assert_eq!(out.machines.len(), 8);
        assert_eq!(out.levels.len(), 4, "L=3 ⇒ 4 supersteps");
        assert_eq!(out.critical_calls, out.machines[0].calls);
        assert!(out.total_calls >= out.critical_calls);
    }

    #[test]
    fn deterministic_given_seed() {
        let o = cover_oracle(400, 5);
        let c = Cardinality::new(8);
        let cfg = DistConfig::greedyml(AccumulationTree::new(6, 2), 7);
        let a = run_greedyml(&o, &c, &cfg).unwrap();
        let b = run_greedyml(&o, &c, &cfg).unwrap();
        assert_eq!(a.solution, b.solution);
        assert_eq!(a.total_calls, b.total_calls);
        let cfg2 = DistConfig { seed: 8, ..cfg.clone() };
        let c2 = run_greedyml(&o, &c, &cfg2).unwrap();
        assert_ne!(a.solution, c2.solution, "different tape should differ");
    }

    #[test]
    fn value_close_to_sequential() {
        let o = cover_oracle(800, 9);
        let c = Cardinality::new(16);
        let seq = crate::greedy::greedy_lazy(&o, &c, &(0..800).collect::<Vec<_>>(), None);
        for b in [2u32, 4, 8] {
            let cfg = DistConfig::greedyml(AccumulationTree::new(8, b), 1);
            let out = run_greedyml(&o, &c, &cfg).unwrap();
            assert!(
                out.value >= 0.75 * seq.value,
                "b={b}: dist {} vs seq {}",
                out.value,
                seq.value
            );
        }
    }

    #[test]
    fn memory_limit_trips_at_root_of_wide_tree() {
        // Wide accumulation (b = m) must hold m−1 child solutions at the
        // root; a narrow tree (b = 2) holds only 1. Choose a limit between.
        let g = Arc::new(crate::data::gen::barabasi_albert(2000, 3, 5));
        let o = KDominatingSet::new(g);
        let k = 40;
        let c = Cardinality::new(k);
        // Probe memory: unlimited wide run's root peak.
        let wide = DistConfig::greedyml(AccumulationTree::randgreedi(16), 3);
        let ok = run_greedyml(&o, &c, &wide).unwrap();
        let root_peak = ok.machines[0].peak_mem;
        let limit = root_peak * 2 / 3;
        let wide_limited = DistConfig { mem_limit: Some(limit), ..wide };
        let err = run_greedyml(&o, &c, &wide_limited).unwrap_err();
        match err {
            DistError::OutOfMemory { machine, level, .. } => {
                assert_eq!(machine, 0, "root is the bottleneck");
                assert_eq!(level, 1);
            }
        }
        // The same limit with a binary tree succeeds (more levels, less
        // fan-in) — the paper's headline memory result (§6.2).
        let narrow = DistConfig {
            mem_limit: Some(limit),
            ..DistConfig::greedyml(AccumulationTree::new(16, 2), 3)
        };
        let out = run_greedyml(&o, &c, &narrow).unwrap();
        assert!(out.value > 0.0);
        assert!(out.peak_mem() <= limit);
    }

    #[test]
    fn single_machine_tree_equals_sequential() {
        let o = cover_oracle(200, 11);
        let c = Cardinality::new(6);
        let cfg = DistConfig::greedyml(AccumulationTree::new(1, 2), 5);
        let out = run_greedyml(&o, &c, &cfg).unwrap();
        let seq = crate::greedy::greedy_lazy(&o, &c, &(0..200).collect::<Vec<_>>(), None);
        assert_eq!(out.solution, seq.solution);
        assert_eq!(out.levels.len(), 1);
        assert_eq!(out.comm_secs, 0.0);
    }

    #[test]
    fn comm_bytes_flow_up_the_tree() {
        let o = cover_oracle(400, 2);
        let c = Cardinality::new(10);
        let cfg = DistConfig::greedyml(AccumulationTree::new(4, 2), 9);
        let out = run_greedyml(&o, &c, &cfg).unwrap();
        let sent: u64 = out.machines.iter().map(|m| m.bytes_sent).sum();
        let received: u64 = out.machines.iter().map(|m| m.bytes_received).sum();
        assert_eq!(sent, received, "no bytes lost in flight");
        assert!(sent > 0);
        assert!(out.comm_secs > 0.0);
        // Non-root machines each send exactly once.
        for mstats in &out.machines[1..] {
            assert!(mstats.bytes_sent > 0, "machine {} never sent", mstats.id);
        }
        assert_eq!(out.machines[0].bytes_sent, 0, "root sends nowhere");
    }

    #[test]
    fn added_elements_join_the_accumulation() {
        let o = cover_oracle(300, 4);
        let c = Cardinality::new(8);
        let base = DistConfig::greedyml(AccumulationTree::new(4, 2), 13);
        let with_added = DistConfig { added_elements: 50, ..base.clone() };
        let a = run_greedyml(&o, &c, &base).unwrap();
        let b = run_greedyml(&o, &c, &with_added).unwrap();
        assert!(b.max_accum_elems >= a.max_accum_elems + 50 - 8);
        // More candidates can only help (or tie) coverage quality here.
        assert!(b.value >= a.value * 0.95);
    }

    #[test]
    fn accumulation_union_is_deduplicated() {
        // added_elements = n draws the whole ground set at every
        // accumulation step; since D is a distinct union, no accumulator
        // can ever see more candidates than the ground set holds.  (The
        // pre-dedup union was |S_prev| + Σ|child| + n > n.)
        let n = 100;
        let o = cover_oracle(n, 6);
        let c = Cardinality::new(5);
        let cfg = DistConfig {
            added_elements: n,
            ..DistConfig::greedyml(AccumulationTree::new(4, 2), 3)
        };
        let out = run_greedyml(&o, &c, &cfg).unwrap();
        assert!(
            out.max_accum_elems <= n,
            "{} accumulation candidates from a {n}-element ground set",
            out.max_accum_elems
        );
        assert!(out.value > 0.0);
    }
}
