//! The GreedyML engine (Algorithm 3.1) — also the substrate for GreeDI and
//! RandGreeDI, which are the single-level special case with different
//! partition/argmax settings.
//!
//! Execution is level-synchronous BSP: level 0 runs GREEDY on every leaf's
//! partition in parallel; each level ℓ ≥ 1 gathers the children's solutions
//! at their parents, runs GREEDY on the union, and keeps
//! `argmax{f(merged), f(previous)}` per the recurrence of Fig. 3.  Machine
//! 0 participates at every level, so its accumulated gain-query count is
//! the paper's "function calls on the critical path".
//!
//! The engine is pure tree orchestration: it partitions the ground set,
//! walks the accumulation levels, and aggregates statistics — *all*
//! superstep fan-out, solution shipping and per-machine resource
//! accounting happen behind the [`Backend`] trait, so the same loop runs
//! on the in-process thread pool ([`ThreadBackend`], modeled comm), on
//! forked worker processes ([`ProcessBackend`], measured comm) and on
//! remote TCP worker daemons ([`TcpBackend`], measured comm over a real
//! network), producing bit-identical solutions.

use super::{DistConfig, DistOutcome, LevelStats, PartitionScheme};
use crate::constraint::Constraint;
use crate::dist::{
    pool, tcp, AccumTask, Backend, BackendSpec, DistError, FaultPolicy, NodeParams, NodeStep,
    ProcessBackend, ResolvedBackend, ShipMode, ShipPlan, StepReport, TcpBackend, ThreadBackend,
    Trace, WireMode,
};
use crate::objective::{Oracle, PartitionPayload, Partitionable};
use crate::tree::AccumulationTree;
use crate::util::rng::RandomTape;
use crate::{ElemId, MachineId};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Run GreedyML with the given config (Algorithm 3.1).
pub fn run_greedyml(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    cfg: &DistConfig,
) -> Result<DistOutcome, DistError> {
    run_dist(oracle, constraint, cfg)
}

/// The shared engine (see module docs). Public so the baselines reuse it.
///
/// Resolves the configured [`BackendSpec`](crate::dist::BackendSpec) and
/// drives [`run_dist_on`] against it.  On the thread backend the two-level
/// executor is spawned once for the whole run (workers persist across
/// supersteps); `cfg.threads` / `GREEDYML_THREADS` control its width, and
/// `threads = 1` reproduces the serial runtime bit-for-bit.  On the
/// process backend one worker process per machine is forked instead, and
/// on the tcp backend one worker session per machine is opened on the
/// configured `greedyml serve` hosts (both need `cfg.problem` to carry
/// the spec the workers rebuild the oracle from).
pub fn run_dist(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    cfg: &DistConfig,
) -> Result<DistOutcome, DistError> {
    let params = NodeParams {
        kind: cfg.kind,
        seed: cfg.seed,
        n: oracle.n(),
        mem_limit: cfg.mem_limit,
        local_view: cfg.local_view,
        added_elements: cfg.added_elements,
        compare_all_children: cfg.compare_all_children,
        coreset: cfg.coreset.resolve()?,
    };
    // Line 2 of Algorithm 3.1, computed once: the same split feeds the
    // partition-shipping Init shards and the engine's Leaf fan-out.
    let parts = make_parts(cfg, oracle.n())?;
    let mut resolved = cfg.backend.resolve()?;
    if resolved != ResolvedBackend::Thread
        && cfg.backend == BackendSpec::Auto
        && cfg.problem.is_none()
    {
        // The env var is advisory: programmatic callers (benches, unit
        // tests, library users with hand-built oracles) carry no problem
        // spec, and failing them because the environment asked for
        // process or tcp workers would make `GREEDYML_BACKEND=process
        // cargo bench` unusable.  Explicit `BackendSpec::Process`/`Tcp`
        // still errors.
        eprintln!(
            "GREEDYML_BACKEND ignored for this run: no problem spec to ship \
             to workers (programmatic oracle); using the thread backend"
        );
        resolved = ResolvedBackend::Thread;
    }
    match resolved {
        ResolvedBackend::Thread => {
            let threads = cfg.threads.unwrap_or_else(pool::default_threads).max(1);
            pool::with_pool(threads, |exec| {
                let mut backend = ThreadBackend::new(
                    exec,
                    oracle,
                    constraint,
                    params.clone(),
                    cfg.comm,
                    cfg.tree.machines(),
                );
                run_dist_on(&mut backend, cfg, parts)
            })
        }
        // The cold remote arms are a one-job session: establish (ship the
        // dataset), one `begin_job` + run, release.  A warm fleet from
        // [`SessionPool`] runs the *same* job path against an already-
        // established session, which is why warm == cold bit-for-bit.
        ResolvedBackend::Process => {
            let problem = problem_spec(cfg, "process")?;
            let fault = cfg.on_fault.resolve()?;
            let wire = cfg.wire.resolve()?;
            let plan = ship_plan(oracle, cfg, &params, problem, &parts)?;
            let mut fleet = ProcessBackend::spawn(
                cfg.tree.machines(),
                cfg.threads.unwrap_or(1),
                plan,
                oracle.n(),
                cfg.worker_bin.as_deref(),
                0,
                fault,
                wire,
            )?;
            fleet.begin_job(&params, problem)?;
            let out = run_dist_on(&mut fleet, cfg, parts);
            fleet.release();
            out
        }
        ResolvedBackend::Tcp => {
            let problem = problem_spec(cfg, "tcp")?;
            let fault = cfg.on_fault.resolve()?;
            let wire = cfg.wire.resolve()?;
            let hosts = tcp_hosts(cfg)?;
            let plan = ship_plan(oracle, cfg, &params, problem, &parts)?;
            let mut fleet = TcpBackend::connect(
                &hosts,
                cfg.tree.machines(),
                cfg.threads.unwrap_or(1),
                plan,
                oracle.n(),
                0,
                fault,
                wire,
            )?;
            fleet.begin_job(&params, problem)?;
            let out = run_dist_on(&mut fleet, cfg, parts);
            fleet.release();
            out
        }
    }
}

/// The problem spec remote workers rebuild the oracle from — required by
/// both remote backends.
fn problem_spec<'a>(cfg: &'a DistConfig, backend: &str) -> Result<&'a str, DistError> {
    cfg.problem.as_deref().ok_or_else(|| {
        DistError::backend(format!(
            "the {backend} backend needs DistConfig::problem (a dataset/problem \
             config spec) so workers can rebuild the oracle — config-built \
             experiments attach it automatically"
        ))
    })
}

/// Resolve the tcp backend's worker hosts from the config or the
/// `GREEDYML_HOSTS` environment.
fn tcp_hosts(cfg: &DistConfig) -> Result<Vec<String>, DistError> {
    match &cfg.hosts {
        Some(h) if !h.is_empty() => Ok(h.clone()),
        // An explicitly-set empty list is a configuration error,
        // not an invitation to fall back to the environment.
        Some(_) => Err(DistError::backend("the tcp backend got an empty hosts list")),
        None => tcp::hosts_from_env().transpose()?.ok_or_else(|| {
            DistError::backend(
                "the tcp backend needs worker hosts: set DistConfig::hosts \
                 (--hosts / run.hosts) or GREEDYML_HOSTS to a host:port list \
                 of running `greedyml serve` daemons",
            )
        }),
    }
}

/// Resolve the configured ship mode into the plan a remote backend
/// executes at Init time: the rebuild recipe (`spec`), or one dataset
/// shard per machine (`partition`).
fn ship_plan<'a>(
    oracle: &dyn Oracle,
    cfg: &DistConfig,
    params: &NodeParams,
    problem: &'a str,
    parts: &[Vec<ElemId>],
) -> Result<ShipPlan<'a>, DistError> {
    match cfg.ship.resolve()? {
        ShipMode::Spec => Ok(ShipPlan::Spec(problem)),
        ShipMode::Partition => {
            let p = oracle.partitionable().ok_or_else(|| {
                DistError::backend(format!(
                    "the '{}' oracle does not support partition shipping (its data \
                     cannot be sliced into shards) — run with --ship spec",
                    oracle.name()
                ))
            })?;
            if p.needs_local_view() && !cfg.local_view {
                return Err(DistError::backend(format!(
                    "partition shipping the '{}' objective needs machine-local \
                     evaluation views: a worker holding an O(n/m) shard cannot \
                     evaluate f against the full dataset — enable local_view \
                     (the paper's §6.4 scheme) or run with --ship spec",
                    oracle.name()
                )));
            }
            Ok(ShipPlan::Partition { payloads: ship_payloads(p, parts, cfg.tree, params) })
        }
    }
}

/// One Init shard per machine: its leaf partition plus the §6.4 added
/// elements every accumulation it will run is seeded to draw
/// ([`crate::dist::node`]'s `sample_added` is deterministic in
/// `(seed, level, machine)`, so the coordinator can replay the draws).
/// Everything else a machine ever evaluates arrives later with the child
/// solutions it receives ([`crate::dist::node::ChildMsg::data`]).
fn ship_payloads(
    p: &dyn Partitionable,
    parts: &[Vec<ElemId>],
    tree: AccumulationTree,
    params: &NodeParams,
) -> Vec<PartitionPayload> {
    parts
        .iter()
        .enumerate()
        .map(|(id, part)| {
            let id = id as MachineId;
            let mut elems = part.clone();
            let mut seen: std::collections::HashSet<ElemId> =
                elems.iter().copied().collect();
            for level in 1..=tree.level_of(id) {
                for e in crate::dist::node::sample_added(params, level, id) {
                    if seen.insert(e) {
                        elems.push(e);
                    }
                }
            }
            p.extract_partition(&elems)
        })
        .collect()
}

/// Line 2 of Algorithm 3.1: split the ground set over the `m` leaves.
/// Deterministic in `(cfg.seed, cfg.partition, n, m)` — the partition-
/// shipping coordinator builds Init shards from the same split the
/// engine later hands to `Backend::run_leaves`.  An explicit
/// [`DistConfig::parts`] pin (live runs keep a fleet's resident shards
/// in lockstep with the coordinator across deltas) overrides the draw.
fn make_parts(cfg: &DistConfig, n: usize) -> Result<Vec<Vec<ElemId>>, DistError> {
    let m = cfg.tree.machines();
    if let Some(parts) = &cfg.parts {
        if parts.len() != m as usize {
            return Err(DistError::backend(format!(
                "DistConfig::parts pins {} partitions for {m} machines",
                parts.len()
            )));
        }
        return Ok(parts.clone());
    }
    Ok(match cfg.partition {
        PartitionScheme::Random => RandomTape::draw(n, m, cfg.seed).partition(),
        PartitionScheme::Contiguous => {
            let mut parts = vec![Vec::new(); m as usize];
            for e in 0..n {
                parts[(e * m as usize / n.max(1)).min(m as usize - 1)].push(e as ElemId);
            }
            parts
        }
    })
}

// ---- resident-shard session pool ---------------------------------------

/// Everything that must match for a warm fleet to answer a run without
/// re-shipping: where the workers live, what dataset they hold resident,
/// and — under partition shipping — exactly which shard split was cut.
#[derive(Clone, Debug, PartialEq, Eq)]
struct SessionKey {
    backend: ResolvedBackend,
    ship: ShipMode,
    /// Resolved frame encoding: a fleet whose workers adopted one mode at
    /// session-open speaks it for the session's lifetime, so a job asking
    /// for the other mode needs a fresh fleet.
    wire: WireMode,
    tree: AccumulationTree,
    threads: usize,
    /// Canonical dataset/objective fingerprint — [`dataset_fingerprint`].
    fingerprint: String,
    /// Resolved worker hosts (tcp only).
    hosts: Option<Vec<String>>,
    worker_bin: Option<String>,
    /// Pinned shard split (partition shipping only).
    part: Option<PartPin>,
    /// Dataset epoch the resident shards are at.  A fleet holding
    /// pre-delta data never key-matches a post-delta job — it is either
    /// advanced in place ([`run_dist_pooled_live`]) or evicted, so stale
    /// shards are structurally unreachable.
    epoch: u64,
}

/// Under partition shipping the resident shards were cut for exactly one
/// `(seed, scheme, n, added_elements)` — the §6.4 added-element draws are
/// baked into each machine's shard — so only a job replaying that split
/// can reuse the session.  Spec shipping has no pin: workers hold the
/// whole dataset, and any seed's split is a subset of it.
#[derive(Clone, Debug, PartialEq, Eq)]
struct PartPin {
    seed: u64,
    scheme: PartitionScheme,
    n: usize,
    added_elements: usize,
}

/// Canonical fingerprint of the dataset a problem spec rebuilds: the
/// `dataset.*` and `objective.*` keys, re-serialized in sorted order.
/// Two specs differing only in run/constraint keys (`problem.k`,
/// `run.seed`…) fingerprint identically, so one resident session serves a
/// whole k-sweep.  A spec that does not parse falls back to its raw text
/// — never reuse across texts we cannot compare.
pub fn dataset_fingerprint(problem: &str) -> String {
    match crate::util::config::Config::parse(problem) {
        Ok(cfg) => {
            let mut out = String::new();
            for prefix in ["dataset", "objective"] {
                for (k, v) in cfg.section(prefix) {
                    out.push_str(k);
                    out.push('=');
                    out.push_str(v);
                    out.push('\n');
                }
            }
            out
        }
        Err(_) => problem.to_string(),
    }
}

/// A session-holding remote fleet, whichever transport carries it.
enum PoolFleet {
    Process(ProcessBackend),
    Tcp(TcpBackend),
}

impl PoolFleet {
    fn begin_job(&mut self, params: &NodeParams, spec: &str) -> Result<(), DistError> {
        match self {
            Self::Process(f) => f.begin_job(params, spec),
            Self::Tcp(f) => f.begin_job(params, spec),
        }
    }

    fn init_bytes(&self) -> u64 {
        match self {
            Self::Process(f) => f.init_bytes(),
            Self::Tcp(f) => f.init_bytes(),
        }
    }

    fn release(&mut self) {
        match self {
            Self::Process(f) => f.release(),
            Self::Tcp(f) => f.release(),
        }
    }

    fn ping_all(&mut self) -> Result<(), DistError> {
        match self {
            Self::Process(f) => f.ping_all(),
            Self::Tcp(f) => f.ping_all(),
        }
    }

    fn advance_epoch(
        &mut self,
        epoch: u64,
        deltas: Vec<crate::objective::PartitionDelta>,
        fresh: Vec<PartitionPayload>,
    ) -> Result<u64, DistError> {
        match self {
            Self::Process(f) => f.advance_epoch(epoch, deltas, fresh),
            Self::Tcp(f) => f.advance_epoch(epoch, deltas, fresh),
        }
    }

    fn as_backend(&mut self) -> &mut dyn Backend {
        match self {
            Self::Process(f) => f,
            Self::Tcp(f) => f,
        }
    }
}

/// Warm remote fleets kept across [`run_dist_pooled`] calls, so many runs
/// against one dataset ship it once — the always-on submodular service's
/// session store.  Sweeps hold one pool per sweep; the job queue
/// ([`crate::coordinator::jobs`]) holds one for its lifetime.
///
/// The pool is a small LRU: a run whose [`SessionKey`] matches a resident
/// fleet reuses it (zero Init bytes); anything else establishes a fresh
/// session, evicting the oldest when full.  A fleet whose job *fails* is
/// dropped, not returned — a worker that died or desynced mid-run must
/// not poison the next job — so the next identical run transparently
/// re-establishes.  Under [`crate::dist::FaultPolicy::Retry`] the pool
/// goes one step further: a job lost to a *retryable* (transport) fault
/// is re-run once against a freshly-established session before the error
/// is surfaced, and warm fleets are pinged before reuse so a daemon that
/// died idle costs a re-establish, not a failed job.  Thread-backend runs
/// never pool (one address space, no shipping to save) and delegate
/// straight to [`run_dist`].
///
/// The pool is **shareable across threads** (`&self` everywhere): the
/// gateway daemon's scheduler runs concurrent jobs against one pool.
/// Each [`run_dist_pooled`] call *checks out* its matching fleet under a
/// short internal lock, runs the whole job with the lock released, and
/// checks the fleet back in afterwards — so N concurrent same-key jobs
/// simply hold N fleets at once (the pool may transiently exceed its
/// capacity; overflow is evicted oldest-first at check-in).  All socket
/// and process I/O — establishing, releasing, pinging, the job itself —
/// happens outside the lock.
pub struct SessionPool {
    state: Mutex<PoolState>,
}

/// The lock-guarded innards of a [`SessionPool`].  Fleets held by an
/// in-flight checkout are *not* in `entries`; every counter lives here so
/// one lock keeps them mutually consistent.
struct PoolState {
    entries: Vec<(SessionKey, PoolFleet)>,
    capacity: usize,
    next_session: u64,
    init_bytes_total: u64,
    sessions_established: u64,
    jobs_run: u64,
    warm_jobs: u64,
    retried_jobs: u64,
    last_was_warm: bool,
}

impl Default for SessionPool {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionPool {
    /// Default capacity: enough for a couple of interleaved datasets
    /// without hoarding worker processes.
    pub const DEFAULT_CAPACITY: usize = 4;

    /// An empty pool with [`SessionPool::DEFAULT_CAPACITY`].
    pub fn new() -> Self {
        Self::with_capacity(Self::DEFAULT_CAPACITY)
    }

    /// An empty pool holding at most `capacity` warm fleets.
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            state: Mutex::new(PoolState {
                entries: Vec::new(),
                capacity: capacity.max(1),
                next_session: 0,
                init_bytes_total: 0,
                sessions_established: 0,
                jobs_run: 0,
                warm_jobs: 0,
                retried_jobs: 0,
                last_was_warm: false,
            }),
        }
    }

    /// Lock the pool state.  A poisoned lock is recovered, not propagated:
    /// the state is a table of fleets and counters that is never left
    /// half-updated across an unwind point, and a long-lived daemon must
    /// not brick its pool because one job's thread panicked.
    fn state(&self) -> MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Total `Init`/`InitPart` wire bytes across every session this pool
    /// ever established — the dist_ship bench asserts a 5-job warm sweep
    /// pays exactly one session's worth.
    pub fn init_bytes_total(&self) -> u64 {
        self.state().init_bytes_total
    }

    /// Sessions established (cache misses).
    pub fn sessions_established(&self) -> u64 {
        self.state().sessions_established
    }

    /// Remote jobs run through the pool (warm + cold).
    pub fn jobs_run(&self) -> u64 {
        self.state().jobs_run
    }

    /// Jobs that reused a resident session.
    pub fn warm_jobs(&self) -> u64 {
        self.state().warm_jobs
    }

    /// Jobs re-run on a fresh session after a retryable fault poisoned
    /// their first attempt (non-zero only under `--on-fault retry`).
    pub fn retried_jobs(&self) -> u64 {
        self.state().retried_jobs
    }

    /// Whether the most recent pooled run reused a resident session.
    /// Under concurrent submission this is a last-writer-wins display
    /// value — concurrent callers that need their *own* run's warmth use
    /// [`run_dist_pooled_tracked`].
    pub fn last_was_warm(&self) -> bool {
        self.state().last_was_warm
    }

    /// Release every resident fleet.  The next pooled run re-establishes
    /// from scratch — benches use this to compare cold against warm.
    /// Fleets checked out by in-flight jobs are untouched (they check
    /// back in afterwards).
    pub fn clear(&self) {
        let drained: Vec<(SessionKey, PoolFleet)> = {
            let mut st = self.state();
            st.entries.drain(..).collect()
        };
        for (_, mut fleet) in drained {
            fleet.release();
        }
    }

    /// Remove the resident fleet matching `key`, if any, for exclusive
    /// use by one job.  The caller must check it back in (or drop it).
    fn check_out(&self, key: &SessionKey) -> Option<PoolFleet> {
        let mut st = self.state();
        st.entries
            .iter()
            .position(|(k, _)| k == key)
            .map(|i| st.entries.remove(i).1)
    }

    /// Remove a resident fleet matching `key` in everything but the
    /// dataset epoch (and the epoch-dependent ground-set size of the
    /// partition pin), holding an *older* epoch — the candidate for an
    /// in-place [`PoolFleet::advance_epoch`].  Returns the epoch the
    /// fleet is at along with the fleet; the caller advances it or
    /// releases it, never serves it as-is.
    fn check_out_stale(&self, key: &SessionKey) -> Option<(u64, PoolFleet)> {
        let mut st = self.state();
        let pos = st.entries.iter().position(|(k, _)| {
            k.epoch < key.epoch
                && match (&k.part, &key.part) {
                    // Live sessions are partition-shipped by construction;
                    // deltas change n, so the pin matches on the draw only.
                    (Some(a), Some(b)) => {
                        a.seed == b.seed
                            && a.scheme == b.scheme
                            && a.added_elements == b.added_elements
                    }
                    _ => false,
                }
                && SessionKey { epoch: k.epoch, part: k.part.clone(), ..key.clone() } == *k
        })?;
        let (k, fleet) = st.entries.remove(pos);
        Some((k.epoch, fleet))
    }

    /// Return a fleet that survived its job to the most-recently-used
    /// slot, then release any overflow (oldest first) outside the lock.
    fn check_in(&self, key: SessionKey, fleet: PoolFleet) {
        let overflow: Vec<PoolFleet> = {
            let mut st = self.state();
            st.entries.push((key, fleet));
            let mut out = Vec::new();
            while st.entries.len() > st.capacity {
                out.push(st.entries.remove(0).1);
            }
            out
        };
        for mut old in overflow {
            old.release();
        }
    }

    /// Evict until a slot is free (releasing outside the lock) and hand
    /// out the next session id.
    fn take_slot(&self) -> u64 {
        let (session, evicted) = {
            let mut st = self.state();
            let mut evicted = Vec::new();
            while st.entries.len() >= st.capacity {
                evicted.push(st.entries.remove(0).1);
            }
            let session = st.next_session;
            st.next_session += 1;
            (session, evicted)
        };
        for mut old in evicted {
            old.release();
        }
        session
    }
}

impl Drop for SessionPool {
    fn drop(&mut self) {
        self.clear();
    }
}

/// What [`run_dist_pooled_tracked`] hands back: the outcome plus the
/// per-run pool facts a concurrent caller cannot read from the pool's
/// shared counters without racing other jobs.
pub struct PooledRun {
    /// The run's outcome, bit-identical to [`run_dist`]'s.
    pub outcome: DistOutcome,
    /// Whether *this* run reused a resident session.
    pub warm: bool,
    /// Whether this run was re-driven on a fresh session after a
    /// retryable fault (`--on-fault retry`).
    pub retried: bool,
}

/// [`run_dist`] against a [`SessionPool`]: a run whose session key matches
/// a warm fleet skips the dataset shipping entirely and goes straight to
/// `begin_job`.  Results are bit-identical to [`run_dist`] — warm and cold
/// execute the same job path against the same resident-oracle state.
pub fn run_dist_pooled(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    cfg: &DistConfig,
    pool: &SessionPool,
) -> Result<DistOutcome, DistError> {
    run_dist_pooled_tracked(oracle, constraint, cfg, pool).map(|run| run.outcome)
}

/// [`run_dist_pooled`] with per-run pool facts attached — the form the
/// thread-shared job queue uses, where `pool.last_was_warm()` would be a
/// race against concurrently-finishing jobs.
pub fn run_dist_pooled_tracked(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    cfg: &DistConfig,
    pool: &SessionPool,
) -> Result<PooledRun, DistError> {
    run_dist_pooled_live(oracle, constraint, cfg, pool, None)
}

/// [`run_dist_pooled_tracked`] over a live dataset: `oracle` must be the
/// live problem's current oracle and `cfg.epoch` its current epoch.  A
/// resident fleet at the same epoch is reused as usual; a fleet exactly
/// one epoch behind is **advanced in place** — the newest delta's
/// per-machine sub-deltas ship over the warm connections
/// ([`crate::dist::ProcessBackend::advance_epoch`] /
/// [`crate::dist::TcpBackend::advance_epoch`]) and only the solve
/// re-runs, not the dataset shipping.  A fleet that is staler, or whose
/// advance fails, is released and the session re-established cold;
/// pre-delta shards are never served either way.  The leaf partition
/// replays the delta history over the epoch-0 draw
/// ([`crate::stream::LiveProblem::parts_for`]), so the incremental
/// re-solve is bit-identical to a cold run on the post-delta dataset.
pub fn run_dist_pooled_live(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    cfg: &DistConfig,
    pool: &SessionPool,
    live: Option<&crate::stream::LiveProblem>,
) -> Result<PooledRun, DistError> {
    if let Some(l) = live {
        if cfg.epoch != l.epoch() {
            return Err(DistError::backend(format!(
                "DistConfig::epoch is {} but the live dataset is at epoch {}",
                cfg.epoch,
                l.epoch()
            )));
        }
        if cfg.added_elements != 0 {
            return Err(DistError::backend(
                "live runs do not support added_elements: the §6.4 draws are \
                 baked into resident shards at session-open and cannot follow \
                 the dataset across deltas",
            ));
        }
    }
    let resolved = cfg.backend.resolve()?;
    if resolved == ResolvedBackend::Thread
        || (cfg.backend == BackendSpec::Auto && cfg.problem.is_none())
    {
        // No session to keep warm (or run_dist's env-advisory fallback
        // applies); the thread backend is rebuilt per run by design.  A
        // live run still pins the replayed partition: a fresh draw over
        // the post-delta id space would scatter deleted ids into leaf
        // streams and diverge from the resident-shard split.
        pool.state().last_was_warm = false;
        let outcome = match live {
            Some(l) if cfg.parts.is_none() => {
                let mut pinned = cfg.clone();
                pinned.parts = Some(l.parts_for(make_parts(cfg, l.n0())?, cfg.seed));
                run_dist(oracle, constraint, &pinned)?
            }
            _ => run_dist(oracle, constraint, cfg)?,
        };
        return Ok(PooledRun { outcome, warm: false, retried: false });
    }
    let backend_name = match resolved {
        ResolvedBackend::Process => "process",
        ResolvedBackend::Tcp => "tcp",
        ResolvedBackend::Thread => unreachable!(),
    };
    let problem = problem_spec(cfg, backend_name)?;
    let ship = cfg.ship.resolve()?;
    if live.is_some() && ship != ShipMode::Partition {
        return Err(DistError::backend(
            "live runs need partition shipping (--ship partition): deltas \
             patch resident shards, and spec-shipped workers hold none",
        ));
    }
    let wire = cfg.wire.resolve()?;
    let key = SessionKey {
        backend: resolved,
        ship,
        wire,
        tree: cfg.tree,
        threads: cfg.threads.unwrap_or(1),
        fingerprint: dataset_fingerprint(problem),
        hosts: match resolved {
            ResolvedBackend::Tcp => Some(tcp_hosts(cfg)?),
            _ => None,
        },
        worker_bin: cfg.worker_bin.clone(),
        part: match ship {
            ShipMode::Partition => Some(PartPin {
                seed: cfg.seed,
                scheme: cfg.partition,
                n: oracle.n(),
                added_elements: cfg.added_elements,
            }),
            ShipMode::Spec => None,
        },
        epoch: cfg.epoch,
    };
    let params = NodeParams {
        kind: cfg.kind,
        seed: cfg.seed,
        n: oracle.n(),
        mem_limit: cfg.mem_limit,
        local_view: cfg.local_view,
        added_elements: cfg.added_elements,
        compare_all_children: cfg.compare_all_children,
        coreset: cfg.coreset.resolve()?,
    };
    let compute_parts = || -> Result<Vec<Vec<ElemId>>, DistError> {
        match live {
            // Replay the delta history over the epoch-0 draw: the same
            // split the fleet's resident shards evolved through.
            Some(l) if cfg.parts.is_none() => {
                Ok(l.parts_for(make_parts(cfg, l.n0())?, cfg.seed))
            }
            _ => make_parts(cfg, oracle.n()),
        }
    };
    let parts = compute_parts()?;
    let fault = cfg.on_fault.resolve()?;

    // Checkout: the matching fleet (if any) leaves the pool for this
    // job's exclusive use, under a lock held only for the table scan.
    // Everything below — ping, establish, the job itself — runs with the
    // pool unlocked, so concurrent jobs only contend for microseconds.
    let mut resident = pool.check_out(&key);
    if fault != FaultPolicy::Fail {
        // Ping-before-reuse: under a recovering policy a stale warm fleet
        // (daemon restarted, worker died idle between jobs) is detected
        // *before* the job commits to it, and costs a re-establish instead
        // of a failed or silently-degraded run.  A non-retryable ping
        // failure is real and surfaces.
        if let Some(f) = resident.as_mut() {
            match f.ping_all() {
                Ok(()) => {}
                Err(e) if e.is_retryable() => resident = None,
                Err(e) => return Err(e),
            }
        }
    }
    // A live session exactly one epoch behind advances in place: only
    // the newest delta ships, over the already-warm connections.  Staler
    // fleets — and a fleet whose advance fails for any reason — are
    // released, never reused: serving pre-delta shards silently is the
    // failure mode this path exists to prevent, and a cold re-establish
    // is always correct.
    if resident.is_none() && key.epoch > 0 {
        if let Some((old_epoch, mut stale)) = pool.check_out_stale(&key) {
            let advanced = live.filter(|l| old_epoch + 1 == l.epoch()).and_then(|l| {
                let d = l.history().last()?;
                let subs = l.sub_deltas(d, cfg.tree.machines(), cfg.seed).ok()?;
                let fresh: Vec<PartitionPayload> =
                    parts.iter().map(|p| l.shard(p)).collect::<Result<_, _>>().ok()?;
                stale.advance_epoch(l.epoch(), subs, fresh).ok()
            });
            if advanced.is_some() {
                resident = Some(stale);
            } else {
                stale.release();
            }
        }
    }
    let warm = resident.is_some();

    let establish = |parts: &[Vec<ElemId>]| -> Result<PoolFleet, DistError> {
        let session = pool.take_slot();
        let plan = ship_plan(oracle, cfg, &params, problem, parts)?;
        let fleet = match resolved {
            ResolvedBackend::Process => PoolFleet::Process(ProcessBackend::spawn(
                cfg.tree.machines(),
                key.threads,
                plan,
                oracle.n(),
                cfg.worker_bin.as_deref(),
                session,
                fault,
                wire,
            )?),
            ResolvedBackend::Tcp => PoolFleet::Tcp(TcpBackend::connect(
                key.hosts.as_deref().expect("tcp key carries hosts"),
                cfg.tree.machines(),
                key.threads,
                plan,
                oracle.n(),
                session,
                fault,
                wire,
            )?),
            ResolvedBackend::Thread => unreachable!(),
        };
        let mut st = pool.state();
        st.init_bytes_total += fleet.init_bytes();
        st.sessions_established += 1;
        Ok(fleet)
    };

    let mut fleet = match resident {
        Some(f) => f,
        None => establish(&parts)?,
    };
    let out = fleet
        .begin_job(&params, problem)
        .and_then(|()| run_dist_on(fleet.as_backend(), cfg, parts));
    {
        let mut st = pool.state();
        st.jobs_run += 1;
        st.last_was_warm = warm;
    }
    match out {
        Ok(outcome) => {
            if warm {
                pool.state().warm_jobs += 1;
            }
            // The fleet survived the job — most-recently-used slot.
            pool.check_in(key, fleet);
            Ok(PooledRun { outcome, warm, retried: false })
        }
        Err(e) if fault == FaultPolicy::Retry && e.is_retryable() => {
            // The fleet's own supervisor already retried worker-level
            // revival; reaching here means the session itself is beyond
            // saving (revival attempts exhausted, or the fault hit during
            // admission).  Un-poison at the pool level: drop the fleet,
            // establish a fresh session, and re-run the job exactly once —
            // the replayed job is deterministic, so a success here is
            // bit-identical to an unfaulted run.
            drop(fleet);
            pool.state().retried_jobs += 1;
            let reparts = compute_parts()?;
            let mut fresh = establish(&reparts)?;
            let retry = fresh
                .begin_job(&params, problem)
                .and_then(|()| run_dist_on(fresh.as_backend(), cfg, reparts));
            {
                let mut st = pool.state();
                st.jobs_run += 1;
                st.last_was_warm = false;
            }
            match retry {
                Ok(outcome) => {
                    pool.check_in(key, fresh);
                    Ok(PooledRun { outcome, warm: false, retried: true })
                }
                Err(e2) => {
                    drop(fresh);
                    Err(e2)
                }
            }
        }
        Err(e) => {
            // Poisoned: drop the fleet (workers reaped / sockets closed on
            // Drop).  The next identical run re-establishes cleanly.
            drop(fleet);
            Err(e)
        }
    }
}

/// One distributed run against an already-constructed backend: partition,
/// walk the accumulation tree, aggregate.  Contains no executor, shipping
/// or metering logic of its own — that is the backend's contract.
fn run_dist_on(
    backend: &mut dyn Backend,
    cfg: &DistConfig,
    parts: Vec<Vec<ElemId>>,
) -> Result<DistOutcome, DistError> {
    let tree = cfg.tree;

    let mut levels: Vec<LevelStats> = Vec::with_capacity(tree.levels() as usize + 1);
    let mut trace_steps: Vec<NodeStep> = Vec::new();
    let mut max_accum_elems = 0usize;

    // ---- Level 0 superstep: GREEDY on every partition. -----------------
    let leaf_reports = backend.run_leaves(parts)?;
    levels.push(collect_reports(0, &leaf_reports, &mut trace_steps, &mut max_accum_elems));

    // ---- Levels 1..=L: accumulate. -------------------------------------
    for level in 1..=tree.levels() {
        let tasks: Vec<AccumTask> = tree
            .nodes_at_level(level)
            .into_iter()
            .map(|id| AccumTask {
                parent: id,
                // j = 0 is the node itself: its S_prev stays in place.
                children: tree.children(level, id).into_iter().filter(|&c| c != id).collect(),
            })
            .collect();
        let reports = backend.run_superstep(level, &tasks)?;
        levels.push(collect_reports(level, &reports, &mut trace_steps, &mut max_accum_elems));
    }

    // ---- Collect the root and every machine's lifetime stats. ----------
    let fin = backend.finish()?;

    let critical_calls = fin.machines[0].calls;
    let total_calls = fin.machines.iter().map(|s| s.calls).sum();
    let comp_secs = levels.iter().map(|l| l.comp_secs).sum();
    let comm_secs = levels.iter().map(|l| l.comm_secs).sum();

    Ok(DistOutcome {
        solution: fin.solution,
        value: fin.value,
        machines: fin.machines,
        levels,
        critical_calls,
        total_calls,
        comp_secs,
        comm_secs,
        comm_measured: backend.measures_comm(),
        max_accum_elems,
        trace: Trace::new(trace_steps),
        faults: fin.faults,
    })
}

/// Record one superstep's reports into the trace, track the largest
/// accumulation union, and fold them into the level aggregate.
fn collect_reports(
    level: u32,
    reports: &[StepReport],
    trace: &mut Vec<NodeStep>,
    max_accum_elems: &mut usize,
) -> LevelStats {
    for r in reports {
        trace.push(NodeStep {
            machine: r.machine,
            level: r.level,
            comp_secs: r.comp_secs,
            comm_secs: r.comm_secs,
            calls: r.calls,
            peak_mem: r.peak_mem,
        });
        *max_accum_elems = (*max_accum_elems).max(r.accum_elems);
    }
    aggregate_level(level, reports)
}

/// Fold one superstep's per-node reports into a [`LevelStats`]: BSP
/// semantics — the superstep lasts as long as its slowest node.
fn aggregate_level(level: u32, reports: &[StepReport]) -> LevelStats {
    let mut out = LevelStats { level, ..Default::default() };
    for r in reports {
        out.active_nodes += 1;
        out.comp_secs = out.comp_secs.max(r.comp_secs);
        out.comm_secs = out.comm_secs.max(r.comm_secs);
        out.max_calls = out.max_calls.max(r.calls);
        out.total_calls += r.calls;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraint::Cardinality;
    use crate::objective::{KCover, KDominatingSet, Oracle};
    use crate::tree::AccumulationTree;
    use std::sync::Arc;

    fn cover_oracle(n: usize, seed: u64) -> KCover {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: n,
                num_items: n / 2,
                mean_size: 6.0,
                zipf_s: 0.9,
            },
            seed,
        );
        KCover::new(Arc::new(data))
    }

    #[test]
    fn runs_and_produces_feasible_solution() {
        let o = cover_oracle(600, 3);
        let c = Cardinality::new(12);
        let cfg = DistConfig::greedyml(AccumulationTree::new(8, 2), 42);
        let out = run_greedyml(&o, &c, &cfg).unwrap();
        assert!(out.solution.len() <= 12);
        assert!(out.value > 0.0);
        assert!((out.value - o.eval(&out.solution)).abs() < 1e-9);
        assert_eq!(out.machines.len(), 8);
        assert_eq!(out.levels.len(), 4, "L=3 ⇒ 4 supersteps");
        assert_eq!(out.critical_calls, out.machines[0].calls);
        assert!(out.total_calls >= out.critical_calls);
        assert!(!out.comm_measured, "thread backend models comm");
    }

    #[test]
    fn deterministic_given_seed() {
        let o = cover_oracle(400, 5);
        let c = Cardinality::new(8);
        let cfg = DistConfig::greedyml(AccumulationTree::new(6, 2), 7);
        let a = run_greedyml(&o, &c, &cfg).unwrap();
        let b = run_greedyml(&o, &c, &cfg).unwrap();
        assert_eq!(a.solution, b.solution);
        assert_eq!(a.total_calls, b.total_calls);
        let cfg2 = DistConfig { seed: 8, ..cfg.clone() };
        let c2 = run_greedyml(&o, &c, &cfg2).unwrap();
        assert_ne!(a.solution, c2.solution, "different tape should differ");
    }

    #[test]
    fn value_close_to_sequential() {
        let o = cover_oracle(800, 9);
        let c = Cardinality::new(16);
        let seq = crate::greedy::greedy_lazy(&o, &c, &(0..800).collect::<Vec<_>>(), None);
        for b in [2u32, 4, 8] {
            let cfg = DistConfig::greedyml(AccumulationTree::new(8, b), 1);
            let out = run_greedyml(&o, &c, &cfg).unwrap();
            assert!(
                out.value >= 0.75 * seq.value,
                "b={b}: dist {} vs seq {}",
                out.value,
                seq.value
            );
        }
    }

    #[test]
    fn memory_limit_trips_at_root_of_wide_tree() {
        // Wide accumulation (b = m) must hold m−1 child solutions at the
        // root; a narrow tree (b = 2) holds only 1. Choose a limit between.
        let g = Arc::new(crate::data::gen::barabasi_albert(2000, 3, 5));
        let o = KDominatingSet::new(g);
        let k = 40;
        let c = Cardinality::new(k);
        // Probe memory: unlimited wide run's root peak.
        let wide = DistConfig::greedyml(AccumulationTree::randgreedi(16), 3);
        let ok = run_greedyml(&o, &c, &wide).unwrap();
        let root_peak = ok.machines[0].peak_mem;
        let limit = root_peak * 2 / 3;
        let wide_limited = DistConfig { mem_limit: Some(limit), ..wide };
        let err = run_greedyml(&o, &c, &wide_limited).unwrap_err();
        match err {
            DistError::OutOfMemory { machine, level, .. } => {
                assert_eq!(machine, 0, "root is the bottleneck");
                assert_eq!(level, 1);
            }
            other => panic!("expected OOM, got {other:?}"),
        }
        // The same limit with a binary tree succeeds (more levels, less
        // fan-in) — the paper's headline memory result (§6.2).
        let narrow = DistConfig {
            mem_limit: Some(limit),
            ..DistConfig::greedyml(AccumulationTree::new(16, 2), 3)
        };
        let out = run_greedyml(&o, &c, &narrow).unwrap();
        assert!(out.value > 0.0);
        assert!(out.peak_mem() <= limit);
    }

    #[test]
    fn single_machine_tree_equals_sequential() {
        let o = cover_oracle(200, 11);
        let c = Cardinality::new(6);
        let cfg = DistConfig::greedyml(AccumulationTree::new(1, 2), 5);
        let out = run_greedyml(&o, &c, &cfg).unwrap();
        let seq = crate::greedy::greedy_lazy(&o, &c, &(0..200).collect::<Vec<_>>(), None);
        assert_eq!(out.solution, seq.solution);
        assert_eq!(out.levels.len(), 1);
        assert_eq!(out.comm_secs, 0.0);
    }

    #[test]
    fn comm_bytes_flow_up_the_tree() {
        let o = cover_oracle(400, 2);
        let c = Cardinality::new(10);
        let cfg = DistConfig::greedyml(AccumulationTree::new(4, 2), 9);
        let out = run_greedyml(&o, &c, &cfg).unwrap();
        let sent: u64 = out.machines.iter().map(|m| m.bytes_sent).sum();
        let received: u64 = out.machines.iter().map(|m| m.bytes_received).sum();
        assert_eq!(sent, received, "no bytes lost in flight");
        assert!(sent > 0);
        assert!(out.comm_secs > 0.0);
        // Non-root machines each send exactly once.
        for mstats in &out.machines[1..] {
            assert!(mstats.bytes_sent > 0, "machine {} never sent", mstats.id);
        }
        assert_eq!(out.machines[0].bytes_sent, 0, "root sends nowhere");
    }

    #[test]
    fn added_elements_join_the_accumulation() {
        let o = cover_oracle(300, 4);
        let c = Cardinality::new(8);
        let base = DistConfig::greedyml(AccumulationTree::new(4, 2), 13);
        let with_added = DistConfig { added_elements: 50, ..base.clone() };
        let a = run_greedyml(&o, &c, &base).unwrap();
        let b = run_greedyml(&o, &c, &with_added).unwrap();
        assert!(b.max_accum_elems >= a.max_accum_elems + 50 - 8);
        // More candidates can only help (or tie) coverage quality here.
        assert!(b.value >= a.value * 0.95);
    }

    #[test]
    fn accumulation_union_is_deduplicated() {
        // added_elements = n draws the whole ground set at every
        // accumulation step; since D is a distinct union, no accumulator
        // can ever see more candidates than the ground set holds.  (The
        // pre-dedup union was |S_prev| + Σ|child| + n > n.)
        let n = 100;
        let o = cover_oracle(n, 6);
        let c = Cardinality::new(5);
        let cfg = DistConfig {
            added_elements: n,
            ..DistConfig::greedyml(AccumulationTree::new(4, 2), 3)
        };
        let out = run_greedyml(&o, &c, &cfg).unwrap();
        assert!(
            out.max_accum_elems <= n,
            "{} accumulation candidates from a {n}-element ground set",
            out.max_accum_elems
        );
        assert!(out.value > 0.0);
    }

    #[test]
    fn trace_steps_carry_memory_watermarks() {
        let o = cover_oracle(300, 8);
        let c = Cardinality::new(8);
        let cfg = DistConfig::greedyml(AccumulationTree::new(4, 2), 21);
        let out = run_greedyml(&o, &c, &cfg).unwrap();
        assert!(out.trace.steps().iter().all(|s| s.peak_mem > 0));
        // The root's last watermark equals its lifetime peak.
        let root_last = out
            .trace
            .steps()
            .iter()
            .filter(|s| s.machine == 0)
            .last()
            .expect("root steps present");
        assert_eq!(root_last.peak_mem, out.machines[0].peak_mem);
    }

    #[test]
    fn tcp_backend_without_hosts_errors() {
        // An explicit empty list (rather than None) keeps the test
        // deterministic: hosts: None would consult GREEDYML_HOSTS, and a
        // developer's ambient environment must not change the outcome.
        let o = cover_oracle(100, 2);
        let c = Cardinality::new(4);
        let cfg = DistConfig {
            backend: crate::dist::BackendSpec::Tcp,
            problem: Some("dataset.kind = retail\ndataset.n = 100\n".to_string()),
            hosts: Some(Vec::new()),
            ..DistConfig::greedyml(AccumulationTree::new(2, 2), 1)
        };
        match run_greedyml(&o, &c, &cfg).unwrap_err() {
            DistError::Backend { message } => {
                assert!(message.contains("hosts"), "{message}")
            }
            other => panic!("expected backend error, got {other:?}"),
        }
    }

    #[test]
    fn fingerprint_ignores_constraint_and_run_keys() {
        let a = "dataset.kind = retail\ndataset.n = 300\nproblem.k = 4\nrun.seed = 1\n";
        let b = "dataset.n = 300\ndataset.kind = retail\nproblem.k = 12\n";
        assert_eq!(
            dataset_fingerprint(a),
            dataset_fingerprint(b),
            "same dataset, different job → one resident session serves both"
        );
        let c = "dataset.kind = retail\ndataset.n = 301\nproblem.k = 4\n";
        assert_ne!(dataset_fingerprint(a), dataset_fingerprint(c));
    }

    #[test]
    fn fingerprint_covers_objective_settings() {
        let a = "dataset.kind = retail\ndataset.n = 100\nobjective.kind = kcover\n";
        let b = "dataset.kind = retail\ndataset.n = 100\nobjective.kind = modular\n";
        assert_ne!(
            dataset_fingerprint(a),
            dataset_fingerprint(b),
            "a session's resident oracle is objective-specific"
        );
    }

    #[test]
    fn pooled_thread_runs_bypass_the_pool_and_match_run_dist() {
        let o = cover_oracle(300, 3);
        let c = Cardinality::new(8);
        let cfg = DistConfig::greedyml(AccumulationTree::new(4, 2), 11);
        let pool = SessionPool::new();
        let pooled = run_dist_pooled(&o, &c, &cfg, &pool).unwrap();
        let direct = run_dist(&o, &c, &cfg).unwrap();
        assert_eq!(pooled.solution, direct.solution);
        assert_eq!(pooled.value.to_bits(), direct.value.to_bits());
        assert!(!pool.last_was_warm());
        assert_eq!(pool.jobs_run(), 0, "thread runs hold no session");
        assert_eq!(pool.sessions_established(), 0);
        assert_eq!(pool.init_bytes_total(), 0);
    }

    #[test]
    fn session_pool_is_shareable_across_threads() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SessionPool>();
        // Concurrent pooled runs through one shared pool (thread backend:
        // the pool is bypassed, but the checkout/counter paths still run
        // under contention) stay bit-identical to a direct run.
        let o = cover_oracle(200, 7);
        let c = Cardinality::new(6);
        let cfg = DistConfig::greedyml(AccumulationTree::new(4, 2), 5);
        let pool = SessionPool::new();
        let direct = run_dist(&o, &c, &cfg).unwrap();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..2)
                .map(|_| s.spawn(|| run_dist_pooled(&o, &c, &cfg, &pool).unwrap()))
                .collect();
            for h in handles {
                let out = h.join().unwrap();
                assert_eq!(out.solution, direct.solution);
                assert_eq!(out.value.to_bits(), direct.value.to_bits());
            }
        });
    }

    #[test]
    fn explicit_parts_pin_overrides_the_seeded_draw() {
        let o = cover_oracle(200, 11);
        let c = Cardinality::new(6);
        // The pin reproduces the contiguous split exactly, so the pinned
        // run must agree with the drawn one bit-for-bit.
        let custom: Vec<Vec<ElemId>> = vec![(0..100).collect(), (100..200).collect()];
        let cfg = DistConfig {
            parts: Some(custom),
            partition: PartitionScheme::Contiguous,
            ..DistConfig::greedyml(AccumulationTree::new(2, 2), 5)
        };
        let out = run_greedyml(&o, &c, &cfg).unwrap();
        let drawn = DistConfig { parts: None, ..cfg.clone() };
        let base = run_greedyml(&o, &c, &drawn).unwrap();
        assert_eq!(out.solution, base.solution);
        assert_eq!(out.value.to_bits(), base.value.to_bits());

        let bad = DistConfig { parts: Some(vec![(0..200).collect()]), ..cfg };
        let err = run_greedyml(&o, &c, &bad).unwrap_err();
        assert!(err.to_string().contains("2 machines"), "{err}");
    }

    #[test]
    fn live_pooled_runs_validate_epoch_and_ship_mode() {
        let o = cover_oracle(100, 2);
        let c = Cardinality::new(4);
        let pool = SessionPool::new();
        let live = crate::stream::LiveProblem::new(&o).unwrap();
        // An epoch mismatch is caught before any backend work.
        let cfg =
            DistConfig { epoch: 3, ..DistConfig::greedyml(AccumulationTree::new(2, 2), 1) };
        let err = run_dist_pooled_live(&o, &c, &cfg, &pool, Some(&live)).unwrap_err();
        assert!(err.to_string().contains("epoch"), "{err}");
        // Live + spec shipping is rejected with a pointer at --ship.
        let cfg = DistConfig {
            backend: crate::dist::BackendSpec::Process,
            problem: Some("dataset.kind = retail\ndataset.n = 100\n".to_string()),
            ship: crate::dist::ShipSpec::Spec,
            ..DistConfig::greedyml(AccumulationTree::new(2, 2), 1)
        };
        let err = run_dist_pooled_live(&o, &c, &cfg, &pool, Some(&live)).unwrap_err();
        assert!(err.to_string().contains("partition shipping"), "{err}");
        assert_eq!(pool.sessions_established(), 0, "nothing was established");
    }

    #[test]
    fn pooled_run_surfaces_the_same_config_errors_as_run_dist() {
        let o = cover_oracle(100, 2);
        let c = Cardinality::new(4);
        let cfg = DistConfig {
            backend: crate::dist::BackendSpec::Process,
            ..DistConfig::greedyml(AccumulationTree::new(2, 2), 1)
        };
        let pool = SessionPool::new();
        match run_dist_pooled(&o, &c, &cfg, &pool).unwrap_err() {
            DistError::Backend { message } => {
                assert!(message.contains("problem"), "{message}")
            }
            other => panic!("expected backend error, got {other:?}"),
        }
        assert_eq!(pool.sessions_established(), 0, "nothing was established");
    }

    #[test]
    fn process_backend_without_problem_spec_errors() {
        let o = cover_oracle(100, 2);
        let c = Cardinality::new(4);
        let cfg = DistConfig {
            backend: crate::dist::BackendSpec::Process,
            ..DistConfig::greedyml(AccumulationTree::new(2, 2), 1)
        };
        match run_greedyml(&o, &c, &cfg).unwrap_err() {
            DistError::Backend { message } => {
                assert!(message.contains("problem"), "{message}")
            }
            other => panic!("expected backend error, got {other:?}"),
        }
    }
}
