//! The four algorithms the paper compares:
//!
//! * [`seq::run_sequential`] — GREEDY / Lazy Greedy on the whole dataset.
//! * [`greedi::run_greedi`] — GreeDI (Mirzasoleiman et al.): *arbitrary*
//!   partition, single accumulation.
//! * [`randgreedi::run_randgreedi`] — RandGreeDI (Barbosa et al.,
//!   Algorithm 2.2): uniform random partition, single accumulation,
//!   argmax over the global solution and every local one.
//! * [`greedyml::run_greedyml`] — this paper's GreedyML (Algorithm 3.1):
//!   uniform random partition, multi-level accumulation tree, per-node
//!   argmax against the node's own previous-level solution (Fig. 3).
//!
//! All four share one engine ([`greedyml::run_dist`]) parameterized by
//! partition scheme, tree shape and argmax semantics, so comparisons
//! measure the algorithmic difference and nothing else.

use crate::dist::{
    BackendSpec, CommModel, CoresetSpec, FaultReport, FaultSpec, MachineStats, ShipSpec,
    WireSpec,
};
use crate::greedy::GreedyKind;
use crate::tree::AccumulationTree;
use crate::ElemId;

pub mod greedi;
pub mod greedyml;
pub mod randgreedi;
pub mod seq;

pub use greedi::{greedi_config, run_greedi};
pub use greedyml::{
    dataset_fingerprint, run_dist, run_dist_pooled, run_dist_pooled_live,
    run_dist_pooled_tracked, run_greedyml, PooledRun, SessionPool,
};
pub use randgreedi::run_randgreedi;
pub use seq::run_sequential;

/// How the ground set is split across leaf machines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionScheme {
    /// Uniform random (the random tape `r_W`; RandGreeDI / GreedyML).
    Random,
    /// Contiguous chunks (GreeDI's "arbitrary" partition).
    Contiguous,
}

/// Configuration of one distributed run.
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// Accumulation tree (machines + branching; RandGreeDI = b = m).
    pub tree: AccumulationTree,
    /// Greedy implementation at every node (paper uses Lazy).
    pub kind: GreedyKind,
    /// Seed of the random tape.
    pub seed: u64,
    /// Per-machine memory limit in bytes (None = unlimited).
    pub mem_limit: Option<u64>,
    /// Partition scheme.
    pub partition: PartitionScheme,
    /// Evaluate objectives against machine-local ground sets (the paper's
    /// k-medoid scheme, §6.4). Coverage objectives ignore the view.
    pub local_view: bool,
    /// Random extra elements added to every accumulation step (§6.4
    /// "added images" variant). 0 = local-only.
    pub added_elements: usize,
    /// RandGreeDI argmax semantics: compare the merged solution against
    /// *every* child solution (Algorithm 2.2 line 7) instead of only the
    /// node's own previous solution (Fig. 3).
    pub compare_all_children: bool,
    /// Communication cost model.
    pub comm: CommModel,
    /// Worker threads of the two-level executor serving the run (`None` =
    /// the `GREEDYML_THREADS` environment variable, else all cores).
    /// Results are bit-identical across thread counts; `Some(1)` runs the
    /// whole simulation serially on the calling thread.
    pub threads: Option<usize>,
    /// Execution backend: in-process thread pool (modeled comm), one
    /// worker process per machine (measured comm), or one TCP session per
    /// machine on remote `greedyml serve` daemons (measured comm over a
    /// real network).  [`BackendSpec::Auto`] defers to the
    /// `GREEDYML_BACKEND` environment variable.  Solutions are
    /// bit-identical across backends.
    pub backend: BackendSpec,
    /// Problem spec for the process and tcp backends: flat `key = value`
    /// config text (`dataset.*` / `problem.*` / `objective.*`) that a
    /// worker parses to rebuild the oracle and constraint in its own
    /// address space.  Required when those backends are selected; ignored
    /// by the thread backend.  See [`crate::coordinator::problem_spec`].
    pub problem: Option<String>,
    /// How the problem travels to process/tcp workers
    /// ([`ShipSpec::Spec`]: rebuild recipe, O(n) worker memory;
    /// [`ShipSpec::Partition`]: O(n/m) dataset shards, solutions travel
    /// with their data).  [`ShipSpec::Auto`] defers to the `GREEDYML_SHIP`
    /// environment variable.  Config key `run.ship` (`sweep.ship` for
    /// sweeps) / CLI flag `--ship`.  The thread backend ignores it.
    /// Results are bit-identical across modes.
    pub ship: ShipSpec,
    /// Worker executable for the process backend (`None` = the
    /// `GREEDYML_WORKER_BIN` environment variable, else this binary).
    /// Integration tests point this at the real `greedyml` binary.
    pub worker_bin: Option<String>,
    /// Worker daemons for the tcp backend, as `host:port` entries
    /// (machine `i` connects to `hosts[i % hosts.len()]`).  `None` defers
    /// to the `GREEDYML_HOSTS` environment variable; selecting the tcp
    /// backend with neither — or with an explicitly empty list — is an
    /// error.  Config key `run.hosts` (`sweep.hosts` for sweeps) / CLI
    /// flag `--hosts`.
    pub hosts: Option<Vec<String>>,
    /// What a remote run does when a worker dies mid-job
    /// ([`FaultSpec::Fail`]: fail the run, the historical behavior;
    /// [`FaultSpec::Retry`]: re-dispatch the dead machine's work onto a
    /// fresh worker and replay deterministically — bit-identical results;
    /// [`FaultSpec::Degrade`]: drop the dead machine's subtree from the
    /// accumulation and finish on the survivors, with the loss accounted
    /// in [`DistOutcome::faults`]).  [`FaultSpec::Auto`] defers to the
    /// `GREEDYML_ON_FAULT` environment variable.  Config key
    /// `run.on_fault` (`sweep.on_fault` / `jobs.on_fault`) / CLI flag
    /// `--on-fault`.  The thread backend cannot lose workers and ignores
    /// it.  See `docs/failure-model.md`.
    pub on_fault: FaultSpec,
    /// How payload-bearing frames are encoded on the worker wire
    /// ([`WireSpec::Json`]: serde_json everywhere, debuggable;
    /// [`WireSpec::Binary`]: raw little-endian sections for `init_part`
    /// and shipped solutions, control frames stay JSON).
    /// [`WireSpec::Auto`] defers to the `GREEDYML_WIRE` environment
    /// variable.  Config key `run.wire` (`sweep.wire` / `jobs.wire`) /
    /// CLI flag `--wire`.  The thread backend ignores it; results are
    /// bit-identical across modes.  See `docs/wire-protocol.md`.
    pub wire: WireSpec,
    /// Coreset mode ([`CoresetSpec::On`]: every node sieve-streams its
    /// candidate set down to an O(k log n / ε) coreset before the greedy
    /// pass, so accumulation ships coresets instead of full solutions'
    /// shards — bounded memory and wire bytes, value within the sieve's
    /// (1/2 − ε) factor of full GreedyML).  [`CoresetSpec::Auto`] defers
    /// to the `GREEDYML_CORESET` environment variable (default off).
    /// Config key `run.coreset` (`sweep.coreset`) / CLI flag `--coreset`.
    /// See `docs/streaming.md`.
    pub coreset: CoresetSpec,
    /// Dataset epoch of this run — 0 for a static dataset, advanced by
    /// one per applied [`crate::objective::PartitionDelta`] on live runs.
    /// Joins the session-pool key and the job cache key, so pre-delta
    /// fleets and cached solutions are never served for post-delta data.
    pub epoch: u64,
    /// Explicit leaf partition (global ids per machine), overriding the
    /// seeded [`PartitionScheme`] draw.  Live runs use this to keep a
    /// fleet's resident shards and the coordinator's view in lockstep
    /// across deltas.  Every machine must get one entry; `None` draws
    /// from the random tape as usual.
    pub parts: Option<Vec<Vec<ElemId>>>,
}

impl DistConfig {
    /// GreedyML defaults for a given tree.
    pub fn greedyml(tree: AccumulationTree, seed: u64) -> Self {
        Self {
            tree,
            kind: GreedyKind::Lazy,
            seed,
            mem_limit: None,
            partition: PartitionScheme::Random,
            local_view: false,
            added_elements: 0,
            compare_all_children: false,
            comm: CommModel::default(),
            threads: None,
            backend: BackendSpec::Auto,
            problem: None,
            ship: ShipSpec::Auto,
            worker_bin: None,
            hosts: None,
            on_fault: FaultSpec::Auto,
            wire: WireSpec::Auto,
            coreset: CoresetSpec::Auto,
            epoch: 0,
            parts: None,
        }
    }
}

/// Per-level aggregates (one BSP superstep each).
#[derive(Clone, Debug, Default)]
pub struct LevelStats {
    /// Tree level (0 = leaves).
    pub level: u32,
    /// Number of nodes that computed at this level.
    pub active_nodes: usize,
    /// Max computation seconds over active nodes (BSP superstep length).
    pub comp_secs: f64,
    /// Max modeled communication seconds over active nodes.
    pub comm_secs: f64,
    /// Max gain queries by any active node at this level.
    pub max_calls: u64,
    /// Total gain queries across the level.
    pub total_calls: u64,
}

/// Result of a distributed run.
#[derive(Clone, Debug)]
pub struct DistOutcome {
    /// Final solution (from the root, machine 0).
    pub solution: Vec<ElemId>,
    /// Objective value of the final solution as seen at the root (under
    /// its evaluation view if `local_view`).
    pub value: f64,
    /// Per-machine statistics (length = m).
    pub machines: Vec<MachineStats>,
    /// Per-level aggregates (length = L + 1).
    pub levels: Vec<LevelStats>,
    /// Gain queries on the critical path — machine 0's total (§5).
    pub critical_calls: u64,
    /// Total gain queries across all machines.
    pub total_calls: u64,
    /// BSP computation seconds: Σ over levels of the superstep max.
    pub comp_secs: f64,
    /// BSP communication seconds: Σ over levels of the superstep max.
    /// α–β-modeled on the thread backend, measured on the process backend
    /// (see [`DistOutcome::comm_measured`]).
    pub comm_secs: f64,
    /// Whether `comm_secs` was *measured* (process backend: real
    /// serialization + pipe transfer wall time) rather than α–β-modeled.
    pub comm_measured: bool,
    /// Largest candidate-set size any accumulator worked on
    /// (Table 1 "Elements per interior node").
    pub max_accum_elems: usize,
    /// Per-(machine, level) timeline (Chrome-trace exportable).
    pub trace: crate::dist::Trace,
    /// Faults survived on the way to this outcome: empty unless a
    /// supervised remote run ([`DistConfig::on_fault`]) recovered from
    /// worker loss.  Non-empty `machines_dropped` means the run *degraded*
    /// — the solution is feasible but was computed without the dropped
    /// machines' elements ([`FaultReport::elements_lost`] of them), so the
    /// paper's approximation guarantee applies to the surviving ground
    /// set only.  Retried faults (`retries > 0`, nothing dropped) cost
    /// wall time, never solution quality.
    pub faults: FaultReport,
}

impl DistOutcome {
    /// Total modeled runtime (computation + communication).
    pub fn total_secs(&self) -> f64 {
        self.comp_secs + self.comm_secs
    }

    /// Peak memory over all machines.
    pub fn peak_mem(&self) -> u64 {
        self.machines.iter().map(|m| m.peak_mem).max().unwrap_or(0)
    }
}
