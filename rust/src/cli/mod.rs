//! Minimal command-line argument parser (no `clap` offline).
//!
//! Grammar: `greedyml <command> [positional…] [--key value | --key=value |
//! --flag]…`.  Flags may repeat (`--set a=1 --set b=2`).

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand (first non-flag token), if any.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> crate::Result<Self> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                anyhow::ensure!(!stripped.is_empty(), "bare '--' is not a valid flag");
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let value = match inline {
                    Some(v) => v,
                    None => {
                        // Consume the next token as the value unless it is
                        // another flag (then this is a boolean flag).
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                out.flags.entry(key).or_default().push(value);
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> crate::Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Last value of a flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeatable flag.
    pub fn get_all(&self, key: &str) -> &[String] {
        self.flags.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Boolean flag (present and not "false").
    pub fn has(&self, key: &str) -> bool {
        matches!(self.get(key), Some(v) if v != "false")
    }

    /// Required string flag.
    pub fn require(&self, key: &str) -> crate::Result<&str> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing required flag --{key}"))
    }

    /// u64 flag with default (supports k/m/g suffixes).
    pub fn u64_or(&self, key: &str, default: u64) -> crate::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => crate::util::config::parse_u64(v)
                .map_err(|m| anyhow::anyhow!("flag --{key}: {m}")),
        }
    }

    /// Unknown-flag guard: error if any flag is not in `allowed`.
    pub fn check_known(&self, allowed: &[&str]) -> crate::Result<()> {
        for key in self.flags.keys() {
            anyhow::ensure!(
                allowed.contains(&key.as_str()),
                "unknown flag --{key} (allowed: {})",
                allowed.join(", ")
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn commands_positionals_flags() {
        let a = parse(&[
            "run", "extra", "--config", "exp.toml", "--set", "a=1", "--set=b=2", "--verbose",
        ]);
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["extra"]);
        assert_eq!(a.get("config"), Some("exp.toml"));
        assert_eq!(a.get_all("set"), &["a=1", "b=2"]);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse(&["tree", "--show", "--machines", "8"]);
        assert!(a.has("show"));
        assert_eq!(a.get("machines"), Some("8"));
    }

    #[test]
    fn numeric_suffixes() {
        let a = parse(&["run", "--k", "32k", "--mem", "100mb"]);
        assert_eq!(a.u64_or("k", 0).unwrap(), 32_000);
        assert_eq!(a.u64_or("mem", 0).unwrap(), 100 << 20);
        assert_eq!(a.u64_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn unknown_flag_guard() {
        let a = parse(&["run", "--bogus", "1"]);
        assert!(a.check_known(&["config"]).is_err());
        assert!(a.check_known(&["bogus"]).is_ok());
    }

    #[test]
    fn require_errors() {
        let a = parse(&["run"]);
        assert!(a.require("config").is_err());
    }

    #[test]
    fn bare_double_dash_rejected() {
        assert!(Args::parse(["--".to_string()]).is_err());
    }
}
