//! # GreedyML
//!
//! A reproduction of *GreedyML: A Parallel Algorithm for Maximizing
//! Constrained Submodular Functions* (Gopal, Ferdous, Maji, Pothen, 2024).
//!
//! The crate is organised in three layers:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   accumulation tree, the simulated distributed BSP runtime, the
//!   `GreedyML`/`RandGreeDI`/`GreeDI`/sequential-`Greedy` algorithms, the
//!   submodular oracles, constraints, datasets, metrics and benchmarks.
//! * **Layer 2 (python/compile/model.py)** — JAX batched marginal-gain
//!   graphs, lowered once (AOT) to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot spot (k-medoid distance gains, packed-bitmap coverage gains).
//!
//! Python never runs at solve time: `rust/src/runtime` loads the AOT
//! artifacts via the PJRT C API (`xla` crate) and executes them natively.

pub mod util;
pub mod check;
pub mod data;
pub mod objective;
pub mod constraint;
pub mod greedy;
pub mod tree;
// `dist` is the crate's most public surface (backends, wire protocol,
// runtime meters) and the one other backends plug into — every public
// item in it must be documented.
#[warn(missing_docs)]
pub mod dist;
pub mod algo;
pub mod stream;
pub mod bsp;
pub mod metrics;
pub mod runtime;
pub mod coordinator;
pub mod cli;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Element identifier within a ground set. Ground sets are dense `0..n`.
pub type ElemId = u32;

/// Machine identifier (a leaf of the accumulation tree).
pub type MachineId = u32;
