//! Per-machine lifetime statistics.
//!
//! One [`MachineStats`] per simulated machine, accumulated across every
//! superstep the machine participates in.  Machine 0 is active at every
//! level of the accumulation tree, so its `calls` total is the paper's
//! "function calls on the critical path" (§5) and its `peak_mem` is the
//! root-bottleneck number the §6.2 memory experiments revolve around.

use crate::MachineId;

/// Everything one machine did over a distributed run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MachineStats {
    /// Machine id (leaf index in the accumulation tree).
    pub id: MachineId,
    /// Marginal-gain queries issued across all its supersteps.
    pub calls: u64,
    /// Σ of `call_cost` over those queries (the δ-weighted cost of Table 1).
    pub cost: u64,
    /// Wall-clock computation seconds across its supersteps.
    pub comp_secs: f64,
    /// Modeled communication seconds (receives at accumulation steps).
    pub comm_secs: f64,
    /// Bytes shipped to its parent when it retired.
    pub bytes_sent: u64,
    /// Bytes received from children across accumulation steps.
    pub bytes_received: u64,
    /// Peak memory over the machine's lifetime.
    pub peak_mem: u64,
    /// Highest tree level at which the machine computed (0 = leaf only).
    pub top_level: u32,
    /// Largest candidate union |D| the machine ran GREEDY on.
    pub max_accum_elems: usize,
}

impl MachineStats {
    /// Fresh zeroed stats for machine `id`.
    pub fn new(id: MachineId) -> Self {
        Self { id, ..Self::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_is_zeroed_except_id() {
        let s = MachineStats::new(7);
        assert_eq!(s.id, 7);
        assert_eq!(s.calls, 0);
        assert_eq!(s.comp_secs, 0.0);
        assert_eq!(s.bytes_sent, 0);
        assert_eq!(s.top_level, 0);
        assert_eq!(s.max_accum_elems, 0);
    }
}
