//! The worker wire protocol shared by every framed-transport backend.
//!
//! Coordinator and workers speak length-prefixed frames — a 4-byte
//! little-endian payload length, a content-type byte, then the payload —
//! over the worker's stdin/stdout (process backend) or a `TcpStream`
//! (tcp backend).  Content type [`CONTENT_JSON`] (`0x01`) is a
//! `serde_json` document: it keeps the protocol debuggable (any frame
//! can be printed and a session replayed by hand) and `serde_json`'s
//! shortest-roundtrip float formatting (ryu) guarantees `f64` values
//! cross the boundary bit-exactly — the backend-parity suite depends on
//! `f(S)` surviving serialization.  Content type [`CONTENT_BINARY`]
//! (`0x02`, since v5) is the compact raw-little-endian encoding used —
//! when the session runs `--wire binary` ([`super::WireMode`]) — for the
//! *payload-bearing* messages only (`init_part`, shipped solutions);
//! every control frame stays JSON under either mode, and results are
//! bit-identical across modes.  A worker adopts the wire mode from its
//! session-opening frame's content type and mirrors it in replies.
//!
//! The protocol is specified prose-first in `docs/wire-protocol.md`; the
//! `wire_doc_stays_in_lockstep_with_the_codec` test fails if a message
//! variant exists in one place but not the other.
//!
//! Message flow (one worker = one simulated machine; the `Hello`/`Welcome`
//! handshake only happens on TCP connections, where the two endpoints may
//! be different builds).  Since v3 a worker's lifetime is split into a
//! **session** — the dataset travels once and stays resident — and any
//! number of **jobs** run against the resident oracle:
//!
//! ```text
//! coordinator → worker          worker → coordinator
//! --------------------          --------------------
//! Hello{version}                Welcome{version} | Fail(err)  (TCP only)
//! Init{session,machine,
//!      threads,problem}         Ready{n}   (spec shipping: full rebuild)
//! InitPart{session,machine,
//!          threads,payload}     Ready{n}   (partition shipping: n = shard size)
//! ── per job, repeatable ──────────────────────────────────────────────
//! Ping                          Pong       (liveness probe, any time
//!                                          after the session opens)
//! Job{job,params,spec}          Ready{n} | Fail(err)  (state reset,
//!                                          constraint rebuilt from spec)
//! Leaf{part}                    Step(report) | Fail(err)
//! Ship                          Sol(child msg)
//! Recv{level,children}          Ack        (receipt — ends the comm timer)
//! Accum{level,comm_secs}        Step(report) | Fail(err)
//! JobDone                       Final{stats,sol,value}  (worker stays
//!                                          resident for the next Job)
//! ── between jobs (v6, partition shipping only) ───────────────────────
//! Delta{epoch,delta}            DeltaDone{epoch,n} | Fail(err)
//!                                          (apply the machine's
//!                                          sub-delta to the resident
//!                                          shard; epoch advances)
//! ── end of session ───────────────────────────────────────────────────
//! Release                       (no reply; the worker exits)
//! ```

use super::backend::WireMode;
use super::node::{ChildMsg, NodeParams, StepReport};
use super::{DistError, MachineStats};
use crate::greedy::GreedyKind;
use crate::objective::{PartitionDecoder, PartitionDelta, PartitionPayload};
use crate::{ElemId, MachineId};
use serde_json::{json, Value};
use std::io::{Read, Write};

/// Hard cap on one frame's payload (a corrupt length prefix must not make
/// the reader allocate gigabytes).
const MAX_FRAME: u32 = 1 << 30;

/// Content-type byte of a JSON frame (the debuggable encoding; all
/// control frames use it under either wire mode).
pub const CONTENT_JSON: u8 = 0x01;

/// Content-type byte of a binary frame (v5): a one-byte message tag
/// followed by raw little-endian fields, with [`PartitionPayload`]s in
/// their section encoding ([`PartitionPayload::encode_binary`]).
pub const CONTENT_BINARY: u8 = 0x02;

/// Read chunk size of the streaming `init_part` ingestion path
/// ([`read_session_init`]): the decoder sees bytes in socket-read-sized
/// chunks, so section conversion overlaps the transfer.
const STREAM_CHUNK: usize = 64 * 1024;

/// Wire-protocol version, checked by the TCP handshake
/// ([`ToWorker::Hello`] / [`FromWorker::Welcome`]).  Bump whenever a frame
/// is added, removed, or changes field semantics: a `greedyml serve`
/// daemon from a different build must refuse a coordinator it cannot
/// faithfully serve instead of desyncing mid-run.  The process backend
/// skips the handshake — both pipe endpoints are the same binary, so the
/// versions are trivially equal.
///
/// v2: partition shipping — the `init_part` command (a worker receives
/// its dataset shard instead of a rebuild recipe) and the optional `data`
/// field on shipped child solutions.
///
/// v3: resident-shard job sessions — `init`/`init_part` carry a session
/// id and ship the dataset *once* (node parameters and the constraint
/// spec moved off the init frames), the new `job` command starts one run
/// against the resident oracle, `job_done` replaces per-run `finish`
/// (the worker stays resident), and `release` ends the session.  The
/// one-shot `finish` command is gone.
///
/// v4: fault tolerance — the `ping`/`pong` liveness probe (the
/// coordinator checks a warm fleet is still alive before reusing it or
/// after reviving a machine) and the `transport` error kind
/// ([`DistError::Transport`], the retryable class of the fault
/// taxonomy).
///
/// v5: binary streamed payloads — every frame gains a content-type byte
/// after the length prefix ([`CONTENT_JSON`] keeps the v4 JSON documents
/// verbatim; [`CONTENT_BINARY`] is the raw-little-endian section
/// encoding of [`PartitionPayload`]-bearing messages, selected with
/// `--wire binary`), and the worker's `init_part` receive path ingests
/// the shard incrementally ([`read_session_init`]) instead of buffering
/// and parsing the whole frame first.
///
/// v6: live-dataset deltas — the `delta` command fans one machine's
/// [`PartitionDelta`] (inserts with data rows + deletes) to a resident
/// partition-shipped worker, which applies it to its shard in place and
/// confirms with `delta_done`; a session's dataset epoch advances without
/// re-shipping O(n/m) shards.  `delta` gets a binary envelope alongside
/// `init_part`/`sol`/`recv` (JSON fallback as always); `delta_done` is a
/// control frame and stays JSON under either mode.
pub const PROTOCOL_VERSION: u32 = 6;

/// Write one frame with an explicit content type.  Returns the total
/// number of bytes put on the wire (4-byte length prefix + content-type
/// byte + payload) so callers can account shipping cost without
/// re-encoding.  The length prefix counts the payload only, excluding
/// the content-type byte.
fn write_raw_frame(w: &mut impl Write, ctype: u8, bytes: &[u8]) -> Result<u64, DistError> {
    let len = u32::try_from(bytes.len())
        .ok()
        .filter(|&l| l <= MAX_FRAME)
        .ok_or_else(|| DistError::backend(format!("frame of {} bytes too large", bytes.len())))?;
    w.write_all(&len.to_le_bytes())
        .and_then(|_| w.write_all(&[ctype]))
        .and_then(|_| w.write_all(bytes))
        .and_then(|_| w.flush())
        .map_err(|e| DistError::backend(format!("frame write: {e}")))?;
    Ok(5 + bytes.len() as u64)
}

/// Write one JSON frame (content type [`CONTENT_JSON`]).
pub fn write_frame(w: &mut impl Write, v: &Value) -> Result<u64, DistError> {
    let bytes = serde_json::to_vec(v)
        .map_err(|e| DistError::backend(format!("frame encode: {e}")))?;
    write_raw_frame(w, CONTENT_JSON, &bytes)
}

/// Read one frame's prefix: its payload length and content-type byte.
/// `Ok(None)` on clean EOF at a frame boundary; EOF after the length
/// prefix is a protocol error.
fn read_frame_prefix(r: &mut impl Read) -> Result<Option<(u32, u8)>, DistError> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(DistError::backend(format!("frame length read: {e}"))),
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(DistError::backend(format!("frame length {len} exceeds cap")));
    }
    let mut ctype = [0u8; 1];
    r.read_exact(&mut ctype)
        .map_err(|e| DistError::backend(format!("frame content-type read: {e}")))?;
    if ctype[0] != CONTENT_JSON && ctype[0] != CONTENT_BINARY {
        return Err(DistError::backend(format!(
            "unknown frame content type {:#04x} (peer speaks a different wire version?)",
            ctype[0]
        )));
    }
    Ok(Some((len, ctype[0])))
}

/// Read one frame as `(content type, payload bytes)`; `Ok(None)` on
/// clean EOF at a frame boundary.
fn read_frame_raw(r: &mut impl Read) -> Result<Option<(u8, Vec<u8>)>, DistError> {
    let Some((len, ctype)) = read_frame_prefix(r)? else { return Ok(None) };
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)
        .map_err(|e| DistError::backend(format!("frame body read: {e}")))?;
    Ok(Some((ctype, buf)))
}

/// Read one JSON frame; `Ok(None)` on clean EOF at a frame boundary.  A
/// binary frame on a JSON-only channel (the gateway protocol, handshake
/// frames) is a protocol error.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Value>, DistError> {
    match read_frame_raw(r)? {
        None => Ok(None),
        Some((CONTENT_JSON, buf)) => serde_json::from_slice(&buf)
            .map(Some)
            .map_err(|e| DistError::backend(format!("frame decode: {e}"))),
        Some((ctype, _)) => Err(DistError::backend(format!(
            "unexpected content type {ctype:#04x} on a JSON-only channel"
        ))),
    }
}

/// Coordinator → worker commands.
#[derive(Clone, Debug, PartialEq)]
pub enum ToWorker {
    /// TCP connection handshake: the coordinator announces its
    /// [`PROTOCOL_VERSION`] as the very first frame on the socket.  The
    /// worker replies [`FromWorker::Welcome`] on a match and
    /// [`FromWorker::Fail`] (then closes) on a mismatch.  Never sent over
    /// the process backend's pipes.
    Hello {
        /// The coordinator's [`PROTOCOL_VERSION`].
        version: u32,
    },
    /// Session open (spec shipping): which machine this worker simulates,
    /// the executor width for its in-worker gain scans, and the problem
    /// spec (flat config text) to rebuild the oracle from.  The rebuilt
    /// oracle stays **resident** for the whole session and serves every
    /// subsequent [`ToWorker::Job`]; run parameters travel per job, not
    /// here.
    Init {
        /// Coordinator-chosen session id (echoed in errors/logs only).
        session: u64,
        /// The simulated machine this worker becomes.
        machine: MachineId,
        /// Executor width for the worker's nested gain scans.
        threads: usize,
        /// Flat `key = value` problem spec the worker rebuilds from.
        problem: String,
    },
    /// Session open (partition shipping, `--ship partition`): instead of
    /// a rebuild recipe the worker receives its O(n/m) dataset shard —
    /// its leaf partition plus the §6.4 added elements it will draw — and
    /// builds a [`PartitionPayload`]-backed facade oracle that stays
    /// **resident** across jobs; nothing is regenerated and the shard is
    /// never re-shipped.  Replies `Ready` with the *shard* element count
    /// (not the global ground-set size), which the coordinator checks
    /// against what it shipped.
    InitPart {
        /// Coordinator-chosen session id (echoed in errors/logs only).
        session: u64,
        /// The simulated machine this worker becomes.
        machine: MachineId,
        /// Executor width for the worker's nested gain scans.
        threads: usize,
        /// The machine's dataset shard.
        payload: PartitionPayload,
    },
    /// Start one run against the resident oracle: the node program's
    /// parameters plus the flat spec text the constraint is rebuilt from.
    /// Resets any per-job worker state (solution, pending children) and
    /// replies `Ready` with the resident oracle's *global* ground-set
    /// size, or `Fail` if the job is unservable (e.g. a dataset-view
    /// objective without `local_view` under partition shipping) — the
    /// session survives a failed job admission.
    Job {
        /// Coordinator-chosen job id, unique within the session.
        job: u64,
        /// The node program's parameters for this run.
        params: NodeParams,
        /// Flat `key = value` spec for the constraint/objective settings.
        spec: String,
    },
    /// Level-0 superstep: GREEDY on this partition.
    Leaf {
        /// The machine's data partition (element ids).
        part: Vec<ElemId>,
    },
    /// Ship the held solution to the coordinator (the worker retires).
    Ship,
    /// Deliver child solutions for the coming accumulation; the worker
    /// acks immediately so the coordinator can stop its transfer clock.
    Recv {
        /// Tree level of the coming accumulation.
        level: u32,
        /// The retiring children's shipped solutions.
        children: Vec<ChildMsg>,
    },
    /// Run the accumulation step on the previously delivered children,
    /// booking `comm_secs` (the coordinator-measured shipping time).
    Accum {
        /// Tree level of the accumulation.
        level: u32,
        /// Coordinator-measured Ship → Recv wall seconds to book.
        comm_secs: f64,
    },
    /// End the current job: ship final stats (and the solution, for the
    /// root).  The worker replies `Final` and **stays resident**, ready
    /// for the next [`ToWorker::Job`].
    JobDone,
    /// End the session: the worker exits without replying.  Best-effort —
    /// a dropped connection (EOF) releases the session just the same.
    Release,
    /// Liveness probe: the worker replies [`FromWorker::Pong`]
    /// immediately, at any point in the session where a command is legal.
    /// The coordinator pings a warm fleet before reusing it (a daemon may
    /// have died while the fleet sat idle) and a revived session after
    /// replaying its command log.
    Ping,
    /// Advance the resident shard by one dataset epoch (v6): the worker
    /// applies its per-machine sub-delta to the resident
    /// [`crate::objective::PartitionOracle`] in place — compacting deletes
    /// out, appending owned inserts — and replies
    /// [`FromWorker::DeltaDone`] with its post-delta shard size.  Only
    /// legal between jobs of a partition-shipped session.
    Delta {
        /// The coordinator's dataset epoch *after* this delta.
        epoch: u64,
        /// This machine's sub-delta: the full delete list (a worker skips
        /// deletes it does not hold) plus exactly the inserts the delta
        /// ownership tape assigns to it.
        delta: PartitionDelta,
    },
}

/// Worker → coordinator replies.
#[derive(Clone, Debug, PartialEq)]
pub enum FromWorker {
    /// TCP handshake reply: the worker's [`PROTOCOL_VERSION`], sent only
    /// when it matches the coordinator's [`ToWorker::Hello`].
    Welcome {
        /// The worker's [`PROTOCOL_VERSION`].
        version: u32,
    },
    /// Handshake reply: the rebuilt oracle's ground-set size (sanity check
    /// that coordinator and worker built the same problem).
    Ready {
        /// Ground-set size of the worker's rebuilt oracle.
        n: usize,
    },
    /// A completed superstep.
    Step(StepReport),
    /// Receipt of a `Recv` payload.
    Ack,
    /// The shipped solution of a retiring machine.
    Sol(ChildMsg),
    /// Final stats + solution.
    Final {
        /// The machine's lifetime statistics.
        stats: MachineStats,
        /// The machine's final solution (meaningful at the root).
        sol: Vec<ElemId>,
        /// f(sol) as this machine evaluated it.
        value: f64,
    },
    /// The node program failed (OOM) or the worker itself did.
    Fail(DistError),
    /// Liveness probe reply to [`ToWorker::Ping`].
    Pong,
    /// Receipt of a [`ToWorker::Delta`] (v6): the epoch the worker
    /// advanced to and its post-delta shard size, which the coordinator
    /// checks against its own replay of the partition.
    DeltaDone {
        /// Echo of the delta frame's epoch.
        epoch: u64,
        /// Elements held after applying the delta.
        n: usize,
    },
}

impl ToWorker {
    /// Encode as a JSON frame body.
    pub fn to_value(&self) -> Value {
        match self {
            Self::Hello { version } => json!({ "t": "hello", "version": version }),
            Self::Init { session, machine, threads, problem } => json!({
                "t": "init",
                "session": session,
                "machine": machine,
                "threads": threads,
                "problem": problem,
            }),
            Self::InitPart { session, machine, threads, payload } => json!({
                "t": "init_part",
                "session": session,
                "machine": machine,
                "threads": threads,
                "payload": payload.to_value(),
            }),
            Self::Job { job, params, spec } => json!({
                "t": "job",
                "job": job,
                "params": params_to_value(params),
                "spec": spec,
            }),
            Self::Leaf { part } => json!({ "t": "leaf", "part": part }),
            Self::Ship => json!({ "t": "ship" }),
            Self::Recv { level, children } => json!({
                "t": "recv",
                "level": level,
                "children": children.iter().map(child_to_value).collect::<Vec<_>>(),
            }),
            Self::Accum { level, comm_secs } => {
                json!({ "t": "accum", "level": level, "comm_secs": comm_secs })
            }
            Self::JobDone => json!({ "t": "job_done" }),
            Self::Release => json!({ "t": "release" }),
            Self::Ping => json!({ "t": "ping" }),
            Self::Delta { epoch, delta } => json!({
                "t": "delta",
                "epoch": epoch,
                "delta": delta.to_value(),
            }),
        }
    }

    /// Decode from a JSON frame body.
    pub fn from_value(v: &Value) -> Result<Self, DistError> {
        match str_field(v, "t")? {
            "hello" => Ok(Self::Hello { version: u64_field(v, "version")? as u32 }),
            "init" => Ok(Self::Init {
                session: u64_field(v, "session")?,
                machine: u64_field(v, "machine")? as MachineId,
                threads: u64_field(v, "threads")? as usize,
                problem: str_field(v, "problem")?.to_string(),
            }),
            "init_part" => Ok(Self::InitPart {
                session: u64_field(v, "session")?,
                machine: u64_field(v, "machine")? as MachineId,
                threads: u64_field(v, "threads")? as usize,
                payload: PartitionPayload::from_value(field(v, "payload")?)
                    .map_err(|e| DistError::backend(format!("partition payload: {e}")))?,
            }),
            "job" => Ok(Self::Job {
                job: u64_field(v, "job")?,
                params: params_from_value(field(v, "params")?)?,
                spec: str_field(v, "spec")?.to_string(),
            }),
            "leaf" => Ok(Self::Leaf { part: elems_field(v, "part")? }),
            "ship" => Ok(Self::Ship),
            "recv" => Ok(Self::Recv {
                level: u64_field(v, "level")? as u32,
                children: arr_field(v, "children")?
                    .iter()
                    .map(child_from_value)
                    .collect::<Result<_, _>>()?,
            }),
            "accum" => Ok(Self::Accum {
                level: u64_field(v, "level")? as u32,
                comm_secs: f64_field(v, "comm_secs")?,
            }),
            "job_done" => Ok(Self::JobDone),
            "release" => Ok(Self::Release),
            "ping" => Ok(Self::Ping),
            "delta" => Ok(Self::Delta {
                epoch: u64_field(v, "epoch")?,
                delta: PartitionDelta::from_value(field(v, "delta")?)
                    .map_err(|e| DistError::backend(format!("partition delta: {e}")))?,
            }),
            other => Err(DistError::backend(format!("unknown command '{other}'"))),
        }
    }
}

impl FromWorker {
    /// Encode as a JSON frame body.
    pub fn to_value(&self) -> Value {
        match self {
            Self::Welcome { version } => json!({ "t": "welcome", "version": version }),
            Self::Ready { n } => json!({ "t": "ready", "n": n }),
            Self::Step(r) => json!({ "t": "step", "report": report_to_value(r) }),
            Self::Ack => json!({ "t": "ack" }),
            Self::Sol(m) => json!({ "t": "sol", "msg": child_to_value(m) }),
            Self::Final { stats, sol, value } => json!({
                "t": "final",
                "stats": stats_to_value(stats),
                "sol": sol,
                "value": value,
            }),
            Self::Fail(e) => json!({ "t": "fail", "error": error_to_value(e) }),
            Self::Pong => json!({ "t": "pong" }),
            Self::DeltaDone { epoch, n } => {
                json!({ "t": "delta_done", "epoch": epoch, "n": n })
            }
        }
    }

    /// Decode from a JSON frame body.
    pub fn from_value(v: &Value) -> Result<Self, DistError> {
        match str_field(v, "t")? {
            "welcome" => Ok(Self::Welcome { version: u64_field(v, "version")? as u32 }),
            "ready" => Ok(Self::Ready { n: u64_field(v, "n")? as usize }),
            "step" => Ok(Self::Step(report_from_value(field(v, "report")?)?)),
            "ack" => Ok(Self::Ack),
            "sol" => Ok(Self::Sol(child_from_value(field(v, "msg")?)?)),
            "final" => Ok(Self::Final {
                stats: stats_from_value(field(v, "stats")?)?,
                sol: elems_field(v, "sol")?,
                value: f64_field(v, "value")?,
            }),
            "fail" => Ok(Self::Fail(error_from_value(field(v, "error")?)?)),
            "pong" => Ok(Self::Pong),
            "delta_done" => Ok(Self::DeltaDone {
                epoch: u64_field(v, "epoch")?,
                n: u64_field(v, "n")? as usize,
            }),
            other => Err(DistError::backend(format!("unknown reply '{other}'"))),
        }
    }
}

// ---- mode-aware message framing (v5) -----------------------------------

/// Binary-envelope message tags (the first payload byte of a
/// [`CONTENT_BINARY`] frame).
const BIN_INIT_PART: u8 = 1;
const BIN_SOL: u8 = 2;
const BIN_RECV: u8 = 3;
const BIN_DELTA: u8 = 4;

/// Write one coordinator → worker command under `mode`.  Binary mode
/// binary-encodes the payload-bearing commands (`init_part`, `recv`,
/// `delta`); everything else stays a JSON frame under either mode.
pub fn write_cmd(w: &mut impl Write, cmd: &ToWorker, mode: WireMode) -> Result<u64, DistError> {
    if mode == WireMode::Binary {
        if let Some(bytes) = encode_binary_cmd(cmd) {
            return write_raw_frame(w, CONTENT_BINARY, &bytes);
        }
    }
    write_frame(w, &cmd.to_value())
}

/// Read one command, reporting the content type it arrived with (how a
/// worker adopts its session's wire mode).  `Ok(None)` on clean EOF.
pub fn read_cmd(r: &mut impl Read) -> Result<Option<(ToWorker, WireMode)>, DistError> {
    match read_frame_raw(r)? {
        None => Ok(None),
        Some((CONTENT_BINARY, buf)) => Ok(Some((decode_binary_cmd(&buf)?, WireMode::Binary))),
        Some((_, buf)) => {
            let v: Value = serde_json::from_slice(&buf)
                .map_err(|e| DistError::backend(format!("frame decode: {e}")))?;
            Ok(Some((ToWorker::from_value(&v)?, WireMode::Json)))
        }
    }
}

/// Write one worker → coordinator reply under `mode`.  Binary mode
/// binary-encodes shipped solutions (`sol`); every other reply stays a
/// JSON frame.
pub fn write_reply(w: &mut impl Write, msg: &FromWorker, mode: WireMode) -> Result<u64, DistError> {
    if mode == WireMode::Binary {
        if let Some(bytes) = encode_binary_reply(msg) {
            return write_raw_frame(w, CONTENT_BINARY, &bytes);
        }
    }
    write_frame(w, &msg.to_value())
}

/// Read one reply under either content type; `Ok(None)` on clean EOF.
pub fn read_reply(r: &mut impl Read) -> Result<Option<FromWorker>, DistError> {
    match read_frame_raw(r)? {
        None => Ok(None),
        Some((CONTENT_BINARY, buf)) => Ok(Some(decode_binary_reply(&buf)?)),
        Some((_, buf)) => {
            let v: Value = serde_json::from_slice(&buf)
                .map_err(|e| DistError::backend(format!("frame decode: {e}")))?;
            Ok(Some(FromWorker::from_value(&v)?))
        }
    }
}

/// Read a session-opening command (`init` / `init_part` / `hello` …) the
/// streaming way: a binary `init_part` frame's shard bytes are fed
/// through an incremental [`PartitionDecoder`] in socket-read-sized
/// chunks, so the under-construction shard grows section by section as
/// bytes land instead of waiting for the whole frame.  Returns the
/// command plus the wire mode the frame arrived in — the worker mirrors
/// that mode on its replies for the rest of the session.
pub fn read_session_init(r: &mut impl Read) -> Result<Option<(ToWorker, WireMode)>, DistError> {
    let Some((len, ctype)) = read_frame_prefix(r)? else { return Ok(None) };
    if ctype != CONTENT_BINARY {
        let mut buf = vec![0u8; len as usize];
        r.read_exact(&mut buf)
            .map_err(|e| DistError::backend(format!("frame body read: {e}")))?;
        let v: Value = serde_json::from_slice(&buf)
            .map_err(|e| DistError::backend(format!("frame decode: {e}")))?;
        return Ok(Some((ToWorker::from_value(&v)?, WireMode::Json)));
    }
    // Binary session opener: fixed envelope prefix, then the shard
    // streamed through the incremental decoder.
    let envelope = 1 + 8 + 4 + 4;
    if (len as usize) < envelope {
        return Err(DistError::backend(format!(
            "binary session frame of {len} bytes is shorter than its envelope"
        )));
    }
    let mut head = [0u8; 17];
    r.read_exact(&mut head)
        .map_err(|e| DistError::backend(format!("frame body read: {e}")))?;
    if head[0] != BIN_INIT_PART {
        return Err(DistError::backend(format!(
            "binary frame tag {} cannot open a session (expected init_part)",
            head[0]
        )));
    }
    let session = u64::from_le_bytes(head[1..9].try_into().unwrap());
    let machine = u32::from_le_bytes(head[9..13].try_into().unwrap()) as MachineId;
    let threads = u32::from_le_bytes(head[13..17].try_into().unwrap()) as usize;
    let mut decoder = PartitionDecoder::new(len as usize - envelope);
    let mut remaining = len as usize - envelope;
    let mut chunk = vec![0u8; STREAM_CHUNK.min(remaining.max(1))];
    while remaining > 0 {
        let take = remaining.min(chunk.len());
        r.read_exact(&mut chunk[..take])
            .map_err(|e| DistError::backend(format!("frame body read: {e}")))?;
        decoder
            .feed(&chunk[..take])
            .map_err(|e| DistError::backend(format!("partition payload: {e}")))?;
        remaining -= take;
    }
    let payload = decoder
        .finish()
        .map_err(|e| DistError::backend(format!("partition payload: {e}")))?;
    Ok(Some((ToWorker::InitPart { session, machine, threads, payload }, WireMode::Binary)))
}

/// Binary-encode a command, or `None` when the command has no binary
/// form (control frames travel as JSON under either mode).
fn encode_binary_cmd(cmd: &ToWorker) -> Option<Vec<u8>> {
    match cmd {
        ToWorker::InitPart { session, machine, threads, payload } => {
            let mut out = Vec::with_capacity(17 + payload.binary_len());
            out.push(BIN_INIT_PART);
            out.extend_from_slice(&session.to_le_bytes());
            out.extend_from_slice(&machine.to_le_bytes());
            out.extend_from_slice(&(*threads as u32).to_le_bytes());
            payload.encode_binary(&mut out);
            Some(out)
        }
        ToWorker::Recv { level, children } => {
            let mut out = vec![BIN_RECV];
            out.extend_from_slice(&level.to_le_bytes());
            out.extend_from_slice(&(children.len() as u32).to_le_bytes());
            for child in children {
                encode_binary_child(&mut out, child);
            }
            Some(out)
        }
        ToWorker::Delta { epoch, delta } => {
            let mut out = Vec::with_capacity(9 + delta.binary_len());
            out.push(BIN_DELTA);
            out.extend_from_slice(&epoch.to_le_bytes());
            delta.encode_binary(&mut out);
            Some(out)
        }
        _ => None,
    }
}

fn decode_binary_cmd(bytes: &[u8]) -> Result<ToWorker, DistError> {
    let mut cur = Cursor::new(bytes);
    match cur.u8()? {
        BIN_INIT_PART => {
            let session = cur.u64()?;
            let machine = cur.u32()? as MachineId;
            let threads = cur.u32()? as usize;
            let payload = PartitionPayload::decode_binary(cur.rest())
                .map_err(|e| DistError::backend(format!("partition payload: {e}")))?;
            Ok(ToWorker::InitPart { session, machine, threads, payload })
        }
        BIN_RECV => {
            let level = cur.u32()?;
            let n = cur.u32()? as usize;
            let mut children = Vec::new();
            for _ in 0..n {
                children.push(decode_binary_child(&mut cur)?);
            }
            cur.done()?;
            Ok(ToWorker::Recv { level, children })
        }
        BIN_DELTA => {
            let epoch = cur.u64()?;
            let delta = PartitionDelta::decode_binary(cur.rest())
                .map_err(|e| DistError::backend(format!("partition delta: {e}")))?;
            Ok(ToWorker::Delta { epoch, delta })
        }
        other => Err(DistError::backend(format!("unknown binary command tag {other}"))),
    }
}

/// Binary-encode a reply, or `None` when it has no binary form.
fn encode_binary_reply(msg: &FromWorker) -> Option<Vec<u8>> {
    match msg {
        FromWorker::Sol(child) => {
            let mut out = vec![BIN_SOL];
            encode_binary_child(&mut out, child);
            Some(out)
        }
        _ => None,
    }
}

fn decode_binary_reply(bytes: &[u8]) -> Result<FromWorker, DistError> {
    let mut cur = Cursor::new(bytes);
    match cur.u8()? {
        BIN_SOL => {
            let child = decode_binary_child(&mut cur)?;
            cur.done()?;
            Ok(FromWorker::Sol(child))
        }
        other => Err(DistError::backend(format!("unknown binary reply tag {other}"))),
    }
}

/// A shipped child solution inside a binary envelope: fixed fields, the
/// solution ids, then (optionally) the coreset ids and the extracted
/// shard, length-prefixed so multiple children pack into one `recv` frame.
fn encode_binary_child(out: &mut Vec<u8>, m: &ChildMsg) {
    out.extend_from_slice(&m.from.to_le_bytes());
    out.extend_from_slice(&m.value.to_bits().to_le_bytes());
    out.extend_from_slice(&m.bytes.to_le_bytes());
    out.extend_from_slice(&(m.sol.len() as u32).to_le_bytes());
    out.push(m.data.is_some() as u8);
    out.push(m.coreset.is_some() as u8);
    for &e in &m.sol {
        out.extend_from_slice(&e.to_le_bytes());
    }
    if let Some(cs) = &m.coreset {
        out.extend_from_slice(&(cs.len() as u32).to_le_bytes());
        for &e in cs {
            out.extend_from_slice(&e.to_le_bytes());
        }
    }
    if let Some(data) = &m.data {
        out.extend_from_slice(&(data.binary_len() as u64).to_le_bytes());
        data.encode_binary(out);
    }
}

fn decode_binary_child(cur: &mut Cursor<'_>) -> Result<ChildMsg, DistError> {
    let from = cur.u32()? as MachineId;
    let value = f64::from_bits(cur.u64()?);
    let bytes = cur.u64()?;
    let sol_len = cur.u32()? as usize;
    let has_data = match cur.u8()? {
        0 => false,
        1 => true,
        other => return Err(DistError::backend(format!("binary child: bad data flag {other}"))),
    };
    let has_coreset = match cur.u8()? {
        0 => false,
        1 => true,
        other => {
            return Err(DistError::backend(format!("binary child: bad coreset flag {other}")))
        }
    };
    let sol_bytes = cur.take(sol_len.checked_mul(4).ok_or_else(|| {
        DistError::backend(format!("binary child: solution length {sol_len} overflows"))
    })?)?;
    let sol = sol_bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as ElemId)
        .collect();
    let coreset = if has_coreset {
        let cs_len = cur.u32()? as usize;
        let cs_bytes = cur.take(cs_len.checked_mul(4).ok_or_else(|| {
            DistError::backend(format!("binary child: coreset length {cs_len} overflows"))
        })?)?;
        Some(
            cs_bytes
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as ElemId)
                .collect(),
        )
    } else {
        None
    };
    let data = if has_data {
        let plen = cur.u64()?;
        let plen = usize::try_from(plen).map_err(|_| {
            DistError::backend(format!("binary child: payload length {plen} overflows"))
        })?;
        let payload = PartitionPayload::decode_binary(cur.take(plen)?)
            .map_err(|e| DistError::backend(format!("child data payload: {e}")))?;
        Some(payload)
    } else {
        None
    };
    Ok(ChildMsg { from, sol, value, bytes, data, coreset })
}

/// Bounds-checked reader over a binary frame's payload: every read is
/// validated against the bytes actually present, so a hostile length
/// field can produce a [`DistError`] but never a panic or a
/// frame-unbacked allocation.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], DistError> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len()).ok_or_else(|| {
            DistError::backend(format!(
                "binary frame truncated: wanted {n} bytes at offset {} of {}",
                self.pos,
                self.buf.len()
            ))
        })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, DistError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, DistError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, DistError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    fn done(&self) -> Result<(), DistError> {
        if self.pos != self.buf.len() {
            return Err(DistError::backend(format!(
                "binary frame has {} trailing bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ---- field helpers ----------------------------------------------------

fn field<'a>(v: &'a Value, key: &str) -> Result<&'a Value, DistError> {
    v.get(key)
        .ok_or_else(|| DistError::backend(format!("frame missing field '{key}'")))
}

fn str_field<'a>(v: &'a Value, key: &str) -> Result<&'a str, DistError> {
    field(v, key)?
        .as_str()
        .ok_or_else(|| DistError::backend(format!("field '{key}' is not a string")))
}

fn u64_field(v: &Value, key: &str) -> Result<u64, DistError> {
    field(v, key)?
        .as_u64()
        .ok_or_else(|| DistError::backend(format!("field '{key}' is not a u64")))
}

fn f64_field(v: &Value, key: &str) -> Result<f64, DistError> {
    field(v, key)?
        .as_f64()
        .ok_or_else(|| DistError::backend(format!("field '{key}' is not a number")))
}

fn bool_field(v: &Value, key: &str) -> Result<bool, DistError> {
    field(v, key)?
        .as_bool()
        .ok_or_else(|| DistError::backend(format!("field '{key}' is not a bool")))
}

fn arr_field<'a>(v: &'a Value, key: &str) -> Result<&'a [Value], DistError> {
    field(v, key)?
        .as_array()
        .map(|a| a.as_slice())
        .ok_or_else(|| DistError::backend(format!("field '{key}' is not an array")))
}

fn elems_field(v: &Value, key: &str) -> Result<Vec<ElemId>, DistError> {
    arr_field(v, key)?
        .iter()
        .map(|e| {
            e.as_u64()
                .map(|x| x as ElemId)
                .ok_or_else(|| DistError::backend(format!("field '{key}': non-integer element")))
        })
        .collect()
}

// ---- struct codecs ----------------------------------------------------

fn params_to_value(p: &NodeParams) -> Value {
    json!({
        "kind": match p.kind { GreedyKind::Naive => "naive", GreedyKind::Lazy => "lazy" },
        "seed": p.seed,
        "n": p.n,
        "mem_limit": p.mem_limit,
        "local_view": p.local_view,
        "added_elements": p.added_elements,
        "compare_all_children": p.compare_all_children,
        "coreset": p.coreset,
    })
}

fn params_from_value(v: &Value) -> Result<NodeParams, DistError> {
    Ok(NodeParams {
        kind: match str_field(v, "kind")? {
            "naive" => GreedyKind::Naive,
            "lazy" => GreedyKind::Lazy,
            other => return Err(DistError::backend(format!("unknown greedy kind '{other}'"))),
        },
        seed: u64_field(v, "seed")?,
        n: u64_field(v, "n")? as usize,
        mem_limit: match field(v, "mem_limit")? {
            Value::Null => None,
            other => Some(other.as_u64().ok_or_else(|| {
                DistError::backend("field 'mem_limit' is neither null nor u64")
            })?),
        },
        local_view: bool_field(v, "local_view")?,
        added_elements: u64_field(v, "added_elements")? as usize,
        compare_all_children: bool_field(v, "compare_all_children")?,
        coreset: bool_field(v, "coreset")?,
    })
}

fn child_to_value(m: &ChildMsg) -> Value {
    let mut v = json!({ "from": m.from, "sol": m.sol, "value": m.value, "bytes": m.bytes });
    if let Some(data) = &m.data {
        v["data"] = data.to_value();
    }
    if let Some(cs) = &m.coreset {
        v["coreset"] = json!(cs);
    }
    v
}

fn child_from_value(v: &Value) -> Result<ChildMsg, DistError> {
    Ok(ChildMsg {
        from: u64_field(v, "from")? as MachineId,
        sol: elems_field(v, "sol")?,
        value: f64_field(v, "value")?,
        bytes: u64_field(v, "bytes")?,
        data: match v.get("data") {
            None | Some(Value::Null) => None,
            Some(d) => Some(
                PartitionPayload::from_value(d)
                    .map_err(|e| DistError::backend(format!("child data payload: {e}")))?,
            ),
        },
        coreset: match v.get("coreset") {
            None | Some(Value::Null) => None,
            Some(_) => Some(elems_field(v, "coreset")?),
        },
    })
}

fn report_to_value(r: &StepReport) -> Value {
    json!({
        "machine": r.machine,
        "level": r.level,
        "comp_secs": r.comp_secs,
        "comm_secs": r.comm_secs,
        "calls": r.calls,
        "accum_elems": r.accum_elems,
        "peak_mem": r.peak_mem,
    })
}

fn report_from_value(v: &Value) -> Result<StepReport, DistError> {
    Ok(StepReport {
        machine: u64_field(v, "machine")? as MachineId,
        level: u64_field(v, "level")? as u32,
        comp_secs: f64_field(v, "comp_secs")?,
        comm_secs: f64_field(v, "comm_secs")?,
        calls: u64_field(v, "calls")?,
        accum_elems: u64_field(v, "accum_elems")? as usize,
        peak_mem: u64_field(v, "peak_mem")?,
    })
}

fn stats_to_value(s: &MachineStats) -> Value {
    json!({
        "id": s.id,
        "calls": s.calls,
        "cost": s.cost,
        "comp_secs": s.comp_secs,
        "comm_secs": s.comm_secs,
        "bytes_sent": s.bytes_sent,
        "bytes_received": s.bytes_received,
        "peak_mem": s.peak_mem,
        "top_level": s.top_level,
        "max_accum_elems": s.max_accum_elems,
    })
}

fn stats_from_value(v: &Value) -> Result<MachineStats, DistError> {
    Ok(MachineStats {
        id: u64_field(v, "id")? as MachineId,
        calls: u64_field(v, "calls")?,
        cost: u64_field(v, "cost")?,
        comp_secs: f64_field(v, "comp_secs")?,
        comm_secs: f64_field(v, "comm_secs")?,
        bytes_sent: u64_field(v, "bytes_sent")?,
        bytes_received: u64_field(v, "bytes_received")?,
        peak_mem: u64_field(v, "peak_mem")?,
        top_level: u64_field(v, "top_level")? as u32,
        max_accum_elems: u64_field(v, "max_accum_elems")? as usize,
    })
}

fn error_to_value(e: &DistError) -> Value {
    match e {
        DistError::OutOfMemory { machine, level, label, requested, in_use, limit } => json!({
            "kind": "oom",
            "machine": machine,
            "level": level,
            "label": label,
            "requested": requested,
            "in_use": in_use,
            "limit": limit,
        }),
        DistError::Backend { message } => json!({ "kind": "backend", "message": message }),
        DistError::Transport { message } => json!({ "kind": "transport", "message": message }),
    }
}

fn error_from_value(v: &Value) -> Result<DistError, DistError> {
    match str_field(v, "kind")? {
        "oom" => Ok(DistError::OutOfMemory {
            machine: u64_field(v, "machine")? as MachineId,
            level: u64_field(v, "level")? as u32,
            label: str_field(v, "label")?.to_string(),
            requested: u64_field(v, "requested")?,
            in_use: u64_field(v, "in_use")?,
            limit: u64_field(v, "limit")?,
        }),
        "backend" => Ok(DistError::backend(str_field(v, "message")?)),
        "transport" => Ok(DistError::transport(str_field(v, "message")?)),
        other => Err(DistError::backend(format!("unknown error kind '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::PartitionData;

    /// A small delta for codec samples: one insert (with its data row),
    /// one delete.
    fn sample_delta() -> PartitionDelta {
        PartitionDelta {
            n_global: 1001,
            insert: PartitionPayload {
                n_global: 1001,
                elems: vec![1000],
                data: PartitionData::Cover {
                    universe: 40,
                    offsets: vec![0, 2],
                    items: vec![4, 11],
                    weights: None,
                    self_cover: false,
                    dominating: false,
                },
            },
            delete: vec![9],
        }
    }

    /// A small shard payload for codec samples.
    fn sample_payload() -> PartitionPayload {
        PartitionPayload {
            n_global: 1000,
            elems: vec![9, 2, 511],
            data: PartitionData::Cover {
                universe: 40,
                offsets: vec![0, 2, 2, 5],
                items: vec![1, 3, 0, 7, 39],
                weights: None,
                self_cover: false,
                dominating: false,
            },
        }
    }

    fn roundtrip_cmd(msg: ToWorker) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg.to_value()).unwrap();
        let v = read_frame(&mut buf.as_slice()).unwrap().expect("frame present");
        assert_eq!(ToWorker::from_value(&v).unwrap(), msg);
    }

    fn roundtrip_reply(msg: FromWorker) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg.to_value()).unwrap();
        let v = read_frame(&mut buf.as_slice()).unwrap().expect("frame present");
        assert_eq!(FromWorker::from_value(&v).unwrap(), msg);
    }

    /// One sample of every coordinator → worker command (the lockstep test
    /// derives the live tag set from this list — extend it when adding a
    /// variant).
    fn all_commands() -> Vec<ToWorker> {
        vec![
            ToWorker::Hello { version: PROTOCOL_VERSION },
            ToWorker::Init {
                session: 7,
                machine: 3,
                threads: 2,
                problem: "dataset.kind = retail\ndataset.n = 300\n".to_string(),
            },
            ToWorker::InitPart {
                session: 7,
                machine: 1,
                threads: 2,
                payload: sample_payload(),
            },
            ToWorker::Job {
                job: 2,
                params: NodeParams {
                    kind: GreedyKind::Lazy,
                    seed: 42,
                    n: 1000,
                    mem_limit: Some(1 << 20),
                    local_view: true,
                    added_elements: 50,
                    compare_all_children: false,
                    coreset: true,
                },
                spec: "problem.k = 4\n".to_string(),
            },
            ToWorker::Leaf { part: vec![5, 1, 999] },
            ToWorker::Ship,
            ToWorker::Recv {
                level: 2,
                children: vec![
                    ChildMsg {
                        from: 4,
                        sol: vec![7, 8],
                        value: 12.5,
                        bytes: 64,
                        data: None,
                        coreset: None,
                    },
                    // Partition shipping + coreset mode: the solution
                    // travels with its coreset and its extracted data shard.
                    ChildMsg {
                        from: 5,
                        sol: vec![9],
                        value: 3.25,
                        bytes: 20,
                        data: Some(sample_payload()),
                        coreset: Some(vec![9, 2, 511]),
                    },
                ],
            },
            ToWorker::Accum { level: 2, comm_secs: 0.125 },
            ToWorker::JobDone,
            ToWorker::Release,
            ToWorker::Ping,
            ToWorker::Delta { epoch: 3, delta: sample_delta() },
        ]
    }

    /// One sample of every worker → coordinator reply (see [`all_commands`]).
    fn all_replies() -> Vec<FromWorker> {
        vec![
            FromWorker::Welcome { version: PROTOCOL_VERSION },
            FromWorker::Ready { n: 512 },
            FromWorker::Step(StepReport {
                machine: 1,
                level: 2,
                comp_secs: 0.5,
                comm_secs: 0.001,
                calls: 900,
                accum_elems: 33,
                peak_mem: 4096,
            }),
            FromWorker::Ack,
            FromWorker::Sol(ChildMsg {
                from: 0,
                sol: vec![1, 2, 3],
                value: 7.25,
                bytes: 96,
                data: None,
                coreset: Some(vec![1, 2, 3, 8]),
            }),
            FromWorker::Final {
                stats: MachineStats { id: 6, calls: 10, peak_mem: 77, ..MachineStats::new(6) },
                sol: vec![9],
                value: 3.5,
            },
            FromWorker::Fail(DistError::OutOfMemory {
                machine: 2,
                level: 1,
                label: "child solutions".to_string(),
                requested: 100,
                in_use: 50,
                limit: 120,
            }),
            FromWorker::Pong,
            FromWorker::DeltaDone { epoch: 3, n: 340 },
        ]
    }

    #[test]
    fn commands_roundtrip() {
        for cmd in all_commands() {
            roundtrip_cmd(cmd);
        }
    }

    #[test]
    fn replies_roundtrip() {
        for reply in all_replies() {
            roundtrip_reply(reply);
        }
        roundtrip_reply(FromWorker::Fail(DistError::backend("spawn failed")));
        roundtrip_reply(FromWorker::Fail(DistError::transport("worker 1 disconnected")));
    }

    /// Every `"t"` tag scanned out of a document (the prose spec quotes
    /// each frame's tag as `"t": "<tag>"`).
    fn doc_tags(doc: &str) -> std::collections::BTreeSet<String> {
        let mut tags = std::collections::BTreeSet::new();
        let needle = "\"t\": \"";
        let mut rest = doc;
        while let Some(pos) = rest.find(needle) {
            rest = &rest[pos + needle.len()..];
            if let Some(end) = rest.find('"') {
                tags.insert(rest[..end].to_string());
            }
        }
        tags
    }

    #[test]
    fn wire_doc_stays_in_lockstep_with_the_codec() {
        // Keep `docs/wire-protocol.md` honest: every message variant the
        // codec speaks must be named in the spec (as `"t": "<tag>"`), the
        // spec must not describe tags the codec does not speak, and every
        // variant must round-trip through its own frame.
        let doc = include_str!("../../../docs/wire-protocol.md");
        let documented = doc_tags(doc);
        let mut live = std::collections::BTreeSet::new();
        for cmd in all_commands() {
            live.insert(cmd.to_value()["t"].as_str().unwrap().to_string());
            roundtrip_cmd(cmd);
        }
        for reply in all_replies() {
            live.insert(reply.to_value()["t"].as_str().unwrap().to_string());
            roundtrip_reply(reply);
        }
        assert_eq!(
            live, documented,
            "docs/wire-protocol.md and dist/wire.rs disagree on the message set \
             (left = codec, right = doc) — update both together"
        );
    }

    #[test]
    fn ship_frame_bytes_match_the_documented_hex_dump() {
        // The annotated hex dump in docs/wire-protocol.md shows this exact
        // frame; if the encoding ever changes, the doc must change with it.
        let mut buf = Vec::new();
        write_frame(&mut buf, &ToWorker::Ship.to_value()).unwrap();
        assert_eq!(
            buf,
            [0x0c, 0x00, 0x00, 0x00, 0x01, 0x7b, 0x22, 0x74, 0x22, 0x3a, 0x22, 0x73, 0x68,
             0x69, 0x70, 0x22, 0x7d],
            "Ship frame no longer matches the hex dump in docs/wire-protocol.md"
        );
    }

    #[test]
    fn job_done_frame_bytes_match_the_documented_hex_dump() {
        // docs/wire-protocol.md pins the session-layer frames the same way
        // it pins `Ship`.
        let mut buf = Vec::new();
        let written = write_frame(&mut buf, &ToWorker::JobDone.to_value()).unwrap();
        assert_eq!(
            buf,
            [0x10, 0x00, 0x00, 0x00, 0x01, 0x7b, 0x22, 0x74, 0x22, 0x3a, 0x22, 0x6a, 0x6f,
             0x62, 0x5f, 0x64, 0x6f, 0x6e, 0x65, 0x22, 0x7d],
            "JobDone frame no longer matches the hex dump in docs/wire-protocol.md"
        );
        assert_eq!(written, buf.len() as u64, "write_frame must report the on-wire size");
    }

    #[test]
    fn release_frame_bytes_match_the_documented_hex_dump() {
        let mut buf = Vec::new();
        let written = write_frame(&mut buf, &ToWorker::Release.to_value()).unwrap();
        assert_eq!(
            buf,
            [0x0f, 0x00, 0x00, 0x00, 0x01, 0x7b, 0x22, 0x74, 0x22, 0x3a, 0x22, 0x72, 0x65,
             0x6c, 0x65, 0x61, 0x73, 0x65, 0x22, 0x7d],
            "Release frame no longer matches the hex dump in docs/wire-protocol.md"
        );
        assert_eq!(written, buf.len() as u64, "write_frame must report the on-wire size");
    }

    #[test]
    fn f64_values_cross_the_wire_bit_exactly() {
        // The parity suite compares f(S) with to_bits(); ryu's shortest
        // representation must reproduce the exact double.
        for v in [1.0 / 3.0, 1e-300, 123456789.123456789, f64::MIN_POSITIVE] {
            let msg = FromWorker::Sol(ChildMsg {
                from: 0,
                sol: vec![],
                value: v,
                bytes: 0,
                data: None,
                coreset: None,
            });
            let mut buf = Vec::new();
            write_frame(&mut buf, &msg.to_value()).unwrap();
            let parsed = read_frame(&mut buf.as_slice()).unwrap().unwrap();
            match FromWorker::from_value(&parsed).unwrap() {
                FromWorker::Sol(m) => assert_eq!(m.value.to_bits(), v.to_bits()),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn eof_between_frames_is_clean_none() {
        let empty: &[u8] = &[];
        assert!(read_frame(&mut &*empty).unwrap().is_none());
    }

    #[test]
    fn truncated_frame_is_an_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &json!({"t": "ack"})).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(read_frame(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn oversized_length_prefix_rejected() {
        let buf = (MAX_FRAME + 1).to_le_bytes().to_vec();
        assert!(read_frame(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn binary_mode_roundtrips_every_command_and_reports_its_content_type() {
        // Under `--wire binary` only the payload-bearing commands change
        // encoding; control frames stay JSON and every command still
        // round-trips through `write_cmd`/`read_cmd`.
        for cmd in all_commands() {
            let mut buf = Vec::new();
            let written = write_cmd(&mut buf, &cmd, WireMode::Binary).unwrap();
            assert_eq!(written, buf.len() as u64, "write_cmd must report the on-wire size");
            let expect_binary = matches!(
                cmd,
                ToWorker::InitPart { .. } | ToWorker::Recv { .. } | ToWorker::Delta { .. }
            );
            let expect_ctype = if expect_binary { CONTENT_BINARY } else { CONTENT_JSON };
            assert_eq!(buf[4], expect_ctype, "wrong content type for {cmd:?}");
            let (decoded, mode) = read_cmd(&mut buf.as_slice()).unwrap().expect("frame");
            assert_eq!(decoded, cmd);
            let expect_mode = if expect_binary { WireMode::Binary } else { WireMode::Json };
            assert_eq!(mode, expect_mode);
        }
    }

    #[test]
    fn binary_mode_roundtrips_every_reply() {
        // Only shipped solutions have a binary form; all other replies
        // stay JSON frames under either mode.
        for reply in all_replies() {
            let mut buf = Vec::new();
            let written = write_reply(&mut buf, &reply, WireMode::Binary).unwrap();
            assert_eq!(written, buf.len() as u64, "write_reply must report the on-wire size");
            let expect_ctype =
                if matches!(reply, FromWorker::Sol(_)) { CONTENT_BINARY } else { CONTENT_JSON };
            assert_eq!(buf[4], expect_ctype, "wrong content type for {reply:?}");
            let decoded = read_reply(&mut buf.as_slice()).unwrap().expect("frame");
            assert_eq!(decoded, reply);
        }
    }

    #[test]
    fn binary_sol_carries_its_extracted_shard() {
        // Partition shipping: the retiring machine's solution travels
        // with its extracted data, which must survive the binary child
        // codec bit-exactly.
        let msg = FromWorker::Sol(ChildMsg {
            from: 3,
            sol: vec![9, 2],
            value: 0.1 + 0.2, // not exactly representable — bit-exactness matters
            bytes: 123,
            data: Some(sample_payload()),
            coreset: Some(vec![9, 2, 511]),
        });
        let mut buf = Vec::new();
        write_reply(&mut buf, &msg, WireMode::Binary).unwrap();
        assert_eq!(buf[4], CONTENT_BINARY);
        assert_eq!(read_reply(&mut buf.as_slice()).unwrap().unwrap(), msg);
    }

    #[test]
    fn json_mode_never_emits_binary_frames() {
        for cmd in all_commands() {
            let mut buf = Vec::new();
            write_cmd(&mut buf, &cmd, WireMode::Json).unwrap();
            assert_eq!(buf[4], CONTENT_JSON, "JSON mode leaked a binary frame for {cmd:?}");
        }
        for reply in all_replies() {
            let mut buf = Vec::new();
            write_reply(&mut buf, &reply, WireMode::Json).unwrap();
            assert_eq!(buf[4], CONTENT_JSON, "JSON mode leaked a binary frame for {reply:?}");
        }
    }

    #[test]
    fn json_only_channels_reject_binary_frames() {
        // The gateway protocol and the TCP handshake read with
        // `read_frame`, which must refuse a v5 binary frame instead of
        // parsing garbage.
        let init = ToWorker::InitPart {
            session: 1,
            machine: 0,
            threads: 1,
            payload: sample_payload(),
        };
        let mut buf = Vec::new();
        write_cmd(&mut buf, &init, WireMode::Binary).unwrap();
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(
            err.to_string().contains("JSON-only"),
            "want a JSON-only channel error, got: {err}"
        );
    }

    #[test]
    fn unknown_content_type_is_a_typed_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &ToWorker::Ship.to_value()).unwrap();
        buf[4] = 0x7f;
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(
            err.to_string().contains("content type"),
            "want a content-type error, got: {err}"
        );
    }

    #[test]
    fn session_init_streams_binary_init_part_and_adopts_the_mode() {
        let init = ToWorker::InitPart {
            session: 99,
            machine: 2,
            threads: 4,
            payload: sample_payload(),
        };
        let mut buf = Vec::new();
        write_cmd(&mut buf, &init, WireMode::Binary).unwrap();
        let (decoded, mode) =
            read_session_init(&mut buf.as_slice()).unwrap().expect("frame present");
        assert_eq!(decoded, init);
        assert_eq!(mode, WireMode::Binary);

        // The JSON path reports Json so a v4-style session runs unchanged.
        let mut buf = Vec::new();
        write_cmd(&mut buf, &init, WireMode::Json).unwrap();
        let (decoded, mode) =
            read_session_init(&mut buf.as_slice()).unwrap().expect("frame present");
        assert_eq!(decoded, init);
        assert_eq!(mode, WireMode::Json);

        // And clean EOF at the frame boundary is a clean None.
        let empty: &[u8] = &[];
        assert!(read_session_init(&mut &*empty).unwrap().is_none());
    }

    #[test]
    fn truncated_binary_session_frame_is_a_typed_error() {
        let init = ToWorker::InitPart {
            session: 99,
            machine: 2,
            threads: 4,
            payload: sample_payload(),
        };
        let mut full = Vec::new();
        write_cmd(&mut full, &init, WireMode::Binary).unwrap();
        // Cut inside the envelope, inside the payload header, and inside
        // a section: every truncation must surface as a DistError.
        for cut in [6, 12, 30, full.len() - 1] {
            let buf = &full[..cut];
            assert!(
                read_session_init(&mut &*buf).is_err(),
                "truncation at {cut} of {} must error",
                full.len()
            );
        }
    }

    #[test]
    fn binary_init_part_frame_bytes_match_the_documented_hex_dump() {
        // The annotated v5 binary dump in docs/wire-protocol.md shows this
        // exact frame; if the encoding ever changes, the doc must change
        // with it.
        let init = ToWorker::InitPart {
            session: 7,
            machine: 1,
            threads: 2,
            payload: PartitionPayload {
                n_global: 4,
                elems: vec![2, 0],
                data: PartitionData::Modular { weights: vec![1.5, -2.0] },
            },
        };
        let mut buf = Vec::new();
        let written = write_cmd(&mut buf, &init, WireMode::Binary).unwrap();
        let expect: Vec<u8> = [
            // frame prefix: payload length 73, content type binary
            &[0x49, 0x00, 0x00, 0x00, 0x02][..],
            // envelope: tag, session = 7, machine = 1, threads = 2
            &[0x01],
            &[0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00],
            &[0x01, 0x00, 0x00, 0x00],
            &[0x02, 0x00, 0x00, 0x00],
            // payload header: family modular, flags 0, 2 sections, reserved
            &[0x04, 0x00, 0x02, 0x00],
            // n_global = 4, meta = 0
            &[0x04, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00],
            &[0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00],
            // section 0 (elems): 2 bytes, width 1
            &[0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x01],
            // section 1 (weights): 16 bytes, width 8
            &[0x10, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x08],
            // elems = [2, 0]
            &[0x02, 0x00],
            // weights: 1.5 and -2.0 as f64 bits, little-endian
            &[0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xf8, 0x3f],
            &[0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xc0],
        ]
        .concat();
        assert_eq!(
            buf, expect,
            "binary init_part frame no longer matches the hex dump in docs/wire-protocol.md"
        );
        assert_eq!(written, buf.len() as u64, "write_cmd must report the on-wire size");
    }

    #[test]
    fn binary_delta_frame_bytes_match_the_documented_hex_dump() {
        // The annotated v6 binary dump in docs/wire-protocol.md shows this
        // exact frame; if the encoding ever changes, the doc must change
        // with it.
        let cmd = ToWorker::Delta {
            epoch: 1,
            delta: PartitionDelta {
                n_global: 4,
                insert: PartitionPayload {
                    n_global: 4,
                    elems: vec![2],
                    data: PartitionData::Modular { weights: vec![1.5] },
                },
                delete: vec![0],
            },
        };
        let mut buf = Vec::new();
        let written = write_cmd(&mut buf, &cmd, WireMode::Binary).unwrap();
        let expect: Vec<u8> = [
            // frame prefix: payload length 72, content type binary
            &[0x48, 0x00, 0x00, 0x00, 0x02][..],
            // envelope: tag delta, epoch = 1
            &[0x04],
            &[0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00],
            // delta header: n_global = 4, one delete, delete id 0
            &[0x04, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00],
            &[0x01, 0x00, 0x00, 0x00],
            &[0x00, 0x00, 0x00, 0x00],
            // insert payload header: family modular, flags 0, 2 sections
            &[0x04, 0x00, 0x02, 0x00],
            // n_global = 4, meta = 0
            &[0x04, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00],
            &[0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00],
            // section 0 (elems): 1 byte, width 1
            &[0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x01],
            // section 1 (weights): 8 bytes, width 8
            &[0x08, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x08],
            // elems = [2]
            &[0x02],
            // weights: 1.5 as f64 bits, little-endian
            &[0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xf8, 0x3f],
        ]
        .concat();
        assert_eq!(
            buf, expect,
            "binary delta frame no longer matches the hex dump in docs/wire-protocol.md"
        );
        assert_eq!(written, buf.len() as u64, "write_cmd must report the on-wire size");

        // And the frame round-trips through the command reader.
        let (decoded, mode) = read_cmd(&mut buf.as_slice()).unwrap().expect("frame");
        assert_eq!(decoded, cmd);
        assert_eq!(mode, WireMode::Binary);
    }
}
