//! Failures of a simulated distributed run.
//!
//! Out-of-memory is the only failure mode the runtime itself produces: the
//! paper's §6.2 experiments *expect* runs to die when a machine's budget
//! cannot hold the data or the accumulated child solutions, and the
//! coordinator reports such runs as failures rather than panicking.

use crate::util::fmt_bytes;
use crate::MachineId;

/// Error produced by a distributed run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DistError {
    /// A [`MemoryMeter`](super::MemoryMeter) charge exceeded the
    /// per-machine limit.  Carries enough context to tell *which* machine
    /// died, at *which* tree level, holding *what* — the coordinates the
    /// memory experiments assert on.
    OutOfMemory {
        /// Machine whose budget was exceeded.
        machine: MachineId,
        /// Tree level at which the charge happened (0 = leaf work).
        level: u32,
        /// What was being allocated ("partition data", "child solutions", …).
        label: &'static str,
        /// Bytes the failing charge asked for.
        requested: u64,
        /// Bytes already in use before the charge.
        in_use: u64,
        /// The per-machine limit.
        limit: u64,
    },
}

impl std::fmt::Display for DistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DistError::OutOfMemory { machine, level, label, requested, in_use, limit } => {
                write!(
                    f,
                    "machine {machine} out of memory at level {level}: {} for '{label}' \
                     on top of {} in use exceeds the {} limit",
                    fmt_bytes(*requested),
                    fmt_bytes(*in_use),
                    fmt_bytes(*limit)
                )
            }
        }
    }
}

impl std::error::Error for DistError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_machine_and_says_out_of_memory() {
        let e = DistError::OutOfMemory {
            machine: 0,
            level: 1,
            label: "child solutions",
            requested: 2048,
            in_use: 1024,
            limit: 1536,
        };
        let msg = e.to_string();
        assert!(msg.contains("machine 0 out of memory"), "{msg}");
        assert!(msg.contains("level 1"), "{msg}");
        assert!(msg.contains("child solutions"), "{msg}");
    }
}
