//! Failures of a distributed run, classified by what recovery they admit.
//!
//! Out-of-memory is the failure mode the runtime semantics produce: the
//! paper's §6.2 experiments *expect* runs to die when a machine's budget
//! cannot hold the data or the accumulated child solutions, and the
//! coordinator reports such runs as failures rather than panicking.  The
//! framed backends add two more modes for the machinery itself, split by
//! the taxonomy [`DistError::is_retryable`] encodes:
//!
//! * [`DistError::Transport`] — **retryable**: the *conversation* with a
//!   worker broke (connection refused or timed out, a worker died
//!   mid-session, a frame read hit the socket timeout).  The machine's
//!   work is deterministic and replayable from the ship plan, so a
//!   supervisor may re-dispatch it to a fresh session
//!   ([`FaultPolicy::Retry`](super::FaultPolicy)) or drop the machine's
//!   contribution with accounting
//!   ([`FaultPolicy::Degrade`](super::FaultPolicy)).
//! * [`DistError::Backend`] — **fatal**: the machinery is *wrong*, not
//!   unlucky — spawn failures, protocol misuse, version-handshake
//!   mismatches, unbuildable problem specs, oracle errors.  Retrying
//!   replays the same bug.
//!
//! [`DistError::OutOfMemory`] is likewise fatal to the run: it is a §6.2
//! memory *result*, not an incident, and must never be confused with an
//! infrastructure fault — a memory result is a finding, a dead worker is
//! an incident, and only the incident is worth retrying.

use crate::util::fmt_bytes;
use crate::MachineId;

/// Error produced by a distributed run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DistError {
    /// A [`MemoryMeter`](super::MemoryMeter) charge exceeded the
    /// per-machine limit.  Carries enough context to tell *which* machine
    /// died, at *which* tree level, holding *what* — the coordinates the
    /// memory experiments assert on.  The label is owned (`String`) so the
    /// error serializes intact across the process-backend wire.
    OutOfMemory {
        /// Machine whose budget was exceeded.
        machine: MachineId,
        /// Tree level at which the charge happened (0 = leaf work).
        level: u32,
        /// What was being allocated ("partition data", "child solutions", …).
        label: String,
        /// Bytes the failing charge asked for.
        requested: u64,
        /// Bytes already in use before the charge.
        in_use: u64,
        /// The per-machine limit.
        limit: u64,
    },
    /// The execution backend itself failed in a way retrying cannot fix
    /// (worker spawn, wire-protocol misuse, version-handshake mismatch,
    /// missing or unbuildable problem spec) — distinct from algorithmic
    /// OOM because the experiments must never confuse an infrastructure
    /// fault with a §6.2 memory result.
    Backend {
        /// Human-readable description of the fault.
        message: String,
    },
    /// The conversation with a worker broke: connection refused or timed
    /// out, a worker died before replying, a frame read hit the socket
    /// timeout.  **Retryable** — the machine's work replays
    /// deterministically from the ship plan, so a supervisor may
    /// re-dispatch it ([`super::FaultPolicy::Retry`]) or drop its
    /// contribution with accounting ([`super::FaultPolicy::Degrade`]).
    Transport {
        /// Human-readable description of the fault, naming the worker.
        message: String,
    },
}

impl DistError {
    /// Shorthand for a fatal backend-infrastructure error.
    pub fn backend(message: impl Into<String>) -> Self {
        DistError::Backend { message: message.into() }
    }

    /// Shorthand for a retryable transport fault.
    pub fn transport(message: impl Into<String>) -> Self {
        DistError::Transport { message: message.into() }
    }

    /// Whether a supervisor may retry (or degrade past) this failure.
    ///
    /// Only [`DistError::Transport`] qualifies: the fault is in the
    /// *conversation*, not the work, and the work replays
    /// deterministically.  [`DistError::OutOfMemory`] is an expected
    /// experimental result and [`DistError::Backend`] is a bug or a
    /// misconfiguration — retrying either replays the same outcome.
    pub fn is_retryable(&self) -> bool {
        matches!(self, DistError::Transport { .. })
    }
}

impl std::fmt::Display for DistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DistError::OutOfMemory { machine, level, label, requested, in_use, limit } => {
                write!(
                    f,
                    "machine {machine} out of memory at level {level}: {} for '{label}' \
                     on top of {} in use exceeds the {} limit",
                    fmt_bytes(*requested),
                    fmt_bytes(*in_use),
                    fmt_bytes(*limit)
                )
            }
            DistError::Backend { message } => write!(f, "backend failure: {message}"),
            DistError::Transport { message } => write!(f, "transport failure: {message}"),
        }
    }
}

impl std::error::Error for DistError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_machine_and_says_out_of_memory() {
        let e = DistError::OutOfMemory {
            machine: 0,
            level: 1,
            label: "child solutions".to_string(),
            requested: 2048,
            in_use: 1024,
            limit: 1536,
        };
        let msg = e.to_string();
        assert!(msg.contains("machine 0 out of memory"), "{msg}");
        assert!(msg.contains("level 1"), "{msg}");
        assert!(msg.contains("child solutions"), "{msg}");
    }

    #[test]
    fn backend_errors_name_the_fault() {
        let e = DistError::backend("worker 3 exited before replying");
        assert!(e.to_string().contains("backend failure"), "{e}");
        assert!(e.to_string().contains("worker 3"), "{e}");
    }

    #[test]
    fn only_transport_faults_are_retryable() {
        assert!(DistError::transport("worker 1 disconnected").is_retryable());
        assert!(!DistError::backend("protocol violation").is_retryable());
        let oom = DistError::OutOfMemory {
            machine: 2,
            level: 0,
            label: "partition data".to_string(),
            requested: 1,
            in_use: 0,
            limit: 1,
        };
        assert!(!oom.is_retryable(), "a §6.2 memory result is a finding, not an incident");
    }

    #[test]
    fn transport_errors_display_distinctly_from_backend_errors() {
        let e = DistError::transport("worker 1 at 10.0.0.2:9000 disconnected");
        assert!(e.to_string().contains("transport failure"), "{e}");
        assert!(e.to_string().contains("10.0.0.2:9000"), "{e}");
    }
}
