//! Failures of a distributed run.
//!
//! Out-of-memory is the failure mode the runtime semantics produce: the
//! paper's §6.2 experiments *expect* runs to die when a machine's budget
//! cannot hold the data or the accumulated child solutions, and the
//! coordinator reports such runs as failures rather than panicking.  The
//! framed backends add a second mode — [`DistError::Backend`] — for the
//! machinery itself: worker spawn and wire-protocol faults on the
//! process backend; unreachable hosts, version-handshake mismatches,
//! dropped connections and per-frame timeouts on the tcp backend.  Those
//! are bugs or environment problems, never an expected experimental
//! outcome, and the two kinds must never be confused — a §6.2 memory
//! result is a finding, a dead worker is an incident.

use crate::util::fmt_bytes;
use crate::MachineId;

/// Error produced by a distributed run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DistError {
    /// A [`MemoryMeter`](super::MemoryMeter) charge exceeded the
    /// per-machine limit.  Carries enough context to tell *which* machine
    /// died, at *which* tree level, holding *what* — the coordinates the
    /// memory experiments assert on.  The label is owned (`String`) so the
    /// error serializes intact across the process-backend wire.
    OutOfMemory {
        /// Machine whose budget was exceeded.
        machine: MachineId,
        /// Tree level at which the charge happened (0 = leaf work).
        level: u32,
        /// What was being allocated ("partition data", "child solutions", …).
        label: String,
        /// Bytes the failing charge asked for.
        requested: u64,
        /// Bytes already in use before the charge.
        in_use: u64,
        /// The per-machine limit.
        limit: u64,
    },
    /// The execution backend itself failed (worker spawn, wire protocol,
    /// missing problem spec, unreachable or version-mismatched TCP
    /// workers, connection loss, frame timeout) — distinct from
    /// algorithmic OOM because the experiments must never confuse an
    /// infrastructure fault with a §6.2 memory result.
    Backend {
        /// Human-readable description of the fault.
        message: String,
    },
}

impl DistError {
    /// Shorthand for a backend-infrastructure error.
    pub fn backend(message: impl Into<String>) -> Self {
        DistError::Backend { message: message.into() }
    }
}

impl std::fmt::Display for DistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DistError::OutOfMemory { machine, level, label, requested, in_use, limit } => {
                write!(
                    f,
                    "machine {machine} out of memory at level {level}: {} for '{label}' \
                     on top of {} in use exceeds the {} limit",
                    fmt_bytes(*requested),
                    fmt_bytes(*in_use),
                    fmt_bytes(*limit)
                )
            }
            DistError::Backend { message } => write!(f, "backend failure: {message}"),
        }
    }
}

impl std::error::Error for DistError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_machine_and_says_out_of_memory() {
        let e = DistError::OutOfMemory {
            machine: 0,
            level: 1,
            label: "child solutions".to_string(),
            requested: 2048,
            in_use: 1024,
            limit: 1536,
        };
        let msg = e.to_string();
        assert!(msg.contains("machine 0 out of memory"), "{msg}");
        assert!(msg.contains("level 1"), "{msg}");
        assert!(msg.contains("child solutions"), "{msg}");
    }

    #[test]
    fn backend_errors_name_the_fault() {
        let e = DistError::backend("worker 3 exited before replying");
        assert!(e.to_string().contains("backend failure"), "{e}");
        assert!(e.to_string().contains("worker 3"), "{e}");
    }
}
