//! The two-level parallel execution subsystem.
//!
//! **Level one — machines.** [`Executor::map`] fans a vector of per-machine
//! tasks out over a pool of workers spawned once per distributed run
//! ([`with_pool`]), and returns the results *in input order*, so a run is
//! bit-deterministic no matter how the scheduler interleaves machines — the
//! property the `deterministic_given_seed` tests rely on.  Errors are
//! ordinary values: the algorithm layer maps each task to a
//! `Result<_, DistError>` and inspects the slots afterwards, which lets an
//! OOM on one machine surface without tearing down the others mid-step
//! (they finish their superstep first, like real BSP ranks would).
//!
//! **Level two — gain scans.** The paper's accumulation tree starves the
//! machine level of parallelism: at level ℓ ≥ 1 only `m/b^ℓ` nodes are
//! active and at the root exactly one, so per-machine threads leave almost
//! every core idle during the upper supersteps (§1, §4).  A task may
//! therefore fan its *own* gain scan back out over the free workers through
//! [`par_gain_batch`]: candidates are split into fixed [`GAIN_CHUNK`]-sized
//! chunks, evaluated wherever a worker is free, and merged in chunk order.
//! Chunk boundaries depend only on the candidate list — never on the thread
//! count — and each chunk's gains are pure per-candidate functions of the
//! shared state, so the merged vector is bit-identical from `threads = 1`
//! to `threads = cores`.
//!
//! Workers are scoped (`std::thread::scope`), so tasks may borrow the
//! oracle, constraint and config from the caller's stack; a panic in any
//! task is captured and re-raised on the thread that submitted the batch.

use crate::objective::GainState;
use crate::ElemId;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Candidates per level-two chunk of [`par_gain_batch`].  Fixed (never
/// derived from the thread count) so gain vectors merge identically however
/// many workers participate; 64 candidates keep a chunk's rows around the
/// size of one k-medoid view tile, so nested chunking preserves the cache
/// blocking of the tiled kernel.
pub const GAIN_CHUNK: usize = 64;

/// Default worker count: the `GREEDYML_THREADS` environment variable when
/// set to a positive integer, otherwise `available_parallelism`.
pub fn default_threads() -> usize {
    match std::env::var("GREEDYML_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
    {
        Some(t) if t >= 1 => t,
        _ => std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
    }
}

/// One type-erased batch of independent, index-addressed tasks.
///
/// `data` points into the submitting thread's stack frame; it is only ever
/// dereferenced through `run` for claimed indices `i < total`, and the
/// submitter blocks inside [`execute`] until `done == total`, so the
/// pointee strictly outlives every dereference.
struct Job {
    run: unsafe fn(*const (), usize),
    data: *const (),
    /// Submission order; a waiting submitter only helps jobs younger than
    /// its own, which bounds help-recursion to the nesting depth.
    id: usize,
    cursor: AtomicUsize,
    done: AtomicUsize,
    total: usize,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

// SAFETY: see the `data` invariant above — the raw pointer never outlives
// the submitter's stack frame, and all result hand-off goes through slot
// mutexes inside the pointee.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

/// Pool state shared between the owning thread and its workers.
struct Shared {
    queue: Mutex<VecDeque<Arc<Job>>>,
    /// Signaled on: job pushed, job fully done, shutdown.
    cv: Condvar,
    shutdown: AtomicBool,
    next_id: AtomicUsize,
    threads: usize,
}

impl Shared {
    fn new(threads: usize) -> Self {
        Self {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_id: AtomicUsize::new(0),
            threads,
        }
    }
}

/// Handle to the pool serving the current region of code.
pub struct Executor<'a> {
    shared: &'a Shared,
}

thread_local! {
    /// The pool whose workers serve this thread (installed by [`with_pool`]
    /// on the owning thread and by each worker at startup), so nested code
    /// can find idle capacity without threading a handle through every
    /// signature.
    static CURRENT: std::cell::Cell<Option<*const Shared>> =
        const { std::cell::Cell::new(None) };
}

/// RAII registration of a pool in [`CURRENT`]; restores the previous value
/// on drop so nested pools shadow cleanly even across unwinds.
struct Registration {
    prev: Option<*const Shared>,
}

impl Registration {
    fn enter(shared: &Shared) -> Registration {
        let prev = CURRENT.with(|c| c.replace(Some(shared as *const Shared)));
        Registration { prev }
    }
}

impl Drop for Registration {
    fn drop(&mut self) {
        let prev = self.prev;
        CURRENT.with(|c| c.set(prev));
    }
}

/// Sets `shutdown` and wakes every worker on drop — including during a
/// panic unwind of the pool-owning closure, so `thread::scope` can join.
struct ShutdownGuard<'a>(&'a Shared);

impl Drop for ShutdownGuard<'_> {
    fn drop(&mut self) {
        self.0.shutdown.store(true, Ordering::SeqCst);
        let _q = self.0.queue.lock().unwrap();
        self.0.cv.notify_all();
    }
}

/// Run `f` with a persistent pool of `threads` workers (the calling thread
/// counts as one).  Workers are spawned once — every [`Executor::map`] and
/// [`par_gain_batch`] inside `f` reuses them instead of paying per-superstep
/// spawn/join.  `threads = 1` spawns nothing and runs everything serially on
/// the caller, bit-for-bit the single-threaded runtime.
pub fn with_pool<R>(threads: usize, f: impl FnOnce(&Executor<'_>) -> R) -> R {
    let threads = threads.max(1);
    let shared = Shared::new(threads);
    if threads == 1 {
        // Still register: nested primitives must see `threads() == 1` and
        // stay serial instead of spawning a pool of their own.
        let _cur = Registration::enter(&shared);
        return f(&Executor { shared: &shared });
    }
    std::thread::scope(|scope| {
        for _ in 0..threads - 1 {
            scope.spawn(|| worker(&shared));
        }
        let _stop = ShutdownGuard(&shared);
        let _cur = Registration::enter(&shared);
        f(&Executor { shared: &shared })
    })
}

/// Look up the pool registered for the current thread, if any.
pub fn with_executor<R>(f: impl FnOnce(Option<&Executor<'_>>) -> R) -> R {
    match CURRENT.with(|c| c.get()) {
        // SAFETY: the pointer was installed by a `Registration` whose
        // `Shared` outlives every thread that can observe it (workers are
        // scoped to `with_pool`, and the guard resets the slot on exit).
        Some(p) => f(Some(&Executor { shared: unsafe { &*p } })),
        None => f(None),
    }
}

/// Apply `f` to every item on an ad-hoc pool; the result vector preserves
/// input order.  Reuses the surrounding [`with_pool`] workers when one is
/// active, otherwise spins up a [`default_threads`]-sized pool for this one
/// call (the pre-executor behaviour, kept for standalone callers).
pub fn parallel_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    with_executor(|cur| match cur {
        Some(exec) => exec.map(items, &f),
        None => {
            let threads = default_threads().min(items.len().max(1));
            with_pool(threads, |exec| exec.map(items, &f))
        }
    })
}

/// Batched marginal gains with level-two fan-out: fixed [`GAIN_CHUNK`]
/// chunking of `es` across free pool workers, merged in chunk order.  Falls
/// back to the state's own (possibly tiled) `gain_batch` when no pool is
/// registered, the pool is serial, the batch is a single chunk, or the
/// state opts out of splitting (`parallel_scan` — the PJRT states keep
/// whole batches for kernel-launch amortization).  The output is
/// bit-identical across all of those paths: chunk boundaries are fixed and
/// each candidate's gain is a pure function of the shared state.
pub fn par_gain_batch(state: &dyn GainState, es: &[ElemId], out: &mut Vec<f64>) {
    with_executor(|cur| match cur {
        Some(exec) if exec.threads() > 1 && es.len() > GAIN_CHUNK && state.parallel_scan() => {
            let chunks: Vec<&[ElemId]> = es.chunks(GAIN_CHUNK).collect();
            let per_chunk: Vec<Vec<f64>> = exec.map(chunks, |chunk| {
                let mut g = Vec::with_capacity(chunk.len());
                state.gain_batch(chunk, &mut g);
                g
            });
            out.clear();
            out.reserve(es.len());
            for g in per_chunk {
                out.extend(g);
            }
        }
        _ => state.gain_batch(es, out),
    })
}

impl Executor<'_> {
    /// Worker count of this pool (including the owning thread).
    pub fn threads(&self) -> usize {
        self.shared.threads
    }

    /// Apply `f` to every item across the pool; results in input order.
    /// Callable from the owning thread *or* from inside a task (nested
    /// batches interleave with outer ones on whatever workers are free).
    pub fn map<T, U, F>(&self, items: Vec<T>, f: F) -> Vec<U>
    where
        T: Send,
        U: Send,
        F: Fn(T) -> U + Sync,
    {
        let n = items.len();
        if self.threads() == 1 || n <= 1 {
            return items.into_iter().map(f).collect();
        }

        // Slot mutexes are uncontended (each index has one owner at a
        // time); the claim cursor in the Job is the only shared point.
        struct MapTask<T, U, F> {
            slots: Vec<Mutex<Option<T>>>,
            results: Vec<Mutex<Option<U>>>,
            f: F,
        }

        unsafe fn run_one<T, U, F: Fn(T) -> U>(data: *const (), i: usize) {
            let task = &*(data as *const MapTask<T, U, F>);
            let item = task.slots[i].lock().unwrap().take().expect("task claimed twice");
            let out = (task.f)(item);
            *task.results[i].lock().unwrap() = Some(out);
        }

        let task = MapTask {
            slots: items.into_iter().map(|t| Mutex::new(Some(t))).collect(),
            results: (0..n).map(|_| Mutex::new(None)).collect(),
            f,
        };
        let job = Arc::new(Job {
            run: run_one::<T, U, F>,
            data: &task as *const MapTask<T, U, F> as *const (),
            id: self.shared.next_id.fetch_add(1, Ordering::Relaxed),
            cursor: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            total: n,
            panic: Mutex::new(None),
        });
        execute(self.shared, &job);

        task.results
            .into_iter()
            .map(|slot| slot.into_inner().unwrap().expect("worker skipped a task"))
            .collect()
    }
}

/// Claim-and-run loop: drain whatever indices of `job` are still unclaimed.
/// Panics inside a task are captured into the job (the submitter re-raises
/// them), so worker threads themselves never die.
fn run_available(shared: &Shared, job: &Job) {
    loop {
        let i = job.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= job.total {
            return;
        }
        let res = catch_unwind(AssertUnwindSafe(|| unsafe { (job.run)(job.data, i) }));
        if let Err(payload) = res {
            *job.panic.lock().unwrap() = Some(payload);
        }
        if job.done.fetch_add(1, Ordering::AcqRel) + 1 == job.total {
            // Completion may unblock a submitter; the lock makes the
            // notify race-free against its check-then-wait.
            let _q = shared.queue.lock().unwrap();
            shared.cv.notify_all();
        }
    }
}

/// Submit a job, help run it, and while stragglers finish help any *younger*
/// queued job (nested gain scans submitted by still-running tasks) — the
/// submitter never idles while the pool has work.
fn execute(shared: &Shared, job: &Arc<Job>) {
    if job.total == 0 {
        return;
    }
    {
        let mut q = shared.queue.lock().unwrap();
        q.push_back(job.clone());
        shared.cv.notify_all();
    }
    run_available(shared, job);
    let mut q = shared.queue.lock().unwrap();
    loop {
        if job.done.load(Ordering::Acquire) >= job.total {
            break;
        }
        q.retain(|j| j.cursor.load(Ordering::Relaxed) < j.total);
        let younger = q.iter().find(|j| j.id > job.id).cloned();
        match younger {
            Some(other) => {
                drop(q);
                run_available(shared, &other);
                q = shared.queue.lock().unwrap();
            }
            None => q = shared.cv.wait(q).unwrap(),
        }
    }
    drop(q);
    if let Some(payload) = job.panic.lock().unwrap().take() {
        resume_unwind(payload);
    }
}

/// Worker thread body: serve any queued job until shutdown.
fn worker(shared: &Shared) {
    let _cur = Registration::enter(shared);
    let mut q = shared.queue.lock().unwrap();
    loop {
        q.retain(|j| j.cursor.load(Ordering::Relaxed) < j.total);
        if let Some(job) = q.front().cloned() {
            drop(q);
            run_available(shared, &job);
            q = shared.queue.lock().unwrap();
            continue;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        q = shared.cv.wait(q).unwrap();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_input_order() {
        let inputs: Vec<usize> = (0..257).collect();
        let out = parallel_map(inputs, |i| i * 2);
        assert_eq!(out, (0..257).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let hits = AtomicU64::new(0);
        let out = parallel_map((0..100u64).collect(), |i| {
            hits.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(out.len(), 100);
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(empty, |x| x).is_empty());
        assert_eq!(parallel_map(vec![41], |x| x + 1), vec![42]);
    }

    #[test]
    fn errors_ride_through_as_values() {
        let out: Vec<Result<u32, String>> = parallel_map((0..10u32).collect(), |i| {
            if i == 3 {
                Err(format!("machine {i} failed"))
            } else {
                Ok(i)
            }
        });
        assert_eq!(out[2], Ok(2));
        assert_eq!(out[3], Err("machine 3 failed".to_string()));
        assert_eq!(out.iter().filter(|r| r.is_err()).count(), 1);
    }

    #[test]
    fn closure_may_borrow_caller_state() {
        let table: Vec<u64> = (0..50).map(|i| i * i).collect();
        let out = parallel_map((0..50usize).collect(), |i| table[i]);
        assert_eq!(out, table);
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        parallel_map(vec![0u32, 1, 2, 3], |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn pool_persists_across_maps() {
        with_pool(4, |exec| {
            assert_eq!(exec.threads(), 4);
            for round in 0..20u64 {
                let out = exec.map((0..33u64).collect(), |i| i + round);
                assert_eq!(out, (0..33).map(|i| i + round).collect::<Vec<_>>());
            }
        });
    }

    #[test]
    fn nested_map_fans_out_from_inside_a_task() {
        // Level-one tasks each run a level-two batch; the inner batches are
        // served by whatever workers the outer batch left idle.
        let out = with_pool(4, |exec| {
            exec.map((0..6u64).collect(), |outer| {
                with_executor(|cur| {
                    let inner = cur.expect("worker registers pool").map(
                        (0..50u64).collect::<Vec<_>>(),
                        |i| i * outer,
                    );
                    inner.iter().sum::<u64>()
                })
            })
        });
        let want: Vec<u64> = (0..6).map(|o| (0..50).sum::<u64>() * o).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn serial_pool_registers_but_spawns_nothing() {
        with_pool(1, |exec| {
            assert_eq!(exec.threads(), 1);
            with_executor(|cur| assert_eq!(cur.expect("registered").threads(), 1));
            let out = exec.map(vec![1, 2, 3], |x| x * 10);
            assert_eq!(out, vec![10, 20, 30]);
        });
        with_executor(|cur| assert!(cur.is_none(), "registration must not leak"));
    }

    #[test]
    fn parallel_map_reuses_surrounding_pool() {
        with_pool(3, |_| {
            let out = parallel_map((0..40u32).collect(), |i| i + 1);
            assert_eq!(out, (1..41).collect::<Vec<_>>());
        });
    }

    #[test]
    fn pool_survives_a_panicking_batch() {
        with_pool(4, |exec| {
            let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
                exec.map(vec![0u32, 1, 2], |i| {
                    if i == 1 {
                        panic!("task failure");
                    }
                    i
                })
            }));
            assert!(res.is_err(), "panic must propagate to the submitter");
            // The pool is still serviceable afterwards.
            let out = exec.map(vec![5u32, 6], |x| x * 2);
            assert_eq!(out, vec![10, 12]);
        });
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}
