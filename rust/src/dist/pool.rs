//! The BSP superstep executor.
//!
//! [`parallel_map`] fans a vector of per-machine tasks out over OS threads
//! and returns the results *in input order*, so a distributed run is
//! bit-deterministic no matter how the scheduler interleaves machines —
//! the property the `deterministic_given_seed` tests rely on.  Errors are
//! ordinary values: the algorithm layer maps each task to a
//! `Result<_, DistError>` and inspects the slots afterwards, which lets an
//! OOM on one machine surface without tearing down the others mid-step
//! (they finish their superstep first, like real BSP ranks would).
//!
//! Threads are scoped (`std::thread::scope`), so the closure may borrow
//! the oracle, constraint and config from the caller's stack; a panic in
//! any worker propagates to the caller on join.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Apply `f` to every item on a pool of up to `available_parallelism`
/// threads; the result vector preserves input order.
pub fn parallel_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n = items.len();
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(n);
    if threads <= 1 {
        return items.into_iter().map(f).collect();
    }

    // Work-stealing by atomic cursor: each worker claims the next unclaimed
    // index, takes its input and writes its result slot.  Slot mutexes are
    // uncontended (one owner each); the cursor is the only shared point.
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<U>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("task claimed twice");
                let out = f(item);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });

    results
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("worker skipped a task"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_input_order() {
        let inputs: Vec<usize> = (0..257).collect();
        let out = parallel_map(inputs, |i| i * 2);
        assert_eq!(out, (0..257).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let hits = AtomicU64::new(0);
        let out = parallel_map((0..100u64).collect(), |i| {
            hits.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(out.len(), 100);
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(empty, |x| x).is_empty());
        assert_eq!(parallel_map(vec![41], |x| x + 1), vec![42]);
    }

    #[test]
    fn errors_ride_through_as_values() {
        let out: Vec<Result<u32, String>> = parallel_map((0..10u32).collect(), |i| {
            if i == 3 {
                Err(format!("machine {i} failed"))
            } else {
                Ok(i)
            }
        });
        assert_eq!(out[2], Ok(2));
        assert_eq!(out[3], Err("machine 3 failed".to_string()));
        assert_eq!(out.iter().filter(|r| r.is_err()).count(), 1);
    }

    #[test]
    fn closure_may_borrow_caller_state() {
        let table: Vec<u64> = (0..50).map(|i| i * i).collect();
        let out = parallel_map((0..50usize).collect(), |i| table[i]);
        assert_eq!(out, table);
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        parallel_map(vec![0u32, 1, 2, 3], |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }
}
