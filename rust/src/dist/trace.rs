//! The per-(machine, level) execution timeline.
//!
//! Each BSP superstep contributes one [`NodeStep`] per active machine; the
//! collected [`Trace`] reconstructs the level-synchronous schedule (level
//! ℓ starts when the slowest node of level ℓ−1 finishes) and exports it as
//! Chrome-trace JSON — open the file in `chrome://tracing` or Perfetto to
//! see the paper's critical path as actual swim lanes.  Each machine also
//! gets a **memory counter track** (`"ph": "C"` events) fed by its
//! [`MemoryMeter`](super::MemoryMeter) watermark at the end of every
//! step, so the §6.2 memory story is visible in the same timeline as the
//! compute/communication spans.

use crate::MachineId;

/// What one machine did during one superstep.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NodeStep {
    /// The machine (trace row).
    pub machine: MachineId,
    /// Tree level of the superstep (0 = leaf GREEDY).
    pub level: u32,
    /// Computation seconds within the step.
    pub comp_secs: f64,
    /// Communication seconds within the step (0 at the leaves; modeled on
    /// the thread backend, measured on the process backend).
    pub comm_secs: f64,
    /// Gain queries issued within the step.
    pub calls: u64,
    /// The machine's memory watermark (meter peak) at the end of the step.
    pub peak_mem: u64,
}

/// An ordered collection of [`NodeStep`]s for one distributed run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    steps: Vec<NodeStep>,
}

impl Trace {
    /// Wrap collected steps.
    pub fn new(steps: Vec<NodeStep>) -> Self {
        Self { steps }
    }

    /// All steps, in collection order (level-major).
    pub fn steps(&self) -> &[NodeStep] {
        &self.steps
    }

    /// Duration of each level's superstep: the slowest active node's
    /// receive + compute time (BSP semantics).  Indexed by level.
    fn level_durations(&self) -> Vec<f64> {
        let top = self.steps.iter().map(|s| s.level).max().unwrap_or(0);
        let mut durs = vec![0.0f64; top as usize + 1];
        for s in &self.steps {
            let d = s.comm_secs + s.comp_secs;
            if d > durs[s.level as usize] {
                durs[s.level as usize] = d;
            }
        }
        durs
    }

    /// End-to-end modeled schedule length: Σ over levels of the superstep
    /// maximum.
    pub fn makespan(&self) -> f64 {
        self.level_durations().iter().sum()
    }

    /// Render as a Chrome-trace JSON document (the "JSON Array Format"
    /// wrapped in an object).  Every span is a complete event (`"ph": "X"`)
    /// with microsecond timestamps; machines are rows (`tid`), and each
    /// accumulation step shows a `recv` span (the gather) followed by its
    /// `greedy` span.  Each step additionally emits a counter event
    /// (`"ph": "C"`, one `mem m<id>` track per machine) carrying the
    /// machine's memory watermark at the step's end.
    pub fn to_chrome_json(&self) -> String {
        let durs = self.level_durations();
        let mut starts = vec![0.0f64; durs.len()];
        for l in 1..durs.len() {
            starts[l] = starts[l - 1] + durs[l - 1];
        }
        let mut events = Vec::new();
        for s in &self.steps {
            let t0 = starts[s.level as usize];
            if s.comm_secs > 0.0 {
                events.push(serde_json::json!({
                    "name": format!("recv L{}", s.level),
                    "cat": "comm",
                    "ph": "X",
                    "pid": 0,
                    "tid": s.machine,
                    "ts": t0 * 1e6,
                    "dur": s.comm_secs * 1e6,
                    "args": { "level": s.level },
                }));
            }
            events.push(serde_json::json!({
                "name": format!("greedy L{}", s.level),
                "cat": "comp",
                "ph": "X",
                "pid": 0,
                "tid": s.machine,
                "ts": (t0 + s.comm_secs) * 1e6,
                "dur": s.comp_secs * 1e6,
                "args": { "level": s.level, "calls": s.calls },
            }));
            // Memory watermark counter: plotted as a per-machine track.
            events.push(serde_json::json!({
                "name": format!("mem m{}", s.machine),
                "cat": "mem",
                "ph": "C",
                "pid": 0,
                "tid": s.machine,
                "ts": (t0 + s.comm_secs + s.comp_secs) * 1e6,
                "args": { "bytes": s.peak_mem },
            }));
        }
        let doc = serde_json::json!({
            "displayTimeUnit": "ms",
            "traceEvents": events,
        });
        serde_json::to_string_pretty(&doc).expect("chrome trace is always serializable")
    }

    /// Write the Chrome-trace JSON to `path`.
    pub fn write(&self, path: &str) -> crate::Result<()> {
        std::fs::write(path, self.to_chrome_json())
            .map_err(|e| anyhow::anyhow!("cannot write trace {path}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    /// A small 2-machine, 2-level trace: both leaves compute, then the
    /// root receives and accumulates.
    fn sample() -> Trace {
        Trace::new(vec![
            NodeStep {
                machine: 0,
                level: 0,
                comp_secs: 0.010,
                comm_secs: 0.0,
                calls: 100,
                peak_mem: 1000,
            },
            NodeStep {
                machine: 1,
                level: 0,
                comp_secs: 0.030,
                comm_secs: 0.0,
                calls: 120,
                peak_mem: 1500,
            },
            NodeStep {
                machine: 0,
                level: 1,
                comp_secs: 0.005,
                comm_secs: 0.002,
                calls: 40,
                peak_mem: 2500,
            },
        ])
    }

    fn events_of(text: &str) -> Vec<Json> {
        let parsed = Json::parse(text).expect("valid JSON");
        parsed.get("traceEvents").unwrap().as_arr().unwrap().to_vec()
    }

    fn spans(events: &[Json]) -> Vec<Json> {
        events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .cloned()
            .collect()
    }

    #[test]
    fn makespan_is_sum_of_level_maxima() {
        let t = sample();
        // Level 0: max(0.010, 0.030); level 1: 0.002 + 0.005.
        assert!((t.makespan() - (0.030 + 0.007)).abs() < 1e-12);
        assert_eq!(Trace::default().makespan(), 0.0);
    }

    #[test]
    fn golden_chrome_trace_shape() {
        let text = sample().to_chrome_json();
        let events = events_of(&text);
        let spans = spans(&events);
        // 3 compute spans + 1 recv span (only the root has comm time).
        assert_eq!(spans.len(), 4, "{text}");
        for e in &spans {
            assert!(e.get("ts").unwrap().as_f64().is_some());
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("tid").unwrap().as_u64().is_some());
            assert!(e.get("name").unwrap().as_str().is_some());
        }
        // The level-1 spans start after the slowest leaf (0.030 s = 30000 µs).
        let lvl1: Vec<_> = spans
            .iter()
            .filter(|e| e.get("args").unwrap().get("level").unwrap().as_u64() == Some(1))
            .collect();
        assert_eq!(lvl1.len(), 2, "recv + greedy at the root");
        for e in &lvl1 {
            assert!(e.get("ts").unwrap().as_f64().unwrap() >= 30_000.0 - 1e-6);
        }
    }

    #[test]
    fn memory_watermarks_are_counter_events() {
        let text = sample().to_chrome_json();
        let events = events_of(&text);
        let counters: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("C"))
            .collect();
        assert_eq!(counters.len(), 3, "one watermark per step:\n{text}");
        // Per-machine tracks with the meter peaks as values.
        let bytes: Vec<u64> = counters
            .iter()
            .map(|e| e.get("args").unwrap().get("bytes").unwrap().as_u64().unwrap())
            .collect();
        assert_eq!(bytes, vec![1000, 1500, 2500]);
        let names: Vec<&str> =
            counters.iter().map(|e| e.get("name").unwrap().as_str().unwrap()).collect();
        assert_eq!(names, vec!["mem m0", "mem m1", "mem m0"]);
        // The root's watermark lands at the end of its step (30 ms + 7 ms).
        let root_ts = counters[2].get("ts").unwrap().as_f64().unwrap();
        assert!((root_ts - 37_000.0).abs() < 1e-6, "{root_ts}");
    }

    #[test]
    fn recv_precedes_compute_within_a_step() {
        let text = sample().to_chrome_json();
        let events = events_of(&text);
        let find = |name: &str| {
            events
                .iter()
                .find(|e| e.get("name").unwrap().as_str() == Some(name))
                .unwrap_or_else(|| panic!("missing event '{name}'"))
                .clone()
        };
        let recv = find("recv L1");
        let comp = find("greedy L1");
        let recv_end = recv.get("ts").unwrap().as_f64().unwrap()
            + recv.get("dur").unwrap().as_f64().unwrap();
        let comp_start = comp.get("ts").unwrap().as_f64().unwrap();
        assert!((recv_end - comp_start).abs() < 1e-6, "{recv_end} vs {comp_start}");
    }

    #[test]
    fn write_roundtrips_through_a_file() {
        let path = std::env::temp_dir().join("greedyml_trace_test.json");
        let path = path.to_str().unwrap().to_string();
        sample().write(&path).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        // 4 spans + 3 memory counters.
        assert_eq!(parsed.get("traceEvents").unwrap().as_arr().unwrap().len(), 7);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn steps_are_preserved() {
        let t = sample();
        assert_eq!(t.steps().len(), 3);
        assert_eq!(t.steps()[1].machine, 1);
        assert_eq!(t.steps()[2].level, 1);
    }
}
