//! Fault policy, fault accounting, and the deterministic fault-injection
//! harness.
//!
//! Three pieces live here, shared by the supervisor
//! ([`RemoteFleet`](super::remote)) and the worker side
//! ([`proc`](super::proc)):
//!
//! * [`FaultSpec`] / [`FaultPolicy`] — what the coordinator does when a
//!   worker dies mid-run (`--on-fault {fail,retry,degrade}` /
//!   `run.on_fault` / `GREEDYML_ON_FAULT`).  `fail` preserves the
//!   pre-supervision behavior: first transport fault aborts the run.
//!   `retry` re-dispatches the dead machine's work to a fresh session
//!   with bounded attempts and exponential backoff — bit-identical to
//!   the fault-free run, because the partition and every seeded draw
//!   replay deterministically from the ship plan.  `degrade` drops the
//!   dead machine's contribution from its parent's accumulation and
//!   keeps going, with full accounting in the [`FaultReport`].
//! * [`FaultReport`] — the accounting a degraded (or retried) run
//!   carries out: faults seen, retries spent, machines dropped, data
//!   elements lost with them.
//! * [`FaultPlan`] — a deterministic fault-injection plan
//!   (`GREEDYML_FAULT_PLAN`, e.g.
//!   `kill:m2@leaf,delay:m0@ship:200ms,drop-frame:m1@recv`) consulted by
//!   the worker-side command loop, so every recovery path is testable in
//!   CI without real crashes.  The plan is pure data: which machine,
//!   which protocol point, what to do — no wall clock, no RNG — so a
//!   faulty run replays exactly.
//!
//! # Fault-plan grammar
//!
//! ```text
//! plan    := entry ("," entry)*
//! entry   := action ":" "m" machine "@" point [":" arg]
//! action  := "kill" | "delay" | "drop-frame"
//! point   := "init" | "job" | "leaf" | "superstep" N | "ship" | "recv"
//! arg     := duration for delay, e.g. "200ms" | "1s" | "50" (ms)
//! ```
//!
//! Points name the worker-side command about to be handled: `init` the
//! session `Init`/`InitPart`, `job` the `Job` frame, `leaf` (alias
//! `superstep0`) the `Leaf` command, `superstepN` the `Accum` of level
//! `N`, `ship` the `Ship`, `recv` the `Recv`.  Each entry fires **once**
//! per session; a revived replacement session does not inherit the plan
//! (the supervisor's reconnects scrub `GREEDYML_FAULT_PLAN` from
//! respawned process workers, and tcp retries dial the next host).
//!
//! What each action does at its point: `kill` drops the connection
//! without replying (the worker process exits; a `greedyml serve` daemon
//! only loses the one session) — exactly what a crashed or OOM-killed
//! host looks like from the coordinator.  `delay` sleeps the given
//! duration, then handles the command normally — for exercising timeout
//! paths.  `drop-frame` swallows the command without replying, so the
//! coordinator's frame timeout turns it into a retryable
//! [`DistError::Transport`]; only meaningful on the tcp backend (process
//! pipes have no read timeout).

use super::DistError;
use crate::MachineId;
use std::time::Duration;

/// How many times the supervisor attempts to revive one dead machine
/// before giving up (per fault, not per run).
pub const RETRY_ATTEMPTS: u32 = 3;

/// Base delay of the supervisor's exponential backoff between revival
/// attempts: attempt `a` sleeps `RETRY_BACKOFF_BASE << a`.
pub const RETRY_BACKOFF_BASE: Duration = Duration::from_millis(50);

/// What the coordinator does when a worker dies mid-run — the
/// `--on-fault` flag / `run.on_fault` config key / `GREEDYML_ON_FAULT`
/// environment variable, before `Auto` is resolved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultSpec {
    /// Defer to `GREEDYML_ON_FAULT` (`fail` | `retry` | `degrade`),
    /// defaulting to [`FaultPolicy::Fail`].
    #[default]
    Auto,
    /// First transport fault aborts the run (the pre-supervision
    /// behavior).
    Fail,
    /// Re-dispatch a dead machine's work to a fresh session, bounded
    /// attempts with exponential backoff; results stay bit-identical.
    Retry,
    /// Drop a dead machine's contribution and keep going, accounting
    /// for the loss in the run's [`FaultReport`].
    Degrade,
}

impl FaultSpec {
    /// Parse a config/CLI token (`auto` | `fail` | `retry` | `degrade`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "" => Ok(Self::Auto),
            "fail" => Ok(Self::Fail),
            "retry" => Ok(Self::Retry),
            "degrade" => Ok(Self::Degrade),
            other => Err(format!("unknown fault policy '{other}' (auto | fail | retry | degrade)")),
        }
    }

    /// Resolve `Auto` through `GREEDYML_ON_FAULT`; an unparsable variable
    /// is an error, not a silent fallback — a mis-spelt policy must not
    /// quietly change how an experiment treats worker loss.
    pub fn resolve(self) -> Result<FaultPolicy, DistError> {
        match self {
            Self::Fail => Ok(FaultPolicy::Fail),
            Self::Retry => Ok(FaultPolicy::Retry),
            Self::Degrade => Ok(FaultPolicy::Degrade),
            Self::Auto => match std::env::var("GREEDYML_ON_FAULT") {
                Err(_) => Ok(FaultPolicy::Fail),
                Ok(v) => match Self::parse(&v) {
                    Ok(Self::Retry) => Ok(FaultPolicy::Retry),
                    Ok(Self::Degrade) => Ok(FaultPolicy::Degrade),
                    Ok(_) => Ok(FaultPolicy::Fail),
                    Err(e) => Err(DistError::backend(format!("GREEDYML_ON_FAULT: {e}"))),
                },
            },
        }
    }
}

/// A [`FaultSpec`] with `Auto` already resolved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultPolicy {
    /// Abort the run on the first transport fault.
    #[default]
    Fail,
    /// Revive dead machines and replay their work.
    Retry,
    /// Drop dead machines' contributions with accounting.
    Degrade,
}

impl FaultPolicy {
    /// The config/CLI token for this policy.
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Fail => "fail",
            Self::Retry => "retry",
            Self::Degrade => "degrade",
        }
    }
}

/// The fault accounting a supervised run carries out in its
/// [`DistOutcome`](crate::algo::DistOutcome): what went wrong, what the
/// supervisor spent recovering, and what (under
/// [`FaultPolicy::Degrade`]) the answer no longer covers.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultReport {
    /// Transport faults observed during the run (a machine that failed,
    /// was revived, and failed again counts once per failure).
    pub faults_seen: u64,
    /// Revival attempts that *succeeded* — each one re-established a
    /// session and replayed the dead machine's command log.
    pub retries: u64,
    /// Machines whose contribution was dropped under
    /// [`FaultPolicy::Degrade`], in drop order.
    pub machines_dropped: Vec<MachineId>,
    /// Ground-set elements that were only covered by dropped machines'
    /// partitions — the data the degraded answer never saw.
    pub elements_lost: u64,
}

impl FaultReport {
    /// True when the run saw no faults at all.
    pub fn is_empty(&self) -> bool {
        self.faults_seen == 0
            && self.retries == 0
            && self.machines_dropped.is_empty()
            && self.elements_lost == 0
    }

    /// Fold another report into this one (per-job accounting summed into
    /// a batch total).
    pub fn absorb(&mut self, other: &FaultReport) {
        self.faults_seen += other.faults_seen;
        self.retries += other.retries;
        self.machines_dropped.extend_from_slice(&other.machines_dropped);
        self.elements_lost += other.elements_lost;
    }
}

impl std::fmt::Display for FaultReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "faults {} retries {} dropped {:?} elements lost {}",
            self.faults_seen, self.retries, self.machines_dropped, self.elements_lost
        )
    }
}

/// What an injected fault does when its point is reached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Drop the connection without replying — a crashed host.
    Kill,
    /// Sleep this long, then handle the command normally.
    Delay(Duration),
    /// Swallow the command without replying (tcp frame timeout fodder).
    DropFrame,
}

/// A protocol point at which an injected fault can fire, named from the
/// worker's side: the command it is about to handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// The session `Init` / `InitPart` frame.
    Init,
    /// The `Job` frame.
    Job,
    /// Superstep `N`: the `Leaf` command for `N = 0` (alias `leaf`), the
    /// `Accum` of level `N` for `N ≥ 1`.
    Superstep(u32),
    /// The `Ship` command.
    Ship,
    /// The `Recv` command.
    Recv,
}

/// One parsed plan entry, one-shot.
#[derive(Clone, Debug, PartialEq, Eq)]
struct PlanEntry {
    action: FaultAction,
    machine: MachineId,
    point: FaultPoint,
    fired: bool,
}

/// A deterministic fault-injection plan: which machines fail, where in
/// the protocol, and how.  Parsed from `GREEDYML_FAULT_PLAN` by each
/// worker session; entries fire at most once.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    entries: Vec<PlanEntry>,
}

impl FaultPlan {
    /// Parse a plan string (see the module docs for the grammar).
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut entries = Vec::new();
        for raw in s.split(',') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            entries.push(Self::parse_entry(raw)?);
        }
        Ok(FaultPlan { entries })
    }

    fn parse_entry(raw: &str) -> Result<PlanEntry, String> {
        let mut pieces = raw.splitn(2, ':');
        let action_tok = pieces.next().unwrap_or_default().trim();
        let rest = pieces
            .next()
            .ok_or_else(|| format!("fault entry '{raw}': expected action:mN@point[:arg]"))?;
        let (target, arg) = match rest.split_once(':') {
            Some((t, a)) => (t.trim(), Some(a.trim())),
            None => (rest.trim(), None),
        };
        let (machine_tok, point_tok) = target
            .split_once('@')
            .ok_or_else(|| format!("fault entry '{raw}': expected mN@point after the action"))?;
        let machine: MachineId = machine_tok
            .trim()
            .strip_prefix('m')
            .and_then(|d| d.parse().ok())
            .ok_or_else(|| format!("fault entry '{raw}': bad machine '{machine_tok}' (mN)"))?;
        let point = Self::parse_point(point_tok.trim())
            .ok_or_else(|| format!("fault entry '{raw}': unknown point '{point_tok}'"))?;
        let action = match action_tok.to_ascii_lowercase().as_str() {
            "kill" => {
                if arg.is_some() {
                    return Err(format!("fault entry '{raw}': kill takes no argument"));
                }
                FaultAction::Kill
            }
            "drop-frame" => {
                if arg.is_some() {
                    return Err(format!("fault entry '{raw}': drop-frame takes no argument"));
                }
                FaultAction::DropFrame
            }
            "delay" => {
                let arg =
                    arg.ok_or_else(|| format!("fault entry '{raw}': delay needs a duration"))?;
                FaultAction::Delay(Self::parse_duration(arg).ok_or_else(|| {
                    format!("fault entry '{raw}': bad duration '{arg}' (e.g. 200ms, 1s)")
                })?)
            }
            other => {
                return Err(format!(
                    "fault entry '{raw}': unknown action '{other}' (kill | delay | drop-frame)"
                ))
            }
        };
        Ok(PlanEntry { action, machine, point, fired: false })
    }

    fn parse_point(tok: &str) -> Option<FaultPoint> {
        let tok = tok.to_ascii_lowercase();
        match tok.as_str() {
            "init" => Some(FaultPoint::Init),
            "job" => Some(FaultPoint::Job),
            "leaf" => Some(FaultPoint::Superstep(0)),
            "ship" => Some(FaultPoint::Ship),
            "recv" => Some(FaultPoint::Recv),
            _ => {
                let n = tok.strip_prefix("superstep")?;
                n.parse().ok().map(FaultPoint::Superstep)
            }
        }
    }

    fn parse_duration(tok: &str) -> Option<Duration> {
        if let Some(ms) = tok.strip_suffix("ms") {
            return ms.trim().parse().ok().map(Duration::from_millis);
        }
        if let Some(s) = tok.strip_suffix('s') {
            return s.trim().parse().ok().map(Duration::from_secs);
        }
        tok.parse().ok().map(Duration::from_millis)
    }

    /// The plan a worker session should follow, from
    /// `GREEDYML_FAULT_PLAN`.  `Ok(None)` when the variable is unset or
    /// the plan is empty; an unparsable plan is an error (a mis-spelt
    /// plan must not silently run fault-free).
    pub fn from_env() -> Result<Option<Self>, DistError> {
        match std::env::var("GREEDYML_FAULT_PLAN") {
            Err(_) => Ok(None),
            Ok(v) => match Self::parse(&v) {
                Ok(plan) if plan.entries.is_empty() => Ok(None),
                Ok(plan) => Ok(Some(plan)),
                Err(e) => Err(DistError::backend(format!("GREEDYML_FAULT_PLAN: {e}"))),
            },
        }
    }

    /// Consult the plan at a protocol point: returns the action of the
    /// first unfired entry matching `(machine, point)` and marks it
    /// fired, or `None`.
    pub fn trigger(&mut self, machine: MachineId, point: FaultPoint) -> Option<FaultAction> {
        let entry = self
            .entries
            .iter_mut()
            .find(|e| !e.fired && e.machine == machine && e.point == point)?;
        entry.fired = true;
        Some(entry.action)
    }

    /// True when no entries remain unfired.
    pub fn exhausted(&self) -> bool {
        self.entries.iter().all(|e| e.fired)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_spec_parses_tokens() {
        assert_eq!(FaultSpec::parse("auto").unwrap(), FaultSpec::Auto);
        assert_eq!(FaultSpec::parse(" Fail ").unwrap(), FaultSpec::Fail);
        assert_eq!(FaultSpec::parse("retry").unwrap(), FaultSpec::Retry);
        assert_eq!(FaultSpec::parse("degrade").unwrap(), FaultSpec::Degrade);
        assert!(FaultSpec::parse("panic").is_err());
    }

    #[test]
    fn explicit_fault_specs_resolve_without_env() {
        assert_eq!(FaultSpec::Fail.resolve().unwrap(), FaultPolicy::Fail);
        assert_eq!(FaultSpec::Retry.resolve().unwrap(), FaultPolicy::Retry);
        assert_eq!(FaultSpec::Degrade.resolve().unwrap(), FaultPolicy::Degrade);
    }

    #[test]
    fn plan_parses_the_documented_example() {
        let mut plan = FaultPlan::parse("kill:m2@leaf,delay:m0@ship:200ms,drop-frame:m1@recv")
            .expect("documented plan parses");
        assert_eq!(plan.trigger(2, FaultPoint::Superstep(0)), Some(FaultAction::Kill));
        assert_eq!(
            plan.trigger(0, FaultPoint::Ship),
            Some(FaultAction::Delay(Duration::from_millis(200)))
        );
        assert_eq!(plan.trigger(1, FaultPoint::Recv), Some(FaultAction::DropFrame));
        assert!(plan.exhausted());
    }

    #[test]
    fn superstep_points_parse_and_leaf_is_superstep_zero() {
        let mut plan = FaultPlan::parse("kill:m1@superstep2,kill:m3@superstep0").unwrap();
        assert_eq!(plan.trigger(1, FaultPoint::Superstep(2)), Some(FaultAction::Kill));
        assert_eq!(plan.trigger(3, FaultPoint::Superstep(0)), Some(FaultAction::Kill));
    }

    #[test]
    fn entries_fire_once_and_filter_by_machine() {
        let mut plan = FaultPlan::parse("kill:m2@leaf").unwrap();
        assert_eq!(plan.trigger(0, FaultPoint::Superstep(0)), None, "wrong machine");
        assert_eq!(plan.trigger(2, FaultPoint::Ship), None, "wrong point");
        assert_eq!(plan.trigger(2, FaultPoint::Superstep(0)), Some(FaultAction::Kill));
        assert_eq!(plan.trigger(2, FaultPoint::Superstep(0)), None, "one-shot");
    }

    #[test]
    fn bad_plans_are_rejected_with_the_offending_entry() {
        for bad in [
            "explode:m0@leaf",
            "kill:m0",
            "kill:x0@leaf",
            "kill:m0@nowhere",
            "delay:m0@ship",
            "delay:m0@ship:fast",
            "kill:m0@leaf:why",
        ] {
            let err = FaultPlan::parse(bad).expect_err(bad);
            assert!(err.contains(bad.split(',').next().unwrap()), "{err}");
        }
    }

    #[test]
    fn empty_and_whitespace_plans_are_empty() {
        assert!(FaultPlan::parse("").unwrap().exhausted());
        assert!(FaultPlan::parse(" , ").unwrap().exhausted());
    }

    #[test]
    fn durations_parse_ms_seconds_and_bare_millis() {
        let mut plan =
            FaultPlan::parse("delay:m0@job:1s,delay:m1@job:250ms,delay:m2@job:50").unwrap();
        assert_eq!(
            plan.trigger(0, FaultPoint::Job),
            Some(FaultAction::Delay(Duration::from_secs(1)))
        );
        assert_eq!(
            plan.trigger(1, FaultPoint::Job),
            Some(FaultAction::Delay(Duration::from_millis(250)))
        );
        assert_eq!(
            plan.trigger(2, FaultPoint::Job),
            Some(FaultAction::Delay(Duration::from_millis(50)))
        );
    }

    #[test]
    fn fault_report_absorbs_and_knows_emptiness() {
        let mut total = FaultReport::default();
        assert!(total.is_empty());
        let job = FaultReport {
            faults_seen: 2,
            retries: 1,
            machines_dropped: vec![3],
            elements_lost: 120,
        };
        total.absorb(&job);
        total.absorb(&job);
        assert_eq!(total.faults_seen, 4);
        assert_eq!(total.retries, 2);
        assert_eq!(total.machines_dropped, vec![3, 3]);
        assert_eq!(total.elements_lost, 240);
        assert!(!total.is_empty());
    }
}
