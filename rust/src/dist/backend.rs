//! The distributed-runtime backend abstraction.
//!
//! The BSP engine ([`crate::algo::run_dist`]) walks the accumulation tree
//! level by level, but *how* a superstep's per-machine tasks execute, how
//! child solutions ship between tree levels, and who mints each machine's
//! [`MemoryMeter`](super::MemoryMeter) is a [`Backend`] concern:
//!
//! * [`ThreadBackend`] — the default simulator: every machine is a task on
//!   the persistent work-stealing pool ([`super::pool`]), solutions move
//!   by `memcpy`, and communication seconds come from the α–β
//!   [`CommModel`].  `threads = 1` reproduces the serial runtime
//!   bit-for-bit.
//! * [`ProcessBackend`](super::proc::ProcessBackend) — one forked worker
//!   process per machine (a hidden `greedyml worker` subcommand speaking
//!   length-prefixed JSON frames over stdin/stdout), so every machine has
//!   a real address space and `comm_secs` is *measured* solution-shipping
//!   wall time instead of a model.
//!
//! * [`TcpBackend`](super::tcp::TcpBackend) — the multi-host transport:
//!   worker sessions hosted by `greedyml serve` daemons over TCP, with a
//!   protocol-version handshake, connect retry and per-frame timeouts;
//!   `comm_secs` is measured over a real network hop.
//!
//! The remote backends are **session-holding**: construction
//! (`ProcessBackend::spawn` / `TcpBackend::connect`) establishes the
//! sessions and ships the dataset exactly once, `begin_job` starts one
//! run against the resident shards, and [`Backend::finish`] ends the
//! *job* while the fleet stays warm for the next `begin_job` — until
//! `release` lets the workers go.  The thread backend shares one address
//! space, so it has no session to keep warm; `run_dist` builds it fresh
//! per run.
//!
//! Every backend runs the identical node program ([`super::node`]), so
//! solutions, values and call counts are bit-identical across them — the
//! property `tests/test_backend.rs` locks down.  An MPI backend slots in
//! behind the same trait (the ROADMAP north star).
//!
//! # Example
//!
//! Backends are selected through [`DistConfig`](crate::algo::DistConfig);
//! the thread backend needs no worker processes or hosts, so a run is
//! self-contained:
//!
//! ```
//! use greedyml::algo::{run_greedyml, DistConfig};
//! use greedyml::constraint::Cardinality;
//! use greedyml::data::gen::{transactions, TransactionParams};
//! use greedyml::dist::BackendSpec;
//! use greedyml::objective::KCover;
//! use greedyml::tree::AccumulationTree;
//! use std::sync::Arc;
//!
//! let params = TransactionParams { num_sets: 120, num_items: 60, mean_size: 4.0, zipf_s: 0.9 };
//! let oracle = KCover::new(Arc::new(transactions(params, 1)));
//! let constraint = Cardinality::new(5);
//! // 4 machines in a binary accumulation tree, explicitly on the
//! // in-process thread backend with a 2-wide executor.
//! let cfg = DistConfig {
//!     backend: BackendSpec::Thread,
//!     threads: Some(2),
//!     ..DistConfig::greedyml(AccumulationTree::new(4, 2), 7)
//! };
//! let out = run_greedyml(&oracle, &constraint, &cfg).unwrap();
//! assert!(out.solution.len() <= 5);
//! assert!(out.value > 0.0);
//! assert!(!out.comm_measured, "the thread backend models communication");
//! ```

use super::fault::FaultReport;
use super::node::{accum_step, leaf_step, NodeParams, NodeState, StepReport};
use super::pool::Executor;
use super::{CommModel, DistError, MachineStats};
use crate::constraint::Constraint;
use crate::objective::Oracle;
use crate::{ElemId, MachineId};

/// Which backend a [`DistConfig`](crate::algo::DistConfig) selects.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendSpec {
    /// Defer to the `GREEDYML_BACKEND` environment variable
    /// (`thread` | `process` | `tcp`), defaulting to
    /// [`BackendSpec::Thread`].
    #[default]
    Auto,
    /// In-process simulator on the persistent thread pool.
    Thread,
    /// One forked worker process per simulated machine.
    Process,
    /// One TCP worker session per simulated machine, hosted by remote
    /// `greedyml serve` daemons
    /// ([`DistConfig::hosts`](crate::algo::DistConfig::hosts)).
    Tcp,
}

impl BackendSpec {
    /// Parse a config/CLI token (`auto` | `thread` | `process` | `tcp`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "" => Ok(Self::Auto),
            "thread" | "threads" => Ok(Self::Thread),
            "process" | "proc" => Ok(Self::Process),
            "tcp" => Ok(Self::Tcp),
            other => Err(format!("unknown backend '{other}' (auto | thread | process | tcp)")),
        }
    }

    /// Resolve `Auto` through `GREEDYML_BACKEND`; an unparsable variable is
    /// an error (silently falling back would make a mis-spelt env var
    /// quietly change what an experiment measured).
    pub fn resolve(self) -> Result<ResolvedBackend, DistError> {
        match self {
            Self::Thread => Ok(ResolvedBackend::Thread),
            Self::Process => Ok(ResolvedBackend::Process),
            Self::Tcp => Ok(ResolvedBackend::Tcp),
            Self::Auto => match std::env::var("GREEDYML_BACKEND") {
                Err(_) => Ok(ResolvedBackend::Thread),
                Ok(v) => match Self::parse(&v) {
                    Ok(Self::Process) => Ok(ResolvedBackend::Process),
                    Ok(Self::Tcp) => Ok(ResolvedBackend::Tcp),
                    Ok(_) => Ok(ResolvedBackend::Thread),
                    Err(e) => Err(DistError::backend(format!("GREEDYML_BACKEND: {e}"))),
                },
            },
        }
    }
}

/// A [`BackendSpec`] with `Auto` already resolved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResolvedBackend {
    /// In-process thread-pool simulator.
    Thread,
    /// Process-per-machine workers.
    Process,
    /// TCP sessions on `greedyml serve` daemons.
    Tcp,
}

/// How a problem travels to remote (process/tcp) workers — the
/// `--ship` flag / `run.ship` config key / `GREEDYML_SHIP` environment
/// variable.  The thread backend shares one address space and ignores it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShipSpec {
    /// Defer to `GREEDYML_SHIP` (`spec` | `partition`), defaulting to
    /// [`ShipMode::Spec`].
    #[default]
    Auto,
    /// Ship the flat problem spec; every worker regenerates the whole
    /// dataset and restricts to its part (O(n) worker memory).
    Spec,
    /// Ship each worker only its O(n/m) dataset shard
    /// ([`crate::objective::PartitionPayload`]); solutions travel with
    /// their extracted data.  Requires a
    /// [`Partitionable`](crate::objective::Partitionable) oracle.
    Partition,
}

impl ShipSpec {
    /// Parse a config/CLI token (`auto` | `spec` | `partition`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "" => Ok(Self::Auto),
            "spec" => Ok(Self::Spec),
            "partition" | "part" => Ok(Self::Partition),
            other => Err(format!("unknown ship mode '{other}' (auto | spec | partition)")),
        }
    }

    /// Resolve `Auto` through `GREEDYML_SHIP`; an unparsable variable is
    /// an error, not a silent fallback — a mis-spelt mode must not
    /// quietly change what an experiment measured.
    pub fn resolve(self) -> Result<ShipMode, DistError> {
        match self {
            Self::Spec => Ok(ShipMode::Spec),
            Self::Partition => Ok(ShipMode::Partition),
            Self::Auto => match std::env::var("GREEDYML_SHIP") {
                Err(_) => Ok(ShipMode::Spec),
                Ok(v) => match Self::parse(&v) {
                    Ok(Self::Partition) => Ok(ShipMode::Partition),
                    Ok(_) => Ok(ShipMode::Spec),
                    Err(e) => Err(DistError::backend(format!("GREEDYML_SHIP: {e}"))),
                },
            },
        }
    }
}

/// A [`ShipSpec`] with `Auto` already resolved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShipMode {
    /// Rebuild-recipe shipping.
    Spec,
    /// Dataset-shard shipping.
    Partition,
}

/// How payload-bearing frames are encoded on the worker wire — the
/// `--wire` flag / `run.wire` config key / `GREEDYML_WIRE` environment
/// variable.  The thread backend shares one address space and ignores
/// it; results are bit-identical across modes either way, so this only
/// changes bytes-on-wire and decode cost, never the answer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WireSpec {
    /// Defer to `GREEDYML_WIRE` (`json` | `binary`), defaulting to
    /// [`WireMode::Json`].
    #[default]
    Auto,
    /// serde_json frames for every message (content type `0x01`) —
    /// debuggable, replayable by hand.
    Json,
    /// Raw little-endian section frames (content type `0x02`) for the
    /// payload-bearing messages (`init_part`, shipped solutions);
    /// control frames stay JSON.
    Binary,
}

impl WireSpec {
    /// Parse a config/CLI token (`auto` | `json` | `binary`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "" => Ok(Self::Auto),
            "json" => Ok(Self::Json),
            "binary" | "bin" => Ok(Self::Binary),
            other => Err(format!("unknown wire mode '{other}' (auto | json | binary)")),
        }
    }

    /// Resolve `Auto` through `GREEDYML_WIRE`; an unparsable variable is
    /// an error, not a silent fallback — a mis-spelt mode must not
    /// quietly change what an experiment measured.
    pub fn resolve(self) -> Result<WireMode, DistError> {
        match self {
            Self::Json => Ok(WireMode::Json),
            Self::Binary => Ok(WireMode::Binary),
            Self::Auto => match std::env::var("GREEDYML_WIRE") {
                Err(_) => Ok(WireMode::Json),
                Ok(v) => match Self::parse(&v) {
                    Ok(Self::Binary) => Ok(WireMode::Binary),
                    Ok(_) => Ok(WireMode::Json),
                    Err(e) => Err(DistError::backend(format!("GREEDYML_WIRE: {e}"))),
                },
            },
        }
    }
}

/// A [`WireSpec`] with `Auto` already resolved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireMode {
    /// JSON frames throughout (content type `0x01`).
    Json,
    /// Binary payload frames (content type `0x02`); control frames stay
    /// JSON.
    Binary,
}

/// Whether leaves sieve their partition down to a streaming coreset before
/// accumulation — the `--coreset` flag / `run.coreset` config key /
/// `GREEDYML_CORESET` environment variable.  In coreset mode every node
/// ships (and is charged for) an O(k·log(k)/ε) coreset instead of full
/// solutions-with-shards, trading the exact GreedyML answer for the sieve
/// value band (see [`crate::stream::coreset`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CoresetSpec {
    /// Defer to `GREEDYML_CORESET` (`on` | `off`), defaulting to off.
    #[default]
    Auto,
    /// Full GreedyML accumulation (the paper's algorithm, the default).
    Off,
    /// Sieve-filter every shard / child union down to its coreset.
    On,
}

impl CoresetSpec {
    /// Parse a config/CLI token (`auto` | `on` | `off`, with the usual
    /// boolean spellings).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "" => Ok(Self::Auto),
            "on" | "true" | "1" | "yes" => Ok(Self::On),
            "off" | "false" | "0" | "no" => Ok(Self::Off),
            other => Err(format!("unknown coreset mode '{other}' (auto | on | off)")),
        }
    }

    /// Resolve `Auto` through `GREEDYML_CORESET`; an unparsable variable
    /// is an error, not a silent fallback — a mis-spelt mode must not
    /// quietly change what an experiment measured.
    pub fn resolve(self) -> Result<bool, DistError> {
        match self {
            Self::On => Ok(true),
            Self::Off => Ok(false),
            Self::Auto => match std::env::var("GREEDYML_CORESET") {
                Err(_) => Ok(false),
                Ok(v) => match Self::parse(&v) {
                    Ok(Self::On) => Ok(true),
                    Ok(_) => Ok(false),
                    Err(e) => Err(DistError::backend(format!("GREEDYML_CORESET: {e}"))),
                },
            },
        }
    }
}

/// What the coordinator ships a remote backend when the **session** is
/// established: either the rebuild recipe for every worker, or the
/// per-machine dataset shards (`payloads[i]` belongs to machine `i`).
/// Shipped exactly once — the constraint spec and node parameters travel
/// later, on each `Job` frame, so one resident shard serves many runs.
#[derive(Clone, Debug)]
pub enum ShipPlan<'a> {
    /// Spec shipping: one flat `key = value` problem spec for all workers.
    Spec(&'a str),
    /// Partition shipping: one dataset shard per machine.
    Partition {
        /// Machine-ordered shards.
        payloads: Vec<crate::objective::PartitionPayload>,
    },
}

/// One accumulation assignment within a superstep: `parent` gathers the
/// solutions of `children` (its own S_prev stays in place — the engine has
/// already removed the `j = 0` self-child).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AccumTask {
    /// The aggregating node.
    pub parent: MachineId,
    /// Retiring children whose solutions ship to `parent`, in tree order.
    pub children: Vec<MachineId>,
}

/// What the backend hands back when the run completes.
#[derive(Clone, Debug)]
pub struct BackendOutcome {
    /// The root's final solution.
    pub solution: Vec<ElemId>,
    /// f(solution) as the root evaluated it.
    pub value: f64,
    /// Per-machine lifetime statistics, indexed by machine id.
    pub machines: Vec<MachineStats>,
    /// Fault accounting for this job: empty unless a supervised remote
    /// fleet ([`FaultPolicy`](super::FaultPolicy) retry/degrade) saw
    /// transport faults.  The thread backend cannot fault and always
    /// reports empty.
    pub faults: FaultReport,
}

/// The three responsibilities the engine delegates: superstep fan-out,
/// solution shipping between tree levels, and per-machine resources
/// (memory meters, stats).  Implementations must execute the shared node
/// program (`dist::node`) so results are backend-independent.
pub trait Backend {
    /// Backend label for reports and errors.
    fn name(&self) -> &'static str;

    /// Superstep 0: run GREEDY on every machine's partition
    /// (`parts[i]` belongs to machine `i`).  Reports come back in machine
    /// order; if any machine fails, the whole superstep still completes
    /// (BSP ranks finish their step) and the first failure in machine
    /// order is returned.
    fn run_leaves(&mut self, parts: Vec<Vec<ElemId>>) -> Result<Vec<StepReport>, DistError>;

    /// Superstep `level ≥ 1`: ship each task's child solutions to its
    /// parent and run the accumulation step there.  Reports come back in
    /// task order; error semantics as in [`Backend::run_leaves`].
    fn run_superstep(
        &mut self,
        level: u32,
        tasks: &[AccumTask],
    ) -> Result<Vec<StepReport>, DistError>;

    /// End the **job** and collect the final solution + per-machine
    /// stats.  Remote fleets stay warm afterwards — the resident shards
    /// survive for the next `begin_job`; only the thread backend (built
    /// fresh per run) has nothing to keep.
    fn finish(&mut self) -> Result<BackendOutcome, DistError>;

    /// Whether `comm_secs` in this backend's reports is measured wall time
    /// (process backend) rather than the α–β model (thread backend).
    fn measures_comm(&self) -> bool;
}

/// The in-process backend: machines are tasks on the persistent
/// work-stealing [`Executor`]; `comm_secs` follows the α–β [`CommModel`].
pub struct ThreadBackend<'a> {
    exec: &'a Executor<'a>,
    oracle: &'a dyn Oracle,
    constraint: &'a dyn Constraint,
    params: NodeParams,
    comm: CommModel,
    /// Live per-machine state (None once retired or not yet started).
    nodes: Vec<Option<NodeState>>,
    /// Stats of machines that shipped their solution and retired.
    retired: Vec<Option<MachineStats>>,
}

impl<'a> ThreadBackend<'a> {
    /// Backend over `machines` simulated machines on an already-running
    /// executor.
    pub fn new(
        exec: &'a Executor<'a>,
        oracle: &'a dyn Oracle,
        constraint: &'a dyn Constraint,
        params: NodeParams,
        comm: CommModel,
        machines: u32,
    ) -> Self {
        Self {
            exec,
            oracle,
            constraint,
            params,
            comm,
            nodes: (0..machines).map(|_| None).collect(),
            retired: (0..machines).map(|_| None).collect(),
        }
    }
}

impl Backend for ThreadBackend<'_> {
    fn name(&self) -> &'static str {
        "thread"
    }

    fn run_leaves(&mut self, parts: Vec<Vec<ElemId>>) -> Result<Vec<StepReport>, DistError> {
        let oracle = self.oracle;
        let constraint = self.constraint;
        let params = &self.params;
        let inputs: Vec<(MachineId, Vec<ElemId>)> =
            parts.into_iter().enumerate().map(|(i, p)| (i as MachineId, p)).collect();
        let results = self.exec.map(inputs, |(id, part)| {
            leaf_step(oracle, constraint, params, id, &part)
        });
        let mut reports = Vec::with_capacity(results.len());
        for r in results {
            let (state, report) = r?;
            let id = state.stats.id as usize;
            self.nodes[id] = Some(state);
            reports.push(report);
        }
        Ok(reports)
    }

    fn run_superstep(
        &mut self,
        level: u32,
        tasks: &[AccumTask],
    ) -> Result<Vec<StepReport>, DistError> {
        // Shipping phase: children hand their solutions to the submitting
        // thread (in-process "communication"), retiring as they go.
        struct Work {
            state: NodeState,
            children: Vec<super::node::ChildMsg>,
        }
        let mut work: Vec<Work> = Vec::with_capacity(tasks.len());
        for task in tasks {
            let state = self.nodes[task.parent as usize].take().expect("parent state missing");
            let mut children = Vec::with_capacity(task.children.len());
            for &c in &task.children {
                let mut child = self.nodes[c as usize].take().expect("child state missing");
                children.push(child.ship());
                self.retired[c as usize] = Some(child.stats);
            }
            work.push(Work { state, children });
        }

        // Accumulation phase: fan out across the pool; modeled gather cost.
        let oracle = self.oracle;
        let constraint = self.constraint;
        let params = &self.params;
        let comm = self.comm;
        let results = self.exec.map(work, |mut w| {
            let msg_bytes: Vec<u64> = w.children.iter().map(|c| c.bytes).collect();
            let comm_secs = comm.gather_time(&msg_bytes);
            let report = accum_step(
                oracle,
                constraint,
                params,
                &mut w.state,
                level,
                &w.children,
                comm_secs,
            )?;
            Ok::<(NodeState, StepReport), DistError>((w.state, report))
        });
        let mut reports = Vec::with_capacity(results.len());
        for r in results {
            let (state, report) = r?;
            let id = state.stats.id as usize;
            self.nodes[id] = Some(state);
            reports.push(report);
        }
        Ok(reports)
    }

    fn finish(&mut self) -> Result<BackendOutcome, DistError> {
        let root = self.nodes[0].take().expect("root state missing");
        let solution = root.sol.clone();
        let value = root.sol_value;
        self.retired[0] = Some(root.stats);
        for (i, slot) in self.nodes.iter_mut().enumerate() {
            if let Some(state) = slot.take() {
                self.retired[i] = Some(state.stats);
            }
        }
        let machines: Vec<MachineStats> = self
            .retired
            .iter_mut()
            .map(|s| s.take().expect("machine stats missing"))
            .collect();
        Ok(BackendOutcome { solution, value, machines, faults: FaultReport::default() })
    }

    fn measures_comm(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_spec_parses_tokens() {
        assert_eq!(BackendSpec::parse("auto").unwrap(), BackendSpec::Auto);
        assert_eq!(BackendSpec::parse("thread").unwrap(), BackendSpec::Thread);
        assert_eq!(BackendSpec::parse(" Process ").unwrap(), BackendSpec::Process);
        assert_eq!(BackendSpec::parse("tcp").unwrap(), BackendSpec::Tcp);
        assert!(BackendSpec::parse("mpi").is_err());
    }

    #[test]
    fn explicit_specs_resolve_without_env() {
        assert_eq!(BackendSpec::Thread.resolve().unwrap(), ResolvedBackend::Thread);
        assert_eq!(BackendSpec::Process.resolve().unwrap(), ResolvedBackend::Process);
        assert_eq!(BackendSpec::Tcp.resolve().unwrap(), ResolvedBackend::Tcp);
    }

    #[test]
    fn ship_spec_parses_tokens() {
        assert_eq!(ShipSpec::parse("auto").unwrap(), ShipSpec::Auto);
        assert_eq!(ShipSpec::parse(" Spec ").unwrap(), ShipSpec::Spec);
        assert_eq!(ShipSpec::parse("partition").unwrap(), ShipSpec::Partition);
        assert_eq!(ShipSpec::parse("part").unwrap(), ShipSpec::Partition);
        assert!(ShipSpec::parse("telepathy").is_err());
    }

    #[test]
    fn explicit_ship_specs_resolve_without_env() {
        assert_eq!(ShipSpec::Spec.resolve().unwrap(), ShipMode::Spec);
        assert_eq!(ShipSpec::Partition.resolve().unwrap(), ShipMode::Partition);
    }

    #[test]
    fn wire_spec_parses_tokens() {
        assert_eq!(WireSpec::parse("auto").unwrap(), WireSpec::Auto);
        assert_eq!(WireSpec::parse(" Json ").unwrap(), WireSpec::Json);
        assert_eq!(WireSpec::parse("binary").unwrap(), WireSpec::Binary);
        assert_eq!(WireSpec::parse("bin").unwrap(), WireSpec::Binary);
        assert!(WireSpec::parse("carrier-pigeon").is_err());
    }

    #[test]
    fn explicit_wire_specs_resolve_without_env() {
        assert_eq!(WireSpec::Json.resolve().unwrap(), WireMode::Json);
        assert_eq!(WireSpec::Binary.resolve().unwrap(), WireMode::Binary);
    }

    #[test]
    fn coreset_spec_parses_tokens() {
        assert_eq!(CoresetSpec::parse("auto").unwrap(), CoresetSpec::Auto);
        assert_eq!(CoresetSpec::parse(" On ").unwrap(), CoresetSpec::On);
        assert_eq!(CoresetSpec::parse("true").unwrap(), CoresetSpec::On);
        assert_eq!(CoresetSpec::parse("off").unwrap(), CoresetSpec::Off);
        assert_eq!(CoresetSpec::parse("0").unwrap(), CoresetSpec::Off);
        assert!(CoresetSpec::parse("maybe").is_err());
    }

    #[test]
    fn explicit_coreset_specs_resolve_without_env() {
        assert!(CoresetSpec::On.resolve().unwrap());
        assert!(!CoresetSpec::Off.resolve().unwrap());
    }
}
