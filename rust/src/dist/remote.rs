//! The shared coordinator-side driver for framed-transport backends.
//!
//! The process backend (pipes to forked workers) and the tcp backend
//! (sockets to `greedyml serve` daemons) speak the identical protocol of
//! [`super::wire`] and differ only in what carries the bytes.  This module
//! is the transport-generic half they share: a [`FramedWorker`] wraps one
//! worker's read/write byte streams behind typed `send`/`recv`, and
//! [`RemoteFleet`] drives a fleet of them through the session/job split of
//! protocol v3:
//!
//! * [`RemoteFleet::establish`] opens the **session** — one `Init` /
//!   `InitPart` per worker ships the dataset (or its shard) exactly once
//!   and verifies each `Ready`; the fleet then stays warm,
//! * [`RemoteFleet::begin_job`] starts one **job** on the warm fleet — a
//!   `Job` frame per worker carrying only the node parameters and
//!   constraint spec,
//! * the [`Backend`] impl runs the job's supersteps (leaf fan-out, the
//!   Ship → Recv gather whose wall time *is* the measured `comm_secs`,
//!   accumulation kick-off) and its `finish` collects `Final`s via
//!   `JobDone` — after which the fleet is ready for the next
//!   `begin_job`,
//! * [`RemoteFleet::release`] ends the session (best-effort `Release`).
//!
//! Keeping this logic in one place is what keeps the transports
//! interchangeable: a backend cannot drift in superstep ordering or error
//! semantics when it only supplies `Read`/`Write` endpoints.

use super::backend::{AccumTask, Backend, BackendOutcome, ShipPlan};
use super::node::{ChildMsg, NodeParams, StepReport};
use super::wire::{read_frame, write_frame, FromWorker, ToWorker};
use super::{DistError, MachineStats};
use crate::{ElemId, MachineId};
use std::io::{Read, Write};
use std::time::Instant;

/// One remote worker (= one simulated machine) behind a framed byte
/// stream: `reader` carries worker → coordinator replies, `writer`
/// coordinator → worker commands.  `peer` (the tcp backend sets it to the
/// worker's `host:port`) labels every transport error, so a multi-host
/// failure names the offending worker, not just its machine number.
pub(crate) struct FramedWorker<R, W> {
    /// The machine this worker simulates (also its index in the fleet).
    pub machine: MachineId,
    peer: Option<String>,
    reader: R,
    writer: W,
}

impl<R: Read, W: Write> FramedWorker<R, W> {
    /// Wrap a worker's byte streams.
    pub fn new(machine: MachineId, reader: R, writer: W) -> Self {
        Self { machine, peer: None, reader, writer }
    }

    /// Label this worker with its transport endpoint (`host:port`) for
    /// error messages.
    pub fn with_peer(mut self, peer: impl Into<String>) -> Self {
        self.peer = Some(peer.into());
        self
    }

    /// "worker 3" / "worker 3 at 10.0.0.2:7401" — the error-message label.
    pub fn who(&self) -> String {
        match &self.peer {
            Some(p) => format!("worker {} at {p}", self.machine),
            None => format!("worker {}", self.machine),
        }
    }

    /// Send one command frame; returns the bytes put on the wire so
    /// session-level shipping cost (Init payloads) can be accounted.
    pub fn send(&mut self, msg: &ToWorker) -> Result<u64, DistError> {
        write_frame(&mut self.writer, &msg.to_value())
            .map_err(|e| DistError::backend(format!("{}: {e}", self.who())))
    }

    /// Receive one reply frame; a closed stream (worker death, dropped
    /// connection) is an error, not a hang — the transport's per-frame
    /// timeout bounds how long a silent-but-open stream can stall this.
    pub fn recv(&mut self) -> Result<FromWorker, DistError> {
        match read_frame(&mut self.reader) {
            Ok(Some(v)) => FromWorker::from_value(&v),
            Ok(None) => Err(DistError::backend(format!(
                "{} disconnected before replying",
                self.who()
            ))),
            Err(e) => Err(DistError::backend(format!("{}: {e}", self.who()))),
        }
    }

    /// Receive, unwrapping a worker-side failure into `Err`.
    pub fn recv_ok(&mut self) -> Result<FromWorker, DistError> {
        match self.recv()? {
            FromWorker::Fail(e) => Err(e),
            other => Ok(other),
        }
    }
}

/// A warm fleet of framed workers holding one **session**: the dataset
/// shipped once at [`RemoteFleet::establish`], any number of jobs run
/// against it via [`RemoteFleet::begin_job`] + the [`Backend`] contract.
/// The transport layer (process spawn, TCP connect + handshake) builds
/// the [`FramedWorker`]s; everything protocol-shaped lives here.
pub(crate) struct RemoteFleet<R, W> {
    name: &'static str,
    workers: Vec<FramedWorker<R, W>>,
    next_job: u64,
    init_bytes: u64,
}

impl<R: Read, W: Write> RemoteFleet<R, W> {
    /// Open a session: send every `Init`/`InitPart` before reading any
    /// `Ready`, so the `m` per-worker rebuilds (dataset regeneration under
    /// spec shipping, shard deserialization under partition shipping) run
    /// concurrently, then verify each worker holds what the coordinator
    /// thinks it shipped.  `n` is the global ground-set size — the
    /// expected `Ready` under spec shipping.
    ///
    /// `workers` must arrive in machine order (worker `i` simulates
    /// machine `i`) — superstep routing indexes the fleet by machine id,
    /// and under partition shipping `payloads[i]` is machine `i`'s shard.
    pub fn establish(
        name: &'static str,
        workers: Vec<FramedWorker<R, W>>,
        threads: usize,
        plan: ShipPlan<'_>,
        n: usize,
        session: u64,
    ) -> Result<Self, DistError> {
        let mut fleet = Self { name, workers, next_job: 0, init_bytes: 0 };
        // Per-worker expected Ready{n}: the global ground set under spec
        // shipping, the shard size under partition shipping.
        let expected: Vec<usize> = match &plan {
            ShipPlan::Spec(_) => vec![n; fleet.workers.len()],
            ShipPlan::Partition { payloads } => {
                if payloads.len() != fleet.workers.len() {
                    return Err(DistError::backend(format!(
                        "{} shards for {} workers",
                        payloads.len(),
                        fleet.workers.len()
                    )));
                }
                payloads.iter().map(|p| p.len()).collect()
            }
        };
        match plan {
            ShipPlan::Spec(problem) => {
                for w in &mut fleet.workers {
                    let init = ToWorker::Init {
                        session,
                        machine: w.machine,
                        threads,
                        problem: problem.to_string(),
                    };
                    fleet.init_bytes += w.send(&init)?;
                }
            }
            ShipPlan::Partition { payloads } => {
                for (w, payload) in fleet.workers.iter_mut().zip(payloads) {
                    let init = ToWorker::InitPart {
                        session,
                        machine: w.machine,
                        threads,
                        payload,
                    };
                    fleet.init_bytes += w.send(&init)?;
                }
            }
        }
        for (w, want) in fleet.workers.iter_mut().zip(expected) {
            match w.recv_ok()? {
                FromWorker::Ready { n } if n == want => {}
                FromWorker::Ready { n } => {
                    return Err(DistError::backend(format!(
                        "{} holds {n} elements, coordinator shipped {want}; \
                         the shipped problem does not describe this oracle",
                        w.who()
                    )))
                }
                other => {
                    return Err(DistError::backend(format!(
                        "{}: expected ready, got {other:?}",
                        w.who()
                    )))
                }
            }
        }
        Ok(fleet)
    }

    /// Start one job on the warm fleet: a `Job` frame per worker carrying
    /// the node parameters and constraint spec.  Every worker must ack
    /// with its resident oracle's global ground-set size (`params.n`) —
    /// anything else means the session does not serve this problem.
    pub fn begin_job(&mut self, params: &NodeParams, spec: &str) -> Result<(), DistError> {
        let job = self.next_job;
        self.next_job += 1;
        for w in &mut self.workers {
            let cmd =
                ToWorker::Job { job, params: params.clone(), spec: spec.to_string() };
            w.send(&cmd)?;
        }
        for w in &mut self.workers {
            match w.recv_ok()? {
                FromWorker::Ready { n } if n == params.n => {}
                FromWorker::Ready { n } => {
                    return Err(DistError::backend(format!(
                        "{} serves a ground set of {n} elements, the job wants {}; \
                         the resident session does not hold this problem",
                        w.who(),
                        params.n
                    )))
                }
                other => {
                    return Err(DistError::backend(format!(
                        "{}: expected ready, got {other:?}",
                        w.who()
                    )))
                }
            }
        }
        Ok(())
    }

    /// Wire bytes the session `Init`/`InitPart` frames put on the
    /// transport — the dataset-shipping cost paid exactly once per
    /// session, however many jobs follow.
    pub fn init_bytes(&self) -> u64 {
        self.init_bytes
    }

    /// Jobs started on this session so far.
    pub fn jobs_started(&self) -> u64 {
        self.next_job
    }

    /// End the session: best-effort `Release` to every worker (a worker
    /// that already died is ignored — the session is over either way).
    pub fn release(&mut self) {
        for w in &mut self.workers {
            let _ = w.send(&ToWorker::Release);
        }
    }
}

impl<R: Read, W: Write> Backend for RemoteFleet<R, W> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn run_leaves(&mut self, parts: Vec<Vec<ElemId>>) -> Result<Vec<StepReport>, DistError> {
        if parts.len() != self.workers.len() {
            return Err(DistError::backend(format!(
                "{} partitions for {} workers",
                parts.len(),
                self.workers.len()
            )));
        }
        for (w, part) in self.workers.iter_mut().zip(parts) {
            w.send(&ToWorker::Leaf { part })?;
        }
        // Every rank finishes its superstep; first failure in machine
        // order wins (same semantics as the thread backend).
        let mut reports = Vec::with_capacity(self.workers.len());
        let mut first_err: Option<DistError> = None;
        for w in &mut self.workers {
            match w.recv()? {
                FromWorker::Step(r) => reports.push(r),
                FromWorker::Fail(e) => first_err = first_err.take().or(Some(e)),
                other => {
                    return Err(DistError::backend(format!(
                        "{}: expected step, got {other:?}",
                        w.who()
                    )))
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(reports),
        }
    }

    fn run_superstep(
        &mut self,
        level: u32,
        tasks: &[AccumTask],
    ) -> Result<Vec<StepReport>, DistError> {
        // Shipping phase: for each parent, gather the retiring children's
        // solutions and forward them.  The clock runs from the first Ship
        // request to the parent's Recv receipt — serialization, two
        // transport hops and deserialization are all inside it, which is
        // exactly the cost the α–β model approximates.  Under partition
        // shipping the forwarded ChildMsg additionally carries the
        // solution's data shard; the clock covers those bytes too, which
        // is the point — that data movement *is* §4.2's communication.
        for task in tasks {
            let t0 = Instant::now();
            let mut children: Vec<ChildMsg> = Vec::with_capacity(task.children.len());
            for &c in &task.children {
                self.workers[c as usize].send(&ToWorker::Ship)?;
                match self.workers[c as usize].recv_ok()? {
                    FromWorker::Sol(msg) => children.push(msg),
                    other => {
                        return Err(DistError::backend(format!(
                            "{}: expected sol, got {other:?}",
                            self.workers[c as usize].who()
                        )))
                    }
                }
            }
            let parent = &mut self.workers[task.parent as usize];
            parent.send(&ToWorker::Recv { level, children })?;
            match parent.recv_ok()? {
                FromWorker::Ack => {}
                other => {
                    return Err(DistError::backend(format!(
                        "{}: expected ack, got {other:?}",
                        parent.who()
                    )))
                }
            }
            let comm_secs = t0.elapsed().as_secs_f64();
            // Kick off the accumulation and move on — parents of this
            // superstep compute concurrently in their own workers.
            parent.send(&ToWorker::Accum { level, comm_secs })?;
        }

        // Collection phase, in task order.
        let mut reports = Vec::with_capacity(tasks.len());
        let mut first_err: Option<DistError> = None;
        for task in tasks {
            let parent = &mut self.workers[task.parent as usize];
            match parent.recv()? {
                FromWorker::Step(r) => reports.push(r),
                FromWorker::Fail(e) => first_err = first_err.take().or(Some(e)),
                other => {
                    return Err(DistError::backend(format!(
                        "{}: expected step, got {other:?}",
                        parent.who()
                    )))
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(reports),
        }
    }

    fn finish(&mut self) -> Result<BackendOutcome, DistError> {
        // End of the *job*, not the session: JobDone collects every
        // worker's Final and the fleet stays warm for the next begin_job.
        for w in &mut self.workers {
            w.send(&ToWorker::JobDone)?;
        }
        let mut machines: Vec<MachineStats> = Vec::with_capacity(self.workers.len());
        let mut solution = Vec::new();
        let mut value = 0.0;
        for w in &mut self.workers {
            match w.recv_ok()? {
                FromWorker::Final { stats, sol, value: v } => {
                    if stats.id != w.machine {
                        return Err(DistError::backend(format!(
                            "{} reported stats for machine {}",
                            w.who(),
                            stats.id
                        )));
                    }
                    if w.machine == 0 {
                        solution = sol;
                        value = v;
                    }
                    machines.push(stats);
                }
                other => {
                    return Err(DistError::backend(format!(
                        "{}: expected final, got {other:?}",
                        w.who()
                    )))
                }
            }
        }
        Ok(BackendOutcome { solution, value, machines })
    }

    fn measures_comm(&self) -> bool {
        // Solutions really serialize and cross a pipe or socket; the
        // Ship → Recv clock above is wall time, not the α–β model.
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::{PartitionData, PartitionPayload};

    /// Drive a RemoteFleet against in-memory byte buffers: scripted
    /// worker replies on the read side, captured commands on the write
    /// side.  No processes, no sockets — pure protocol logic.
    fn scripted(replies: &[FromWorker]) -> Vec<u8> {
        let mut buf = Vec::new();
        for r in replies {
            write_frame(&mut buf, &r.to_value()).unwrap();
        }
        buf
    }

    fn params(n: usize) -> NodeParams {
        NodeParams {
            kind: crate::greedy::GreedyKind::Lazy,
            seed: 1,
            n,
            mem_limit: None,
            local_view: false,
            added_elements: 0,
            compare_all_children: false,
        }
    }

    fn shard(n_global: usize, elems: Vec<ElemId>) -> PartitionPayload {
        let weights = vec![1.0; elems.len()];
        PartitionPayload { n_global, elems, data: PartitionData::Modular { weights } }
    }

    #[test]
    fn establish_rejects_a_divergent_ground_set() {
        let replies = scripted(&[FromWorker::Ready { n: 7 }]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let err = RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 100, 0)
            .err()
            .expect("ground-set mismatch must fail");
        let msg = err.to_string();
        assert!(msg.contains("7 elements"), "{msg}");
        assert!(msg.contains("100"), "{msg}");
    }

    #[test]
    fn partition_establish_checks_the_shard_size_not_the_ground_set() {
        // The worker acknowledges its 3-element shard of a 100-element
        // problem; Ready{3} must pass where spec shipping would demand 100.
        let replies = scripted(&[FromWorker::Ready { n: 3 }]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let plan = ShipPlan::Partition { payloads: vec![shard(100, vec![5, 50, 99])] };
        RemoteFleet::establish("test", vec![worker], 1, plan, 100, 0)
            .expect("shard-sized Ready is correct under partition shipping");

        let replies = scripted(&[FromWorker::Ready { n: 100 }]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let plan = ShipPlan::Partition { payloads: vec![shard(100, vec![5, 50, 99])] };
        let err = RemoteFleet::establish("test", vec![worker], 1, plan, 100, 0)
            .err()
            .expect("a worker claiming the full ground set diverged");
        assert!(err.to_string().contains("coordinator shipped 3"), "{err}");
    }

    #[test]
    fn partition_establish_requires_one_shard_per_worker() {
        let replies = scripted(&[FromWorker::Ready { n: 1 }]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let plan = ShipPlan::Partition { payloads: Vec::new() };
        let err = RemoteFleet::establish("test", vec![worker], 1, plan, 10, 0)
            .err()
            .expect("0 shards for 1 worker must fail");
        assert!(err.to_string().contains("0 shards"), "{err}");
    }

    #[test]
    fn worker_disconnect_is_an_error_not_a_hang() {
        // An empty reply stream = the worker died before Ready.
        let empty: &[u8] = &[];
        let worker = FramedWorker::new(3, empty, Vec::<u8>::new());
        let err = RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 10, 0)
            .err()
            .expect("EOF must fail");
        assert!(err.to_string().contains("worker 3 disconnected"), "{err}");
    }

    #[test]
    fn peer_label_names_the_host_in_errors() {
        let empty: &[u8] = &[];
        let worker =
            FramedWorker::new(2, empty, Vec::<u8>::new()).with_peer("10.0.0.7:7401");
        let err = RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 10, 0)
            .err()
            .expect("EOF must fail");
        let msg = err.to_string();
        assert!(msg.contains("worker 2 at 10.0.0.7:7401"), "{msg}");
    }

    #[test]
    fn worker_fail_reply_surfaces_as_the_inner_error() {
        let replies = scripted(&[FromWorker::Fail(DistError::backend("no such dataset"))]);
        let worker = FramedWorker::new(1, replies.as_slice(), Vec::<u8>::new());
        let err = RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 10, 0)
            .err()
            .expect("Fail must propagate");
        assert!(err.to_string().contains("no such dataset"), "{err}");
    }

    #[test]
    fn establish_counts_the_init_wire_bytes() {
        // init_bytes must equal exactly what write_frame put on the wire
        // for the session's Init frames — the dist_ship bench asserts the
        // 1×shard-per-session property on this number.
        let replies = scripted(&[FromWorker::Ready { n: 100 }]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let fleet =
            RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("the spec"), 100, 0)
                .expect("establish");
        let mut expected = Vec::new();
        let init = ToWorker::Init {
            session: 0,
            machine: 0,
            threads: 1,
            problem: "the spec".to_string(),
        };
        write_frame(&mut expected, &init.to_value()).unwrap();
        assert_eq!(fleet.init_bytes(), expected.len() as u64);
    }

    #[test]
    fn begin_job_acks_the_global_ground_set_and_counts_jobs() {
        // Session Ready, then two job Readys — both acking the *global* n.
        let replies = scripted(&[
            FromWorker::Ready { n: 100 },
            FromWorker::Ready { n: 100 },
            FromWorker::Ready { n: 100 },
        ]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let mut fleet =
            RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 100, 0)
                .expect("establish");
        assert_eq!(fleet.jobs_started(), 0);
        fleet.begin_job(&params(100), "problem.k = 2\n").expect("job 0");
        fleet.begin_job(&params(100), "problem.k = 4\n").expect("job 1");
        assert_eq!(fleet.jobs_started(), 2);
    }

    #[test]
    fn begin_job_rejects_a_session_holding_a_different_problem() {
        let replies = scripted(&[
            FromWorker::Ready { n: 100 },
            FromWorker::Ready { n: 100 },
        ]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let mut fleet =
            RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 100, 0)
                .expect("establish");
        let err = fleet
            .begin_job(&params(60), "problem.k = 2\n")
            .err()
            .expect("a job for a 60-element problem cannot run on a 100-element session");
        let msg = err.to_string();
        assert!(msg.contains("100 elements"), "{msg}");
        assert!(msg.contains("wants 60"), "{msg}");
    }
}
