//! The shared coordinator-side driver for framed-transport backends.
//!
//! The process backend (pipes to forked workers) and the tcp backend
//! (sockets to `greedyml serve` daemons) speak the identical protocol of
//! [`super::wire`] and differ only in what carries the bytes.  This module
//! is the transport-generic half they share: a [`FramedWorker`] wraps one
//! worker's read/write byte streams behind typed `send`/`recv`, and
//! [`RemoteFleet`] drives a fleet of them through the session/job split of
//! protocol v3:
//!
//! * [`RemoteFleet::establish`] opens the **session** — one `Init` /
//!   `InitPart` per worker ships the dataset (or its shard) exactly once
//!   and verifies each `Ready`; the fleet then stays warm,
//! * [`RemoteFleet::begin_job`] starts one **job** on the warm fleet — a
//!   `Job` frame per worker carrying only the node parameters and
//!   constraint spec,
//! * the [`Backend`] impl runs the job's supersteps (leaf fan-out, the
//!   Ship → Recv gather whose wall time *is* the measured `comm_secs`,
//!   accumulation kick-off) and its `finish` collects `Final`s via
//!   `JobDone` — after which the fleet is ready for the next
//!   `begin_job`,
//! * [`RemoteFleet::release`] ends the session (best-effort `Release`).
//!
//! Keeping this logic in one place is what keeps the transports
//! interchangeable: a backend cannot drift in superstep ordering or error
//! semantics when it only supplies `Read`/`Write` endpoints.
//!
//! # Supervision
//!
//! Under [`RemoteFleet::supervise`] the fleet also owns fault recovery.
//! Every transport-level failure (send, EOF, frame timeout) classifies as
//! a retryable [`DistError::Transport`]; what happens next is the
//! session's [`FaultPolicy`]:
//!
//! * **fail** (unsupervised) — the first fault aborts the run, exactly
//!   the pre-supervision behavior.
//! * **retry** — the supervisor revives the machine through a
//!   transport-supplied reconnect closure (respawn a worker process,
//!   dial the next host), re-ships its session `Init`/`InitPart`, and
//!   replays its job-scoped command log.  The partition and every seeded
//!   draw replay deterministically from the ship plan, so the revived
//!   machine's replies are bit-identical to the ones the dead machine
//!   would have sent.  Bounded attempts
//!   ([`RETRY_ATTEMPTS`](super::fault::RETRY_ATTEMPTS)) with exponential
//!   backoff.
//! * **degrade** — the dead machine's contribution is dropped from its
//!   parent's accumulation and the run completes on the survivors, with
//!   full accounting in the [`FaultReport`] (machine 0 holds the root
//!   and can never be dropped).
//!
//! To make replay possible the fleet retains each machine's init frame
//! for the lifetime of the session (under partition shipping that is the
//! machine's O(n/m) shard — the memory price of re-dispatch) and, only
//! while supervised, logs the current job's commands per machine.

use super::backend::{AccumTask, Backend, BackendOutcome, ShipPlan, WireMode};
use super::fault::{FaultPolicy, FaultReport, RETRY_ATTEMPTS, RETRY_BACKOFF_BASE};
use super::node::{ChildMsg, NodeParams, StepReport};
use super::wire::{read_reply, write_cmd, FromWorker, ToWorker};
use super::{DistError, MachineStats};
use crate::objective::{PartitionDelta, PartitionPayload};
use crate::{ElemId, MachineId};
use std::io::{Read, Write};
use std::time::Instant;

/// How a supervised fleet obtains a replacement session for a dead
/// machine: respawn a worker process (process backend) or dial a spare /
/// surviving host (tcp backend).  Called with the machine id and the
/// zero-based revival attempt.
pub(crate) type Reconnect<R, W> =
    Box<dyn FnMut(MachineId, u32) -> Result<FramedWorker<R, W>, DistError> + Send>;

/// One remote worker (= one simulated machine) behind a framed byte
/// stream: `reader` carries worker → coordinator replies, `writer`
/// coordinator → worker commands.  `peer` (the tcp backend sets it to the
/// worker's `host:port`) labels every transport error, so a multi-host
/// failure names the offending worker, not just its machine number.
pub(crate) struct FramedWorker<R, W> {
    /// The machine this worker simulates (also its index in the fleet).
    pub machine: MachineId,
    peer: Option<String>,
    /// Frame encoding for payload-bearing commands (`--wire`); results
    /// are bit-identical either way.
    mode: WireMode,
    reader: R,
    writer: W,
}

impl<R: Read, W: Write> FramedWorker<R, W> {
    /// Wrap a worker's byte streams (JSON wire mode).
    pub fn new(machine: MachineId, reader: R, writer: W) -> Self {
        Self { machine, peer: None, mode: WireMode::Json, reader, writer }
    }

    /// Label this worker with its transport endpoint (`host:port`) for
    /// error messages.
    pub fn with_peer(mut self, peer: impl Into<String>) -> Self {
        self.peer = Some(peer.into());
        self
    }

    /// Select the wire mode for this worker's payload-bearing frames.
    pub fn with_mode(mut self, mode: WireMode) -> Self {
        self.mode = mode;
        self
    }

    /// "worker 3" / "worker 3 at 10.0.0.2:7401" — the error-message label.
    pub fn who(&self) -> String {
        match &self.peer {
            Some(p) => format!("worker {} at {p}", self.machine),
            None => format!("worker {}", self.machine),
        }
    }

    /// Send one command frame; returns the bytes put on the wire so
    /// session-level shipping cost (Init payloads) can be accounted.  A
    /// write failure (broken pipe, reset connection) is a retryable
    /// [`DistError::Transport`].
    pub fn send(&mut self, msg: &ToWorker) -> Result<u64, DistError> {
        write_cmd(&mut self.writer, msg, self.mode)
            .map_err(|e| DistError::transport(format!("{}: {e}", self.who())))
    }

    /// Receive one reply frame; a closed stream (worker death, dropped
    /// connection) is an error, not a hang — the transport's per-frame
    /// timeout bounds how long a silent-but-open stream can stall this.
    /// EOF, I/O failures (including that timeout) and undecodable frames
    /// all classify as retryable [`DistError::Transport`]s: supervision
    /// replays the machine, and a peer that keeps sending garbage
    /// exhausts its bounded retries.
    pub fn recv(&mut self) -> Result<FromWorker, DistError> {
        match read_reply(&mut self.reader) {
            Ok(Some(msg)) => Ok(msg),
            Ok(None) => Err(DistError::transport(format!(
                "{} disconnected before replying",
                self.who()
            ))),
            Err(e) => Err(DistError::transport(format!("{}: {e}", self.who()))),
        }
    }

    /// Receive, unwrapping a worker-side failure into `Err`.
    pub fn recv_ok(&mut self) -> Result<FromWorker, DistError> {
        match self.recv()? {
            FromWorker::Fail(e) => Err(e),
            other => Ok(other),
        }
    }
}

/// A warm fleet of framed workers holding one **session**: the dataset
/// shipped once at [`RemoteFleet::establish`], any number of jobs run
/// against it via [`RemoteFleet::begin_job`] + the [`Backend`] contract.
/// The transport layer (process spawn, TCP connect + handshake) builds
/// the [`FramedWorker`]s; everything protocol-shaped lives here.
pub(crate) struct RemoteFleet<R, W> {
    name: &'static str,
    workers: Vec<FramedWorker<R, W>>,
    next_job: u64,
    init_bytes: u64,
    /// Each machine's session `Init`/`InitPart`, retained for re-dispatch
    /// (under partition shipping this keeps the machine's shard resident
    /// at the coordinator — the memory price of being able to revive).
    init_cmds: Vec<ToWorker>,
    /// The `Ready{n}` each machine must ack for its init on replay.
    expected_ready: Vec<usize>,
    /// Per-machine log of the current job's commands; populated only
    /// while supervised, cleared by `begin_job` and a successful
    /// `finish`.
    logs: Vec<Vec<ToWorker>>,
    /// Machines dropped by [`FaultPolicy::Degrade`]; dead machines are
    /// skipped by every superstep and synthesized in reports.
    dead: Vec<bool>,
    /// Ground-set elements riding on each machine: its partition size
    /// plus every child subtree that successfully shipped into it — what
    /// `elements_lost` charges when the machine is dropped.
    subtree_elems: Vec<u64>,
    supervisor: Option<Supervisor<R, W>>,
}

/// Supervision state, present only under retry/degrade policies.
struct Supervisor<R, W> {
    policy: FaultPolicy,
    reconnect: Reconnect<R, W>,
    report: FaultReport,
}

/// What a supervised operation did about a transport fault.
enum Recovered {
    /// Retry: the machine was revived and its log replayed; the reply to
    /// the in-flight command, when the caller was waiting on one.
    Reply(Option<FromWorker>),
    /// Degrade: the machine was dropped with accounting; the caller
    /// synthesizes its part of the superstep.
    Dropped,
}

/// The zeroed [`StepReport`] standing in for a dropped machine's
/// superstep — shape-compatible with traces and stats, zero cost booked.
fn dropped_step(machine: MachineId, level: u32) -> StepReport {
    StepReport { machine, level, ..StepReport::default() }
}

/// The wire name of a command, for replay error messages.
fn cmd_name(cmd: &ToWorker) -> &'static str {
    match cmd {
        ToWorker::Hello { .. } => "hello",
        ToWorker::Init { .. } => "init",
        ToWorker::InitPart { .. } => "init-part",
        ToWorker::Job { .. } => "job",
        ToWorker::Leaf { .. } => "leaf",
        ToWorker::Ship => "ship",
        ToWorker::Recv { .. } => "recv",
        ToWorker::Accum { .. } => "accum",
        ToWorker::JobDone => "job-done",
        ToWorker::Delta { .. } => "delta",
        ToWorker::Release => "release",
        ToWorker::Ping => "ping",
    }
}

/// Whether `reply` is the kind of frame the protocol defines for `cmd` —
/// the type check replay applies to every re-driven command.
fn replay_reply_matches(cmd: &ToWorker, reply: &FromWorker) -> bool {
    matches!(
        (cmd, reply),
        (
            ToWorker::Init { .. } | ToWorker::InitPart { .. } | ToWorker::Job { .. },
            FromWorker::Ready { .. }
        ) | (ToWorker::Leaf { .. } | ToWorker::Accum { .. }, FromWorker::Step(_))
            | (ToWorker::Ship, FromWorker::Sol(_))
            | (ToWorker::Recv { .. }, FromWorker::Ack)
            | (ToWorker::JobDone, FromWorker::Final { .. })
            | (ToWorker::Delta { .. }, FromWorker::DeltaDone { .. })
            | (ToWorker::Ping, FromWorker::Pong)
    )
}

impl<R: Read, W: Write> RemoteFleet<R, W> {
    /// Open a session: send every `Init`/`InitPart` before reading any
    /// `Ready`, so the `m` per-worker rebuilds (dataset regeneration under
    /// spec shipping, shard deserialization under partition shipping) run
    /// concurrently, then verify each worker holds what the coordinator
    /// thinks it shipped.  `n` is the global ground-set size — the
    /// expected `Ready` under spec shipping.
    ///
    /// `workers` must arrive in machine order (worker `i` simulates
    /// machine `i`) — superstep routing indexes the fleet by machine id,
    /// and under partition shipping `payloads[i]` is machine `i`'s shard.
    pub fn establish(
        name: &'static str,
        workers: Vec<FramedWorker<R, W>>,
        threads: usize,
        plan: ShipPlan<'_>,
        n: usize,
        session: u64,
    ) -> Result<Self, DistError> {
        let machines = workers.len();
        let mut fleet = Self {
            name,
            workers,
            next_job: 0,
            init_bytes: 0,
            init_cmds: Vec::with_capacity(machines),
            expected_ready: Vec::new(),
            logs: vec![Vec::new(); machines],
            dead: vec![false; machines],
            subtree_elems: vec![0; machines],
            supervisor: None,
        };
        // Per-worker expected Ready{n}: the global ground set under spec
        // shipping, the shard size under partition shipping.
        let expected: Vec<usize> = match &plan {
            ShipPlan::Spec(_) => vec![n; fleet.workers.len()],
            ShipPlan::Partition { payloads } => {
                if payloads.len() != fleet.workers.len() {
                    return Err(DistError::backend(format!(
                        "{} shards for {} workers",
                        payloads.len(),
                        fleet.workers.len()
                    )));
                }
                payloads.iter().map(|p| p.len()).collect()
            }
        };
        match plan {
            ShipPlan::Spec(problem) => {
                for w in &mut fleet.workers {
                    let init = ToWorker::Init {
                        session,
                        machine: w.machine,
                        threads,
                        problem: problem.to_string(),
                    };
                    fleet.init_bytes += w.send(&init)?;
                    fleet.init_cmds.push(init);
                }
            }
            ShipPlan::Partition { payloads } => {
                for (w, payload) in fleet.workers.iter_mut().zip(payloads) {
                    let init = ToWorker::InitPart {
                        session,
                        machine: w.machine,
                        threads,
                        payload,
                    };
                    fleet.init_bytes += w.send(&init)?;
                    fleet.init_cmds.push(init);
                }
            }
        }
        fleet.expected_ready = expected.clone();
        for (w, want) in fleet.workers.iter_mut().zip(expected) {
            match w.recv_ok()? {
                FromWorker::Ready { n } if n == want => {}
                FromWorker::Ready { n } => {
                    return Err(DistError::backend(format!(
                        "{} holds {n} elements, coordinator shipped {want}; \
                         the shipped problem does not describe this oracle",
                        w.who()
                    )))
                }
                other => {
                    return Err(DistError::backend(format!(
                        "{}: expected ready, got {other:?}",
                        w.who()
                    )))
                }
            }
        }
        Ok(fleet)
    }

    /// Start one job on the warm fleet: a `Job` frame per worker carrying
    /// the node parameters and constraint spec.  Every worker must ack
    /// with its resident oracle's global ground-set size (`params.n`) —
    /// anything else means the session does not serve this problem.  A
    /// fleet that lost machines to an earlier degraded job refuses new
    /// work: the pool must re-establish a whole session instead.
    pub fn begin_job(&mut self, params: &NodeParams, spec: &str) -> Result<(), DistError> {
        if let Some(m) = self.dead.iter().position(|&d| d) {
            return Err(DistError::transport(format!(
                "machine {m} was dropped by an earlier degraded job; \
                 re-establish the session"
            )));
        }
        for log in &mut self.logs {
            log.clear();
        }
        let job = self.next_job;
        self.next_job += 1;
        for m in 0..self.workers.len() {
            let cmd = ToWorker::Job { job, params: params.clone(), spec: spec.to_string() };
            self.sup_send(m as MachineId, cmd)?;
        }
        for m in 0..self.workers.len() {
            match self.sup_recv(m as MachineId)? {
                // Dropped during admission (degrade) — its partition loss
                // is charged when run_leaves assigns the partitions.
                None => {}
                Some(FromWorker::Ready { n }) if n == params.n => {}
                Some(FromWorker::Ready { n }) => {
                    return Err(DistError::backend(format!(
                        "{} serves a ground set of {n} elements, the job wants {}; \
                         the resident session does not hold this problem",
                        self.workers[m].who(),
                        params.n
                    )))
                }
                Some(FromWorker::Fail(e)) => return Err(e),
                Some(other) => {
                    return Err(DistError::backend(format!(
                        "{}: expected ready, got {other:?}",
                        self.workers[m].who()
                    )))
                }
            }
        }
        Ok(())
    }

    /// Wire bytes the session `Init`/`InitPart` frames put on the
    /// transport — the dataset-shipping cost paid exactly once per
    /// session, however many jobs follow.
    pub fn init_bytes(&self) -> u64 {
        self.init_bytes
    }

    /// Jobs started on this session so far.
    pub fn jobs_started(&self) -> u64 {
        self.next_job
    }

    /// Advance the resident dataset one epoch: fan one `Delta` frame per
    /// machine (its shard's slice of the global diff), await every
    /// `DeltaDone`, and verify each machine's post-delta shard size
    /// against `fresh` — the coordinator-side payloads the same delta
    /// produces.  `fresh` also replaces the retained init frames, so a
    /// machine revived *after* the advance rebuilds from the post-delta
    /// shard directly (one frame instead of replaying the stale init
    /// plus the delta — equivalent by compaction, cheaper on the wire).
    /// Returns the delta wire bytes.  Only partition-shipped sessions
    /// can advance: spec shipping has no resident shard to diff.
    pub fn advance_epoch(
        &mut self,
        epoch: u64,
        deltas: Vec<PartitionDelta>,
        fresh: Vec<PartitionPayload>,
    ) -> Result<u64, DistError> {
        if let Some(m) = self.dead.iter().position(|&d| d) {
            return Err(DistError::transport(format!(
                "machine {m} was dropped by an earlier degraded job; \
                 re-establish the session"
            )));
        }
        let machines = self.workers.len();
        if deltas.len() != machines || fresh.len() != machines {
            return Err(DistError::backend(format!(
                "{} deltas / {} shards for {} workers",
                deltas.len(),
                fresh.len(),
                machines
            )));
        }
        if self.init_cmds.iter().any(|c| !matches!(c, ToWorker::InitPart { .. })) {
            return Err(DistError::backend(
                "delta on a spec-shipped session (live datasets need \
                 partition shipping)",
            ));
        }
        // Fan every delta before reading any DeltaDone so the m
        // shard compactions run concurrently, mirroring establish.
        let mut delta_bytes = 0u64;
        for (w, delta) in self.workers.iter_mut().zip(deltas) {
            delta_bytes += w.send(&ToWorker::Delta { epoch, delta })?;
        }
        for (m, payload) in fresh.into_iter().enumerate() {
            let want = payload.elems.len();
            match self.workers[m].recv_ok()? {
                FromWorker::DeltaDone { epoch: e, n } if e == epoch && n == want => {}
                FromWorker::DeltaDone { epoch: e, n } => {
                    return Err(DistError::backend(format!(
                        "{} holds {n} elements at epoch {e}, the coordinator's \
                         delta leaves {want} at epoch {epoch}; the resident \
                         shard diverged",
                        self.workers[m].who()
                    )))
                }
                other => {
                    return Err(DistError::backend(format!(
                        "{}: expected delta-done, got {other:?}",
                        self.workers[m].who()
                    )))
                }
            }
            let (session, threads) = match &self.init_cmds[m] {
                ToWorker::InitPart { session, threads, .. } => (*session, *threads),
                _ => unreachable!("checked above: every init is an InitPart"),
            };
            self.expected_ready[m] = want;
            self.init_cmds[m] =
                ToWorker::InitPart { session, machine: m as MachineId, threads, payload };
        }
        Ok(delta_bytes)
    }

    /// End the session: best-effort `Release` to every worker (a worker
    /// that already died is ignored — the session is over either way).
    pub fn release(&mut self) {
        for w in &mut self.workers {
            let _ = w.send(&ToWorker::Release);
        }
    }

    /// Put the fleet under supervision: transport faults are no longer
    /// immediately fatal but handled per `policy` (see the module docs).
    /// `reconnect` is how the transport layer obtains a replacement
    /// session for a dead machine.
    pub fn supervise(&mut self, policy: FaultPolicy, reconnect: Reconnect<R, W>) {
        self.supervisor =
            Some(Supervisor { policy, reconnect, report: FaultReport::default() });
    }

    /// The fault accounting accumulated since the last job finished
    /// (empty for an unsupervised or fault-free fleet).
    pub fn fault_report(&self) -> FaultReport {
        self.supervisor.as_ref().map(|s| s.report.clone()).unwrap_or_default()
    }

    /// Probe every worker with a `Ping`.  Deliberately does **not**
    /// recover: a warm fleet that fails its probe — or that lost machines
    /// to a degraded job — is for the pool to discard and re-establish,
    /// not to patch mid-idle.
    pub fn ping_all(&mut self) -> Result<(), DistError> {
        if let Some(m) = self.dead.iter().position(|&d| d) {
            return Err(DistError::transport(format!(
                "machine {m} was dropped by an earlier degraded job"
            )));
        }
        for w in &mut self.workers {
            w.send(&ToWorker::Ping)?;
        }
        for w in &mut self.workers {
            match w.recv()? {
                FromWorker::Pong => {}
                FromWorker::Fail(e) => return Err(e),
                other => {
                    return Err(DistError::backend(format!(
                        "{}: expected pong, got {other:?}",
                        w.who()
                    )))
                }
            }
        }
        Ok(())
    }

    /// Send `cmd` to `machine`, logging it for replay when supervised.
    /// `Ok(false)` means the machine is (or just became) dead under
    /// degrade — the caller must skip its reply.
    fn sup_send(&mut self, machine: MachineId, cmd: ToWorker) -> Result<bool, DistError> {
        let m = machine as usize;
        if self.dead[m] {
            return Ok(false);
        }
        let sent = self.workers[m].send(&cmd);
        if self.supervisor.is_some() {
            self.logs[m].push(cmd);
        }
        match sent {
            Ok(_) => Ok(true),
            Err(e) => match self.handle_fault(machine, e, false)? {
                // Revived: the replay re-delivered the logged command.
                Recovered::Reply(_) => Ok(true),
                Recovered::Dropped => Ok(false),
            },
        }
    }

    /// Receive the pending reply from `machine`.  `Ok(None)` means the
    /// machine is dead under degrade — the caller synthesizes its report.
    fn sup_recv(&mut self, machine: MachineId) -> Result<Option<FromWorker>, DistError> {
        let m = machine as usize;
        if self.dead[m] {
            return Ok(None);
        }
        match self.workers[m].recv() {
            Ok(reply) => Ok(Some(reply)),
            Err(e) => match self.handle_fault(machine, e, true)? {
                Recovered::Reply(r) => Ok(r),
                Recovered::Dropped => Ok(None),
            },
        }
    }

    /// Apply the fault policy to a transport failure on `machine`.
    /// `consume_last` says whether the caller was waiting on a reply to
    /// the machine's last logged command (recv) or had only sent (send).
    fn handle_fault(
        &mut self,
        machine: MachineId,
        err: DistError,
        consume_last: bool,
    ) -> Result<Recovered, DistError> {
        if !err.is_retryable() {
            return Err(err);
        }
        let policy = match &mut self.supervisor {
            None => return Err(err),
            Some(sup) => {
                sup.report.faults_seen += 1;
                sup.policy
            }
        };
        match policy {
            FaultPolicy::Fail => Err(err),
            FaultPolicy::Retry => {
                self.revive(machine, consume_last).map(Recovered::Reply)
            }
            FaultPolicy::Degrade => {
                if machine == 0 {
                    return Err(DistError::transport(format!(
                        "machine 0 holds the root of the accumulation tree \
                         and cannot be dropped: {err}"
                    )));
                }
                self.dead[machine as usize] = true;
                self.drop_contribution(machine);
                Ok(Recovered::Dropped)
            }
        }
    }

    /// Account a machine whose contribution will never reach the root:
    /// the machine itself when it dies under degrade, and its orphaned
    /// live children when their parent is already dead.
    fn drop_contribution(&mut self, machine: MachineId) {
        let elems = self.subtree_elems[machine as usize];
        let sup = self.supervisor.as_mut().expect("degrade implies supervision");
        sup.report.machines_dropped.push(machine);
        sup.report.elements_lost += elems;
    }

    /// Revive a dead machine under retry: reconnect through the
    /// supervisor's closure, then replay — bounded attempts, exponential
    /// backoff (attempt `a > 0` sleeps `RETRY_BACKOFF_BASE << (a-1)`).
    /// Returns the in-flight reply when `consume_last`.
    fn revive(
        &mut self,
        machine: MachineId,
        consume_last: bool,
    ) -> Result<Option<FromWorker>, DistError> {
        let m = machine as usize;
        let mut last_err = DistError::transport(format!("machine {machine} lost"));
        for attempt in 0..RETRY_ATTEMPTS {
            if attempt > 0 {
                std::thread::sleep(RETRY_BACKOFF_BASE * (1 << (attempt - 1)));
            }
            let fresh = {
                let sup = self.supervisor.as_mut().expect("retry implies supervision");
                match (sup.reconnect)(machine, attempt) {
                    Ok(w) => w,
                    Err(e) if e.is_retryable() => {
                        last_err = e;
                        continue;
                    }
                    Err(e) => return Err(e),
                }
            };
            self.workers[m] = fresh;
            match self.replay(machine, consume_last) {
                Ok(reply) => {
                    let sup =
                        self.supervisor.as_mut().expect("retry implies supervision");
                    sup.report.retries += 1;
                    return Ok(reply);
                }
                Err(e) if e.is_retryable() => {
                    last_err = e;
                    continue;
                }
                Err(e) => return Err(e),
            }
        }
        Err(DistError::transport(format!(
            "machine {machine} could not be revived after {RETRY_ATTEMPTS} attempts: \
             {last_err}"
        )))
    }

    /// Re-drive a replacement session to where the dead one stood: the
    /// machine's session init, then the current job's command log.  Every
    /// reply but the last is consumed and type-checked; the last is
    /// returned when `consume_last` (the caller was mid-recv) and left
    /// pending otherwise (the caller had only sent).  The replies are
    /// bit-identical to the originals — partition and seeded draws replay
    /// deterministically — so discarding them is sound.
    fn replay(
        &mut self,
        machine: MachineId,
        consume_last: bool,
    ) -> Result<Option<FromWorker>, DistError> {
        let m = machine as usize;
        let script: Vec<&ToWorker> =
            std::iter::once(&self.init_cmds[m]).chain(self.logs[m].iter()).collect();
        let last = script.len() - 1;
        for (i, cmd) in script.into_iter().enumerate() {
            self.workers[m].send(cmd)?;
            if i == last && !consume_last {
                return Ok(None);
            }
            let reply = self.workers[m].recv()?;
            if let FromWorker::Fail(e) = reply {
                return Err(e);
            }
            if !replay_reply_matches(cmd, &reply) {
                return Err(DistError::backend(format!(
                    "{}: replay of {} produced {reply:?}",
                    self.workers[m].who(),
                    cmd_name(cmd)
                )));
            }
            // The revived session must hold exactly what the original
            // acked when the coordinator shipped it.
            let want = match cmd {
                ToWorker::Init { .. } | ToWorker::InitPart { .. } => {
                    Some(self.expected_ready[m])
                }
                ToWorker::Job { params, .. } => Some(params.n),
                _ => None,
            };
            if let (Some(want), FromWorker::Ready { n }) = (want, &reply) {
                if *n != want {
                    return Err(DistError::backend(format!(
                        "{}: replayed {} acked {n} elements, expected {want}",
                        self.workers[m].who(),
                        cmd_name(cmd)
                    )));
                }
            }
            if i == last {
                return Ok(Some(reply));
            }
        }
        unreachable!("the script always contains at least the init command")
    }
}

impl<R: Read, W: Write> Backend for RemoteFleet<R, W> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn run_leaves(&mut self, parts: Vec<Vec<ElemId>>) -> Result<Vec<StepReport>, DistError> {
        if parts.len() != self.workers.len() {
            return Err(DistError::backend(format!(
                "{} partitions for {} workers",
                parts.len(),
                self.workers.len()
            )));
        }
        for (m, part) in parts.into_iter().enumerate() {
            // The machine's subtree weight starts at its partition size
            // and absorbs child subtrees as they ship into it — the
            // degrade accounting's charge if the machine is dropped.
            self.subtree_elems[m] = part.len() as u64;
            if self.dead[m] {
                // Died during job admission: its partition now has an
                // owner and is charged as lost.
                if let Some(sup) = self.supervisor.as_mut() {
                    sup.report.elements_lost += part.len() as u64;
                }
                continue;
            }
            self.sup_send(m as MachineId, ToWorker::Leaf { part })?;
        }
        // Every rank finishes its superstep; first failure in machine
        // order wins (same semantics as the thread backend).
        let mut reports = Vec::with_capacity(self.workers.len());
        let mut first_err: Option<DistError> = None;
        for m in 0..self.workers.len() {
            match self.sup_recv(m as MachineId)? {
                None => reports.push(dropped_step(m as MachineId, 0)),
                Some(FromWorker::Step(r)) => reports.push(r),
                Some(FromWorker::Fail(e)) => first_err = first_err.take().or(Some(e)),
                Some(other) => {
                    return Err(DistError::backend(format!(
                        "{}: expected step, got {other:?}",
                        self.workers[m].who()
                    )))
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(reports),
        }
    }

    fn run_superstep(
        &mut self,
        level: u32,
        tasks: &[AccumTask],
    ) -> Result<Vec<StepReport>, DistError> {
        // Shipping phase: for each parent, gather the retiring children's
        // solutions and forward them.  The clock runs from the first Ship
        // request to the parent's Recv receipt — serialization, two
        // transport hops and deserialization are all inside it, which is
        // exactly the cost the α–β model approximates.  Under partition
        // shipping the forwarded ChildMsg additionally carries the
        // solution's data shard; the clock covers those bytes too, which
        // is the point — that data movement *is* §4.2's communication.
        for task in tasks {
            if self.dead[task.parent as usize] {
                // The parent died earlier: its surviving children have no
                // one to ship to — their contributions are lost too, but
                // the workers themselves stay healthy (they idle until
                // JobDone and still report their stats).
                for &c in &task.children {
                    if !self.dead[c as usize] {
                        self.drop_contribution(c);
                    }
                }
                continue;
            }
            let t0 = Instant::now();
            let mut children: Vec<ChildMsg> = Vec::with_capacity(task.children.len());
            for &c in &task.children {
                if !self.sup_send(c, ToWorker::Ship)? {
                    continue;
                }
                match self.sup_recv(c)? {
                    // Died shipping; dropped with accounting by the
                    // supervisor — the parent accumulates the survivors.
                    None => continue,
                    Some(FromWorker::Sol(msg)) => {
                        self.subtree_elems[task.parent as usize] +=
                            self.subtree_elems[c as usize];
                        children.push(msg);
                    }
                    Some(FromWorker::Fail(e)) => return Err(e),
                    Some(other) => {
                        return Err(DistError::backend(format!(
                            "{}: expected sol, got {other:?}",
                            self.workers[c as usize].who()
                        )))
                    }
                }
            }
            if !self.sup_send(task.parent, ToWorker::Recv { level, children })? {
                continue;
            }
            match self.sup_recv(task.parent)? {
                None => continue,
                Some(FromWorker::Ack) => {}
                Some(FromWorker::Fail(e)) => return Err(e),
                Some(other) => {
                    return Err(DistError::backend(format!(
                        "{}: expected ack, got {other:?}",
                        self.workers[task.parent as usize].who()
                    )))
                }
            }
            let comm_secs = t0.elapsed().as_secs_f64();
            // Kick off the accumulation and move on — parents of this
            // superstep compute concurrently in their own workers.
            self.sup_send(task.parent, ToWorker::Accum { level, comm_secs })?;
        }

        // Collection phase, in task order.
        let mut reports = Vec::with_capacity(tasks.len());
        let mut first_err: Option<DistError> = None;
        for task in tasks {
            match self.sup_recv(task.parent)? {
                None => reports.push(dropped_step(task.parent, level)),
                Some(FromWorker::Step(r)) => reports.push(r),
                Some(FromWorker::Fail(e)) => first_err = first_err.take().or(Some(e)),
                Some(other) => {
                    return Err(DistError::backend(format!(
                        "{}: expected step, got {other:?}",
                        self.workers[task.parent as usize].who()
                    )))
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(reports),
        }
    }

    fn finish(&mut self) -> Result<BackendOutcome, DistError> {
        // End of the *job*, not the session: JobDone collects every
        // worker's Final and the fleet stays warm for the next begin_job.
        for m in 0..self.workers.len() {
            self.sup_send(m as MachineId, ToWorker::JobDone)?;
        }
        let mut machines: Vec<MachineStats> = Vec::with_capacity(self.workers.len());
        let mut solution = Vec::new();
        let mut value = 0.0;
        for m in 0..self.workers.len() {
            let machine = m as MachineId;
            match self.sup_recv(machine)? {
                // A dropped machine reports zeroed stats — the degraded
                // run's accounting lives in the FaultReport, not here.
                None => machines.push(MachineStats::new(machine)),
                Some(FromWorker::Final { stats, sol, value: v }) => {
                    if stats.id != machine {
                        return Err(DistError::backend(format!(
                            "{} reported stats for machine {}",
                            self.workers[m].who(),
                            stats.id
                        )));
                    }
                    if machine == 0 {
                        solution = sol;
                        value = v;
                    }
                    machines.push(stats);
                }
                Some(FromWorker::Fail(e)) => return Err(e),
                Some(other) => {
                    return Err(DistError::backend(format!(
                        "{}: expected final, got {other:?}",
                        self.workers[m].who()
                    )))
                }
            }
        }
        // The job is over: its replay log has served its purpose, and the
        // report resets so a pooled fleet accounts per job.
        for log in &mut self.logs {
            log.clear();
        }
        let faults = self
            .supervisor
            .as_mut()
            .map(|s| std::mem::take(&mut s.report))
            .unwrap_or_default();
        Ok(BackendOutcome { solution, value, machines, faults })
    }

    fn measures_comm(&self) -> bool {
        // Solutions really serialize and cross a pipe or socket; the
        // Ship → Recv clock above is wall time, not the α–β model.
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::wire::{read_cmd, read_frame, write_frame};
    use crate::objective::{PartitionData, PartitionPayload};

    /// Drive a RemoteFleet against in-memory byte buffers: scripted
    /// worker replies on the read side, captured commands on the write
    /// side.  No processes, no sockets — pure protocol logic.
    fn scripted(replies: &[FromWorker]) -> Vec<u8> {
        let mut buf = Vec::new();
        for r in replies {
            write_frame(&mut buf, &r.to_value()).unwrap();
        }
        buf
    }

    fn params(n: usize) -> NodeParams {
        NodeParams {
            kind: crate::greedy::GreedyKind::Lazy,
            seed: 1,
            n,
            mem_limit: None,
            local_view: false,
            added_elements: 0,
            compare_all_children: false,
            coreset: false,
        }
    }

    fn shard(n_global: usize, elems: Vec<ElemId>) -> PartitionPayload {
        let weights = vec![1.0; elems.len()];
        PartitionPayload { n_global, elems, data: PartitionData::Modular { weights } }
    }

    #[test]
    fn establish_rejects_a_divergent_ground_set() {
        let replies = scripted(&[FromWorker::Ready { n: 7 }]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let err = RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 100, 0)
            .err()
            .expect("ground-set mismatch must fail");
        let msg = err.to_string();
        assert!(msg.contains("7 elements"), "{msg}");
        assert!(msg.contains("100"), "{msg}");
    }

    #[test]
    fn partition_establish_checks_the_shard_size_not_the_ground_set() {
        // The worker acknowledges its 3-element shard of a 100-element
        // problem; Ready{3} must pass where spec shipping would demand 100.
        let replies = scripted(&[FromWorker::Ready { n: 3 }]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let plan = ShipPlan::Partition { payloads: vec![shard(100, vec![5, 50, 99])] };
        RemoteFleet::establish("test", vec![worker], 1, plan, 100, 0)
            .expect("shard-sized Ready is correct under partition shipping");

        let replies = scripted(&[FromWorker::Ready { n: 100 }]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let plan = ShipPlan::Partition { payloads: vec![shard(100, vec![5, 50, 99])] };
        let err = RemoteFleet::establish("test", vec![worker], 1, plan, 100, 0)
            .err()
            .expect("a worker claiming the full ground set diverged");
        assert!(err.to_string().contains("coordinator shipped 3"), "{err}");
    }

    #[test]
    fn partition_establish_requires_one_shard_per_worker() {
        let replies = scripted(&[FromWorker::Ready { n: 1 }]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let plan = ShipPlan::Partition { payloads: Vec::new() };
        let err = RemoteFleet::establish("test", vec![worker], 1, plan, 10, 0)
            .err()
            .expect("0 shards for 1 worker must fail");
        assert!(err.to_string().contains("0 shards"), "{err}");
    }

    #[test]
    fn worker_disconnect_is_an_error_not_a_hang() {
        // An empty reply stream = the worker died before Ready.
        let empty: &[u8] = &[];
        let worker = FramedWorker::new(3, empty, Vec::<u8>::new());
        let err = RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 10, 0)
            .err()
            .expect("EOF must fail");
        assert!(err.to_string().contains("worker 3 disconnected"), "{err}");
        assert!(err.is_retryable(), "a worker death is a transport fault: {err}");
    }

    #[test]
    fn peer_label_names_the_host_in_errors() {
        let empty: &[u8] = &[];
        let worker =
            FramedWorker::new(2, empty, Vec::<u8>::new()).with_peer("10.0.0.7:7401");
        let err = RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 10, 0)
            .err()
            .expect("EOF must fail");
        let msg = err.to_string();
        assert!(msg.contains("worker 2 at 10.0.0.7:7401"), "{msg}");
    }

    #[test]
    fn worker_fail_reply_surfaces_as_the_inner_error() {
        let replies = scripted(&[FromWorker::Fail(DistError::backend("no such dataset"))]);
        let worker = FramedWorker::new(1, replies.as_slice(), Vec::<u8>::new());
        let err = RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 10, 0)
            .err()
            .expect("Fail must propagate");
        assert!(err.to_string().contains("no such dataset"), "{err}");
    }

    #[test]
    fn establish_counts_the_init_wire_bytes() {
        // init_bytes must equal exactly what write_frame put on the wire
        // for the session's Init frames — the dist_ship bench asserts the
        // 1×shard-per-session property on this number.
        let replies = scripted(&[FromWorker::Ready { n: 100 }]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let fleet =
            RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("the spec"), 100, 0)
                .expect("establish");
        let mut expected = Vec::new();
        let init = ToWorker::Init {
            session: 0,
            machine: 0,
            threads: 1,
            problem: "the spec".to_string(),
        };
        write_frame(&mut expected, &init.to_value()).unwrap();
        assert_eq!(fleet.init_bytes(), expected.len() as u64);
    }

    #[test]
    fn begin_job_acks_the_global_ground_set_and_counts_jobs() {
        // Session Ready, then two job Readys — both acking the *global* n.
        let replies = scripted(&[
            FromWorker::Ready { n: 100 },
            FromWorker::Ready { n: 100 },
            FromWorker::Ready { n: 100 },
        ]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let mut fleet =
            RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 100, 0)
                .expect("establish");
        assert_eq!(fleet.jobs_started(), 0);
        fleet.begin_job(&params(100), "problem.k = 2\n").expect("job 0");
        fleet.begin_job(&params(100), "problem.k = 4\n").expect("job 1");
        assert_eq!(fleet.jobs_started(), 2);
    }

    #[test]
    fn begin_job_rejects_a_session_holding_a_different_problem() {
        let replies = scripted(&[
            FromWorker::Ready { n: 100 },
            FromWorker::Ready { n: 100 },
        ]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let mut fleet =
            RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 100, 0)
                .expect("establish");
        let err = fleet
            .begin_job(&params(60), "problem.k = 2\n")
            .err()
            .expect("a job for a 60-element problem cannot run on a 100-element session");
        let msg = err.to_string();
        assert!(msg.contains("100 elements"), "{msg}");
        assert!(msg.contains("wants 60"), "{msg}");
    }

    #[test]
    fn advance_epoch_fans_deltas_and_patches_the_retained_inits() {
        use crate::objective::PartitionDelta;
        let w0 = mem_worker(0, &[ready(3), FromWorker::DeltaDone { epoch: 1, n: 3 }]);
        let w1 = mem_worker(1, &[ready(2), FromWorker::DeltaDone { epoch: 1, n: 3 }]);
        let plan = ShipPlan::Partition {
            payloads: vec![shard(10, vec![0, 1, 2]), shard(10, vec![5, 6])],
        };
        let mut fleet =
            RemoteFleet::establish("test", vec![w0, w1], 1, plan, 10, 0).expect("establish");
        let deltas = vec![
            PartitionDelta { n_global: 12, insert: shard(12, vec![10]), delete: vec![1] },
            PartitionDelta { n_global: 12, insert: shard(12, vec![11]), delete: Vec::new() },
        ];
        let fresh = vec![shard(12, vec![0, 2, 10]), shard(12, vec![5, 6, 11])];
        let bytes = fleet.advance_epoch(1, deltas, fresh.clone()).expect("advance");
        assert!(bytes > 0, "delta frames cost wire bytes");
        assert_eq!(fleet.expected_ready, vec![3, 3]);
        // The retained inits now ship the post-delta shards: a machine
        // revived after the advance rebuilds the fresh dataset directly.
        for m in 0..2 {
            match &fleet.init_cmds[m] {
                ToWorker::InitPart { payload, .. } => assert_eq!(payload, &fresh[m]),
                other => panic!("expected init_part, got {other:?}"),
            }
        }
        // The wire saw exactly init_part then delta, per worker.
        let mut cursor = fleet.workers[0].writer.as_slice();
        let (init, _) = read_cmd(&mut cursor).unwrap().expect("init_part");
        assert!(matches!(init, ToWorker::InitPart { machine: 0, .. }), "{init:?}");
        let (cmd, _) = read_cmd(&mut cursor).unwrap().expect("delta");
        match cmd {
            ToWorker::Delta { epoch, delta } => {
                assert_eq!(epoch, 1);
                assert_eq!(delta.delete, vec![1]);
                assert_eq!(delta.insert.elems, vec![10]);
            }
            other => panic!("expected delta, got {other:?}"),
        }
        assert!(read_cmd(&mut cursor).unwrap().is_none(), "no further commands");
    }

    #[test]
    fn advance_epoch_rejects_a_diverged_shard_size() {
        use crate::objective::PartitionDelta;
        // The worker claims 5 elements after the delta; the coordinator's
        // own application of the same delta leaves 2.
        let w0 = mem_worker(0, &[ready(3), FromWorker::DeltaDone { epoch: 1, n: 5 }]);
        let plan = ShipPlan::Partition { payloads: vec![shard(10, vec![0, 1, 2])] };
        let mut fleet =
            RemoteFleet::establish("test", vec![w0], 1, plan, 10, 0).expect("establish");
        let delta =
            PartitionDelta { n_global: 10, insert: shard(10, Vec::new()), delete: vec![1] };
        let err = fleet
            .advance_epoch(1, vec![delta], vec![shard(10, vec![0, 2])])
            .expect_err("a diverged shard must fail the advance");
        let msg = err.to_string();
        assert!(msg.contains("diverged"), "{msg}");
        assert!(msg.contains("holds 5"), "{msg}");
    }

    #[test]
    fn advance_epoch_refuses_a_spec_shipped_session() {
        use crate::objective::PartitionDelta;
        let replies = scripted(&[ready(10)]);
        let worker = FramedWorker::new(0, replies.as_slice(), Vec::<u8>::new());
        let mut fleet =
            RemoteFleet::establish("test", vec![worker], 1, ShipPlan::Spec("spec"), 10, 0)
                .expect("establish");
        let delta =
            PartitionDelta { n_global: 10, insert: shard(10, Vec::new()), delete: vec![3] };
        let err = fleet
            .advance_epoch(1, vec![delta], vec![shard(10, Vec::new())])
            .expect_err("spec sessions hold no shard to patch");
        assert!(err.to_string().contains("partition shipping"), "{err}");
    }

    // ---- supervision -----------------------------------------------------

    use std::io::Cursor;
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    type MemWorker = FramedWorker<Cursor<Vec<u8>>, Vec<u8>>;

    /// A worker over owned buffers (`'static`, so reconnect closures can
    /// hand out replacements): scripted replies in, captured commands out.
    fn mem_worker(machine: MachineId, replies: &[FromWorker]) -> MemWorker {
        FramedWorker::new(machine, Cursor::new(scripted(replies)), Vec::new())
    }

    fn ready(n: usize) -> FromWorker {
        FromWorker::Ready { n }
    }

    fn step(machine: MachineId, level: u32, calls: u64) -> FromWorker {
        FromWorker::Step(StepReport { machine, level, calls, ..StepReport::default() })
    }

    #[test]
    fn retry_revives_a_dead_worker_and_replays_its_log() {
        let w0 = mem_worker(0, &[ready(100), ready(100), step(0, 0, 3)]);
        // Machine 1 dies after acking the job: EOF where its leaf Step
        // should be.
        let w1 = mem_worker(1, &[ready(100), ready(100)]);
        let mut fleet =
            RemoteFleet::establish("test", vec![w0, w1], 1, ShipPlan::Spec("spec"), 100, 0)
                .expect("establish");
        let mut spare = Some(mem_worker(1, &[ready(100), ready(100), step(1, 0, 7)]));
        fleet.supervise(
            FaultPolicy::Retry,
            Box::new(move |machine, _attempt| {
                assert_eq!(machine, 1, "only machine 1 dies");
                spare.take().ok_or_else(|| DistError::transport("out of spares"))
            }),
        );
        fleet.begin_job(&params(100), "problem.k = 2\n").expect("job");
        let reports = fleet
            .run_leaves(vec![(0..50).collect(), (50..100).collect()])
            .expect("revival must recover the leaf superstep");
        assert_eq!(reports[1].calls, 7, "the replayed Step is the one reported");
        let report = fleet.fault_report();
        assert_eq!(report.faults_seen, 1);
        assert_eq!(report.retries, 1);
        assert!(report.machines_dropped.is_empty());
        assert_eq!(report.elements_lost, 0);
        // The replacement was re-driven through the full script: session
        // init, then the job log — the re-dispatch the paper's
        // determinism makes sound.
        let mut cursor = fleet.workers[1].writer.as_slice();
        let mut cmds = Vec::new();
        while let Some(v) = read_frame(&mut cursor).unwrap() {
            cmds.push(ToWorker::from_value(&v).unwrap());
        }
        assert_eq!(cmds.len(), 3, "init + job + leaf, no more: {cmds:?}");
        assert!(matches!(cmds[0], ToWorker::Init { machine: 1, .. }), "{:?}", cmds[0]);
        assert!(matches!(cmds[1], ToWorker::Job { .. }), "{:?}", cmds[1]);
        assert!(
            matches!(&cmds[2], ToWorker::Leaf { part } if part.len() == 50),
            "{:?}",
            cmds[2]
        );
    }

    #[test]
    fn retry_replays_binary_init_part_frames() {
        // Under `--wire binary` the retained session init re-encodes as a
        // binary frame on revival: the replacement worker must receive a
        // byte-exact re-dispatch of its shard, in the session's mode.
        let w0 = mem_worker(0, &[ready(2), ready(100), step(0, 0, 3)])
            .with_mode(WireMode::Binary);
        // Machine 1 dies after acking the job: EOF where its Step should be.
        let w1 = mem_worker(1, &[ready(2), ready(100)]).with_mode(WireMode::Binary);
        let payloads = vec![shard(100, vec![0, 1]), shard(100, vec![2, 3])];
        let plan = ShipPlan::Partition { payloads: payloads.clone() };
        let mut fleet =
            RemoteFleet::establish("test", vec![w0, w1], 1, plan, 100, 0).expect("establish");
        let mut spare = Some(
            mem_worker(1, &[ready(2), ready(100), step(1, 0, 7)]).with_mode(WireMode::Binary),
        );
        fleet.supervise(
            FaultPolicy::Retry,
            Box::new(move |machine, _attempt| {
                assert_eq!(machine, 1, "only machine 1 dies");
                spare.take().ok_or_else(|| DistError::transport("out of spares"))
            }),
        );
        fleet.begin_job(&params(100), "problem.k = 2\n").expect("job");
        let reports = fleet
            .run_leaves(vec![(0..50).collect(), (50..100).collect()])
            .expect("revival must recover the leaf superstep");
        assert_eq!(reports[1].calls, 7, "the replayed Step is the one reported");
        // The replacement's stream decodes with the mode-aware reader: the
        // shard arrived as a binary frame, the control frames as JSON.
        let mut cursor = fleet.workers[1].writer.as_slice();
        let (init, mode) = read_cmd(&mut cursor).unwrap().expect("replayed init_part");
        assert_eq!(mode, WireMode::Binary, "the shard must replay as a binary frame");
        match init {
            ToWorker::InitPart { machine: 1, payload, .. } => {
                assert_eq!(payload, payloads[1], "the replayed shard must be bit-identical");
            }
            other => panic!("expected init_part, got {other:?}"),
        }
        let (job, mode) = read_cmd(&mut cursor).unwrap().expect("replayed job");
        assert_eq!(mode, WireMode::Json, "control frames stay JSON under binary mode");
        assert!(matches!(job, ToWorker::Job { .. }), "{job:?}");
        let (leaf, _) = read_cmd(&mut cursor).unwrap().expect("replayed leaf");
        assert!(matches!(&leaf, ToWorker::Leaf { part } if part.len() == 50), "{leaf:?}");
        assert!(read_cmd(&mut cursor).unwrap().is_none(), "no further commands");
    }

    #[test]
    fn retry_gives_up_after_bounded_attempts() {
        let w0 = mem_worker(0, &[ready(10), ready(10), step(0, 0, 1)]);
        let w1 = mem_worker(1, &[ready(10), ready(10)]);
        let mut fleet =
            RemoteFleet::establish("test", vec![w0, w1], 1, ShipPlan::Spec("spec"), 10, 0)
                .expect("establish");
        let attempts = Arc::new(AtomicU32::new(0));
        let seen = Arc::clone(&attempts);
        fleet.supervise(
            FaultPolicy::Retry,
            Box::new(move |_machine, _attempt| {
                seen.fetch_add(1, Ordering::SeqCst);
                Err(DistError::transport("host pool exhausted"))
            }),
        );
        fleet.begin_job(&params(10), "problem.k = 1\n").expect("job");
        let err = fleet
            .run_leaves(vec![(0..5).collect(), (5..10).collect()])
            .expect_err("no replacement can be found");
        assert!(err.to_string().contains("could not be revived"), "{err}");
        assert_eq!(attempts.load(Ordering::SeqCst), RETRY_ATTEMPTS);
    }

    #[test]
    fn degrade_drops_a_dead_machine_and_accounts_the_loss() {
        let w0 = mem_worker(
            0,
            &[
                ready(100),
                ready(100),
                step(0, 0, 2),
                FromWorker::Ack,
                step(0, 1, 4),
                FromWorker::Final {
                    stats: MachineStats::new(0),
                    sol: vec![1, 2],
                    value: 5.0,
                },
            ],
        );
        // Machine 1 computes its leaf, then dies when asked to Ship.
        let w1 = mem_worker(1, &[ready(100), ready(100), step(1, 0, 3)]);
        let mut fleet =
            RemoteFleet::establish("test", vec![w0, w1], 1, ShipPlan::Spec("spec"), 100, 0)
                .expect("establish");
        fleet.supervise(
            FaultPolicy::Degrade,
            Box::new(|_machine, _attempt| Err(DistError::backend("degrade never reconnects"))),
        );
        fleet.begin_job(&params(100), "problem.k = 2\n").expect("job");
        fleet.run_leaves(vec![(0..40).collect(), (40..100).collect()]).expect("leaves");
        let reports = fleet
            .run_superstep(1, &[AccumTask { parent: 0, children: vec![1] }])
            .expect("degrade completes the superstep on the survivors");
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].calls, 4, "the root accumulated without the dead child");
        let outcome = fleet.finish().expect("finish");
        assert_eq!(outcome.solution, vec![1, 2]);
        assert_eq!(outcome.machines.len(), 2, "stats stay shape-compatible");
        assert_eq!(outcome.machines[1].calls, 0, "dropped machine reports zeroed stats");
        assert_eq!(outcome.faults.machines_dropped, vec![1]);
        assert_eq!(outcome.faults.elements_lost, 60, "machine 1 owned 60 elements");
        assert_eq!(outcome.faults.faults_seen, 1);
        assert_eq!(outcome.faults.retries, 0);
        // A fleet that lost machines must not be reused warm.
        let err = fleet.ping_all().expect_err("dropped machines poison the fleet");
        assert!(err.to_string().contains("degraded"), "{err}");
        let err = fleet
            .begin_job(&params(100), "problem.k = 2\n")
            .expect_err("no new jobs on a degraded fleet");
        assert!(err.to_string().contains("re-establish"), "{err}");
    }

    #[test]
    fn degrade_never_drops_machine_zero() {
        // Machine 0 dies at its leaf step; machine 1 stays healthy.
        let w0 = mem_worker(0, &[ready(10), ready(10)]);
        let w1 = mem_worker(1, &[ready(10), ready(10), step(1, 0, 1)]);
        let mut fleet =
            RemoteFleet::establish("test", vec![w0, w1], 1, ShipPlan::Spec("spec"), 10, 0)
                .expect("establish");
        fleet.supervise(
            FaultPolicy::Degrade,
            Box::new(|_machine, _attempt| Err(DistError::backend("no reconnect"))),
        );
        fleet.begin_job(&params(10), "problem.k = 1\n").expect("job");
        let err = fleet
            .run_leaves(vec![(0..5).collect(), (5..10).collect()])
            .expect_err("the root's machine cannot be dropped");
        assert!(err.to_string().contains("machine 0"), "{err}");
        assert!(err.to_string().contains("cannot be dropped"), "{err}");
    }

    #[test]
    fn ping_all_probes_every_worker() {
        let w0 = mem_worker(0, &[ready(10), FromWorker::Pong]);
        let w1 = mem_worker(1, &[ready(10), FromWorker::Pong]);
        let mut fleet =
            RemoteFleet::establish("test", vec![w0, w1], 1, ShipPlan::Spec("spec"), 10, 0)
                .expect("establish");
        fleet.ping_all().expect("both workers pong");
        // The next probe hits EOF — a worker that died while the fleet
        // sat idle fails the probe instead of hanging a job.
        let err = fleet.ping_all().expect_err("dead worker fails the probe");
        assert!(err.to_string().contains("disconnected"), "{err}");
        assert!(err.is_retryable(), "{err}");
    }
}
