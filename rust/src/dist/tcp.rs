//! The multi-host TCP backend.
//!
//! The thread and process backends still live on one box; this module is
//! the first genuinely distributed deployment path.  A `greedyml serve
//! --bind <addr>` **worker daemon** runs on each host and accepts one TCP
//! connection per simulated machine; the coordinator's [`TcpBackend`]
//! places the `m` machines onto the configured hosts round-robin
//! (machine `i` → `hosts[i % hosts.len()]`) and drives them with the
//! same length-prefixed frames as the process backend ([`super::wire`]),
//! through the same transport-generic session fleet (`RemoteFleet` in
//! `dist/remote.rs`) and the same worker-side session loop — so
//! solutions stay bit-identical to the thread backend while `comm_secs`
//! becomes *measured* wall time over a real network hop.  A connected
//! fleet is a *session*: the shipped dataset stays resident in the
//! daemons, and [`TcpBackend::begin_job`] runs any number of jobs
//! against it before [`TcpBackend::release`] lets the workers go.
//!
//! What is TCP-specific, and lives here:
//!
//! * **Connection handshake** — the first frames on a fresh socket are
//!   [`ToWorker::Hello`] / [`FromWorker::Welcome`] carrying
//!   [`PROTOCOL_VERSION`]; a daemon from a different build refuses the
//!   session instead of desyncing mid-run.  The pipe transport skips
//!   this (both ends are the same binary).
//! * **Connect retry** — a worker daemon that is still starting (tests
//!   and CI spawn `serve` right before the run) gets a window of
//!   reconnect attempts with capped exponential backoff and
//!   deterministic jitter ([`connect_window`]: default
//!   [`CONNECT_RETRY_WINDOW`], tune with
//!   `GREEDYML_TCP_CONNECT_TIMEOUT` seconds); after that the run fails
//!   into a *retryable* [`DistError::Transport`] naming the `host:port`
//!   it could not reach.
//! * **Mid-session reconnect** — a worker's state (its resident shard,
//!   its `S_prev`) dies with its connection, so by default
//!   ([`FaultPolicy::Fail`]) a dropped socket fails the session — and
//!   every job still queued on it — rather than silently recomputing.
//!   Under `--on-fault retry`/`degrade` the fleet is *supervised*
//!   (`RemoteFleet::supervise`): a machine whose socket dies mid-job is
//!   re-dialed onto the **next host in the ring**
//!   (`hosts[(machine + attempt + 1) % hosts.len()]` — on a multi-host
//!   fleet a crashed daemon's machines land on its neighbours; with one
//!   host we re-dial it), re-handshaken, and replayed
//!   deterministically from the retained init + job log, so the
//!   recovered run stays bit-identical.  See `docs/failure-model.md`.
//! * **Per-frame timeouts** — coordinator-side socket reads and writes
//!   time out after [`frame_timeout`] (default 600 s, tune with
//!   `GREEDYML_TCP_TIMEOUT`, `0` disables), so a wedged-but-open remote
//!   worker becomes a retryable [`DistError::Transport`] instead of a
//!   hang.  Daemon sessions use a short pre-handshake timeout (port
//!   scans must not pin threads) and a generous multi-hour one
//!   afterwards — a worker legitimately idles while other machines
//!   compute, but a coordinator that vanished without closing the
//!   socket must not leak the session forever.
//!
//! Hosts come from [`DistConfig::hosts`](crate::algo::DistConfig::hosts)
//! (the `--hosts` flag / `run.hosts` config key) or the `GREEDYML_HOSTS`
//! environment variable.  When every host is `127.0.0.1`, the full path —
//! handshake, oracle rebuild, real socket shipping — runs on one machine,
//! which is how the tier-1 suite exercises it without a cluster.

use super::backend::{AccumTask, Backend, BackendOutcome, ShipPlan, WireMode};
use super::fault::{FaultPolicy, FaultReport};
use super::node::{NodeParams, StepReport};
use super::proc::serve_session;
use super::remote::{FramedWorker, RemoteFleet};
use super::wire::{read_frame, write_frame, FromWorker, ToWorker, PROTOCOL_VERSION};
use super::DistError;
use crate::{ElemId, MachineId};
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Default window [`TcpBackend::connect`] keeps retrying a refused
/// connection before failing the run — long enough for a just-spawned
/// `greedyml serve` to reach `accept`, short enough that a wrong
/// `--hosts` entry fails visibly.  Override with
/// `GREEDYML_TCP_CONNECT_TIMEOUT` (see [`connect_window`]).
pub const CONNECT_RETRY_WINDOW: Duration = Duration::from_secs(5);

/// First pause between connect attempts; doubles per attempt up to
/// [`CONNECT_BACKOFF_CAP`], plus deterministic jitter
/// ([`connect_backoff`]).
const CONNECT_BACKOFF_BASE: Duration = Duration::from_millis(25);

/// Ceiling on the exponential connect backoff, so a long window polls
/// about once a second instead of stretching into multi-minute gaps.
const CONNECT_BACKOFF_CAP: Duration = Duration::from_millis(800);

/// Default per-frame socket timeout (seconds) — see [`frame_timeout`].
const DEFAULT_FRAME_TIMEOUT_SECS: u64 = 600;

/// How long a daemon waits for a fresh connection's `Hello` frame before
/// dropping it — idle or port-scan connections must not pin a session
/// thread (and its file descriptor) forever.  Widened to
/// [`SESSION_IDLE_TIMEOUT`] once the handshake completes.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(30);

/// Read timeout of an established daemon session.  A worker legitimately
/// idles for as long as other machines' supersteps take, so this is
/// generous — but finite: if a coordinator host dies without a FIN/RST
/// (crash, network partition), the session must eventually unblock and
/// release its thread and rebuilt oracle instead of leaking them for the
/// daemon's lifetime.
const SESSION_IDLE_TIMEOUT: Duration = Duration::from_secs(6 * 3600);

/// The coordinator's per-frame socket timeout: `GREEDYML_TCP_TIMEOUT`
/// seconds when set (`0` disables), else 600 s.  Bounds every socket read
/// and write, so a remote worker that stops responding mid-superstep
/// surfaces as [`DistError::Backend`] instead of hanging the run; raise
/// it (or disable) for problems whose leaf GREEDY legitimately computes
/// longer than the default.  An unparsable value is an error, not a
/// silent fall-back to the default — a user who set `30m` to extend the
/// window must not have their run killed by the 600 s they overrode.
pub fn frame_timeout() -> Result<Option<Duration>, DistError> {
    match std::env::var("GREEDYML_TCP_TIMEOUT") {
        Err(_) => Ok(Some(Duration::from_secs(DEFAULT_FRAME_TIMEOUT_SECS))),
        Ok(v) => match v.trim().parse::<u64>() {
            Ok(0) => Ok(None),
            Ok(secs) => Ok(Some(Duration::from_secs(secs))),
            Err(_) => Err(DistError::backend(format!(
                "GREEDYML_TCP_TIMEOUT: '{v}' is not a whole number of seconds (0 disables)"
            ))),
        },
    }
}

/// The connect-retry window: `GREEDYML_TCP_CONNECT_TIMEOUT` seconds when
/// set, else [`CONNECT_RETRY_WINDOW`] (5 s).  Raise it when daemons are
/// provisioned on demand and legitimately take longer than 5 s to come
/// up.  Zero and unparsable values are errors, not silent fall-backs —
/// a connect window of nothing can never succeed, and a user who set
/// `2m` must not have their override quietly replaced by the default.
pub fn connect_window() -> Result<Duration, DistError> {
    match std::env::var("GREEDYML_TCP_CONNECT_TIMEOUT") {
        Err(_) => Ok(CONNECT_RETRY_WINDOW),
        Ok(v) => match v.trim().parse::<u64>() {
            Ok(secs) if secs > 0 => Ok(Duration::from_secs(secs)),
            _ => Err(DistError::backend(format!(
                "GREEDYML_TCP_CONNECT_TIMEOUT: '{v}' is not a positive whole number of seconds"
            ))),
        },
    }
}

/// Parse a `host:port,host:port` list (the `--hosts` flag, the
/// `run.hosts`/`sweep.hosts` config keys, and `GREEDYML_HOSTS` all use
/// this form; IPv6 addresses need brackets, `[::1]:7401`).  Entries are
/// trimmed and blank entries are skipped (so a trailing comma is fine);
/// an all-blank list and a missing, non-numeric or zero port are errors
/// *here* — catching a malformed entry at parse time gives a clear
/// message, instead of `connect` burning its whole retry window on an
/// address that could never be dialed.
pub fn parse_hosts(s: &str) -> Result<Vec<String>, DistError> {
    let hosts: Vec<String> = s
        .split(',')
        .map(|h| h.trim().to_string())
        .filter(|h| !h.is_empty())
        .collect();
    if hosts.is_empty() {
        return Err(DistError::backend(format!("no worker hosts in '{s}'")));
    }
    for h in &hosts {
        let parts = if let Some(rest) = h.strip_prefix('[') {
            // Bracketed IPv6: [addr]:port.
            rest.split_once("]:")
        } else if h.matches(':').count() == 1 {
            // Hostname or IPv4: exactly one colon before the port.  A
            // bare IPv6 literal has several and must be bracketed —
            // `::1` alone names no port at all.
            h.split_once(':')
        } else {
            None
        };
        // Port 0 and an empty host part both parse but can never be
        // dialed — reject them here, not after a burned retry window.
        let valid = parts.map_or(false, |(addr, port)| {
            !addr.is_empty() && port.parse::<u16>().map_or(false, |p| p != 0)
        });
        if !valid {
            return Err(DistError::backend(format!(
                "host '{h}' is not host:port (IPv6 addresses need brackets: [addr]:port)"
            )));
        }
    }
    Ok(hosts)
}

/// Prefix a host-list parse failure with where the list came from,
/// without nesting a second "backend failure:" banner.
fn label_hosts_error(source: &str, e: DistError) -> String {
    match e {
        DistError::Backend { message } => format!("{source}: {message}"),
        other => format!("{source}: {other}"),
    }
}

/// Worker hosts from the `GREEDYML_HOSTS` environment variable, if set
/// and non-empty.  An unparsable value is an error, not a fallback — a
/// mis-spelt host list must not quietly change where an experiment ran.
pub fn hosts_from_env() -> Option<Result<Vec<String>, DistError>> {
    match std::env::var("GREEDYML_HOSTS") {
        Ok(v) if !v.trim().is_empty() => Some(
            parse_hosts(&v)
                .map_err(|e| DistError::backend(label_hosts_error("GREEDYML_HOSTS", e))),
        ),
        _ => None,
    }
}

/// Worker hosts from a config key (`run.hosts` / `sweep.hosts`):
/// `Ok(None)` when the key is absent, a `key: reason` error when the
/// value does not parse.  Shared by the experiment and sweep runners so
/// the two entry points cannot drift.
pub fn hosts_from_config(
    cfg: &crate::util::config::Config,
    key: &str,
) -> crate::Result<Option<Vec<String>>> {
    match cfg.get(key) {
        None => Ok(None),
        Some(v) => parse_hosts(v)
            .map(Some)
            .map_err(|e| anyhow::anyhow!("{}", label_hosts_error(key, e))),
    }
}

/// The session fleet over socket transports.
type TcpFleet = RemoteFleet<BufReader<TcpStream>, BufWriter<TcpStream>>;

/// The multi-host [`Backend`]: one TCP session per simulated machine,
/// placed round-robin over `greedyml serve` daemons.  The shipped
/// dataset stays resident in the daemons until [`TcpBackend::release`];
/// each run is a [`TcpBackend::begin_job`] + `run_dist_on` pass over
/// the warm sessions.
pub struct TcpBackend {
    inner: TcpFleet,
}

impl TcpBackend {
    /// Connect `machines` sessions round-robin over `hosts`, handshake
    /// protocol versions, ship the [`ShipPlan`] (the problem spec, or each
    /// machine's dataset shard) exactly once, and verify every worker
    /// holds what the coordinator shipped.  `n` is the global ground-set
    /// size the shipped problem must rebuild to.
    ///
    /// Under [`FaultPolicy::Retry`] or [`FaultPolicy::Degrade`] the fleet
    /// is supervised: a machine whose socket dies mid-run is re-dialed
    /// onto the next host in the ring and replayed deterministically
    /// (retry), or dropped from the accumulation tree with its loss
    /// accounted (degrade).  [`FaultPolicy::Fail`] keeps the historical
    /// fail-the-session behavior.
    #[allow(clippy::too_many_arguments)]
    pub fn connect(
        hosts: &[String],
        machines: u32,
        threads: usize,
        plan: ShipPlan<'_>,
        n: usize,
        session: u64,
        fault: FaultPolicy,
        wire: WireMode,
    ) -> Result<Self, DistError> {
        let window = connect_window()?;
        Self::connect_with_retry(hosts, machines, threads, plan, n, session, window, fault, wire)
    }

    /// [`TcpBackend::connect`] with an explicit retry window (tests use a
    /// short one so a dead host fails fast).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn connect_with_retry(
        hosts: &[String],
        machines: u32,
        threads: usize,
        plan: ShipPlan<'_>,
        n: usize,
        session: u64,
        retry: Duration,
        fault: FaultPolicy,
        wire: WireMode,
    ) -> Result<Self, DistError> {
        if hosts.is_empty() {
            return Err(DistError::backend("the tcp backend needs at least one worker host"));
        }
        let timeout = frame_timeout()?;
        let mut workers = Vec::with_capacity(machines as usize);
        for machine in 0..machines {
            let host = &hosts[machine as usize % hosts.len()];
            workers.push(dial(host, machine, timeout, retry, wire)?);
        }
        let mut inner = RemoteFleet::establish("tcp", workers, threads, plan, n, session)?;
        if fault != FaultPolicy::Fail {
            // The reconnect closure revives machine `m` on attempt `a` by
            // dialing `hosts[(m + a + 1) % hosts.len()]` — the *next* host
            // in the placement ring, then its successor, and so on.  On a
            // multi-host fleet a crashed daemon's machines migrate to its
            // neighbours; with a single host every attempt re-dials it
            // (covering daemon restarts).  The fresh session replays the
            // retained init + job log, so placement never affects results.
            let ring: Vec<String> = hosts.to_vec();
            inner.supervise(
                fault,
                Box::new(move |machine: MachineId, attempt: u32| {
                    let host =
                        &ring[(machine as usize + attempt as usize + 1) % ring.len()];
                    dial(host, machine, frame_timeout()?, connect_window()?, wire)
                }),
            );
        }
        Ok(Self { inner })
    }

    /// Start one job against the resident sessions — see
    /// [`RemoteFleet::begin_job`].
    pub fn begin_job(&mut self, params: &NodeParams, spec: &str) -> Result<(), DistError> {
        self.inner.begin_job(params, spec)
    }

    /// Wire bytes the session's `Init`/`InitPart` frames cost — paid once,
    /// however many jobs follow.
    pub fn init_bytes(&self) -> u64 {
        self.inner.init_bytes()
    }

    /// Probe every live session with `Ping` (see
    /// [`RemoteFleet::ping_all`]) — how the session pool validates a warm
    /// fleet before reusing it.
    pub fn ping_all(&mut self) -> Result<(), DistError> {
        self.inner.ping_all()
    }

    /// Faults absorbed by the current job so far (see
    /// [`RemoteFleet::fault_report`]).
    pub fn fault_report(&self) -> FaultReport {
        self.inner.fault_report()
    }

    /// Advance the resident dataset one epoch in place — fan per-machine
    /// `Delta` frames and verify every `DeltaDone` (see
    /// [`RemoteFleet::advance_epoch`]).  Returns the delta wire bytes.
    pub fn advance_epoch(
        &mut self,
        epoch: u64,
        deltas: Vec<crate::objective::PartitionDelta>,
        fresh: Vec<crate::objective::PartitionPayload>,
    ) -> Result<u64, DistError> {
        self.inner.advance_epoch(epoch, deltas, fresh)
    }

    /// End the session: best-effort `Release` to every daemon, which
    /// drops its resident oracle and closes the connection.
    pub fn release(&mut self) {
        self.inner.release();
    }
}

/// Dial one worker session: connect (with retry), set per-frame
/// timeouts, handshake protocol versions, and label the worker with its
/// `host:port` so every later transport error names the offending
/// daemon.  Shared by the initial placement loop and the supervised
/// reconnect path, so a revived session is configured exactly like the
/// one it replaces.
fn dial(
    host: &str,
    machine: MachineId,
    timeout: Option<Duration>,
    retry: Duration,
    wire: WireMode,
) -> Result<FramedWorker<BufReader<TcpStream>, BufWriter<TcpStream>>, DistError> {
    let stream = connect_retry(host, retry)?;
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(timeout)
        .and_then(|_| stream.set_write_timeout(timeout))
        .map_err(|e| DistError::transport(format!("worker at {host}: set timeout: {e}")))?;
    let reader = stream
        .try_clone()
        .map_err(|e| DistError::transport(format!("worker at {host}: clone socket: {e}")))?;
    let mut worker = FramedWorker::new(machine, BufReader::new(reader), BufWriter::new(stream))
        .with_peer(host.to_string())
        .with_mode(wire);
    handshake(&mut worker, host)?;
    Ok(worker)
}

/// The pause before connect attempt `attempt + 1` against `host`:
/// exponential from [`CONNECT_BACKOFF_BASE`], capped at
/// [`CONNECT_BACKOFF_CAP`], plus up to 50% *deterministic* jitter
/// hashed from `(host, attempt)`.  The jitter de-synchronizes a fleet
/// of coordinators (or one coordinator's machines) hammering the same
/// just-restarting daemon, without introducing an RNG: the same
/// host/attempt pair always backs off identically, so fault-injection
/// runs replay bit-for-bit.
fn connect_backoff(host: &str, attempt: u32) -> Duration {
    use std::hash::{Hash, Hasher};
    let base = CONNECT_BACKOFF_BASE.as_millis() as u64;
    let cap = CONNECT_BACKOFF_CAP.as_millis() as u64;
    let exp = base.saturating_mul(1u64 << attempt.min(16)).min(cap);
    let mut h = std::collections::hash_map::DefaultHasher::new();
    host.hash(&mut h);
    attempt.hash(&mut h);
    Duration::from_millis(exp + h.finish() % (exp / 2 + 1))
}

/// Dial `host` until it accepts or the retry window closes, backing off
/// between attempts ([`connect_backoff`]).  Each attempt uses
/// [`TcpStream::connect_timeout`] bounded by the remaining window, so a
/// blackholed host (dropped SYNs, no RST) fails within ~`retry` instead
/// of blocking on the kernel's minutes-long connect timeout.  Giving up
/// is a *retryable* [`DistError::Transport`] naming the `host:port` —
/// under supervision the next revival attempt may reach a different
/// host in the ring.
fn connect_retry(host: &str, retry: Duration) -> Result<TcpStream, DistError> {
    use std::net::ToSocketAddrs;
    let deadline = Instant::now() + retry;
    let mut attempt: u32 = 0;
    loop {
        let result = (|| -> std::io::Result<TcpStream> {
            let mut last: Option<std::io::Error> = None;
            for addr in host.to_socket_addrs()? {
                let left = deadline
                    .saturating_duration_since(Instant::now())
                    .max(CONNECT_BACKOFF_BASE);
                match TcpStream::connect_timeout(&addr, left) {
                    Ok(stream) => return Ok(stream),
                    Err(e) => last = Some(e),
                }
            }
            Err(last.unwrap_or_else(|| {
                std::io::Error::new(std::io::ErrorKind::NotFound, "no addresses resolved")
            }))
        })();
        match result {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(DistError::transport(format!(
                        "cannot reach worker at {host} after {:.1}s ({} attempts): {e} \
                         (is `greedyml serve --bind {host}` running?)",
                        retry.as_secs_f64(),
                        attempt + 1
                    )));
                }
                let pause = connect_backoff(host, attempt)
                    .min(deadline.saturating_duration_since(Instant::now()));
                std::thread::sleep(pause);
                attempt += 1;
            }
        }
    }
}

/// Exchange `Hello`/`Welcome` on a fresh connection and verify the
/// protocol versions match.
fn handshake(
    worker: &mut FramedWorker<BufReader<TcpStream>, BufWriter<TcpStream>>,
    host: &str,
) -> Result<(), DistError> {
    worker.send(&ToWorker::Hello { version: PROTOCOL_VERSION })?;
    match worker.recv_ok()? {
        FromWorker::Welcome { version } if version == PROTOCOL_VERSION => Ok(()),
        FromWorker::Welcome { version } => Err(DistError::backend(format!(
            "worker at {host} speaks wire-protocol v{version}, coordinator speaks \
             v{PROTOCOL_VERSION} — deploy matching greedyml builds"
        ))),
        other => Err(DistError::backend(format!(
            "worker at {host}: expected welcome, got {other:?}"
        ))),
    }
}

impl Backend for TcpBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn run_leaves(&mut self, parts: Vec<Vec<ElemId>>) -> Result<Vec<StepReport>, DistError> {
        self.inner.run_leaves(parts)
    }

    fn run_superstep(
        &mut self,
        level: u32,
        tasks: &[AccumTask],
    ) -> Result<Vec<StepReport>, DistError> {
        self.inner.run_superstep(level, tasks)
    }

    fn finish(&mut self) -> Result<BackendOutcome, DistError> {
        self.inner.finish()
    }

    fn measures_comm(&self) -> bool {
        self.inner.measures_comm()
    }
}

// ---- worker daemon -----------------------------------------------------

/// Entry point of the `greedyml serve` subcommand: bind `addr`, print the
/// resolved address (`greedyml serve: listening on <ip>:<port>` — the one
/// stdout line, so spawners can `--bind 127.0.0.1:0` and read the port
/// back), then accept connections forever.  Each connection is one worker
/// session — handshake, `Init` shipping the dataset once, then any number
/// of `Job` runs against the resident shard until `Release` — served on
/// its own thread, so a single daemon hosts as many simulated machines as
/// coordinators place on it.  Session errors are logged to stderr
/// and never take the daemon down; stop it with SIGTERM/Ctrl-C.
pub fn run_serve(bind: &str) -> crate::Result<()> {
    let listener =
        TcpListener::bind(bind).map_err(|e| anyhow::anyhow!("cannot bind {bind}: {e}"))?;
    let addr = listener.local_addr().map_err(|e| anyhow::anyhow!("local_addr: {e}"))?;
    println!("greedyml serve: listening on {addr}");
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                std::thread::spawn(move || {
                    if let Err(e) = serve_connection(stream) {
                        eprintln!("greedyml serve: session from {peer}: {e:#}");
                    }
                });
            }
            Err(e) => {
                // A persistent accept failure (e.g. EMFILE) must degrade
                // to slow retries, not a hot stderr-spamming spin.
                eprintln!("greedyml serve: accept: {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Serve one accepted connection: version handshake, then the shared
/// worker session loop ([`serve_session`]).
fn serve_connection(stream: TcpStream) -> crate::Result<()> {
    let _ = stream.set_nodelay(true);
    // Read timeout only until the handshake completes (SO_RCVTIMEO is a
    // property of the socket, shared with the cloned reader below).
    let _ = stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
    let reader = stream.try_clone().map_err(|e| anyhow::anyhow!("clone socket: {e}"))?;
    let mut input = BufReader::new(reader);
    let mut output = BufWriter::new(stream);

    let first = read_frame(&mut input)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .ok_or_else(|| anyhow::anyhow!("EOF before hello"))?;
    match ToWorker::from_value(&first).map_err(|e| anyhow::anyhow!("{e}"))? {
        ToWorker::Hello { version } if version == PROTOCOL_VERSION => {
            write_frame(&mut output, &FromWorker::Welcome { version: PROTOCOL_VERSION }.to_value())
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let _ = input.get_ref().set_read_timeout(Some(SESSION_IDLE_TIMEOUT));
        }
        ToWorker::Hello { version } => {
            let msg = format!(
                "coordinator speaks wire-protocol v{version}, this daemon speaks \
                 v{PROTOCOL_VERSION} — deploy matching greedyml builds"
            );
            let _ = write_frame(
                &mut output,
                &FromWorker::Fail(DistError::backend(msg.clone())).to_value(),
            );
            anyhow::bail!("{msg}");
        }
        other => {
            let _ = write_frame(
                &mut output,
                &FromWorker::Fail(DistError::backend("expected hello as the first frame"))
                    .to_value(),
            );
            anyhow::bail!("expected hello as the first frame, got {other:?}");
        }
    }
    serve_session(&mut input, &mut output)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::greedy::GreedyKind;

    const SPEC: &str =
        "dataset.kind = retail\ndataset.n = 100\ndataset.seed = 2\nproblem.k = 4\n";

    fn params() -> NodeParams {
        NodeParams {
            kind: GreedyKind::Lazy,
            seed: 1,
            n: 100,
            mem_limit: None,
            local_view: false,
            added_elements: 0,
            compare_all_children: false,
            coreset: false,
        }
    }

    /// Accept `sessions` connections on an ephemeral port, serving each
    /// with the real daemon session handler on its own thread.
    fn local_daemon(sessions: usize) -> (String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let mut workers = Vec::new();
            for _ in 0..sessions {
                let (stream, _) = listener.accept().unwrap();
                workers.push(std::thread::spawn(move || {
                    let _ = serve_connection(stream);
                }));
            }
            for w in workers {
                w.join().unwrap();
            }
        });
        (addr, handle)
    }

    #[test]
    fn parse_hosts_splits_trims_and_validates() {
        assert_eq!(
            parse_hosts(" 10.0.0.1:7401 ,10.0.0.2:7402, ").unwrap(),
            vec!["10.0.0.1:7401".to_string(), "10.0.0.2:7402".to_string()]
        );
        assert_eq!(parse_hosts("[::1]:7401").unwrap(), vec!["[::1]:7401".to_string()]);
        assert!(parse_hosts("").is_err());
        assert!(parse_hosts(" , ").is_err());
        assert!(parse_hosts("no-port-here").is_err());
        assert!(parse_hosts("host:notaport").is_err());
        assert!(parse_hosts("::1").is_err(), "bare IPv6 literals name no port");
        assert!(parse_hosts("[::1]").is_err(), "bracketed address still needs a port");
        assert!(parse_hosts(":7401").is_err(), "empty host part is undialable");
        assert!(parse_hosts("host:0").is_err(), "port 0 is undialable");
    }

    #[test]
    fn dead_host_fails_within_the_retry_window() {
        // Bind-then-drop reserves a port nobody listens on.
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let hosts = vec![format!("127.0.0.1:{port}")];
        let err = TcpBackend::connect_with_retry(
            &hosts,
            1,
            1,
            ShipPlan::Spec(SPEC),
            100,
            0,
            Duration::from_millis(200),
            FaultPolicy::Fail,
            WireMode::Json,
        )
        .unwrap_err();
        assert!(err.is_retryable(), "an unreachable host is a transport fault: {err}");
        let msg = err.to_string();
        assert!(msg.contains("cannot reach worker"), "{msg}");
        assert!(msg.contains(&format!("127.0.0.1:{port}")), "names the host:port: {msg}");
        assert!(msg.contains("greedyml serve"), "{msg}");
    }

    #[test]
    fn connect_backoff_is_deterministic_capped_and_growing() {
        let a = connect_backoff("10.0.0.1:7401", 3);
        assert_eq!(a, connect_backoff("10.0.0.1:7401", 3), "same (host, attempt) → same pause");
        assert_ne!(
            connect_backoff("10.0.0.1:7401", 0),
            connect_backoff("10.0.0.2:7401", 0),
            "jitter separates hosts retrying in lockstep"
        );
        // Exponential base under the cap: attempt 0 starts at BASE, and
        // even with full jitter a later attempt never exceeds 1.5 × cap.
        assert!(connect_backoff("h:1", 0) >= CONNECT_BACKOFF_BASE);
        for attempt in 0..40 {
            assert!(connect_backoff("h:1", attempt) <= CONNECT_BACKOFF_CAP * 3 / 2);
        }
    }

    #[test]
    fn version_mismatch_is_refused_with_a_fail_frame() {
        let (addr, handle) = local_daemon(1);
        let stream = connect_retry(&addr, Duration::from_secs(2)).unwrap();
        let reader = stream.try_clone().unwrap();
        let mut input = BufReader::new(reader);
        let mut output = BufWriter::new(stream);
        write_frame(&mut output, &ToWorker::Hello { version: PROTOCOL_VERSION + 1 }.to_value())
            .unwrap();
        let v = read_frame(&mut input).unwrap().expect("a Fail frame, not a silent close");
        match FromWorker::from_value(&v).unwrap() {
            FromWorker::Fail(DistError::Backend { message }) => {
                assert!(message.contains("wire-protocol"), "{message}");
            }
            other => panic!("expected fail, got {other:?}"),
        }
        handle.join().unwrap();
    }

    #[test]
    fn single_machine_session_runs_two_jobs_over_a_socket() {
        // The full coordinator path — connect, handshake, Init/Ready with
        // a worker that rebuilds the oracle once, then two complete jobs
        // against the resident session — over a real localhost socket, no
        // child processes.  The second job re-ships nothing and must
        // reproduce the first bit-for-bit.
        let (addr, handle) = local_daemon(1);
        let mut backend = TcpBackend::connect_with_retry(
            &[addr],
            1,
            1,
            ShipPlan::Spec(SPEC),
            100,
            0,
            Duration::from_secs(5),
            FaultPolicy::Retry,
            WireMode::Json,
        )
        .unwrap();
        assert_eq!(backend.name(), "tcp");
        assert!(backend.measures_comm());
        backend.ping_all().expect("a fresh fleet answers pings");
        assert!(backend.fault_report().is_empty(), "no faults were injected");
        let shipped_once = backend.init_bytes();
        assert!(shipped_once > 0);
        let mut outcomes = Vec::new();
        for _ in 0..2 {
            backend.begin_job(&params(), SPEC).unwrap();
            let reports = backend.run_leaves(vec![(0..100).collect()]).unwrap();
            assert_eq!(reports.len(), 1);
            assert!(reports[0].calls > 0);
            let outcome = backend.finish().unwrap();
            assert_eq!(outcome.machines.len(), 1);
            assert_eq!(outcome.solution.len(), 4, "k = 4 cardinality constraint");
            assert!(outcome.value > 0.0);
            outcomes.push((outcome.solution, outcome.value.to_bits()));
        }
        assert_eq!(outcomes[0], outcomes[1], "warm job must be bit-identical");
        assert_eq!(backend.init_bytes(), shipped_once, "no re-shipping between jobs");
        backend.release();
        drop(backend);
        handle.join().unwrap();
    }

    #[test]
    fn ground_set_mismatch_is_reported_against_the_rebuilt_oracle() {
        // Coordinator claims n = 100 but ships a 60-element problem: the
        // session-establish Ready{n} check must catch the divergence.
        let (addr, handle) = local_daemon(1);
        let bad_spec = "dataset.kind = retail\ndataset.n = 60\ndataset.seed = 2\nproblem.k = 4\n";
        let err = TcpBackend::connect_with_retry(
            &[addr],
            1,
            1,
            ShipPlan::Spec(bad_spec),
            100,
            0,
            Duration::from_secs(5),
            FaultPolicy::Fail,
            WireMode::Json,
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("60 elements"), "{msg}");
        handle.join().unwrap();
    }
}
