//! The process-per-machine backend.
//!
//! [`ProcessBackend`] forks one worker process per simulated machine — a
//! hidden `greedyml worker` subcommand — and drives it over stdin/stdout
//! with the length-prefixed JSON frames of [`super::wire`].  Each machine
//! therefore owns a *real* address space: its dataset copy, partition and
//! solutions live in a separate heap, and solution shipping is real
//! serialization + pipe I/O, so `comm_secs` is **measured** wall time
//! (the coordinator clocks each gather from the first `Ship` request to
//! the parent's `Recv` receipt) instead of the α–β model the thread
//! backend books.
//!
//! Workers rebuild the oracle from the problem spec carried by
//! [`DistConfig::problem`](crate::algo::DistConfig::problem) — flat
//! `key = value` config text — because closures cannot cross a process
//! boundary; the generators are seeded, so every worker reconstructs
//! byte-identical data and the run stays bit-compatible with the thread
//! backend (`tests/test_backend.rs`).
//!
//! All protocol driving lives in the transport-generic `RemoteBackend`
//! (`dist/remote.rs`); this module only owns what is pipe-specific —
//! forking the workers, wiring their stdio, and killing orphans on error
//! paths.  The worker-side command loop (`serve_session`) is likewise
//! shared with the tcp backend's `greedyml serve` daemon, which serves
//! the same sessions over sockets.

use super::backend::{AccumTask, Backend, BackendOutcome};
use super::node::{accum_step, leaf_step, ChildMsg, NodeParams, NodeState};
use super::remote::{FramedWorker, RemoteBackend};
use super::wire::{read_frame, write_frame, FromWorker, ToWorker};
use super::{pool, DistError};
use crate::{ElemId, MachineId};
use std::io::{BufReader, BufWriter, Read, Write};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};

/// Resolve the worker executable: explicit config value, then the
/// `GREEDYML_WORKER_BIN` environment variable, then this very binary.
fn worker_binary(explicit: Option<&str>) -> Result<std::path::PathBuf, DistError> {
    if let Some(p) = explicit {
        return Ok(p.into());
    }
    if let Ok(p) = std::env::var("GREEDYML_WORKER_BIN") {
        if !p.trim().is_empty() {
            return Ok(p.into());
        }
    }
    std::env::current_exe()
        .map_err(|e| DistError::backend(format!("cannot locate worker binary: {e}")))
}

/// The forked worker processes, killed on drop unless already exited.
/// Separate from [`ProcessBackend`] so an error during the Init/Ready
/// handshake (which consumes the guard) still reaps every child.
struct Children(Vec<Child>);

impl Drop for Children {
    fn drop(&mut self) {
        // On the success path the workers have already exited after Final;
        // on error paths make sure no orphans linger.
        for child in &mut self.0 {
            match child.try_wait() {
                Ok(Some(_)) => {}
                _ => {
                    let _ = child.kill();
                    let _ = child.wait();
                }
            }
        }
    }
}

/// The fleet driver over pipe transports.
type PipeFleet = RemoteBackend<BufReader<ChildStdout>, BufWriter<ChildStdin>>;

/// The process-per-machine [`Backend`].
pub struct ProcessBackend {
    children: Children,
    inner: PipeFleet,
}

impl ProcessBackend {
    /// Fork `machines` workers, handshake each with the node parameters
    /// and the problem spec, and verify they rebuilt the same ground set.
    pub fn spawn(
        machines: u32,
        params: &NodeParams,
        threads: usize,
        problem: &str,
        worker_bin: Option<&str>,
    ) -> Result<Self, DistError> {
        let bin = worker_binary(worker_bin)?;
        let mut children = Children(Vec::with_capacity(machines as usize));
        let mut workers = Vec::with_capacity(machines as usize);
        for machine in 0..machines {
            let mut child = Command::new(&bin)
                .arg("worker")
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(|e| {
                    DistError::backend(format!("cannot spawn worker {}: {e}", bin.display()))
                })?;
            let stdin = BufWriter::new(child.stdin.take().expect("piped stdin"));
            let stdout = BufReader::new(child.stdout.take().expect("piped stdout"));
            children.0.push(child);
            workers.push(FramedWorker::new(machine, stdout, stdin));
        }
        let inner = RemoteBackend::init("process", workers, params, threads, problem)?;
        Ok(Self { children, inner })
    }
}

impl Backend for ProcessBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn run_leaves(&mut self, parts: Vec<Vec<ElemId>>) -> Result<Vec<super::StepReport>, DistError> {
        self.inner.run_leaves(parts)
    }

    fn run_superstep(
        &mut self,
        level: u32,
        tasks: &[AccumTask],
    ) -> Result<Vec<super::StepReport>, DistError> {
        self.inner.run_superstep(level, tasks)
    }

    fn finish(&mut self) -> Result<BackendOutcome, DistError> {
        let outcome = self.inner.finish()?;
        // Workers exit after Final; reap them so Drop has nothing to kill.
        for child in &mut self.children.0 {
            let _ = child.wait();
        }
        Ok(outcome)
    }

    fn measures_comm(&self) -> bool {
        self.inner.measures_comm()
    }
}

// ---- worker side -------------------------------------------------------

/// Entry point of the hidden `greedyml worker` subcommand: serve one
/// simulated machine over stdin/stdout until `Finish` or EOF.
pub fn run_worker() -> crate::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut input = BufReader::new(stdin.lock());
    let mut output = BufWriter::new(stdout.lock());
    serve_session(&mut input, &mut output)
}

/// One worker session over any framed byte stream: read `Init`, rebuild
/// the problem, reply `Ready`, then serve supersteps until `Finish` or
/// EOF.  The process backend runs this over a worker's stdio; the tcp
/// backend's `greedyml serve` daemon runs it per accepted connection
/// (after the `Hello`/`Welcome` version handshake).
pub(crate) fn serve_session(
    input: &mut impl Read,
    output: &mut impl Write,
) -> crate::Result<()> {
    let first = read_frame(input)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .ok_or_else(|| anyhow::anyhow!("worker: EOF before init"))?;
    let ToWorker::Init { machine, threads, params, problem } =
        ToWorker::from_value(&first).map_err(|e| anyhow::anyhow!("{e}"))?
    else {
        anyhow::bail!("worker: first frame must be init");
    };

    let built = build_worker_problem(&problem);
    let (oracle, constraint) = match built {
        Ok(pair) => pair,
        Err(e) => {
            reply(output, &FromWorker::Fail(DistError::backend(format!("{e:#}"))))?;
            return Ok(());
        }
    };
    reply(output, &FromWorker::Ready { n: oracle.n() })?;

    // The worker's own two-level executor serves the nested gain scans;
    // the machine-level parallelism lives in the worker fan-out, so one
    // thread per worker is the default.
    pool::with_pool(threads.max(1), |_exec| {
        serve(input, output, oracle.as_ref(), constraint.as_ref(), &params, machine)
    })
}

/// Rebuild the oracle + constraint a worker simulates, from the flat
/// config text the coordinator shipped.
fn build_worker_problem(
    problem: &str,
) -> crate::Result<(std::sync::Arc<dyn crate::objective::Oracle>, Box<dyn crate::constraint::Constraint>)>
{
    let cfg = crate::util::config::Config::parse(problem)
        .map_err(|e| anyhow::anyhow!("problem spec: {e}"))?;
    let built = crate::coordinator::build_problem(&cfg, None)?;
    let (constraint, _k) =
        crate::coordinator::experiment::build_constraint(&cfg, built.oracle.n())?;
    Ok((built.oracle, constraint))
}

fn reply(output: &mut impl Write, msg: &FromWorker) -> crate::Result<()> {
    write_frame(output, &msg.to_value()).map_err(|e| anyhow::anyhow!("{e}"))
}

/// The command loop: one superstep role per frame.
fn serve(
    input: &mut impl Read,
    output: &mut impl Write,
    oracle: &dyn crate::objective::Oracle,
    constraint: &dyn crate::constraint::Constraint,
    params: &NodeParams,
    machine: MachineId,
) -> crate::Result<()> {
    let mut state: Option<NodeState> = None;
    let mut pending: Option<(u32, Vec<ChildMsg>)> = None;
    loop {
        let Some(frame) = read_frame(input).map_err(|e| anyhow::anyhow!("{e}"))? else {
            return Ok(()); // coordinator went away — exit quietly
        };
        let cmd = ToWorker::from_value(&frame).map_err(|e| anyhow::anyhow!("{e}"))?;
        match cmd {
            ToWorker::Leaf { part } => {
                match leaf_step(oracle, constraint, params, machine, &part) {
                    Ok((s, report)) => {
                        state = Some(s);
                        reply(output, &FromWorker::Step(report))?;
                    }
                    Err(e) => reply(output, &FromWorker::Fail(e))?,
                }
            }
            ToWorker::Ship => match state.as_mut() {
                Some(s) => {
                    let msg = s.ship();
                    reply(output, &FromWorker::Sol(msg))?;
                }
                None => reply(
                    output,
                    &FromWorker::Fail(DistError::backend(format!(
                        "worker {machine}: ship before leaf"
                    ))),
                )?,
            },
            ToWorker::Recv { level, children } => {
                pending = Some((level, children));
                reply(output, &FromWorker::Ack)?;
            }
            ToWorker::Accum { level, comm_secs } => {
                let took = pending.take();
                let result = match (state.as_mut(), took) {
                    (Some(s), Some((lvl, children))) if lvl == level => {
                        accum_step(oracle, constraint, params, s, level, &children, comm_secs)
                    }
                    _ => Err(DistError::backend(format!(
                        "worker {machine}: accum at level {level} without matching recv"
                    ))),
                };
                match result {
                    Ok(report) => reply(output, &FromWorker::Step(report))?,
                    Err(e) => reply(output, &FromWorker::Fail(e))?,
                }
            }
            ToWorker::Finish => {
                match state.take() {
                    Some(s) => reply(
                        output,
                        &FromWorker::Final {
                            stats: s.stats.clone(),
                            sol: s.sol,
                            value: s.sol_value,
                        },
                    )?,
                    None => reply(
                        output,
                        &FromWorker::Fail(DistError::backend(format!(
                            "worker {machine}: finish before any superstep"
                        ))),
                    )?,
                }
                return Ok(());
            }
            ToWorker::Init { .. } => {
                reply(
                    output,
                    &FromWorker::Fail(DistError::backend(format!(
                        "worker {machine}: duplicate init"
                    ))),
                )?;
                anyhow::bail!("duplicate init");
            }
            ToWorker::Hello { .. } => {
                // The handshake belongs before Init, on the TCP accept
                // path — mid-session it is a protocol violation.
                reply(
                    output,
                    &FromWorker::Fail(DistError::backend(format!(
                        "worker {machine}: hello mid-session"
                    ))),
                )?;
                anyhow::bail!("hello mid-session");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::greedy::GreedyKind;

    fn params() -> NodeParams {
        NodeParams {
            kind: GreedyKind::Lazy,
            seed: 1,
            n: 100,
            mem_limit: None,
            local_view: false,
            added_elements: 0,
            compare_all_children: false,
        }
    }

    #[test]
    fn spawn_with_missing_binary_is_a_backend_error() {
        let err = ProcessBackend::spawn(
            2,
            &params(),
            1,
            "dataset.kind = retail\ndataset.n = 100\n",
            Some("/nonexistent/greedyml-worker-binary"),
        )
        .unwrap_err();
        match err {
            DistError::Backend { message } => {
                assert!(message.contains("cannot spawn worker"), "{message}")
            }
            other => panic!("expected backend error, got {other:?}"),
        }
    }

    /// Drive `serve` in-process over byte buffers: a 1-machine session is
    /// leaf → finish, no child traffic — the protocol state machine works
    /// without forking anything.
    #[test]
    fn serve_runs_a_single_machine_session_in_memory() {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 100,
                num_items: 50,
                mean_size: 5.0,
                zipf_s: 0.9,
            },
            5,
        );
        let oracle = crate::objective::KCover::new(std::sync::Arc::new(data));
        let constraint = crate::constraint::Cardinality::new(4);
        let mut input = Vec::new();
        let part: Vec<ElemId> = (0..100).collect();
        write_frame(&mut input, &ToWorker::Leaf { part }.to_value()).unwrap();
        write_frame(&mut input, &ToWorker::Finish.to_value()).unwrap();
        let mut output = Vec::new();
        serve(&mut input.as_slice(), &mut output, &oracle, &constraint, &params(), 0).unwrap();

        let mut cursor = output.as_slice();
        let step = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&step).unwrap() {
            FromWorker::Step(r) => {
                assert_eq!(r.level, 0);
                assert!(r.calls > 0);
            }
            other => panic!("expected step, got {other:?}"),
        }
        let fin = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&fin).unwrap() {
            FromWorker::Final { stats, sol, value } => {
                assert_eq!(stats.id, 0);
                assert_eq!(sol.len(), 4);
                assert!(value > 0.0);
            }
            other => panic!("expected final, got {other:?}"),
        }
        assert!(read_frame(&mut cursor).unwrap().is_none(), "no trailing frames");
    }

    #[test]
    fn serve_reports_protocol_misuse_as_fail() {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 40,
                num_items: 20,
                mean_size: 4.0,
                zipf_s: 0.9,
            },
            5,
        );
        let oracle = crate::objective::KCover::new(std::sync::Arc::new(data));
        let constraint = crate::constraint::Cardinality::new(3);
        let mut input = Vec::new();
        write_frame(&mut input, &ToWorker::Ship.to_value()).unwrap();
        let mut output = Vec::new();
        // Ship before leaf: the worker answers Fail and keeps serving
        // (the EOF after it ends the loop cleanly).
        serve(&mut input.as_slice(), &mut output, &oracle, &constraint, &params(), 7).unwrap();
        let v = read_frame(&mut output.as_slice()).unwrap().unwrap();
        match FromWorker::from_value(&v).unwrap() {
            FromWorker::Fail(DistError::Backend { message }) => {
                assert!(message.contains("ship before leaf"), "{message}")
            }
            other => panic!("expected fail, got {other:?}"),
        }
    }

    #[test]
    fn serve_session_rejects_a_hello_first_frame() {
        // Over pipes there is no handshake: the first frame must be Init.
        let mut input = Vec::new();
        write_frame(
            &mut input,
            &ToWorker::Hello { version: super::super::wire::PROTOCOL_VERSION }.to_value(),
        )
        .unwrap();
        let mut output = Vec::new();
        let err = serve_session(&mut input.as_slice(), &mut output).unwrap_err();
        assert!(err.to_string().contains("first frame must be init"), "{err}");
    }
}
