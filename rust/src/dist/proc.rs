//! The process-per-machine backend.
//!
//! [`ProcessBackend`] forks one worker process per simulated machine — a
//! hidden `greedyml worker` subcommand — and drives it over stdin/stdout
//! with the length-prefixed JSON frames of [`super::wire`].  Each machine
//! therefore owns a *real* address space: its dataset copy, partition and
//! solutions live in a separate heap, and solution shipping is real
//! serialization + pipe I/O, so `comm_secs` is **measured** wall time
//! (the coordinator clocks each gather from the first `Ship` request to
//! the parent's `Recv` receipt) instead of the α–β model the thread
//! backend books.
//!
//! Workers adopt the problem per the run's ship mode
//! ([`ShipSpec`](super::ShipSpec)): under spec shipping they rebuild the
//! oracle from the problem spec carried by
//! [`DistConfig::problem`](crate::algo::DistConfig::problem) — flat
//! `key = value` config text — because closures cannot cross a process
//! boundary; the generators are seeded, so every worker reconstructs
//! byte-identical data.  Under partition shipping they instead receive
//! their O(n/m) dataset shard
//! ([`PartitionPayload`](crate::objective::PartitionPayload)) and
//! regenerate nothing.  Either way the run stays bit-compatible with the
//! thread backend (`tests/test_backend.rs`).
//!
//! All protocol driving lives in the transport-generic `RemoteBackend`
//! (`dist/remote.rs`); this module only owns what is pipe-specific —
//! forking the workers, wiring their stdio, and killing orphans on error
//! paths.  The worker-side command loop (`serve_session`) is likewise
//! shared with the tcp backend's `greedyml serve` daemon, which serves
//! the same sessions over sockets.

use super::backend::{AccumTask, Backend, BackendOutcome, ShipPlan};
use super::node::{accum_step, leaf_step, ChildMsg, NodeParams, NodeState};
use super::remote::{FramedWorker, RemoteBackend};
use super::wire::{read_frame, write_frame, FromWorker, ToWorker};
use super::{pool, DistError};
use crate::constraint::Constraint;
use crate::objective::{Oracle, PartitionOracle};
use crate::{ElemId, MachineId};
use std::io::{BufReader, BufWriter, Read, Write};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::Arc;

/// Resolve the worker executable: explicit config value, then the
/// `GREEDYML_WORKER_BIN` environment variable, then this very binary.
fn worker_binary(explicit: Option<&str>) -> Result<std::path::PathBuf, DistError> {
    if let Some(p) = explicit {
        return Ok(p.into());
    }
    if let Ok(p) = std::env::var("GREEDYML_WORKER_BIN") {
        if !p.trim().is_empty() {
            return Ok(p.into());
        }
    }
    std::env::current_exe()
        .map_err(|e| DistError::backend(format!("cannot locate worker binary: {e}")))
}

/// The forked worker processes, killed on drop unless already exited.
/// Separate from [`ProcessBackend`] so an error during the Init/Ready
/// handshake (which consumes the guard) still reaps every child.
struct Children(Vec<Child>);

impl Drop for Children {
    fn drop(&mut self) {
        // On the success path the workers have already exited after Final;
        // on error paths make sure no orphans linger.
        for child in &mut self.0 {
            match child.try_wait() {
                Ok(Some(_)) => {}
                _ => {
                    let _ = child.kill();
                    let _ = child.wait();
                }
            }
        }
    }
}

/// The fleet driver over pipe transports.
type PipeFleet = RemoteBackend<BufReader<ChildStdout>, BufWriter<ChildStdin>>;

/// The process-per-machine [`Backend`].
pub struct ProcessBackend {
    children: Children,
    inner: PipeFleet,
}

impl ProcessBackend {
    /// Fork `machines` workers, handshake each with the node parameters
    /// and the [`ShipPlan`] (the problem spec, or each machine's dataset
    /// shard), and verify each rebuilt what the coordinator shipped.
    pub fn spawn(
        machines: u32,
        params: &NodeParams,
        threads: usize,
        plan: ShipPlan<'_>,
        worker_bin: Option<&str>,
    ) -> Result<Self, DistError> {
        let bin = worker_binary(worker_bin)?;
        let mut children = Children(Vec::with_capacity(machines as usize));
        let mut workers = Vec::with_capacity(machines as usize);
        for machine in 0..machines {
            let mut child = Command::new(&bin)
                .arg("worker")
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(|e| {
                    DistError::backend(format!("cannot spawn worker {}: {e}", bin.display()))
                })?;
            let stdin = BufWriter::new(child.stdin.take().expect("piped stdin"));
            let stdout = BufReader::new(child.stdout.take().expect("piped stdout"));
            children.0.push(child);
            workers.push(FramedWorker::new(machine, stdout, stdin));
        }
        let inner = RemoteBackend::init("process", workers, params, threads, plan)?;
        Ok(Self { children, inner })
    }
}

impl Backend for ProcessBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn run_leaves(&mut self, parts: Vec<Vec<ElemId>>) -> Result<Vec<super::StepReport>, DistError> {
        self.inner.run_leaves(parts)
    }

    fn run_superstep(
        &mut self,
        level: u32,
        tasks: &[AccumTask],
    ) -> Result<Vec<super::StepReport>, DistError> {
        self.inner.run_superstep(level, tasks)
    }

    fn finish(&mut self) -> Result<BackendOutcome, DistError> {
        let outcome = self.inner.finish()?;
        // Workers exit after Final; reap them so Drop has nothing to kill.
        for child in &mut self.children.0 {
            let _ = child.wait();
        }
        Ok(outcome)
    }

    fn measures_comm(&self) -> bool {
        self.inner.measures_comm()
    }
}

// ---- worker side -------------------------------------------------------

/// Entry point of the hidden `greedyml worker` subcommand: serve one
/// simulated machine over stdin/stdout until `Finish` or EOF.
pub fn run_worker() -> crate::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut input = BufReader::new(stdin.lock());
    let mut output = BufWriter::new(stdout.lock());
    serve_session(&mut input, &mut output)
}

/// What a worker holds for one session: either the whole dataset rebuilt
/// from a spec, or a [`PartitionOracle`] over its shipped shard — which
/// grows as child solutions arrive with their data.
pub(crate) enum WorkerProblem {
    /// Spec shipping: the full oracle, regenerated locally.
    Spec {
        /// The rebuilt oracle.
        oracle: Arc<dyn Oracle>,
        /// The rebuilt constraint.
        constraint: Box<dyn Constraint>,
    },
    /// Partition shipping: the shard facade (mutable — `Recv` ingests
    /// child-solution data into it between supersteps).
    Partition {
        /// The shard-backed oracle facade.
        oracle: PartitionOracle,
        /// The rebuilt constraint (global element ids, like everything
        /// the facade speaks, so id-keyed constraints stay exact).
        constraint: Box<dyn Constraint>,
    },
}

impl WorkerProblem {
    fn oracle(&self) -> &dyn Oracle {
        match self {
            Self::Spec { oracle, .. } => oracle.as_ref(),
            Self::Partition { oracle, .. } => oracle,
        }
    }

    fn constraint(&self) -> &dyn Constraint {
        match self {
            Self::Spec { constraint, .. } => constraint.as_ref(),
            Self::Partition { constraint, .. } => constraint.as_ref(),
        }
    }

    fn partition(&self) -> Option<&PartitionOracle> {
        match self {
            Self::Spec { .. } => None,
            Self::Partition { oracle, .. } => Some(oracle),
        }
    }

    fn partition_mut(&mut self) -> Option<&mut PartitionOracle> {
        match self {
            Self::Spec { .. } => None,
            Self::Partition { oracle, .. } => Some(oracle),
        }
    }
}

/// One worker session over any framed byte stream: read `Init` (spec
/// shipping — rebuild the whole problem) or `InitPart` (partition
/// shipping — adopt the shipped shard), reply `Ready`, then serve
/// supersteps until `Finish` or EOF.  The process backend runs this over
/// a worker's stdio; the tcp backend's `greedyml serve` daemon runs it
/// per accepted connection (after the `Hello`/`Welcome` version
/// handshake).
pub(crate) fn serve_session(
    input: &mut impl Read,
    output: &mut impl Write,
) -> crate::Result<()> {
    let first = read_frame(input)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .ok_or_else(|| anyhow::anyhow!("worker: EOF before init"))?;
    let (machine, threads, params, built) =
        match ToWorker::from_value(&first).map_err(|e| anyhow::anyhow!("{e}"))? {
            ToWorker::Init { machine, threads, params, problem } => {
                (machine, threads, params, build_worker_problem(&problem))
            }
            ToWorker::InitPart { machine, threads, params, spec, payload } => {
                let built = build_partition_problem(&spec, &payload, params.local_view);
                (machine, threads, params, built)
            }
            _ => anyhow::bail!("worker: first frame must be init or init_part"),
        };

    let mut problem = match built {
        Ok(p) => p,
        Err(e) => {
            reply(output, &FromWorker::Fail(DistError::backend(format!("{e:#}"))))?;
            return Ok(());
        }
    };
    let ready = match &problem {
        // Spec shipping acknowledges the rebuilt global ground set;
        // partition shipping acknowledges the shard size it received.
        WorkerProblem::Spec { oracle, .. } => oracle.n(),
        WorkerProblem::Partition { oracle, .. } => oracle.len_local(),
    };
    reply(output, &FromWorker::Ready { n: ready })?;

    // The worker's own two-level executor serves the nested gain scans;
    // the machine-level parallelism lives in the worker fan-out, so one
    // thread per worker is the default.
    pool::with_pool(threads.max(1), |_exec| {
        serve(input, output, &mut problem, &params, machine)
    })
}

/// Rebuild the oracle + constraint a worker simulates, from the flat
/// config text the coordinator shipped.
fn build_worker_problem(problem: &str) -> crate::Result<WorkerProblem> {
    let cfg = crate::util::config::Config::parse(problem)
        .map_err(|e| anyhow::anyhow!("problem spec: {e}"))?;
    let built = crate::coordinator::build_problem(&cfg, None)?;
    let (constraint, _k) =
        crate::coordinator::experiment::build_constraint(&cfg, built.oracle.n())?;
    Ok(WorkerProblem::Spec { oracle: built.oracle, constraint })
}

/// Adopt a shipped shard: no dataset regeneration — the payload *is* the
/// data.  The spec text only supplies the constraint/objective settings.
fn build_partition_problem(
    spec: &str,
    payload: &crate::objective::PartitionPayload,
    local_view: bool,
) -> crate::Result<WorkerProblem> {
    let cfg = crate::util::config::Config::parse(spec)
        .map_err(|e| anyhow::anyhow!("problem spec: {e}"))?;
    let oracle = PartitionOracle::from_payload(payload)
        .map_err(|e| anyhow::anyhow!("partition payload: {e}"))?;
    if oracle.needs_local_view() && !local_view {
        anyhow::bail!(
            "the {} objective needs machine-local evaluation views under partition \
             shipping (run with local_view, the §6.4 scheme) — a shard cannot \
             evaluate against the full dataset",
            oracle.name()
        );
    }
    let (constraint, _k) =
        crate::coordinator::experiment::build_constraint(&cfg, oracle.n())?;
    Ok(WorkerProblem::Partition { oracle, constraint })
}

fn reply(output: &mut impl Write, msg: &FromWorker) -> crate::Result<()> {
    write_frame(output, &msg.to_value()).map_err(|e| anyhow::anyhow!("{e}"))
}

/// The command loop: one superstep role per frame.  All ids on the wire
/// are global; under partition shipping the oracle facade translates to
/// the shard's local dense space internally, and this loop only adds the
/// data-shard handling — extract on `Ship`, ingest on `Recv`.
fn serve(
    input: &mut impl Read,
    output: &mut impl Write,
    problem: &mut WorkerProblem,
    params: &NodeParams,
    machine: MachineId,
) -> crate::Result<()> {
    let mut state: Option<NodeState> = None;
    let mut pending: Option<(u32, Vec<ChildMsg>)> = None;
    loop {
        let Some(frame) = read_frame(input).map_err(|e| anyhow::anyhow!("{e}"))? else {
            return Ok(()); // coordinator went away — exit quietly
        };
        let cmd = ToWorker::from_value(&frame).map_err(|e| anyhow::anyhow!("{e}"))?;
        match cmd {
            ToWorker::Leaf { part } => {
                if let Some(p) = problem.partition() {
                    // Pre-validate so a coordinator that forgot to ship an
                    // element fails the protocol, not the process.
                    if let Some(&missing) = part.iter().find(|&&e| !p.holds(e)) {
                        reply(
                            output,
                            &FromWorker::Fail(DistError::backend(format!(
                                "worker {machine}: partition element {missing} is \
                                 not in the shipped shard"
                            ))),
                        )?;
                        continue;
                    }
                }
                match leaf_step(problem.oracle(), problem.constraint(), params, machine, &part)
                {
                    Ok((s, report)) => {
                        state = Some(s);
                        reply(output, &FromWorker::Step(report))?;
                    }
                    Err(e) => reply(output, &FromWorker::Fail(e))?,
                }
            }
            ToWorker::Ship => match state.as_mut() {
                Some(s) => {
                    let mut msg = s.ship();
                    // Partition shipping: the solution travels with its
                    // extracted data shard, so a parent that holds only
                    // its own partition can evaluate it.
                    if let Some(p) = problem.partition() {
                        match p.extract(&msg.sol) {
                            Ok(payload) => msg.data = Some(payload),
                            Err(e) => {
                                reply(
                                    output,
                                    &FromWorker::Fail(DistError::backend(format!(
                                        "worker {machine}: {e}"
                                    ))),
                                )?;
                                continue;
                            }
                        }
                    }
                    reply(output, &FromWorker::Sol(msg))?;
                }
                None => reply(
                    output,
                    &FromWorker::Fail(DistError::backend(format!(
                        "worker {machine}: ship before leaf"
                    ))),
                )?,
            },
            ToWorker::Recv { level, children } => {
                if let Some(p) = problem.partition_mut() {
                    // Absorb each child's data before acking — the Ack is
                    // the receipt that the payload (solutions *and* their
                    // shards) has fully arrived.
                    let mut failed = None;
                    for child in &children {
                        let result = match &child.data {
                            Some(payload) => p.ingest(payload),
                            None => Err(format!(
                                "child {} shipped a solution without its data shard \
                                 (mixed ship modes?)",
                                child.from
                            )),
                        };
                        if let Err(e) = result {
                            failed = Some(format!("worker {machine}: recv: {e}"));
                            break;
                        }
                    }
                    if let Some(msg) = failed {
                        reply(output, &FromWorker::Fail(DistError::backend(msg)))?;
                        continue;
                    }
                }
                pending = Some((level, children));
                reply(output, &FromWorker::Ack)?;
            }
            ToWorker::Accum { level, comm_secs } => {
                let took = pending.take();
                let result = match (state.as_mut(), took) {
                    (Some(s), Some((lvl, children))) if lvl == level => accum_step(
                        problem.oracle(),
                        problem.constraint(),
                        params,
                        s,
                        level,
                        &children,
                        comm_secs,
                    ),
                    _ => Err(DistError::backend(format!(
                        "worker {machine}: accum at level {level} without matching recv"
                    ))),
                };
                match result {
                    Ok(report) => reply(output, &FromWorker::Step(report))?,
                    Err(e) => reply(output, &FromWorker::Fail(e))?,
                }
            }
            ToWorker::Finish => {
                match state.take() {
                    Some(s) => reply(
                        output,
                        &FromWorker::Final {
                            stats: s.stats.clone(),
                            sol: s.sol,
                            value: s.sol_value,
                        },
                    )?,
                    None => reply(
                        output,
                        &FromWorker::Fail(DistError::backend(format!(
                            "worker {machine}: finish before any superstep"
                        ))),
                    )?,
                }
                return Ok(());
            }
            ToWorker::Init { .. } | ToWorker::InitPart { .. } => {
                reply(
                    output,
                    &FromWorker::Fail(DistError::backend(format!(
                        "worker {machine}: duplicate init"
                    ))),
                )?;
                anyhow::bail!("duplicate init");
            }
            ToWorker::Hello { .. } => {
                // The handshake belongs before Init, on the TCP accept
                // path — mid-session it is a protocol violation.
                reply(
                    output,
                    &FromWorker::Fail(DistError::backend(format!(
                        "worker {machine}: hello mid-session"
                    ))),
                )?;
                anyhow::bail!("hello mid-session");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::greedy::GreedyKind;

    fn params() -> NodeParams {
        NodeParams {
            kind: GreedyKind::Lazy,
            seed: 1,
            n: 100,
            mem_limit: None,
            local_view: false,
            added_elements: 0,
            compare_all_children: false,
        }
    }

    /// Wrap an oracle/constraint pair the way a spec-shipped session does.
    fn spec_problem(
        oracle: impl crate::objective::Oracle + 'static,
        constraint: impl crate::constraint::Constraint + 'static,
    ) -> WorkerProblem {
        WorkerProblem::Spec { oracle: Arc::new(oracle), constraint: Box::new(constraint) }
    }

    #[test]
    fn spawn_with_missing_binary_is_a_backend_error() {
        let err = ProcessBackend::spawn(
            2,
            &params(),
            1,
            ShipPlan::Spec("dataset.kind = retail\ndataset.n = 100\n"),
            Some("/nonexistent/greedyml-worker-binary"),
        )
        .unwrap_err();
        match err {
            DistError::Backend { message } => {
                assert!(message.contains("cannot spawn worker"), "{message}")
            }
            other => panic!("expected backend error, got {other:?}"),
        }
    }

    /// Drive `serve` in-process over byte buffers: a 1-machine session is
    /// leaf → finish, no child traffic — the protocol state machine works
    /// without forking anything.
    #[test]
    fn serve_runs_a_single_machine_session_in_memory() {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 100,
                num_items: 50,
                mean_size: 5.0,
                zipf_s: 0.9,
            },
            5,
        );
        let oracle = crate::objective::KCover::new(std::sync::Arc::new(data));
        let constraint = crate::constraint::Cardinality::new(4);
        let mut input = Vec::new();
        let part: Vec<ElemId> = (0..100).collect();
        write_frame(&mut input, &ToWorker::Leaf { part }.to_value()).unwrap();
        write_frame(&mut input, &ToWorker::Finish.to_value()).unwrap();
        let mut output = Vec::new();
        let mut problem = spec_problem(oracle, constraint);
        serve(&mut input.as_slice(), &mut output, &mut problem, &params(), 0).unwrap();

        let mut cursor = output.as_slice();
        let step = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&step).unwrap() {
            FromWorker::Step(r) => {
                assert_eq!(r.level, 0);
                assert!(r.calls > 0);
            }
            other => panic!("expected step, got {other:?}"),
        }
        let fin = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&fin).unwrap() {
            FromWorker::Final { stats, sol, value } => {
                assert_eq!(stats.id, 0);
                assert_eq!(sol.len(), 4);
                assert!(value > 0.0);
            }
            other => panic!("expected final, got {other:?}"),
        }
        assert!(read_frame(&mut cursor).unwrap().is_none(), "no trailing frames");
    }

    #[test]
    fn serve_reports_protocol_misuse_as_fail() {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 40,
                num_items: 20,
                mean_size: 4.0,
                zipf_s: 0.9,
            },
            5,
        );
        let oracle = crate::objective::KCover::new(std::sync::Arc::new(data));
        let constraint = crate::constraint::Cardinality::new(3);
        let mut input = Vec::new();
        write_frame(&mut input, &ToWorker::Ship.to_value()).unwrap();
        let mut output = Vec::new();
        // Ship before leaf: the worker answers Fail and keeps serving
        // (the EOF after it ends the loop cleanly).
        let mut problem = spec_problem(oracle, constraint);
        serve(&mut input.as_slice(), &mut output, &mut problem, &params(), 7).unwrap();
        let v = read_frame(&mut output.as_slice()).unwrap().unwrap();
        match FromWorker::from_value(&v).unwrap() {
            FromWorker::Fail(DistError::Backend { message }) => {
                assert!(message.contains("ship before leaf"), "{message}")
            }
            other => panic!("expected fail, got {other:?}"),
        }
    }

    #[test]
    fn init_part_session_serves_a_shard_without_rebuilding_the_dataset() {
        // A full in-memory partition-shipped session: InitPart carries a
        // 2-element modular shard of a "global" 50-element problem the
        // worker never sees; Leaf runs on those global ids; the shipped
        // solution carries its extracted data.
        let oracle = crate::objective::Modular::new(
            (0..50).map(|i| i as f64 + 1.0).collect::<Vec<_>>(),
        );
        let p = crate::objective::Oracle::partitionable(&oracle).unwrap();
        let payload = p.extract_partition(&[40, 7]);
        let mut input = Vec::new();
        write_frame(
            &mut input,
            &ToWorker::InitPart {
                machine: 0,
                threads: 1,
                params: NodeParams { n: 50, ..params() },
                spec: "problem.k = 1\n".to_string(),
                payload,
            }
            .to_value(),
        )
        .unwrap();
        write_frame(&mut input, &ToWorker::Leaf { part: vec![40, 7] }.to_value()).unwrap();
        write_frame(&mut input, &ToWorker::Ship.to_value()).unwrap();
        let mut output = Vec::new();
        serve_session(&mut input.as_slice(), &mut output).unwrap();

        let mut cursor = output.as_slice();
        let ready = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&ready).unwrap() {
            FromWorker::Ready { n } => assert_eq!(n, 2, "shard size, not the ground set"),
            other => panic!("expected ready, got {other:?}"),
        }
        let step = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&step).unwrap() {
            FromWorker::Step(r) => assert!(r.calls > 0),
            other => panic!("expected step, got {other:?}"),
        }
        let sol = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&sol).unwrap() {
            FromWorker::Sol(msg) => {
                assert_eq!(msg.sol, vec![40], "k = 1 argmax is the heaviest global id");
                let data = msg.data.expect("partition mode ships solution data");
                assert_eq!(data.elems, vec![40]);
            }
            other => panic!("expected sol, got {other:?}"),
        }
    }

    #[test]
    fn init_part_leaf_outside_the_shard_is_a_fail_not_a_panic() {
        let oracle = crate::objective::Modular::new(vec![1.0; 20]);
        let p = crate::objective::Oracle::partitionable(&oracle).unwrap();
        let payload = p.extract_partition(&[3, 4]);
        let mut input = Vec::new();
        write_frame(
            &mut input,
            &ToWorker::InitPart {
                machine: 2,
                threads: 1,
                params: NodeParams { n: 20, ..params() },
                spec: "problem.k = 1\n".to_string(),
                payload,
            }
            .to_value(),
        )
        .unwrap();
        write_frame(&mut input, &ToWorker::Leaf { part: vec![3, 19] }.to_value()).unwrap();
        let mut output = Vec::new();
        serve_session(&mut input.as_slice(), &mut output).unwrap();
        let mut cursor = output.as_slice();
        let _ready = read_frame(&mut cursor).unwrap().unwrap();
        let fail = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&fail).unwrap() {
            FromWorker::Fail(DistError::Backend { message }) => {
                assert!(message.contains("19"), "{message}");
                assert!(message.contains("shard"), "{message}");
            }
            other => panic!("expected fail, got {other:?}"),
        }
    }

    #[test]
    fn serve_session_rejects_a_hello_first_frame() {
        // Over pipes there is no handshake: the first frame must be Init.
        let mut input = Vec::new();
        write_frame(
            &mut input,
            &ToWorker::Hello { version: super::super::wire::PROTOCOL_VERSION }.to_value(),
        )
        .unwrap();
        let mut output = Vec::new();
        let err = serve_session(&mut input.as_slice(), &mut output).unwrap_err();
        assert!(err.to_string().contains("first frame must be init"), "{err}");
    }
}
