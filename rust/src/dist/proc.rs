//! The process-per-machine backend.
//!
//! [`ProcessBackend`] forks one worker process per simulated machine — a
//! hidden `greedyml worker` subcommand — and drives it over stdin/stdout
//! with the length-prefixed JSON frames of [`super::wire`].  Each machine
//! therefore owns a *real* address space: its dataset copy, partition and
//! solutions live in a separate heap, and solution shipping is real
//! serialization + pipe I/O, so `comm_secs` is **measured** wall time
//! (the coordinator clocks each gather from the first `Ship` request to
//! the parent's `Recv` receipt) instead of the α–β model the thread
//! backend books.
//!
//! Workers adopt the problem per the run's ship mode
//! ([`ShipSpec`](super::ShipSpec)): under spec shipping they rebuild the
//! oracle from the problem spec carried by
//! [`DistConfig::problem`](crate::algo::DistConfig::problem) — flat
//! `key = value` config text — because closures cannot cross a process
//! boundary; the generators are seeded, so every worker reconstructs
//! byte-identical data.  Under partition shipping they instead receive
//! their O(n/m) dataset shard
//! ([`PartitionPayload`](crate::objective::PartitionPayload)) and
//! regenerate nothing.  Either way the run stays bit-compatible with the
//! thread backend (`tests/test_backend.rs`).
//!
//! All protocol driving lives in the transport-generic `RemoteBackend`
//! (`dist/remote.rs`); this module only owns what is pipe-specific —
//! forking the workers, wiring their stdio, and killing orphans on error
//! paths.  The worker-side command loop (`serve_session`) is likewise
//! shared with the tcp backend's `greedyml serve` daemon, which serves
//! the same sessions over sockets.

use super::backend::{AccumTask, Backend, BackendOutcome, ShipPlan, WireMode};
use super::fault::{FaultAction, FaultPlan, FaultPoint, FaultPolicy};
use super::node::{accum_step, leaf_step, ChildMsg, NodeParams, NodeState};
use super::remote::{FramedWorker, RemoteFleet};
use super::wire::{read_cmd, read_session_init, write_frame, write_reply, FromWorker, ToWorker};
use super::{pool, DistError};
use crate::constraint::Constraint;
use crate::objective::{Oracle, PartitionOracle};
use crate::{ElemId, MachineId};
use std::io::{BufReader, BufWriter, Read, Write};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::{Arc, Mutex};

/// Resolve the worker executable: explicit config value, then the
/// `GREEDYML_WORKER_BIN` environment variable, then this very binary.
fn worker_binary(explicit: Option<&str>) -> Result<std::path::PathBuf, DistError> {
    if let Some(p) = explicit {
        return Ok(p.into());
    }
    if let Ok(p) = std::env::var("GREEDYML_WORKER_BIN") {
        if !p.trim().is_empty() {
            return Ok(p.into());
        }
    }
    std::env::current_exe()
        .map_err(|e| DistError::backend(format!("cannot locate worker binary: {e}")))
}

/// The forked worker processes, killed on drop unless already exited.
/// Separate from [`ProcessBackend`] so an error during the Init/Ready
/// handshake (which consumes the guard) still reaps every child.  Shared
/// (`Arc<Mutex<…>>`) with the supervisor's respawn closure, which pushes
/// replacement workers here so they are reaped the same way.
struct Children(Arc<Mutex<Vec<Child>>>);

impl Drop for Children {
    fn drop(&mut self) {
        // On the success path the workers have already exited after Final;
        // on error paths make sure no orphans linger.
        let mut children = self.0.lock().unwrap_or_else(|e| e.into_inner());
        for child in children.iter_mut() {
            match child.try_wait() {
                Ok(Some(_)) => {}
                _ => {
                    let _ = child.kill();
                    let _ = child.wait();
                }
            }
        }
    }
}

/// Fork one `greedyml worker` process and frame its stdio.  Replacement
/// workers (`scrub_fault_plan`) do not inherit `GREEDYML_FAULT_PLAN` —
/// a revived machine simulates a healthy spare host, and an injected
/// fault must not re-fire forever.
fn spawn_worker(
    bin: &std::path::Path,
    machine: MachineId,
    wire: WireMode,
    scrub_fault_plan: bool,
) -> Result<(Child, FramedWorker<BufReader<ChildStdout>, BufWriter<ChildStdin>>), DistError> {
    let mut cmd = Command::new(bin);
    cmd.arg("worker").stdin(Stdio::piped()).stdout(Stdio::piped()).stderr(Stdio::inherit());
    if scrub_fault_plan {
        cmd.env_remove("GREEDYML_FAULT_PLAN");
    }
    let mut child = cmd
        .spawn()
        .map_err(|e| DistError::backend(format!("cannot spawn worker {}: {e}", bin.display())))?;
    let stdin = BufWriter::new(child.stdin.take().expect("piped stdin"));
    let stdout = BufReader::new(child.stdout.take().expect("piped stdout"));
    Ok((child, FramedWorker::new(machine, stdout, stdin).with_mode(wire)))
}

/// The fleet driver over pipe transports.
type PipeFleet = RemoteFleet<BufReader<ChildStdout>, BufWriter<ChildStdin>>;

/// The process-per-machine [`Backend`]: a session fleet of forked
/// workers.  [`ProcessBackend::spawn`] ships the dataset once; each run
/// is then a [`ProcessBackend::begin_job`] followed by the usual
/// [`Backend`] supersteps, and the fleet stays warm across jobs until
/// [`ProcessBackend::release`] (or drop — the [`Children`] guard kills
/// whatever is left).
pub struct ProcessBackend {
    children: Children,
    inner: PipeFleet,
}

impl ProcessBackend {
    /// Fork `machines` workers and open the session: each worker receives
    /// its [`ShipPlan`] half (the problem spec, or its dataset shard) and
    /// acks what it rebuilt.  `n` is the global ground-set size the spec
    /// describes.  No job is started — call
    /// [`begin_job`](ProcessBackend::begin_job) per run.
    ///
    /// Under [`FaultPolicy::Retry`] or [`FaultPolicy::Degrade`] the fleet
    /// is supervised: a worker that dies mid-run is respawned (a fresh
    /// `greedyml worker`, its session re-established and its command log
    /// replayed — bit-identical, since the shipped problem and every
    /// seeded draw replay deterministically) or dropped with accounting.
    /// Replacement workers do not inherit `GREEDYML_FAULT_PLAN`.
    pub fn spawn(
        machines: u32,
        threads: usize,
        plan: ShipPlan<'_>,
        n: usize,
        worker_bin: Option<&str>,
        session: u64,
        fault: FaultPolicy,
        wire: WireMode,
    ) -> Result<Self, DistError> {
        let bin = worker_binary(worker_bin)?;
        let children = Children(Arc::new(Mutex::new(Vec::with_capacity(machines as usize))));
        let mut workers = Vec::with_capacity(machines as usize);
        for machine in 0..machines {
            let (child, worker) = spawn_worker(&bin, machine, wire, false)?;
            children.0.lock().unwrap_or_else(|e| e.into_inner()).push(child);
            workers.push(worker);
        }
        let mut inner = RemoteFleet::establish("process", workers, threads, plan, n, session)?;
        if fault != FaultPolicy::Fail {
            let roster = Arc::clone(&children.0);
            inner.supervise(
                fault,
                Box::new(move |machine, _attempt| {
                    let (child, worker) = spawn_worker(&bin, machine, wire, true)?;
                    roster.lock().unwrap_or_else(|e| e.into_inner()).push(child);
                    Ok(worker)
                }),
            );
        }
        Ok(Self { children, inner })
    }

    /// Start one job on the warm fleet (see [`RemoteFleet::begin_job`]).
    pub fn begin_job(&mut self, params: &NodeParams, spec: &str) -> Result<(), DistError> {
        self.inner.begin_job(params, spec)
    }

    /// Wire bytes the session init put on the pipes (dataset shipped once).
    pub fn init_bytes(&self) -> u64 {
        self.inner.init_bytes()
    }

    /// End the session: `Release` every worker and reap the processes so
    /// the [`Children`] drop guard has nothing to kill.
    pub fn release(&mut self) {
        self.inner.release();
        let mut children = self.children.0.lock().unwrap_or_else(|e| e.into_inner());
        for child in children.iter_mut() {
            let _ = child.wait();
        }
    }

    /// Probe every live machine with `Ping` (see [`RemoteFleet::ping_all`]).
    pub fn ping_all(&mut self) -> Result<(), DistError> {
        self.inner.ping_all()
    }

    /// The fault accounting of the most recent job (see
    /// [`RemoteFleet::fault_report`]).
    pub fn fault_report(&self) -> super::FaultReport {
        self.inner.fault_report()
    }

    /// Advance the resident dataset one epoch in place — fan per-machine
    /// `Delta` frames and verify every `DeltaDone` (see
    /// [`RemoteFleet::advance_epoch`]).  Returns the delta wire bytes.
    pub fn advance_epoch(
        &mut self,
        epoch: u64,
        deltas: Vec<crate::objective::PartitionDelta>,
        fresh: Vec<crate::objective::PartitionPayload>,
    ) -> Result<u64, DistError> {
        self.inner.advance_epoch(epoch, deltas, fresh)
    }
}

impl Backend for ProcessBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn run_leaves(&mut self, parts: Vec<Vec<ElemId>>) -> Result<Vec<super::StepReport>, DistError> {
        self.inner.run_leaves(parts)
    }

    fn run_superstep(
        &mut self,
        level: u32,
        tasks: &[AccumTask],
    ) -> Result<Vec<super::StepReport>, DistError> {
        self.inner.run_superstep(level, tasks)
    }

    fn finish(&mut self) -> Result<BackendOutcome, DistError> {
        // Ends the job, not the session — workers stay resident for the
        // next begin_job; release() tears the fleet down.
        self.inner.finish()
    }

    fn measures_comm(&self) -> bool {
        self.inner.measures_comm()
    }
}

// ---- worker side -------------------------------------------------------

/// Entry point of the hidden `greedyml worker` subcommand: serve one
/// simulated machine over stdin/stdout until `Release` or EOF.
pub fn run_worker() -> crate::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut input = BufReader::new(stdin.lock());
    let mut output = BufWriter::new(stdout.lock());
    serve_session(&mut input, &mut output)
}

/// What a worker holds **resident for the whole session**: either the
/// whole dataset rebuilt from a spec, or a [`PartitionOracle`] over its
/// shipped shard — which grows as child solutions arrive with their data.
/// Constraints are per-job (they arrive inside [`ToWorker::Job`]), so the
/// resident problem is data only.
pub(crate) enum WorkerProblem {
    /// Spec shipping: the full oracle, regenerated locally.
    Spec {
        /// The rebuilt oracle.
        oracle: Arc<dyn Oracle>,
    },
    /// Partition shipping: the shard facade (mutable — `Recv` ingests
    /// child-solution data into it between supersteps).
    Partition {
        /// The shard-backed oracle facade.
        oracle: PartitionOracle,
    },
}

impl WorkerProblem {
    fn oracle(&self) -> &dyn Oracle {
        match self {
            Self::Spec { oracle } => oracle.as_ref(),
            Self::Partition { oracle } => oracle,
        }
    }

    fn partition(&self) -> Option<&PartitionOracle> {
        match self {
            Self::Spec { .. } => None,
            Self::Partition { oracle } => Some(oracle),
        }
    }

    fn partition_mut(&mut self) -> Option<&mut PartitionOracle> {
        match self {
            Self::Spec { .. } => None,
            Self::Partition { oracle } => Some(oracle),
        }
    }
}

/// The per-job context of the command loop: the node parameters and the
/// constraint the current [`ToWorker::Job`] rebuilt.  Dropped and rebuilt
/// on every job; the dataset ([`WorkerProblem`]) outlives it.
struct JobCtx {
    params: NodeParams,
    constraint: Box<dyn Constraint>,
}

/// One worker session over any framed byte stream: read `Init` (spec
/// shipping — rebuild the whole dataset) or `InitPart` (partition
/// shipping — adopt the shipped shard), reply `Ready`, then serve jobs —
/// each a `Job` … supersteps … `JobDone` sequence against the resident
/// oracle — until `Release` or EOF.  The process backend runs this over a
/// worker's stdio; the tcp backend's `greedyml serve` daemon runs it per
/// accepted connection (after the `Hello`/`Welcome` version handshake).
///
/// The session adopts its **wire mode** from the opening frame's content
/// type (a binary `init_part` ingests its shard incrementally via
/// [`read_session_init`]'s streaming path) and mirrors that mode in its
/// replies for the rest of the session.
pub(crate) fn serve_session(
    input: &mut impl Read,
    output: &mut impl Write,
) -> crate::Result<()> {
    let (first, mode) = read_session_init(input)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .ok_or_else(|| anyhow::anyhow!("worker: EOF before init"))?;
    let (machine, threads, built) = match first {
        ToWorker::Init { session: _, machine, threads, problem } => {
            (machine, threads, build_worker_problem(&problem))
        }
        ToWorker::InitPart { session: _, machine, threads, payload } => {
            (machine, threads, build_partition_problem(&payload))
        }
        _ => anyhow::bail!("worker: first frame must be init or init_part"),
    };

    // The deterministic fault-injection plan this session follows
    // (`GREEDYML_FAULT_PLAN`); an unparsable plan is a hard error — it
    // must not silently run fault-free.
    let mut fault = match FaultPlan::from_env() {
        Ok(f) => f,
        Err(e) => {
            reply(output, &FromWorker::Fail(e.clone()))?;
            anyhow::bail!("{e}");
        }
    };
    let mut suppress_ready = false;
    if let Some(plan) = fault.as_mut() {
        match plan.trigger(machine, FaultPoint::Init) {
            Some(FaultAction::Kill) => {
                anyhow::bail!("fault-injected kill: machine {machine} at init")
            }
            Some(FaultAction::Delay(d)) => std::thread::sleep(d),
            Some(FaultAction::DropFrame) => suppress_ready = true,
            None => {}
        }
    }

    let mut problem = match built {
        Ok(p) => p,
        Err(e) => {
            reply(output, &FromWorker::Fail(DistError::backend(format!("{e:#}"))))?;
            return Ok(());
        }
    };
    let ready = match &problem {
        // Spec shipping acknowledges the rebuilt global ground set;
        // partition shipping acknowledges the shard size it received.
        WorkerProblem::Spec { oracle } => oracle.n(),
        WorkerProblem::Partition { oracle } => oracle.len_local(),
    };
    if !suppress_ready {
        reply(output, &FromWorker::Ready { n: ready })?;
    }

    // The worker's own two-level executor serves the nested gain scans;
    // the machine-level parallelism lives in the worker fan-out, so one
    // thread per worker is the default.
    pool::with_pool(threads.max(1), |_exec| {
        serve(input, output, &mut problem, machine, mode, &mut fault)
    })
}

/// Rebuild the resident oracle a worker simulates, from the flat config
/// text the coordinator shipped.
fn build_worker_problem(problem: &str) -> crate::Result<WorkerProblem> {
    let cfg = crate::util::config::Config::parse(problem)
        .map_err(|e| anyhow::anyhow!("problem spec: {e}"))?;
    let built = crate::coordinator::build_problem(&cfg, None)?;
    Ok(WorkerProblem::Spec { oracle: built.oracle })
}

/// Adopt a shipped shard: no dataset regeneration — the payload *is* the
/// data.
fn build_partition_problem(
    payload: &crate::objective::PartitionPayload,
) -> crate::Result<WorkerProblem> {
    let oracle = PartitionOracle::from_payload(payload)
        .map_err(|e| anyhow::anyhow!("partition payload: {e}"))?;
    Ok(WorkerProblem::Partition { oracle })
}

/// Admit one job against the resident problem: rebuild the constraint
/// from the job's spec text and re-check the shard/objective contract.
/// An `Err` fails *the job*, not the session.
fn setup_job(
    problem: &WorkerProblem,
    params: &NodeParams,
    spec: &str,
) -> crate::Result<Box<dyn Constraint>> {
    if let Some(p) = problem.partition() {
        if p.needs_local_view() && !params.local_view {
            anyhow::bail!(
                "the {} objective needs machine-local evaluation views under partition \
                 shipping (run with local_view, the §6.4 scheme) — a shard cannot \
                 evaluate against the full dataset",
                p.name()
            );
        }
    }
    let cfg = crate::util::config::Config::parse(spec)
        .map_err(|e| anyhow::anyhow!("problem spec: {e}"))?;
    let (constraint, _k) =
        crate::coordinator::experiment::build_constraint(&cfg, problem.oracle().n())?;
    Ok(constraint)
}

fn reply(output: &mut impl Write, msg: &FromWorker) -> crate::Result<()> {
    write_frame(output, &msg.to_value()).map_err(|e| anyhow::anyhow!("{e}"))
}

/// Mode-aware reply for the messages that have a binary form (`Sol`):
/// under a binary session the shipped solution — with its extracted data
/// shard — travels as a binary frame; every other reply is JSON either
/// way, so [`reply`] covers them.
fn reply_in(output: &mut impl Write, msg: &FromWorker, mode: WireMode) -> crate::Result<()> {
    write_reply(output, msg, mode).map_err(|e| anyhow::anyhow!("{e}"))
}

/// The command loop: one superstep role per frame, grouped into jobs.
/// All ids on the wire are global; under partition shipping the oracle
/// facade translates to the shard's local dense space internally, and
/// this loop only adds the data-shard handling — extract on `Ship`,
/// ingest on `Recv`.  Superstep commands outside an active job are
/// protocol violations answered with `Fail`; `JobDone` ships the final
/// state and keeps the session alive for the next `Job`.
///
/// Before each command is handled the session's [`FaultPlan`] (if any)
/// is consulted: `kill` drops the connection without replying, `delay`
/// sleeps, `drop-frame` swallows the command — the deterministic
/// injection points every recovery path is tested through.
fn serve(
    input: &mut impl Read,
    output: &mut impl Write,
    problem: &mut WorkerProblem,
    machine: MachineId,
    mode: WireMode,
    fault: &mut Option<FaultPlan>,
) -> crate::Result<()> {
    let mut job: Option<JobCtx> = None;
    let mut state: Option<NodeState> = None;
    let mut pending: Option<(u32, Vec<ChildMsg>)> = None;
    loop {
        let Some((cmd, _ctype)) = read_cmd(input).map_err(|e| anyhow::anyhow!("{e}"))? else {
            return Ok(()); // coordinator went away — exit quietly
        };
        let point = match &cmd {
            ToWorker::Job { .. } => Some(FaultPoint::Job),
            ToWorker::Leaf { .. } => Some(FaultPoint::Superstep(0)),
            ToWorker::Ship => Some(FaultPoint::Ship),
            ToWorker::Recv { .. } => Some(FaultPoint::Recv),
            ToWorker::Accum { level, .. } => Some(FaultPoint::Superstep(*level)),
            _ => None,
        };
        if let (Some(plan), Some(point)) = (fault.as_mut(), point) {
            match plan.trigger(machine, point) {
                Some(FaultAction::Kill) => {
                    anyhow::bail!("fault-injected kill: machine {machine} at {point:?}")
                }
                Some(FaultAction::Delay(d)) => std::thread::sleep(d),
                Some(FaultAction::DropFrame) => continue,
                None => {}
            }
        }
        match cmd {
            ToWorker::Job { job: _, params, spec } => {
                // Every job starts from a clean slate: per-job state dies
                // here, the resident dataset does not.
                state = None;
                pending = None;
                match setup_job(problem, &params, &spec) {
                    Ok(constraint) => {
                        job = Some(JobCtx { params, constraint });
                        // Ack with the *global* ground-set size — stable
                        // across jobs even as a resident partition shard
                        // grows by ingesting child data.
                        reply(output, &FromWorker::Ready { n: problem.oracle().n() })?;
                    }
                    Err(e) => {
                        job = None;
                        reply(
                            output,
                            &FromWorker::Fail(DistError::backend(format!(
                                "worker {machine}: {e:#}"
                            ))),
                        )?;
                    }
                }
            }
            ToWorker::Leaf { part } => {
                let Some(ctx) = job.as_ref() else {
                    reply(
                        output,
                        &FromWorker::Fail(DistError::backend(format!(
                            "worker {machine}: leaf without an active job"
                        ))),
                    )?;
                    continue;
                };
                if let Some(p) = problem.partition() {
                    // Pre-validate so a coordinator that forgot to ship an
                    // element fails the protocol, not the process.
                    if let Some(&missing) = part.iter().find(|&&e| !p.holds(e)) {
                        reply(
                            output,
                            &FromWorker::Fail(DistError::backend(format!(
                                "worker {machine}: partition element {missing} is \
                                 not in the shipped shard"
                            ))),
                        )?;
                        continue;
                    }
                }
                match leaf_step(
                    problem.oracle(),
                    ctx.constraint.as_ref(),
                    &ctx.params,
                    machine,
                    &part,
                ) {
                    Ok((s, report)) => {
                        state = Some(s);
                        reply(output, &FromWorker::Step(report))?;
                    }
                    Err(e) => reply(output, &FromWorker::Fail(e))?,
                }
            }
            ToWorker::Ship => match state.as_mut() {
                Some(s) => {
                    let mut msg = s.ship();
                    // Partition shipping: the solution travels with its
                    // extracted data shard, so a parent that holds only
                    // its own partition can evaluate it.  Coreset mode
                    // ships the whole coreset's data — the parent
                    // accumulates over it, and it covers the solution.
                    if let Some(p) = problem.partition() {
                        let wanted: &[ElemId] = msg.coreset.as_deref().unwrap_or(&msg.sol);
                        match p.extract(wanted) {
                            Ok(payload) => msg.data = Some(payload),
                            Err(e) => {
                                reply(
                                    output,
                                    &FromWorker::Fail(DistError::backend(format!(
                                        "worker {machine}: {e}"
                                    ))),
                                )?;
                                continue;
                            }
                        }
                    }
                    reply_in(output, &FromWorker::Sol(msg), mode)?;
                }
                None => reply(
                    output,
                    &FromWorker::Fail(DistError::backend(format!(
                        "worker {machine}: ship before leaf"
                    ))),
                )?,
            },
            ToWorker::Recv { level, children } => {
                if job.is_none() {
                    reply(
                        output,
                        &FromWorker::Fail(DistError::backend(format!(
                            "worker {machine}: recv without an active job"
                        ))),
                    )?;
                    continue;
                }
                if let Some(p) = problem.partition_mut() {
                    // Absorb each child's data before acking — the Ack is
                    // the receipt that the payload (solutions *and* their
                    // shards) has fully arrived.
                    let mut failed = None;
                    for child in &children {
                        let result = match &child.data {
                            Some(payload) => p.ingest(payload),
                            None => Err(format!(
                                "child {} shipped a solution without its data shard \
                                 (mixed ship modes?)",
                                child.from
                            )),
                        };
                        if let Err(e) = result {
                            failed = Some(format!("worker {machine}: recv: {e}"));
                            break;
                        }
                    }
                    if let Some(msg) = failed {
                        reply(output, &FromWorker::Fail(DistError::backend(msg)))?;
                        continue;
                    }
                }
                pending = Some((level, children));
                reply(output, &FromWorker::Ack)?;
            }
            ToWorker::Accum { level, comm_secs } => {
                let Some(ctx) = job.as_ref() else {
                    reply(
                        output,
                        &FromWorker::Fail(DistError::backend(format!(
                            "worker {machine}: accum without an active job"
                        ))),
                    )?;
                    continue;
                };
                let took = pending.take();
                let result = match (state.as_mut(), took) {
                    (Some(s), Some((lvl, children))) if lvl == level => accum_step(
                        problem.oracle(),
                        ctx.constraint.as_ref(),
                        &ctx.params,
                        s,
                        level,
                        &children,
                        comm_secs,
                    ),
                    _ => Err(DistError::backend(format!(
                        "worker {machine}: accum at level {level} without matching recv"
                    ))),
                };
                match result {
                    Ok(report) => reply(output, &FromWorker::Step(report))?,
                    Err(e) => reply(output, &FromWorker::Fail(e))?,
                }
            }
            ToWorker::JobDone => {
                // End of one job: ship the final state, stay resident for
                // the next Job on this session.
                match state.take() {
                    Some(s) => reply(
                        output,
                        &FromWorker::Final {
                            stats: s.stats.clone(),
                            sol: s.sol,
                            value: s.sol_value,
                        },
                    )?,
                    None => reply(
                        output,
                        &FromWorker::Fail(DistError::backend(format!(
                            "worker {machine}: job_done before any superstep"
                        ))),
                    )?,
                }
                job = None;
                pending = None;
            }
            ToWorker::Ping => {
                // Liveness probe — answerable at any point in the session.
                reply(output, &FromWorker::Pong)?;
            }
            ToWorker::Delta { epoch, delta } => {
                // Live-dataset update (v6): only meaningful on a
                // partition-shipped session, and only between jobs —
                // whatever per-job state exists describes the pre-delta
                // dataset, so it dies here either way.
                state = None;
                pending = None;
                match problem.partition_mut() {
                    Some(p) => match p.apply_delta(&delta) {
                        Ok(()) => reply(
                            output,
                            &FromWorker::DeltaDone { epoch, n: p.len_local() },
                        )?,
                        Err(e) => reply(
                            output,
                            &FromWorker::Fail(DistError::backend(format!(
                                "worker {machine}: delta: {e}"
                            ))),
                        )?,
                    },
                    None => reply(
                        output,
                        &FromWorker::Fail(DistError::backend(format!(
                            "worker {machine}: delta on a spec-shipped session \
                             (live datasets need partition shipping)"
                        ))),
                    )?,
                }
            }
            ToWorker::Release => {
                return Ok(()); // explicit end of session, no reply
            }
            ToWorker::Init { .. } | ToWorker::InitPart { .. } => {
                reply(
                    output,
                    &FromWorker::Fail(DistError::backend(format!(
                        "worker {machine}: duplicate init"
                    ))),
                )?;
                anyhow::bail!("duplicate init");
            }
            ToWorker::Hello { .. } => {
                // The handshake belongs before Init, on the TCP accept
                // path — mid-session it is a protocol violation.
                reply(
                    output,
                    &FromWorker::Fail(DistError::backend(format!(
                        "worker {machine}: hello mid-session"
                    ))),
                )?;
                anyhow::bail!("hello mid-session");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::wire::{read_frame, read_reply, write_cmd};
    use crate::greedy::GreedyKind;

    fn params() -> NodeParams {
        NodeParams {
            kind: GreedyKind::Lazy,
            seed: 1,
            n: 100,
            mem_limit: None,
            local_view: false,
            added_elements: 0,
            compare_all_children: false,
            coreset: false,
        }
    }

    /// Wrap an oracle the way a spec-shipped session does.
    fn spec_problem(oracle: impl crate::objective::Oracle + 'static) -> WorkerProblem {
        WorkerProblem::Spec { oracle: Arc::new(oracle) }
    }

    fn job_frame(params: NodeParams, spec: &str) -> ToWorker {
        ToWorker::Job { job: 0, params, spec: spec.to_string() }
    }

    fn expect_ready(cursor: &mut &[u8], want: usize, what: &str) {
        let v = read_frame(cursor).unwrap().unwrap();
        match FromWorker::from_value(&v).unwrap() {
            FromWorker::Ready { n } => assert_eq!(n, want, "{what}"),
            other => panic!("expected ready ({what}), got {other:?}"),
        }
    }

    #[test]
    fn spawn_with_missing_binary_is_a_backend_error() {
        let err = ProcessBackend::spawn(
            2,
            1,
            ShipPlan::Spec("dataset.kind = retail\ndataset.n = 100\n"),
            100,
            Some("/nonexistent/greedyml-worker-binary"),
            0,
            FaultPolicy::Fail,
            WireMode::Json,
        )
        .unwrap_err();
        match err {
            DistError::Backend { message } => {
                assert!(message.contains("cannot spawn worker"), "{message}")
            }
            other => panic!("expected backend error, got {other:?}"),
        }
    }

    /// Drive `serve` in-process over byte buffers: a 1-machine job is
    /// job → leaf → job_done, no child traffic — the protocol state
    /// machine works without forking anything.
    #[test]
    fn serve_runs_a_single_machine_job_in_memory() {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 100,
                num_items: 50,
                mean_size: 5.0,
                zipf_s: 0.9,
            },
            5,
        );
        let oracle = crate::objective::KCover::new(std::sync::Arc::new(data));
        let mut input = Vec::new();
        write_frame(&mut input, &job_frame(params(), "problem.k = 4\n").to_value()).unwrap();
        let part: Vec<ElemId> = (0..100).collect();
        write_frame(&mut input, &ToWorker::Leaf { part }.to_value()).unwrap();
        write_frame(&mut input, &ToWorker::JobDone.to_value()).unwrap();
        let mut output = Vec::new();
        let mut problem = spec_problem(oracle);
        serve(&mut input.as_slice(), &mut output, &mut problem, 0, WireMode::Json, &mut None)
            .unwrap();

        let mut cursor = output.as_slice();
        expect_ready(&mut cursor, 100, "job ack");
        let step = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&step).unwrap() {
            FromWorker::Step(r) => {
                assert_eq!(r.level, 0);
                assert!(r.calls > 0);
            }
            other => panic!("expected step, got {other:?}"),
        }
        let fin = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&fin).unwrap() {
            FromWorker::Final { stats, sol, value } => {
                assert_eq!(stats.id, 0);
                assert_eq!(sol.len(), 4);
                assert!(value > 0.0);
            }
            other => panic!("expected final, got {other:?}"),
        }
        assert!(read_frame(&mut cursor).unwrap().is_none(), "no trailing frames");
    }

    /// The tentpole at its smallest: one resident oracle, two jobs on it,
    /// bit-identical Finals — no re-init between them.
    #[test]
    fn serve_runs_two_jobs_on_one_resident_session_bit_identically() {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 80,
                num_items: 40,
                mean_size: 5.0,
                zipf_s: 0.9,
            },
            5,
        );
        let oracle = crate::objective::KCover::new(std::sync::Arc::new(data));
        let mut input = Vec::new();
        let part: Vec<ElemId> = (0..80).collect();
        for _ in 0..2 {
            write_frame(
                &mut input,
                &job_frame(NodeParams { n: 80, ..params() }, "problem.k = 4\n").to_value(),
            )
            .unwrap();
            write_frame(&mut input, &ToWorker::Leaf { part: part.clone() }.to_value())
                .unwrap();
            write_frame(&mut input, &ToWorker::JobDone.to_value()).unwrap();
        }
        let mut output = Vec::new();
        let mut problem = spec_problem(oracle);
        serve(&mut input.as_slice(), &mut output, &mut problem, 0, WireMode::Json, &mut None)
            .unwrap();

        let mut cursor = output.as_slice();
        let mut finals = Vec::new();
        for round in 0..2 {
            expect_ready(&mut cursor, 80, "job ack");
            let step = read_frame(&mut cursor).unwrap().unwrap();
            assert!(
                matches!(FromWorker::from_value(&step).unwrap(), FromWorker::Step(_)),
                "round {round}"
            );
            let fin = read_frame(&mut cursor).unwrap().unwrap();
            match FromWorker::from_value(&fin).unwrap() {
                FromWorker::Final { sol, value, .. } => finals.push((sol, value.to_bits())),
                other => panic!("expected final, got {other:?}"),
            }
        }
        assert_eq!(finals[0], finals[1], "a warm second job must be bit-identical");
    }

    #[test]
    fn serve_reports_protocol_misuse_as_fail() {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 40,
                num_items: 20,
                mean_size: 4.0,
                zipf_s: 0.9,
            },
            5,
        );
        let oracle = crate::objective::KCover::new(std::sync::Arc::new(data));
        let mut input = Vec::new();
        write_frame(&mut input, &job_frame(params(), "problem.k = 3\n").to_value()).unwrap();
        write_frame(&mut input, &ToWorker::Ship.to_value()).unwrap();
        let mut output = Vec::new();
        // Ship before leaf: the worker answers Fail and keeps serving
        // (the EOF after it ends the loop cleanly).
        let mut problem = spec_problem(oracle);
        serve(&mut input.as_slice(), &mut output, &mut problem, 7, WireMode::Json, &mut None)
            .unwrap();
        let mut cursor = output.as_slice();
        let _ready = read_frame(&mut cursor).unwrap().unwrap();
        let v = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&v).unwrap() {
            FromWorker::Fail(DistError::Backend { message }) => {
                assert!(message.contains("ship before leaf"), "{message}")
            }
            other => panic!("expected fail, got {other:?}"),
        }
    }

    #[test]
    fn superstep_commands_without_a_job_are_fails_not_panics() {
        let oracle = crate::objective::Modular::new(vec![1.0; 10]);
        let mut input = Vec::new();
        write_frame(&mut input, &ToWorker::Leaf { part: vec![0, 1] }.to_value()).unwrap();
        write_frame(&mut input, &ToWorker::JobDone.to_value()).unwrap();
        let mut output = Vec::new();
        let mut problem = spec_problem(oracle);
        serve(&mut input.as_slice(), &mut output, &mut problem, 3, WireMode::Json, &mut None)
            .unwrap();
        let mut cursor = output.as_slice();
        for want in ["leaf without an active job", "job_done before any superstep"] {
            let v = read_frame(&mut cursor).unwrap().unwrap();
            match FromWorker::from_value(&v).unwrap() {
                FromWorker::Fail(DistError::Backend { message }) => {
                    assert!(message.contains(want), "{message}")
                }
                other => panic!("expected fail ({want}), got {other:?}"),
            }
        }
    }

    #[test]
    fn init_part_session_serves_a_shard_without_rebuilding_the_dataset() {
        // A full in-memory partition-shipped session: InitPart carries a
        // 2-element modular shard of a "global" 50-element problem the
        // worker never sees; the Job supplies the constraint; Leaf runs on
        // those global ids; the shipped solution carries its extracted
        // data.
        let oracle = crate::objective::Modular::new(
            (0..50).map(|i| i as f64 + 1.0).collect::<Vec<_>>(),
        );
        let p = crate::objective::Oracle::partitionable(&oracle).unwrap();
        let payload = p.extract_partition(&[40, 7]);
        let mut input = Vec::new();
        write_frame(
            &mut input,
            &ToWorker::InitPart { session: 0, machine: 0, threads: 1, payload }.to_value(),
        )
        .unwrap();
        write_frame(
            &mut input,
            &job_frame(NodeParams { n: 50, ..params() }, "problem.k = 1\n").to_value(),
        )
        .unwrap();
        write_frame(&mut input, &ToWorker::Leaf { part: vec![40, 7] }.to_value()).unwrap();
        write_frame(&mut input, &ToWorker::Ship.to_value()).unwrap();
        let mut output = Vec::new();
        serve_session(&mut input.as_slice(), &mut output).unwrap();

        let mut cursor = output.as_slice();
        expect_ready(&mut cursor, 2, "session ack: shard size, not the ground set");
        expect_ready(&mut cursor, 50, "job ack: the global ground set");
        let step = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&step).unwrap() {
            FromWorker::Step(r) => assert!(r.calls > 0),
            other => panic!("expected step, got {other:?}"),
        }
        let sol = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&sol).unwrap() {
            FromWorker::Sol(msg) => {
                assert_eq!(msg.sol, vec![40], "k = 1 argmax is the heaviest global id");
                let data = msg.data.expect("partition mode ships solution data");
                assert_eq!(data.elems, vec![40]);
            }
            other => panic!("expected sol, got {other:?}"),
        }
    }

    #[test]
    fn binary_session_adopts_the_wire_mode_and_ships_sol_in_binary() {
        // The v5 wire end to end: a binary InitPart opens the session, so
        // the worker answers the Ship with a binary Sol frame — control
        // replies stay JSON under either mode.
        let oracle = crate::objective::Modular::new(
            (0..50).map(|i| i as f64 + 1.0).collect::<Vec<_>>(),
        );
        let p = crate::objective::Oracle::partitionable(&oracle).unwrap();
        let payload = p.extract_partition(&[40, 7]);
        let mut input = Vec::new();
        let init = ToWorker::InitPart { session: 0, machine: 0, threads: 1, payload };
        write_cmd(&mut input, &init, WireMode::Binary).unwrap();
        write_cmd(
            &mut input,
            &job_frame(NodeParams { n: 50, ..params() }, "problem.k = 1\n"),
            WireMode::Binary,
        )
        .unwrap();
        write_cmd(&mut input, &ToWorker::Leaf { part: vec![40, 7] }, WireMode::Binary).unwrap();
        write_cmd(&mut input, &ToWorker::Ship, WireMode::Binary).unwrap();
        let mut output = Vec::new();
        serve_session(&mut input.as_slice(), &mut output).unwrap();

        // Frame-level: Ready, Ready, Step travel as JSON (0x01); the
        // payload-bearing Sol is the only binary frame (0x02).
        let mut ctypes = Vec::new();
        let mut at = 0usize;
        while at + 5 <= output.len() {
            let len = u32::from_le_bytes(output[at..at + 4].try_into().unwrap()) as usize;
            ctypes.push(output[at + 4]);
            at += 5 + len;
        }
        assert_eq!(at, output.len(), "replies split cleanly into v5 frames");
        assert_eq!(ctypes, vec![0x01, 0x01, 0x01, 0x02]);

        // Message-level: read_reply decodes the mixed stream and the
        // binary Sol matches what the JSON session produces.
        let mut cursor = output.as_slice();
        match read_reply(&mut cursor).unwrap().unwrap() {
            FromWorker::Ready { n } => assert_eq!(n, 2, "session ack: shard size"),
            other => panic!("expected ready, got {other:?}"),
        }
        match read_reply(&mut cursor).unwrap().unwrap() {
            FromWorker::Ready { n } => assert_eq!(n, 50, "job ack: global ground set"),
            other => panic!("expected ready, got {other:?}"),
        }
        assert!(matches!(read_reply(&mut cursor).unwrap().unwrap(), FromWorker::Step(_)));
        match read_reply(&mut cursor).unwrap().unwrap() {
            FromWorker::Sol(msg) => {
                assert_eq!(msg.sol, vec![40]);
                let data = msg.data.expect("partition mode ships solution data");
                assert_eq!(data.elems, vec![40]);
            }
            other => panic!("expected sol, got {other:?}"),
        }
        assert!(read_reply(&mut cursor).unwrap().is_none(), "clean EOF after the Sol");
    }

    #[test]
    fn delta_between_jobs_updates_the_resident_shard() {
        // v6 live deltas end to end on one in-memory session: solve on the
        // shipped shard, apply a delta (delete the winner, insert a heavier
        // element), solve again — the second job must see the new dataset.
        let oracle = crate::objective::Modular::new(
            (0..50).map(|i| i as f64 + 1.0).collect::<Vec<_>>(),
        );
        let p = crate::objective::Oracle::partitionable(&oracle).unwrap();
        let payload = p.extract_partition(&[40, 7]);
        let delta = crate::objective::PartitionDelta {
            n_global: 50,
            insert: p.extract_partition(&[49]),
            delete: vec![40],
        };
        let mut input = Vec::new();
        write_frame(
            &mut input,
            &ToWorker::InitPart { session: 0, machine: 0, threads: 1, payload }.to_value(),
        )
        .unwrap();
        write_frame(
            &mut input,
            &job_frame(NodeParams { n: 50, ..params() }, "problem.k = 1\n").to_value(),
        )
        .unwrap();
        write_frame(&mut input, &ToWorker::Leaf { part: vec![40, 7] }.to_value()).unwrap();
        write_frame(&mut input, &ToWorker::Ship.to_value()).unwrap();
        write_frame(&mut input, &ToWorker::Delta { epoch: 1, delta }.to_value()).unwrap();
        write_frame(
            &mut input,
            &job_frame(NodeParams { n: 50, ..params() }, "problem.k = 1\n").to_value(),
        )
        .unwrap();
        write_frame(&mut input, &ToWorker::Leaf { part: vec![7, 49] }.to_value()).unwrap();
        write_frame(&mut input, &ToWorker::Ship.to_value()).unwrap();
        let mut output = Vec::new();
        serve_session(&mut input.as_slice(), &mut output).unwrap();

        let mut cursor = output.as_slice();
        expect_ready(&mut cursor, 2, "session ack");
        expect_ready(&mut cursor, 50, "first job ack");
        let _step = read_frame(&mut cursor).unwrap().unwrap();
        let sol = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&sol).unwrap() {
            FromWorker::Sol(msg) => assert_eq!(msg.sol, vec![40], "pre-delta argmax"),
            other => panic!("expected sol, got {other:?}"),
        }
        let done = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&done).unwrap() {
            FromWorker::DeltaDone { epoch, n } => {
                assert_eq!(epoch, 1);
                assert_eq!(n, 2, "40 deleted, 49 inserted: still two held elements");
            }
            other => panic!("expected delta_done, got {other:?}"),
        }
        expect_ready(&mut cursor, 50, "second job ack");
        let _step = read_frame(&mut cursor).unwrap().unwrap();
        let sol = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&sol).unwrap() {
            FromWorker::Sol(msg) => {
                assert_eq!(msg.sol, vec![49], "post-delta argmax is the inserted element");
                assert_eq!(msg.data.expect("partition mode ships data").elems, vec![49]);
            }
            other => panic!("expected sol, got {other:?}"),
        }
    }

    #[test]
    fn delta_on_a_spec_session_is_a_fail_not_a_panic() {
        let oracle = crate::objective::Modular::new(vec![1.0; 10]);
        let p = crate::objective::Oracle::partitionable(&oracle).unwrap();
        let delta = crate::objective::PartitionDelta {
            n_global: 10,
            insert: p.extract_partition(&[]),
            delete: vec![0],
        };
        let mut input = Vec::new();
        write_frame(&mut input, &ToWorker::Delta { epoch: 1, delta }.to_value()).unwrap();
        let mut output = Vec::new();
        let mut problem = spec_problem(oracle);
        serve(&mut input.as_slice(), &mut output, &mut problem, 0, WireMode::Json, &mut None)
            .unwrap();
        let mut cursor = output.as_slice();
        let v = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&v).unwrap() {
            FromWorker::Fail(DistError::Backend { message }) => {
                assert!(message.contains("partition shipping"), "{message}")
            }
            other => panic!("expected fail, got {other:?}"),
        }
    }

    #[test]
    fn init_part_leaf_outside_the_shard_is_a_fail_not_a_panic() {
        let oracle = crate::objective::Modular::new(vec![1.0; 20]);
        let p = crate::objective::Oracle::partitionable(&oracle).unwrap();
        let payload = p.extract_partition(&[3, 4]);
        let mut input = Vec::new();
        write_frame(
            &mut input,
            &ToWorker::InitPart { session: 0, machine: 2, threads: 1, payload }.to_value(),
        )
        .unwrap();
        write_frame(
            &mut input,
            &job_frame(NodeParams { n: 20, ..params() }, "problem.k = 1\n").to_value(),
        )
        .unwrap();
        write_frame(&mut input, &ToWorker::Leaf { part: vec![3, 19] }.to_value()).unwrap();
        let mut output = Vec::new();
        serve_session(&mut input.as_slice(), &mut output).unwrap();
        let mut cursor = output.as_slice();
        let _session_ready = read_frame(&mut cursor).unwrap().unwrap();
        let _job_ready = read_frame(&mut cursor).unwrap().unwrap();
        let fail = read_frame(&mut cursor).unwrap().unwrap();
        match FromWorker::from_value(&fail).unwrap() {
            FromWorker::Fail(DistError::Backend { message }) => {
                assert!(message.contains("19"), "{message}");
                assert!(message.contains("shard"), "{message}");
            }
            other => panic!("expected fail, got {other:?}"),
        }
    }

    #[test]
    fn serve_session_rejects_a_hello_first_frame() {
        // Over pipes there is no handshake: the first frame must be Init.
        let mut input = Vec::new();
        write_frame(
            &mut input,
            &ToWorker::Hello { version: super::super::wire::PROTOCOL_VERSION }.to_value(),
        )
        .unwrap();
        let mut output = Vec::new();
        let err = serve_session(&mut input.as_slice(), &mut output).unwrap_err();
        assert!(err.to_string().contains("first frame must be init"), "{err}");
    }

    #[test]
    fn serve_answers_ping_with_pong_at_any_point() {
        let oracle = crate::objective::Modular::new(vec![1.0; 10]);
        let mut input = Vec::new();
        write_frame(&mut input, &ToWorker::Ping.to_value()).unwrap();
        let mut output = Vec::new();
        let mut problem = spec_problem(oracle);
        serve(&mut input.as_slice(), &mut output, &mut problem, 0, WireMode::Json, &mut None)
            .unwrap();
        let mut cursor = output.as_slice();
        let v = read_frame(&mut cursor).unwrap().unwrap();
        assert!(matches!(FromWorker::from_value(&v).unwrap(), FromWorker::Pong));
    }

    #[test]
    fn injected_kill_drops_the_connection_without_replying() {
        // The fault plan kills machine 0 at its leaf: the job is acked,
        // then the worker dies mid-superstep — from the coordinator's
        // side, an EOF where the Step should be (a retryable transport
        // fault, exactly what a crashed host looks like).
        let oracle = crate::objective::Modular::new(vec![1.0; 100]);
        let mut input = Vec::new();
        write_frame(&mut input, &job_frame(params(), "problem.k = 2\n").to_value()).unwrap();
        let part: Vec<ElemId> = (0..100).collect();
        write_frame(&mut input, &ToWorker::Leaf { part }.to_value()).unwrap();
        let mut output = Vec::new();
        let mut problem = spec_problem(oracle);
        let mut plan = Some(FaultPlan::parse("kill:m0@leaf").unwrap());
        let err =
            serve(&mut input.as_slice(), &mut output, &mut problem, 0, WireMode::Json, &mut plan)
                .unwrap_err();
        assert!(err.to_string().contains("fault-injected kill"), "{err}");
        let mut cursor = output.as_slice();
        expect_ready(&mut cursor, 100, "the job was still admitted");
        assert!(
            read_frame(&mut cursor).unwrap().is_none(),
            "no Step may follow the kill"
        );
    }

    #[test]
    fn injected_kill_fires_once_and_filters_by_machine() {
        // The same plan on machine 1 is inert: entries are per-machine.
        let oracle = crate::objective::Modular::new(vec![1.0; 100]);
        let mut input = Vec::new();
        write_frame(&mut input, &job_frame(params(), "problem.k = 2\n").to_value()).unwrap();
        let part: Vec<ElemId> = (0..100).collect();
        write_frame(&mut input, &ToWorker::Leaf { part }.to_value()).unwrap();
        write_frame(&mut input, &ToWorker::JobDone.to_value()).unwrap();
        let mut output = Vec::new();
        let mut problem = spec_problem(oracle);
        let mut plan = Some(FaultPlan::parse("kill:m0@leaf").unwrap());
        serve(&mut input.as_slice(), &mut output, &mut problem, 1, WireMode::Json, &mut plan)
            .unwrap();
        let mut cursor = output.as_slice();
        expect_ready(&mut cursor, 100, "job ack");
        let step = read_frame(&mut cursor).unwrap().unwrap();
        assert!(matches!(FromWorker::from_value(&step).unwrap(), FromWorker::Step(_)));
    }

    #[test]
    fn injected_drop_frame_swallows_the_command_without_replying() {
        // drop-frame at the leaf: the command vanishes, the session lives
        // on — the coordinator's frame timeout is what turns the silence
        // into a transport fault (tcp backend).
        let oracle = crate::objective::Modular::new(vec![1.0; 10]);
        let mut input = Vec::new();
        write_frame(&mut input, &job_frame(params(), "problem.k = 1\n").to_value()).unwrap();
        write_frame(&mut input, &ToWorker::Leaf { part: vec![0, 1] }.to_value()).unwrap();
        write_frame(&mut input, &ToWorker::Ping.to_value()).unwrap();
        let mut output = Vec::new();
        let mut problem = spec_problem(oracle);
        let mut plan = Some(FaultPlan::parse("drop-frame:m0@leaf").unwrap());
        serve(&mut input.as_slice(), &mut output, &mut problem, 0, WireMode::Json, &mut plan)
            .unwrap();
        let mut cursor = output.as_slice();
        let v = read_frame(&mut cursor).unwrap().unwrap();
        assert!(matches!(FromWorker::from_value(&v).unwrap(), FromWorker::Ready { .. }));
        let v = read_frame(&mut cursor).unwrap().unwrap();
        assert!(
            matches!(FromWorker::from_value(&v).unwrap(), FromWorker::Pong),
            "the Leaf was swallowed — the next reply is the Ping's Pong"
        );
    }
}
