//! The simulated distributed runtime underneath the algorithm layer.
//!
//! The paper's algorithms run on `m` MPI ranks; this crate reproduces them
//! on one process by giving each *simulated machine* the resources the
//! paper accounts for, so every §5/§6 measurement has a faithful source:
//!
//! * [`pool`] — the two-level parallel execution subsystem: a persistent
//!   work-stealing pool spawned once per run ([`pool::with_pool`]), the
//!   order-preserving superstep fan-out ([`Executor::map`] /
//!   [`parallel_map`]), and the deterministic intra-task gain-scan fan-out
//!   ([`pool::par_gain_batch`]) that lets the single active accumulation
//!   node borrow the idle cores of its retired siblings.
//! * [`MemoryMeter`] — per-machine memory accounting with a hard limit;
//!   a charge that would exceed [`DistConfig::mem_limit`] aborts the run
//!   with [`DistError::OutOfMemory`], reproducing §6.2's "cannot even hold
//!   the data" regime as a real error.
//! * [`CommModel`] — the α–β (latency + bandwidth) communication model
//!   behind the modeled `comm_secs` of Fig. 6.
//! * [`MachineStats`] — everything one machine did over its lifetime:
//!   gain queries, abstract cost, computation/communication seconds, bytes
//!   shipped, peak memory, highest active tree level.
//! * [`NodeStep`] / [`Trace`] — the per-(machine, level) timeline,
//!   exportable as Chrome-trace JSON (`chrome://tracing` / Perfetto).
//!
//! [`DistConfig::mem_limit`]: crate::algo::DistConfig::mem_limit

pub mod comm;
pub mod error;
pub mod memory;
pub mod pool;
pub mod stats;
pub mod trace;

pub use comm::CommModel;
pub use error::DistError;
pub use memory::MemoryMeter;
pub use pool::{parallel_map, Executor};
pub use stats::MachineStats;
pub use trace::{NodeStep, Trace};
