//! The distributed runtime underneath the algorithm layer.
//!
//! The paper's algorithms run on `m` MPI ranks; this crate reproduces them
//! by giving each *simulated machine* the resources the paper accounts
//! for, so every §5/§6 measurement has a faithful source.  Since PR 3 the
//! engine reaches those resources only through the [`Backend`] trait —
//! superstep fan-out, solution shipping between tree levels, and
//! per-machine meters/stats are backend concerns:
//!
//! * [`backend`] — the [`Backend`] trait, the [`BackendSpec`] selector
//!   (`run.backend` config key / `--backend` flag / `GREEDYML_BACKEND`),
//!   and [`ThreadBackend`]: machines as tasks on the persistent pool,
//!   α–β-modeled communication (the default; `threads = 1` is bit-for-bit
//!   the serial runtime).
//! * [`proc`] — [`ProcessBackend`](proc::ProcessBackend): one forked
//!   worker process per machine (the hidden `greedyml worker`
//!   subcommand), real address spaces, *measured* solution-shipping time.
//! * [`tcp`] — [`TcpBackend`](tcp::TcpBackend): the multi-host transport.
//!   `greedyml serve --bind <addr>` daemons host worker sessions over
//!   TCP; the coordinator places machines onto hosts (`--hosts` /
//!   `run.hosts` / `GREEDYML_HOSTS`), with a version handshake, connect
//!   retry and per-frame timeouts.  Same frames, same session loop, same
//!   bit-identical results — `comm_secs` measured over a real network.
//!
//!   Both remote backends hold **resident-shard sessions** (wire protocol
//!   v3): the dataset ships once when the fleet is established, and any
//!   number of *jobs* — each a full GreedyML run with its own parameters,
//!   constraint and seed — execute against the resident shards before the
//!   session is released.  [`crate::algo::SessionPool`] keeps warm fleets
//!   across `run_dist` calls; sweeps and the job queue ride on it.
//! * [`fault`] — fault tolerance for those remote fleets: the
//!   retryable/fatal error taxonomy's policy side ([`FaultSpec`] /
//!   [`FaultPolicy`]: `--on-fault` / `run.on_fault` /
//!   `GREEDYML_ON_FAULT` — fail, retry with deterministic re-dispatch,
//!   or degrade with [`FaultReport`] accounting), and the seeded
//!   fault-injection harness ([`FaultPlan`], `GREEDYML_FAULT_PLAN`) the
//!   worker side consults so every recovery path is CI-testable without
//!   real crashes.
//! * [`node`] — the per-machine node program (leaf GREEDY, accumulate,
//!   ship) every backend executes bit-identically.
//! * [`wire`] — the length-prefixed JSON frames of the worker protocol
//!   (specified in `docs/wire-protocol.md`), shared by the process and
//!   tcp transports through one coordinator-side driver (`remote`).
//!   Problems reach remote workers in one of two **ship modes**
//!   ([`ShipSpec`]: `--ship` / `run.ship` / `GREEDYML_SHIP`): `spec`
//!   ships a rebuild recipe and every worker regenerates the whole
//!   dataset (O(n) worker memory), while `partition` ships each worker
//!   only its O(n/m) dataset shard
//!   ([`crate::objective::PartitionPayload`]) and solutions travel with
//!   their extracted data — the paper's actual deployment model (§1,
//!   §4.2), where no machine ever holds the full dataset.
//! * [`pool`] — the two-level parallel execution subsystem: a persistent
//!   work-stealing pool spawned once per run ([`pool::with_pool`]), the
//!   order-preserving superstep fan-out ([`Executor::map`] /
//!   [`parallel_map`]), and the deterministic intra-task gain-scan fan-out
//!   ([`pool::par_gain_batch`]) that lets the single active accumulation
//!   node borrow the idle cores of its retired siblings.
//! * [`MemoryMeter`] — per-machine memory accounting with a hard limit;
//!   a charge that would exceed [`DistConfig::mem_limit`] aborts the run
//!   with [`DistError::OutOfMemory`], reproducing §6.2's "cannot even hold
//!   the data" regime as a real error.
//! * [`CommModel`] — the α–β (latency + bandwidth) communication model
//!   behind the thread backend's modeled `comm_secs` (Fig. 6).
//! * [`MachineStats`] — everything one machine did over its lifetime:
//!   gain queries, abstract cost, computation/communication seconds, bytes
//!   shipped, peak memory, highest active tree level.
//! * [`NodeStep`] / [`Trace`] — the per-(machine, level) timeline with
//!   memory watermarks, exportable as Chrome-trace JSON
//!   (`chrome://tracing` / Perfetto).
//!
//! [`DistConfig::mem_limit`]: crate::algo::DistConfig::mem_limit

pub mod backend;
pub mod comm;
pub mod error;
pub mod fault;
pub mod memory;
pub mod node;
pub mod pool;
pub mod proc;
mod remote;
pub mod stats;
pub mod tcp;
pub mod trace;
pub mod wire;

pub use backend::{
    AccumTask, Backend, BackendOutcome, BackendSpec, CoresetSpec, ResolvedBackend, ShipMode,
    ShipPlan, ShipSpec, ThreadBackend, WireMode, WireSpec,
};
pub use comm::CommModel;
pub use error::DistError;
pub use fault::{FaultPlan, FaultPolicy, FaultReport, FaultSpec};
pub use memory::MemoryMeter;
pub use node::{ChildMsg, NodeParams, NodeState, StepReport};
pub use pool::{parallel_map, Executor};
pub use proc::ProcessBackend;
pub use stats::MachineStats;
pub use tcp::TcpBackend;
pub use trace::{NodeStep, Trace};
