//! The per-machine node program of Algorithm 3.1, shared by every backend.
//!
//! A backend decides *where* each simulated machine runs (a pool task, a
//! forked worker process, eventually an MPI rank) — but *what* a machine
//! does during a superstep must be byte-for-byte identical everywhere, or
//! the backends stop being interchangeable.  This module is that single
//! source of truth: [`leaf_step`] (level 0: GREEDY on the machine's
//! partition) and [`accum_step`] (level ℓ ≥ 1: union the child solutions,
//! GREEDY on the union, argmax against the previous solution), both
//! operating on one [`NodeState`] and charging its [`MemoryMeter`].
//!
//! Determinism contract: given the same oracle data, [`NodeParams`] and
//! inputs, both steps produce identical solutions, values, call counts and
//! memory-charge sequences regardless of the backend, the thread count or
//! the host process — the backend-parity suite (`tests/test_backend.rs`)
//! enforces this.

use super::{DistError, MachineStats, MemoryMeter};
use crate::constraint::Constraint;
use crate::greedy::{greedy, GreedyKind, GreedyOutcome};
use crate::objective::Oracle;
use crate::util::rng::Rng;
use crate::util::timer::timed;
use crate::{ElemId, MachineId};

/// The slice of [`DistConfig`](crate::algo::DistConfig) a node program
/// needs — the full config also carries coordinator-side concerns (tree
/// shape, backend choice, comm model) that never cross into a worker.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeParams {
    /// Greedy implementation at every node.
    pub kind: GreedyKind,
    /// Seed of the random tape (also seeds §6.4 added-element draws).
    pub seed: u64,
    /// Ground-set size (bounds the added-element draws).
    pub n: usize,
    /// Per-machine memory limit in bytes (None = unlimited).
    pub mem_limit: Option<u64>,
    /// Evaluate objectives against machine-local ground sets (§6.4).
    pub local_view: bool,
    /// Random extra elements added to every accumulation step (§6.4).
    pub added_elements: usize,
    /// RandGreeDI argmax semantics (compare every child solution).
    pub compare_all_children: bool,
    /// Coreset mode (`--coreset`): leaves sieve their shard down to a
    /// coreset and every level accumulates over coresets only — the meter
    /// charges the coreset, never the full shard (see [`crate::stream`]).
    pub coreset: bool,
}

/// Rolling state of one machine between supersteps.
pub struct NodeState {
    /// Lifetime statistics (id, calls, bytes, peaks).
    pub stats: MachineStats,
    /// The machine's memory budget.
    pub meter: MemoryMeter,
    /// S_prev: the machine's best solution so far.
    pub sol: Vec<ElemId>,
    /// f(S_prev) as evaluated at this machine's last active level.
    pub sol_value: f64,
    /// Bytes currently charged for holding `sol`.
    pub sol_bytes: u64,
    /// Coreset mode: the machine's current coreset (always ⊇ `sol`) — what
    /// crosses the wire instead of the solution alone.  `None` otherwise.
    pub coreset: Option<Vec<ElemId>>,
    /// Bytes currently charged for holding `coreset`.
    pub coreset_bytes: u64,
}

impl NodeState {
    /// Package the held solution for shipping to the parent (Algorithm 3.1
    /// lines 6-7: send & break).  Records the sent bytes in the stats; the
    /// solution is moved out, leaving the node retired.  Under partition
    /// shipping the transport layer attaches the solution's extracted data
    /// shard ([`ChildMsg::data`]) before the message crosses the wire.
    pub fn ship(&mut self) -> ChildMsg {
        // Coreset mode ships the whole coreset (solution included): the
        // wire bytes are the coreset's bytes, still far below a shard.
        let bytes = if self.coreset.is_some() { self.coreset_bytes } else { self.sol_bytes };
        self.stats.bytes_sent += bytes;
        ChildMsg {
            from: self.stats.id,
            sol: std::mem::take(&mut self.sol),
            value: self.sol_value,
            bytes,
            data: None,
            coreset: std::mem::take(&mut self.coreset),
        }
    }
}

/// A child's shipped solution — the one payload that crosses machine
/// boundaries, and therefore the unit the process backend serializes
/// (see [`crate::dist::wire`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ChildMsg {
    /// Sending machine.
    pub from: MachineId,
    /// The child's final solution (always global element ids).
    pub sol: Vec<ElemId>,
    /// f(sol) as the child evaluated it.
    pub value: f64,
    /// Bytes of the shipped solution (Σ `elem_bytes`).
    pub bytes: u64,
    /// Under partition shipping (`--ship partition`), the extracted data
    /// shard for `sol` — the parent holds only its own O(n/m) partition,
    /// so a solution must travel *with* its data (exactly the bytes §4.2
    /// already accounts as `bytes`).  `None` everywhere else: the thread
    /// backend shares one address space and spec-shipped workers hold the
    /// full rebuilt dataset.
    pub data: Option<crate::objective::PartitionPayload>,
    /// Coreset mode: the child's shipped coreset — the parent accumulates
    /// over this (a superset of `sol`) instead of the solution alone, and
    /// under partition shipping `data` covers these elements.  `None`
    /// outside coreset mode.
    pub coreset: Option<Vec<ElemId>>,
}

/// What one machine did during a single superstep — the backend returns
/// one per active node and the engine folds them into level stats and the
/// Chrome trace.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepReport {
    /// The machine that computed.
    pub machine: MachineId,
    /// Tree level of the superstep.
    pub level: u32,
    /// Wall computation seconds within the step.
    pub comp_secs: f64,
    /// Communication seconds (α–β-modeled on the thread backend, measured
    /// wall time on the process backend).
    pub comm_secs: f64,
    /// Gain queries issued within the step.
    pub calls: u64,
    /// Size of the candidate union |D| at an accumulation step (0 at leaves).
    pub accum_elems: usize,
    /// The machine's memory watermark (meter peak) at the end of the step.
    pub peak_mem: u64,
}

/// Level-0 superstep body: GREEDY on the machine's partition.
pub fn leaf_step(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    p: &NodeParams,
    id: MachineId,
    part: &[ElemId],
) -> Result<(NodeState, StepReport), DistError> {
    let mut stats = MachineStats::new(id);
    let mut meter = MemoryMeter::new(p.mem_limit);
    let view = p.local_view.then_some(part);
    if p.coreset {
        // Coreset mode: stream the shard through one sieve pass and keep
        // only the candidate union resident — the meter charges the
        // coreset, never the shard (the streaming memory model; elements
        // outside the live sieves are discarded as they pass).
        let k = constraint.rank();
        let ((cs, out), secs) = timed(|| {
            let cs = crate::stream::shard_coreset(oracle, k, part, view);
            let mut out = greedy(p.kind, oracle, constraint, &cs.elems, view);
            out.calls += cs.best.calls;
            out.cost += cs.best.cost;
            // Greedy over the coreset usually clears the winning sieve, but
            // the (1/2 − ε) certificate belongs to the sieve — keep the max.
            if cs.best.value > out.value {
                out.value = cs.best.value;
                out.solution = cs.best.solution.clone();
            }
            (cs, out)
        });
        let coreset_bytes: u64 =
            cs.elems.iter().map(|&e| oracle.elem_bytes(e) as u64).sum();
        meter.charge(coreset_bytes, id, 0, "coreset")?;
        stats.calls = out.calls;
        stats.cost = out.cost;
        stats.comp_secs = secs;
        let sol_bytes: u64 =
            out.solution.iter().map(|&e| oracle.elem_bytes(e) as u64).sum();
        meter.charge(sol_bytes, id, 0, "local solution")?;
        stats.peak_mem = meter.peak();
        let report = StepReport {
            machine: id,
            level: 0,
            comp_secs: secs,
            comm_secs: 0.0,
            calls: out.calls,
            accum_elems: 0,
            peak_mem: meter.peak(),
        };
        return Ok((
            NodeState {
                stats,
                meter,
                sol: out.solution,
                sol_value: out.value,
                sol_bytes,
                coreset: Some(cs.elems),
                coreset_bytes,
            },
            report,
        ));
    }
    let data_bytes: u64 = part.iter().map(|&e| oracle.elem_bytes(e) as u64).sum();
    meter.charge(data_bytes, id, 0, "partition data")?;
    let (out, secs): (GreedyOutcome, f64) =
        timed(|| greedy(p.kind, oracle, constraint, part, view));
    stats.calls = out.calls;
    stats.cost = out.cost;
    stats.comp_secs = secs;
    let sol_bytes: u64 = out.solution.iter().map(|&e| oracle.elem_bytes(e) as u64).sum();
    meter.charge(sol_bytes, id, 0, "local solution")?;
    // The partition itself is no longer needed once the local solution
    // exists (only S_prev crosses levels).
    meter.release(data_bytes);
    stats.peak_mem = meter.peak();
    let report = StepReport {
        machine: id,
        level: 0,
        comp_secs: secs,
        comm_secs: 0.0,
        calls: out.calls,
        accum_elems: 0,
        peak_mem: meter.peak(),
    };
    Ok((
        NodeState {
            stats,
            meter,
            sol: out.solution,
            sol_value: out.value,
            sol_bytes,
            coreset: None,
            coreset_bytes: 0,
        },
        report,
    ))
}

/// Level ℓ ≥ 1 superstep body: receive child solutions, union with S_prev
/// (plus §6.4 added elements), GREEDY on the union, keep the argmax.
///
/// `comm_secs` is supplied by the backend — the α–β model on the thread
/// backend, the measured solution-shipping wall time on the process
/// backend — so the node program stays identical while the *meaning* of
/// the communication column changes underneath it.
pub fn accum_step(
    oracle: &dyn Oracle,
    constraint: &dyn Constraint,
    p: &NodeParams,
    ctx: &mut NodeState,
    level: u32,
    children: &[ChildMsg],
    comm_secs: f64,
) -> Result<StepReport, DistError> {
    let id = ctx.stats.id;
    // Receive child solutions: memory charges + the backend's comm time.
    let recv_bytes: u64 = children.iter().map(|c| c.bytes).sum();
    ctx.meter.charge(recv_bytes, id, level, "child solutions")?;
    ctx.stats.comm_secs += comm_secs;
    ctx.stats.bytes_received += recv_bytes;

    // D ← S_prev ∪ child solutions (lines 8-13), plus the §6.4 optional
    // random extra elements.  The union is built *distinct*: solutions can
    // overlap across levels, and `sample_added` can re-draw elements
    // already in D — blind concatenation would inflate `accum_elems` and
    // charge the memory meter twice for the same resident element.
    // Membership is tracked in a |D|-sized set, not an O(n) bitmap: the
    // union is O(b·k + added) elements and this runs once per active node
    // per level.
    // Coreset mode: each message carries the child's whole coreset, and
    // this node contributes its own previous coreset — the union stays a
    // coreset union, never bare solutions.
    let own: &[ElemId] = ctx.coreset.as_deref().unwrap_or(&ctx.sol);
    let contrib = |c: &'_ ChildMsg| -> &[ElemId] { c.coreset.as_deref().unwrap_or(&c.sol) };
    let cap = own.len()
        + children.iter().map(|c| contrib(c).len()).sum::<usize>()
        + p.added_elements;
    let mut seen = std::collections::HashSet::with_capacity(cap);
    let mut d: Vec<ElemId> = Vec::with_capacity(cap);
    for &e in own.iter().chain(children.iter().flat_map(|c| contrib(c).iter())) {
        if seen.insert(e) {
            d.push(e);
        }
    }
    let added = sample_added(p, level, id);
    let mut add_bytes = 0u64;
    for &e in &added {
        if seen.insert(e) {
            add_bytes += oracle.elem_bytes(e) as u64;
            d.push(e);
        }
    }
    if add_bytes > 0 {
        ctx.meter.charge(add_bytes, id, level, "added elements")?;
    }
    let accum_elems = d.len();

    // Run GREEDY on the union (line 14).  Coreset mode first re-sieves the
    // union down to this node's own coreset and runs GREEDY over that,
    // keeping the "every message is a coreset" invariant at every level.
    let view = p.local_view.then_some(&d[..]);
    let mut next_coreset: Option<Vec<ElemId>> = None;
    let (out, secs) = if p.coreset {
        let k = constraint.rank();
        let ((cs, out), secs) = timed(|| {
            let cs = crate::stream::shard_coreset(oracle, k, &d, view);
            let mut out = greedy(p.kind, oracle, constraint, &cs.elems, view);
            out.calls += cs.best.calls;
            out.cost += cs.best.cost;
            if cs.best.value > out.value {
                out.value = cs.best.value;
                out.solution = cs.best.solution.clone();
            }
            (cs, out)
        });
        next_coreset = Some(cs.elems);
        (out, secs)
    } else {
        timed(|| greedy(p.kind, oracle, constraint, &d, view))
    };
    let mut calls = out.calls;
    let mut cost = out.cost;

    // Line 15: S_prev ← argmax{f(S), f(S_prev)}.  Under a local view the
    // stored f(S_prev) was computed against different data, so re-evaluate
    // it against this node's view.
    let prev_value = if p.local_view {
        let mut st = oracle.new_state(view);
        for &e in &ctx.sol {
            calls += 1;
            cost += st.call_cost(e);
            st.commit(e);
        }
        st.value()
    } else {
        ctx.sol_value
    };

    let mut best_sol = out.solution;
    let mut best_val = out.value;
    if prev_value > best_val {
        best_val = prev_value;
        best_sol = ctx.sol.clone();
    }
    if p.compare_all_children {
        // RandGreeDI (Algorithm 2.2 line 7): also compare every child's
        // local solution.  Only the argmax winner is cloned — b can be as
        // large as m.
        let mut winner: Option<&ChildMsg> = None;
        for c in children {
            if c.value > best_val {
                best_val = c.value;
                winner = Some(c);
            }
        }
        if let Some(c) = winner {
            best_sol = c.sol.clone();
        }
    }

    ctx.stats.calls += calls;
    ctx.stats.cost += cost;
    ctx.stats.comp_secs += secs;
    ctx.stats.top_level = level;
    ctx.stats.max_accum_elems = ctx.stats.max_accum_elems.max(accum_elems);

    // Swap in the new solution. The merged solution is a subset of D
    // (greedy selects *from* the union), so its data is already charged;
    // release everything D-related first, then re-charge just the retained
    // solution.
    // Coreset mode: the node's next coreset is the re-sieve of D, extended
    // (deterministically, in solution order) so it always covers the
    // retained solution — under partition shipping the parent's parent
    // must receive data for every solution element.
    if let Some(cs) = next_coreset.as_mut() {
        let have: std::collections::HashSet<ElemId> = cs.iter().copied().collect();
        for &e in &best_sol {
            if !have.contains(&e) {
                cs.push(e);
            }
        }
    }
    let new_bytes: u64 = best_sol.iter().map(|&e| oracle.elem_bytes(e) as u64).sum();
    let next_cs_bytes: u64 = next_coreset
        .as_deref()
        .map_or(0, |cs| cs.iter().map(|&e| oracle.elem_bytes(e) as u64).sum());
    ctx.meter.release(recv_bytes + add_bytes + ctx.sol_bytes + ctx.coreset_bytes);
    ctx.meter.charge(new_bytes, id, level, "merged solution")?;
    if next_cs_bytes > 0 {
        ctx.meter.charge(next_cs_bytes, id, level, "coreset")?;
    }
    ctx.sol = best_sol;
    ctx.sol_value = best_val;
    ctx.sol_bytes = new_bytes;
    ctx.coreset = next_coreset;
    ctx.coreset_bytes = next_cs_bytes;
    ctx.stats.peak_mem = ctx.meter.peak();
    Ok(StepReport {
        machine: id,
        level,
        comp_secs: secs,
        comm_secs,
        calls,
        accum_elems,
        peak_mem: ctx.meter.peak(),
    })
}

/// §6.4 "added images": extra random elements mixed into every
/// accumulation step, seeded per (level, node) for reproducibility.
/// `pub(crate)`: the partition-shipping coordinator replays these draws
/// to know which extra elements each machine's Init shard must carry.
pub(crate) fn sample_added(p: &NodeParams, level: u32, id: MachineId) -> Vec<ElemId> {
    if p.added_elements == 0 {
        return Vec::new();
    }
    let count = p.added_elements.min(p.n);
    let mut rng = Rng::split(p.seed ^ 0xADDED, ((level as u64) << 32) | id as u64);
    rng.sample_distinct(p.n, count).into_iter().map(|e| e as ElemId).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraint::Cardinality;
    use crate::objective::KCover;
    use std::sync::Arc;

    fn params(n: usize) -> NodeParams {
        NodeParams {
            kind: GreedyKind::Lazy,
            seed: 7,
            n,
            mem_limit: None,
            local_view: false,
            added_elements: 0,
            compare_all_children: false,
            coreset: false,
        }
    }

    fn oracle(n: usize) -> KCover {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: n,
                num_items: n / 2,
                mean_size: 5.0,
                zipf_s: 0.9,
            },
            11,
        );
        KCover::new(Arc::new(data))
    }

    #[test]
    fn leaf_then_accum_runs_and_reports() {
        let o = oracle(200);
        let c = Cardinality::new(6);
        let p = params(200);
        let part_a: Vec<ElemId> = (0..100).collect();
        let part_b: Vec<ElemId> = (100..200).collect();
        let (mut a, ra) = leaf_step(&o, &c, &p, 0, &part_a).unwrap();
        let (mut b, rb) = leaf_step(&o, &c, &p, 1, &part_b).unwrap();
        assert_eq!(ra.level, 0);
        assert!(ra.calls > 0 && rb.calls > 0);
        assert!(ra.peak_mem > 0);
        let msg = b.ship();
        assert_eq!(msg.from, 1);
        assert_eq!(b.stats.bytes_sent, msg.bytes);
        assert!(b.sol.is_empty(), "shipping moves the solution out");
        let rep = accum_step(&o, &c, &p, &mut a, 1, &[msg], 0.25).unwrap();
        assert_eq!(rep.level, 1);
        assert!(rep.accum_elems >= a.sol.len());
        assert_eq!(a.stats.top_level, 1);
        assert!((a.stats.comm_secs - 0.25).abs() < 1e-12, "comm passes through");
        assert!(a.stats.bytes_received > 0);
    }

    #[test]
    fn steps_are_deterministic_across_invocations() {
        let o = oracle(300);
        let c = Cardinality::new(8);
        let p = NodeParams { added_elements: 20, ..params(300) };
        let part: Vec<ElemId> = (0..150).collect();
        let run = || {
            let (mut s, _) = leaf_step(&o, &c, &p, 0, &part).unwrap();
            let (mut t, _) = leaf_step(&o, &c, &p, 1, &(150..300).collect::<Vec<_>>()).unwrap();
            let msg = t.ship();
            accum_step(&o, &c, &p, &mut s, 1, &[msg], 0.0).unwrap();
            (s.sol.clone(), s.sol_value, s.stats.calls)
        };
        let (sol1, v1, c1) = run();
        let (sol2, v2, c2) = run();
        assert_eq!(sol1, sol2);
        assert_eq!(v1.to_bits(), v2.to_bits());
        assert_eq!(c1, c2);
    }

    #[test]
    fn coreset_steps_ship_coresets_and_charge_less_memory() {
        let o = oracle(800);
        let c = Cardinality::new(6);
        let full = params(800);
        let p = NodeParams { coreset: true, ..params(800) };
        let part_a: Vec<ElemId> = (0..400).collect();
        let part_b: Vec<ElemId> = (400..800).collect();

        let (full_a, full_ra) = leaf_step(&o, &c, &full, 0, &part_a).unwrap();
        let (mut a, ra) = leaf_step(&o, &c, &p, 0, &part_a).unwrap();
        let (mut b, _) = leaf_step(&o, &c, &p, 1, &part_b).unwrap();
        // The coreset covers the solution and the meter charged it, not the
        // shard — peak memory must come in strictly below the full leaf.
        let cs = a.coreset.clone().expect("coreset mode keeps a coreset");
        assert!(a.sol.iter().all(|e| cs.contains(e)), "solution must be inside the coreset");
        assert!(cs.len() < part_a.len(), "coreset should shrink the shard");
        assert!(ra.peak_mem < full_ra.peak_mem, "coreset {} vs full {}", ra.peak_mem, full_ra.peak_mem);
        drop(full_a);

        // Shipping moves the coreset; the wire bytes are the coreset's.
        let msg = b.ship();
        let shipped = msg.coreset.clone().expect("coreset crosses the wire");
        assert!(msg.sol.iter().all(|e| shipped.contains(e)));
        assert!(msg.bytes >= msg.sol.iter().map(|&e| o.elem_bytes(e) as u64).sum::<u64>());

        let rep = accum_step(&o, &c, &p, &mut a, 1, &[msg], 0.0).unwrap();
        assert!(rep.accum_elems <= cs.len() + shipped.len());
        let merged = a.coreset.clone().expect("accumulation keeps the invariant");
        assert!(a.sol.iter().all(|e| merged.contains(e)));
        assert!(a.sol_value > 0.0);
    }

    #[test]
    fn leaf_oom_carries_partition_data_label() {
        let o = oracle(200);
        let c = Cardinality::new(4);
        let p = NodeParams { mem_limit: Some(8), ..params(200) };
        let part: Vec<ElemId> = (0..100).collect();
        match leaf_step(&o, &c, &p, 3, &part).unwrap_err() {
            DistError::OutOfMemory { machine, level, label, .. } => {
                assert_eq!(machine, 3);
                assert_eq!(level, 0);
                assert_eq!(label, "partition data");
            }
            other => panic!("expected OOM, got {other:?}"),
        }
    }
}
