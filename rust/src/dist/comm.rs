//! The α–β communication cost model.
//!
//! The simulator runs on one machine, so communication time is *modeled*,
//! not measured: receiving a message of `b` bytes costs
//! `latency + b · secs_per_byte` (the classic α–β model), and a gather of
//! several child solutions in one BSP superstep costs the sum over
//! messages — the root of a RandGreeDI tree pays `m − 1` latencies plus
//! the bandwidth term for every child solution, while a binary GreedyML
//! tree pays one message per level.  This is exactly the divergence the
//! Fig. 6 strong-scaling bench plots.

/// Latency + bandwidth model for solution shipping.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CommModel {
    /// Fixed per-message cost α in seconds.
    pub latency_secs: f64,
    /// Inverse bandwidth β in seconds per byte.
    pub secs_per_byte: f64,
}

impl Default for CommModel {
    /// A commodity-cluster default: 10 µs latency, 1 GiB/s bandwidth.
    fn default() -> Self {
        Self { latency_secs: 1e-5, secs_per_byte: 1.0 / (1u64 << 30) as f64 }
    }
}

impl CommModel {
    /// Build from explicit α (seconds) and β (seconds per byte).
    pub fn new(latency_secs: f64, secs_per_byte: f64) -> Self {
        Self { latency_secs, secs_per_byte }
    }

    /// A free network (modeled comm time identically zero).
    pub fn zero() -> Self {
        Self { latency_secs: 0.0, secs_per_byte: 0.0 }
    }

    /// Modeled seconds for one node to gather one message per child in a
    /// BSP superstep; `msg_bytes` holds the per-child message sizes.  Zero
    /// children gather in zero time.
    pub fn gather_time(&self, msg_bytes: &[u64]) -> f64 {
        msg_bytes
            .iter()
            .map(|&b| self.latency_secs + self.secs_per_byte * b as f64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_children_cost_nothing() {
        assert_eq!(CommModel::default().gather_time(&[]), 0.0);
        assert_eq!(CommModel::zero().gather_time(&[]), 0.0);
    }

    #[test]
    fn monotone_in_bytes() {
        let c = CommModel::default();
        let mut prev = 0.0;
        for bytes in [0u64, 1, 1024, 1 << 20, 1 << 30] {
            let t = c.gather_time(&[bytes]);
            assert!(t > prev || (bytes == 0 && t > 0.0), "bytes {bytes}: {t} vs {prev}");
            prev = t;
        }
    }

    #[test]
    fn monotone_in_message_count() {
        let c = CommModel::default();
        let one = c.gather_time(&[4096]);
        let two = c.gather_time(&[4096, 4096]);
        let three = c.gather_time(&[4096, 4096, 4096]);
        assert!(two > one && three > two);
        assert!((two - 2.0 * one).abs() < 1e-12, "gather is additive over messages");
    }

    #[test]
    fn latency_dominates_small_messages() {
        let c = CommModel::default();
        // A 1-byte message is essentially one latency...
        assert!((c.gather_time(&[1]) - c.latency_secs) / c.latency_secs < 1e-3);
        // ...while a 1 GiB message is essentially one bandwidth second.
        let big = c.gather_time(&[1 << 30]);
        assert!((big - 1.0).abs() < 1e-3, "{big}");
    }
}
