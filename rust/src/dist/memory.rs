//! Per-machine memory accounting.
//!
//! Every simulated machine owns one [`MemoryMeter`].  The algorithm layer
//! charges it for whatever the machine must hold at that moment — its data
//! partition, its current solution, received child solutions, §6.4 added
//! elements — and releases what it drops.  A charge that would push usage
//! past the configured limit fails the run with
//! [`DistError::OutOfMemory`], tagged with the machine, tree level and a
//! label for the allocation, so the §6.2 memory experiments can assert on
//! exactly where a configuration dies.

use super::DistError;
use crate::MachineId;

/// Charge/release byte accounting with an optional hard limit.
#[derive(Clone, Debug)]
pub struct MemoryMeter {
    limit: Option<u64>,
    in_use: u64,
    peak: u64,
}

impl MemoryMeter {
    /// New meter; `limit = None` means unlimited.
    pub fn new(limit: Option<u64>) -> Self {
        Self { limit, in_use: 0, peak: 0 }
    }

    /// Charge `bytes`.  Fails (leaving usage unchanged) if the new total
    /// would exceed the limit; `machine`, `level` and `label` describe the
    /// allocation for the error.
    pub fn charge(
        &mut self,
        bytes: u64,
        machine: MachineId,
        level: u32,
        label: &'static str,
    ) -> Result<(), DistError> {
        let new_total = self.in_use.saturating_add(bytes);
        if let Some(limit) = self.limit {
            if new_total > limit {
                return Err(DistError::OutOfMemory {
                    machine,
                    level,
                    label: label.to_string(),
                    requested: bytes,
                    in_use: self.in_use,
                    limit,
                });
            }
        }
        self.in_use = new_total;
        self.peak = self.peak.max(self.in_use);
        Ok(())
    }

    /// Release `bytes` (saturating: releasing more than is held clamps to
    /// zero rather than underflowing).
    pub fn release(&mut self, bytes: u64) {
        self.in_use = self.in_use.saturating_sub(bytes);
    }

    /// Bytes currently held.
    pub fn in_use(&self) -> u64 {
        self.in_use
    }

    /// Highest usage ever reached.
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// The configured limit.
    pub fn limit(&self) -> Option<u64> {
        self.limit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_release_peak_accounting() {
        let mut m = MemoryMeter::new(None);
        m.charge(100, 0, 0, "a").unwrap();
        m.charge(50, 0, 0, "b").unwrap();
        assert_eq!(m.in_use(), 150);
        assert_eq!(m.peak(), 150);
        m.release(120);
        assert_eq!(m.in_use(), 30);
        assert_eq!(m.peak(), 150, "peak must not decrease on release");
        m.charge(40, 0, 0, "c").unwrap();
        assert_eq!(m.in_use(), 70);
        assert_eq!(m.peak(), 150, "new usage below old peak keeps the peak");
    }

    #[test]
    fn limit_allows_exact_fit_but_not_one_more_byte() {
        let mut m = MemoryMeter::new(Some(100));
        m.charge(100, 0, 0, "fits").unwrap();
        assert!(m.charge(1, 0, 0, "overflow").is_err());
        assert_eq!(m.in_use(), 100, "failed charge must not change usage");
    }

    #[test]
    fn oom_error_carries_machine_level_label() {
        let mut m = MemoryMeter::new(Some(10));
        let err = m.charge(64, 3, 2, "child solutions").unwrap_err();
        match err {
            DistError::OutOfMemory { machine, level, label, requested, in_use, limit } => {
                assert_eq!(machine, 3);
                assert_eq!(level, 2);
                assert_eq!(label, "child solutions");
                assert_eq!(requested, 64);
                assert_eq!(in_use, 0);
                assert_eq!(limit, 10);
            }
            other => panic!("expected OutOfMemory, got {other:?}"),
        }
    }

    #[test]
    fn release_saturates_at_zero() {
        let mut m = MemoryMeter::new(None);
        m.charge(5, 0, 0, "x").unwrap();
        m.release(1000);
        assert_eq!(m.in_use(), 0);
    }

    #[test]
    fn charging_after_release_can_oom_again() {
        let mut m = MemoryMeter::new(Some(100));
        m.charge(80, 1, 0, "data").unwrap();
        m.release(80);
        m.charge(90, 1, 1, "solutions").unwrap();
        assert!(m.charge(20, 1, 1, "more").is_err());
        assert_eq!(m.peak(), 90);
    }
}
