//! Experiment configuration system.
//!
//! Experiments are described by a flat, typed key–value config that can be
//! loaded from a file (simple `key = value` / `[section]` TOML subset),
//! overridden from the CLI (`--set key=value`), and round-tripped into
//! reports so every result records exactly how it was produced.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed configuration: section-qualified keys (`section.key`) → raw
/// string values, with typed accessors.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, String>,
}

/// Config error (missing key / bad type / parse failure).
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigError {
    pub msg: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.msg)
    }
}
impl std::error::Error for ConfigError {}

fn err<T>(msg: impl Into<String>) -> Result<T, ConfigError> {
    Err(ConfigError { msg: msg.into() })
}

impl Config {
    /// Empty config.
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse the TOML subset: `[section]` headers, `key = value` lines,
    /// `#` comments, blank lines.  Values keep their raw string form;
    /// quoting (single or double) is stripped.  A `#` inside a quoted
    /// value is part of the value, not a comment — the process backend
    /// ships problem specs through this parser, and paths may contain
    /// `#`.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let Some(name) = name.strip_suffix(']') else {
                    return err(format!("line {}: unterminated section header", lineno + 1));
                };
                section = name.trim().to_string();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return err(format!("line {}: expected 'key = value'", lineno + 1));
            };
            let key = key.trim();
            if key.is_empty() {
                return err(format!("line {}: empty key", lineno + 1));
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            cfg.values.insert(full, unquote(value.trim()).to_string());
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError { msg: format!("cannot read {path}: {e}") })?;
        Self::parse(&text)
    }

    /// Set a raw value (CLI `--set key=value` overrides).
    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Apply a `key=value` override string.
    pub fn set_kv(&mut self, kv: &str) -> Result<(), ConfigError> {
        let Some((k, v)) = kv.split_once('=') else {
            return err(format!("override '{kv}' is not key=value"));
        };
        self.set(k.trim(), unquote(v.trim()));
        Ok(())
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Whether a key exists.
    pub fn contains(&self, key: &str) -> bool {
        self.values.contains_key(key)
    }

    /// Required string.
    pub fn str(&self, key: &str) -> Result<&str, ConfigError> {
        self.get(key).ok_or(ConfigError { msg: format!("missing key '{key}'") })
    }

    /// String with default.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Required u64 (accepts `_` separators and `k`/`m`/`g` suffixes,
    /// e.g. `mem_limit = 100m`, `k = 32_000`).
    pub fn u64(&self, key: &str) -> Result<u64, ConfigError> {
        parse_u64(self.str(key)?).map_err(|m| ConfigError { msg: format!("key '{key}': {m}") })
    }

    /// u64 with default.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => parse_u64(s).map_err(|m| ConfigError { msg: format!("key '{key}': {m}") }),
        }
    }

    /// Required f64.
    pub fn f64(&self, key: &str) -> Result<f64, ConfigError> {
        self.str(key)?
            .parse()
            .map_err(|e| ConfigError { msg: format!("key '{key}': {e}") })
    }

    /// f64 with default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| ConfigError { msg: format!("key '{key}': {e}") }),
        }
    }

    /// bool with default (`true/false/1/0/yes/no`).
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool, ConfigError> {
        match self.get(key) {
            None => Ok(default),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(other) => err(format!("key '{key}': '{other}' is not a bool")),
        }
    }

    /// Comma-separated u64 list, e.g. `ks = 1000, 2000, 4000`.
    pub fn u64_list(&self, key: &str) -> Result<Vec<u64>, ConfigError> {
        self.str(key)?
            .split(',')
            .map(|s| {
                parse_u64(s.trim())
                    .map_err(|m| ConfigError { msg: format!("key '{key}': {m}") })
            })
            .collect()
    }

    /// All keys under a section prefix (for report round-tripping).
    pub fn section(&self, prefix: &str) -> impl Iterator<Item = (&str, &str)> {
        let want = format!("{prefix}.");
        self.values
            .iter()
            .filter(move |(k, _)| k.starts_with(&want))
            .map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// Dump as a JSON object (experiment provenance in reports).
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::Obj(
            self.values
                .iter()
                .map(|(k, v)| (k.clone(), crate::util::json::Json::Str(v.clone())))
                .collect(),
        )
    }
}

/// Cut a trailing `# comment` off a config line.  A `#` inside a *quoted
/// value* — the value text starts with `'` or `"` right after the `=` —
/// is literal content; a stray apostrophe inside an unquoted value
/// (`don't`) must NOT swallow a trailing comment, so quoting is only
/// honored at the start of the value.
fn strip_comment(line: &str) -> &str {
    let Some(hash) = line.find('#') else { return line };
    // No '=' before the '#': section header, blank, or whole-line comment.
    let Some(eq) = line.find('=').filter(|&e| e < hash) else {
        return &line[..hash];
    };
    let value = line[eq + 1..].trim_start();
    let quote = match value.chars().next() {
        Some(c @ ('"' | '\'')) => c,
        _ => return &line[..hash],
    };
    let open = line.len() - value.len();
    match line[open + quote.len_utf8()..].find(quote) {
        Some(rel) => {
            let close = open + quote.len_utf8() + rel + quote.len_utf8();
            match line[close..].find('#') {
                Some(h) => &line[..close + h],
                None => line,
            }
        }
        // Unterminated quote: fall back to the plain cut.
        None => &line[..hash],
    }
}

fn unquote(s: &str) -> &str {
    let b = s.as_bytes();
    if b.len() >= 2
        && ((b[0] == b'"' && b[b.len() - 1] == b'"')
            || (b[0] == b'\'' && b[b.len() - 1] == b'\''))
    {
        &s[1..s.len() - 1]
    } else {
        s
    }
}

/// Parse a u64 with `_` separators and optional k/m/g (×1e3/1e6/1e9) or
/// kb/mb/gb (×2^10/2^20/2^30) suffix.
pub fn parse_u64(s: &str) -> Result<u64, String> {
    let s = s.trim().to_ascii_lowercase();
    let (digits, mult) = if let Some(d) = s.strip_suffix("kb") {
        (d.to_string(), 1u64 << 10)
    } else if let Some(d) = s.strip_suffix("mb") {
        (d.to_string(), 1u64 << 20)
    } else if let Some(d) = s.strip_suffix("gb") {
        (d.to_string(), 1u64 << 30)
    } else if let Some(d) = s.strip_suffix('k') {
        (d.to_string(), 1_000)
    } else if let Some(d) = s.strip_suffix('m') {
        (d.to_string(), 1_000_000)
    } else if let Some(d) = s.strip_suffix('g') {
        (d.to_string(), 1_000_000_000)
    } else {
        (s.clone(), 1)
    };
    let digits: String = digits.chars().filter(|&c| c != '_').collect();
    let base: u64 = digits
        .trim()
        .parse()
        .map_err(|_| format!("'{s}' is not an unsigned integer"))?;
    base.checked_mul(mult).ok_or_else(|| format!("'{s}' overflows u64"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let cfg = Config::parse(
            r#"
            # experiment
            name = "fig4"
            [tree]
            machines = 32
            branching = 8
            [problem]
            k = 32_000
            mem_limit = 100mb
            frac = 0.25
            ks = 1k, 2k, 4k
            verbose = yes
            "#,
        )
        .unwrap();
        assert_eq!(cfg.str("name").unwrap(), "fig4");
        assert_eq!(cfg.u64("tree.machines").unwrap(), 32);
        assert_eq!(cfg.u64("problem.k").unwrap(), 32_000);
        assert_eq!(cfg.u64("problem.mem_limit").unwrap(), 100 << 20);
        assert!((cfg.f64("problem.frac").unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(cfg.u64_list("problem.ks").unwrap(), vec![1000, 2000, 4000]);
        assert!(cfg.bool_or("problem.verbose", false).unwrap());
        assert_eq!(cfg.u64_or("tree.levels", 1).unwrap(), 1);
    }

    #[test]
    fn overrides_win() {
        let mut cfg = Config::parse("[a]\nx = 1\n").unwrap();
        cfg.set_kv("a.x=2").unwrap();
        assert_eq!(cfg.u64("a.x").unwrap(), 2);
        assert!(cfg.set_kv("nonsense").is_err());
    }

    #[test]
    fn suffixes() {
        assert_eq!(parse_u64("128k").unwrap(), 128_000);
        assert_eq!(parse_u64("2m").unwrap(), 2_000_000);
        assert_eq!(parse_u64("1g").unwrap(), 1_000_000_000);
        assert_eq!(parse_u64("4kb").unwrap(), 4096);
        assert_eq!(parse_u64("3_000").unwrap(), 3000);
        assert!(parse_u64("abc").is_err());
        assert!(parse_u64("99999999999g").is_err());
    }

    #[test]
    fn hash_inside_quoted_value_is_not_a_comment() {
        let cfg = Config::parse(
            "a = \"data/graph#v2.txt\"   # real comment\nb = 'x#y'\nc = plain # cut\n\
             d = don't # cut\n# standalone\ne = 1",
        )
        .unwrap();
        assert_eq!(cfg.str("a").unwrap(), "data/graph#v2.txt");
        assert_eq!(cfg.str("b").unwrap(), "x#y");
        assert_eq!(cfg.str("c").unwrap(), "plain");
        assert_eq!(cfg.str("d").unwrap(), "don't", "mid-value apostrophe is not a quote");
        assert_eq!(cfg.u64("e").unwrap(), 1);
    }

    #[test]
    fn bad_lines_error() {
        assert!(Config::parse("[oops\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
        assert!(Config::parse("= 3\n").is_err());
    }

    #[test]
    fn section_iter_and_json() {
        let cfg = Config::parse("[t]\na = 1\nb = x\n[u]\nc = 2\n").unwrap();
        let t: Vec<_> = cfg.section("t").collect();
        assert_eq!(t, vec![("t.a", "1"), ("t.b", "x")]);
        let j = cfg.to_json();
        assert_eq!(j.get("u.c").unwrap().as_str(), Some("2"));
    }

    #[test]
    fn missing_keys_report_name() {
        let cfg = Config::new();
        let e = cfg.u64("tree.machines").unwrap_err();
        assert!(e.msg.contains("tree.machines"));
    }
}
