//! Packed fixed-universe bitsets with popcount.
//!
//! The k-cover and k-dominating-set oracles reduce every marginal-gain
//! evaluation to `popcount(candidate & !covered)` over the universe — the
//! single hottest operation in those experiments (§4.2: cost per call is
//! `O(δ)`).  We pack the universe into `u64` words so one word covers 64
//! elements and `count_ones()` maps to a hardware `popcnt`.

/// A fixed-size set over the universe `0..len`, packed 64 elements per word.
#[derive(Clone, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl std::fmt::Debug for BitSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BitSet(len={}, count={})", self.len, self.count())
    }
}

impl BitSet {
    /// Empty set over universe `0..len`.
    pub fn new(len: usize) -> Self {
        Self { words: vec![0; len.div_ceil(64)], len }
    }

    /// Build from an iterator of member indices.
    pub fn from_iter<I: IntoIterator<Item = usize>>(len: usize, it: I) -> Self {
        let mut s = Self::new(len);
        for i in it {
            s.insert(i);
        }
        s
    }

    /// Universe size.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if the universe is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Backing words (read-only; used by the PJRT bridge to ship bitmaps).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Number of words.
    pub fn num_words(&self) -> usize {
        self.words.len()
    }

    /// Insert element `i`. Returns true if it was newly inserted.
    #[inline]
    pub fn insert(&mut self, i: usize) -> bool {
        debug_assert!(i < self.len, "index {i} out of universe {}", self.len);
        let w = &mut self.words[i >> 6];
        let mask = 1u64 << (i & 63);
        let fresh = *w & mask == 0;
        *w |= mask;
        fresh
    }

    /// Remove element `i`. Returns true if it was present.
    #[inline]
    pub fn remove(&mut self, i: usize) -> bool {
        let w = &mut self.words[i >> 6];
        let mask = 1u64 << (i & 63);
        let present = *w & mask != 0;
        *w &= !mask;
        present
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        self.words[i >> 6] & (1u64 << (i & 63)) != 0
    }

    /// Cardinality (hardware popcount per word).
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Clear all bits, keeping the allocation.
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// `self |= other`.
    pub fn union_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// `|self ∪ other| − |self|` without materialising the union — the
    /// marginal *coverage gain* of `other` against covered set `self`.
    #[inline]
    pub fn union_gain(&self, other: &BitSet) -> usize {
        debug_assert_eq!(self.len, other.len);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(&a, &b)| (b & !a).count_ones() as usize)
            .sum()
    }

    /// Same as [`union_gain`](Self::union_gain) but `other` given as a
    /// sparse index list — the hot path when adjacency lists are short
    /// (road networks, avg degree ≈ 2.4) and scanning δ indices beats
    /// scanning `len/64` words.
    #[inline]
    pub fn union_gain_sparse(&self, others: &[crate::ElemId]) -> usize {
        let mut gain = 0usize;
        for &i in others {
            gain += (!self.contains(i as usize)) as usize;
        }
        gain
    }

    /// Insert all indices of a sparse list; returns how many were new.
    pub fn insert_sparse(&mut self, others: &[crate::ElemId]) -> usize {
        let mut added = 0usize;
        for &i in others {
            added += self.insert(i as usize) as usize;
        }
        added
    }

    /// Iterate over set members in increasing order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let tz = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some((wi << 6) | tz)
                }
            })
        })
    }

    /// Approximate heap footprint in bytes (memory-limit accounting).
    pub fn mem_bytes(&self) -> usize {
        self.words.len() * 8 + std::mem::size_of::<Self>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_gain(covered: &std::collections::HashSet<usize>, cand: &[usize]) -> usize {
        cand.iter().filter(|i| !covered.contains(i)).count()
    }

    #[test]
    fn insert_contains_remove() {
        let mut s = BitSet::new(130);
        assert!(s.insert(0));
        assert!(s.insert(63));
        assert!(s.insert(64));
        assert!(s.insert(129));
        assert!(!s.insert(64), "double insert must report false");
        assert!(s.contains(0) && s.contains(63) && s.contains(64) && s.contains(129));
        assert!(!s.contains(1) && !s.contains(128));
        assert_eq!(s.count(), 4);
        assert!(s.remove(63));
        assert!(!s.remove(63));
        assert_eq!(s.count(), 3);
    }

    #[test]
    fn union_gain_matches_naive() {
        let mut rng = crate::util::rng::Rng::new(21);
        for _ in 0..50 {
            let n = 1 + rng.below(500) as usize;
            let mut covered = BitSet::new(n);
            let mut covered_naive = std::collections::HashSet::new();
            for _ in 0..rng.below(n as u64 + 1) {
                let i = rng.below(n as u64) as usize;
                covered.insert(i);
                covered_naive.insert(i);
            }
            let cand: Vec<usize> = (0..rng.below(64))
                .map(|_| rng.below(n as u64) as usize)
                .collect::<std::collections::HashSet<_>>()
                .into_iter()
                .collect();
            let cand_set = BitSet::from_iter(n, cand.iter().copied());
            let sparse: Vec<u32> = cand.iter().map(|&i| i as u32).collect();
            let want = naive_gain(&covered_naive, &cand);
            assert_eq!(covered.union_gain(&cand_set), want);
            assert_eq!(covered.union_gain_sparse(&sparse), want);
        }
    }

    #[test]
    fn union_with_and_iter() {
        let a = BitSet::from_iter(200, [1, 5, 64, 127, 199]);
        let b = BitSet::from_iter(200, [5, 6, 128]);
        let mut u = a.clone();
        u.union_with(&b);
        let members: Vec<usize> = u.iter().collect();
        assert_eq!(members, vec![1, 5, 6, 64, 127, 128, 199]);
        assert_eq!(u.count(), 7);
    }

    #[test]
    fn insert_sparse_counts_new_only() {
        let mut s = BitSet::new(100);
        assert_eq!(s.insert_sparse(&[1, 2, 3]), 3);
        assert_eq!(s.insert_sparse(&[3, 4]), 1);
        assert_eq!(s.count(), 4);
    }

    #[test]
    fn clear_keeps_len() {
        let mut s = BitSet::from_iter(70, [0, 69]);
        s.clear();
        assert_eq!(s.count(), 0);
        assert_eq!(s.len(), 70);
    }

    #[test]
    fn zero_len_universe() {
        let s = BitSet::new(0);
        assert_eq!(s.count(), 0);
        assert!(s.is_empty());
        assert_eq!(s.iter().count(), 0);
    }
}
