//! Minimal JSON value model, emitter and parser.
//!
//! Used for (a) the AOT artifact manifest written by `python/compile/aot.py`
//! and read by `rust/src/runtime/manifest.rs`, and (b) machine-readable
//! experiment reports.  `serde`/`serde_json` are not reachable offline, so
//! this is a small, strict, allocation-conscious implementation covering the
//! JSON we produce and consume (objects, arrays, strings with escapes,
//! numbers, booleans, null — no surrogate-pair edge cases beyond `\uXXXX`).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic — useful for golden tests and diffable reports.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Convenience object builder.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Borrow as object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Borrow as array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Borrow as string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as u64 (rejects negatives / fractions).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// Boolean value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialise with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes at once.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "roundtrip of {src}");
        }
    }

    #[test]
    fn parse_nested() {
        let src = r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn pretty_and_compact_agree() {
        let v = Json::obj([
            ("n", Json::from(12u64)),
            ("xs", Json::Arr(vec![Json::from(1.5), Json::from("s")])),
        ]);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("quote\" slash\\ nl\n tab\t ctrl\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn integers_emit_without_decimal() {
        assert_eq!(Json::from(42u64).to_string(), "42");
        assert_eq!(Json::from(2.5).to_string(), "2.5");
    }

    #[test]
    fn u64_accessor() {
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Json::parse("-7").unwrap().as_u64(), None);
        assert_eq!(Json::parse("7.5").unwrap().as_u64(), None);
    }
}
