//! Deterministic pseudo-random number generation.
//!
//! The paper's only source of randomness is the *random tape* `r_W` that maps
//! every element of the ground set to a leaf machine (§3, "Randomness").  All
//! expectation results are over this tape, and the analysis couples
//! executions by *reusing* the same tape — so reproducible, seedable,
//! splittable randomness is a first-class requirement here, not a
//! convenience.  No external RNG crate is reachable offline, so we implement
//! SplitMix64 (seeding / splitting) and xoshiro256** (bulk generation), the
//! same constructions used by `rand`'s `SmallRng`.

/// SplitMix64: a tiny, high-quality 64-bit mixer.  Used to seed and to
/// derive independent streams ("splits") from a master seed.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast all-purpose generator (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (the construction recommended by the authors:
    /// never seed xoshiro with correlated words).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream for substream `index` — e.g. one stream
    /// per simulated machine — without sharing mutable state.
    pub fn split(seed: u64, index: u64) -> Self {
        // Mix the index through SplitMix64 twice to decorrelate low indices.
        let mut sm = SplitMix64::new(seed ^ 0xA076_1D64_78BD_642F_u64.wrapping_mul(index + 1));
        let mixed = sm.next_u64();
        Self::new(mixed)
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits (upper half — the better bits of xoshiro**).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift
    /// rejection method (unbiased, one division in the rare rejection path).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "below(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (no `rand_distr` offline).  Generates
    /// pairs; we keep it allocation-free and simply discard the second value
    /// (cheap relative to everything around it).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > f64::MIN_POSITIVE {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `count` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n, "cannot sample {count} from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(count);
        let mut out = Vec::with_capacity(count);
        for j in (n - count)..n {
            let t = self.below(j as u64 + 1) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

/// The paper's *random tape* `r_W`: a fixed, replayable assignment of every
/// ground-set element to one of `m` leaf machines (uniform i.i.d.).
///
/// The GreedyML analysis (Lemma 4.1 note) requires coupling runs on `V`,
/// `V ∪ {e}` and `V ∪ B` over the *same* tape; materialising the tape as a
/// vector makes that coupling literal: the assignment of an element never
/// depends on which other elements are present.
#[derive(Clone, Debug)]
pub struct RandomTape {
    assignment: Vec<u32>,
    machines: u32,
    seed: u64,
}

impl RandomTape {
    /// Draw a tape for `n` elements over `m` machines from `seed`.
    pub fn draw(n: usize, machines: u32, seed: u64) -> Self {
        assert!(machines > 0, "need at least one machine");
        let mut rng = Rng::new(seed);
        let assignment = (0..n).map(|_| rng.below(machines as u64) as u32).collect();
        Self { assignment, machines, seed }
    }

    /// Machine holding element `e`.
    #[inline]
    pub fn machine_of(&self, e: usize) -> u32 {
        self.assignment[e]
    }

    /// Number of elements covered by the tape.
    pub fn len(&self) -> usize {
        self.assignment.len()
    }

    /// True if the tape covers no elements.
    pub fn is_empty(&self) -> bool {
        self.assignment.is_empty()
    }

    /// Number of machines.
    pub fn machines(&self) -> u32 {
        self.machines
    }

    /// Seed the tape was drawn from (for logging / replay).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Materialise the partition `{P_0, …, P_{m-1}}` as element-id lists.
    /// This is Line 2 of Algorithm 3.1.
    pub fn partition(&self) -> Vec<Vec<crate::ElemId>> {
        let mut parts: Vec<Vec<crate::ElemId>> = vec![Vec::new(); self.machines as usize];
        // Pre-size to avoid repeated growth on large tapes.
        let expect = self.assignment.len() / self.machines as usize + 1;
        for p in &mut parts {
            p.reserve(expect);
        }
        for (e, &m) in self.assignment.iter().enumerate() {
            parts[m as usize].push(e as crate::ElemId);
        }
        parts
    }

    /// Partition of an arbitrary subset of elements (used when re-running
    /// the algorithm on `V ∪ B` with the same tape, as in Lemma 4.1).
    pub fn partition_of(&self, elems: &[crate::ElemId]) -> Vec<Vec<crate::ElemId>> {
        let mut parts: Vec<Vec<crate::ElemId>> = vec![Vec::new(); self.machines as usize];
        for &e in elems {
            parts[self.assignment[e as usize] as usize].push(e);
        }
        parts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values for seed 1234567 from the public-domain C impl.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn rng_deterministic_and_distinct_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::split(42, 0);
        let mut d = Rng::split(42, 1);
        let same = (0..64).filter(|_| c.next_u64() == d.next_u64()).count();
        assert_eq!(same, 0, "split streams should not collide");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let x = rng.below(10) as usize;
            counts[x] += 1;
        }
        for &c in &counts {
            // Expected 10_000; allow generous 10% slack.
            assert!((9_000..=11_000).contains(&c), "bucket count {c} out of range");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(99);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(5);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "normal mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "normal var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<u32> = (0..1000).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>(), "shuffle left input unchanged");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Rng::new(11);
        let s = rng.sample_distinct(100, 30);
        assert_eq!(s.len(), 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30, "samples must be distinct");
        assert!(s.iter().all(|&x| x < 100));
    }

    #[test]
    fn tape_partition_is_a_partition() {
        let tape = RandomTape::draw(10_000, 16, 123);
        let parts = tape.partition();
        assert_eq!(parts.len(), 16);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 10_000);
        let mut seen = vec![false; 10_000];
        for (m, part) in parts.iter().enumerate() {
            for &e in part {
                assert!(!seen[e as usize], "element {e} in two parts");
                seen[e as usize] = true;
                assert_eq!(tape.machine_of(e as usize), m as u32);
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn tape_balance_is_plausible() {
        let tape = RandomTape::draw(160_000, 16, 77);
        for part in tape.partition() {
            // Expected 10_000, sd ≈ 97; 6 sigma window.
            assert!((9_400..=10_600).contains(&part.len()), "part size {}", part.len());
        }
    }

    #[test]
    fn tape_subset_partition_consistent() {
        let tape = RandomTape::draw(1000, 8, 9);
        let subset: Vec<u32> = (0..1000).step_by(3).collect();
        let parts = tape.partition_of(&subset);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, subset.len());
        for (m, part) in parts.iter().enumerate() {
            for &e in part {
                assert_eq!(tape.machine_of(e as usize), m as u32);
            }
        }
    }
}
