//! Summary statistics for experiment reporting.
//!
//! The paper reports *geometric means* over datasets and repetitions (§6:
//! "We repeat each experiment six times and report the geometric mean"), so
//! geomean is the primary aggregate here; the bench harness additionally
//! uses median/percentiles for robust timing.

/// Arithmetic mean. Returns 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean via log-sum (avoids overflow). All inputs must be > 0.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0), "geomean needs positive inputs");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Sample standard deviation (n−1 denominator). 0 for fewer than 2 points.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) with linear interpolation; sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min of a slice (0 for empty).
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Max of a slice (0 for empty).
pub fn max(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Running summary that does not retain samples — used by long experiment
/// loops where we only need count/mean/min/max.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Running {
    /// New empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, sum: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add a sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Count of samples.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Sample stddev.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum_sq - self.n as f64 * m * m) / (self.n - 1) as f64).max(0.0).sqrt()
    }

    /// Minimum sample (∞ when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum sample (−∞ when empty).
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn geomean_matches_hand_computation() {
        let xs = [1.0, 8.0];
        assert!((geomean(&xs) - 2.8284271247461903).abs() < 1e-12);
        let ys = [4.0, 4.0, 4.0];
        assert!((geomean(&ys) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_do_not_panic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
    }

    #[test]
    fn running_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), xs.len() as u64);
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.stddev() - stddev(&xs)).abs() < 1e-9);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 9.0);
    }
}
