//! Wall-clock timing helpers used by the metrics layer and the bench
//! harness.

use std::time::{Duration, Instant};

/// A simple start/stop accumulator: total time across many intervals.
/// The distributed simulator uses one per machine to separate *computation*
/// time from *communication* time (the stacked bars of Fig. 6).
#[derive(Clone, Debug, Default)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Stopwatch {
    /// New, stopped, zeroed.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start (no-op if already running).
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    /// Stop and accumulate (no-op if not running).
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
        }
    }

    /// Accumulated time (not counting a currently-running interval).
    pub fn total(&self) -> Duration {
        self.total
    }

    /// Accumulated seconds.
    pub fn secs(&self) -> f64 {
        self.total.as_secs_f64()
    }

    /// Time a closure, accumulating its duration, and return its value.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.total += t0.elapsed();
        out
    }
}

/// Time a closure once; returns (value, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(sw.secs() >= 0.009, "accumulated {}", sw.secs());
    }

    #[test]
    fn start_stop_idempotent() {
        let mut sw = Stopwatch::new();
        sw.stop(); // no-op
        sw.start();
        sw.start(); // no-op
        std::thread::sleep(Duration::from_millis(2));
        sw.stop();
        let t = sw.secs();
        sw.stop(); // no-op
        assert_eq!(sw.secs(), t);
        assert!(t > 0.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
