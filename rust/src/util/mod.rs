//! Substrate utilities: PRNG/random tape, packed bitsets, JSON, config,
//! statistics and timing.  These replace external crates (`rand`, `serde`,
//! `serde_json`, `toml`) that are unavailable in the offline build
//! environment — see DESIGN.md §2.

pub mod bitset;
pub mod config;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

/// Format a byte count human-readably (reports and memory-limit errors).
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a large count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(1024), "1.00 KiB");
        assert_eq!(fmt_bytes(100 << 20), "100.00 MiB");
        assert_eq!(fmt_bytes(3 << 30), "3.00 GiB");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1,000");
        assert_eq!(fmt_count(65_608_366), "65,608,366");
    }
}
