//! The accumulation tree `T(m, L, b)` (§3).
//!
//! A complete b-ary tree with `m` leaves (machines).  Node identity is the
//! pair `(level, id)`: leaves are `(0, 0..m)`, and a node exists at level
//! `ℓ ≥ 1` iff `id mod b^ℓ == 0`.  Each internal node receives the lowest
//! id of its children; the root is `(L, 0)` with `L = ceil(log_b m)`.
//! Closed forms from Algorithm 3.1:
//!
//! * `level(id, b) = max{ ℓ : id mod b^ℓ == 0 }` (capped at L; id 0 → L),
//! * `parent(id, ℓ) = b^ℓ · ⌊id / b^ℓ⌋` — the parent a node at level ℓ−1
//!   sends to when entering level ℓ,
//! * `child(id, ℓ, j) = id + j · b^{ℓ−1}` for `j = 0..b` (bounded by m).
//!
//! When `m` is not a power of b, at most one node per level has fewer than
//! b children (Fig. 2, b=3 and b=4 examples).

use crate::MachineId;

/// Immutable description of an accumulation tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AccumulationTree {
    m: u32,
    b: u32,
    levels: u32,
}

impl AccumulationTree {
    /// Build the tree for `m` machines with branching factor `b`.
    /// `L = ceil(log_b m)`; `m = 1` gives the degenerate single-node tree
    /// (L = 0).  RandGreeDI is exactly `AccumulationTree::new(m, m)` (L=1).
    pub fn new(m: u32, b: u32) -> Self {
        assert!(m >= 1, "need at least one machine");
        assert!(b >= 2 || m == 1, "branching factor must be ≥ 2");
        let levels = if m == 1 { 0 } else { ceil_log(m, b) };
        Self { m, b, levels }
    }

    /// The RandGreeDI tree: a single accumulation level over all machines.
    pub fn randgreedi(m: u32) -> Self {
        if m == 1 {
            Self::new(1, 2)
        } else {
            Self::new(m, m)
        }
    }

    /// Number of machines (leaves).
    pub fn machines(&self) -> u32 {
        self.m
    }

    /// Branching factor.
    pub fn branching(&self) -> u32 {
        self.b
    }

    /// Number of accumulation levels L (root level).
    pub fn levels(&self) -> u32 {
        self.levels
    }

    /// `b^ℓ`, saturating (safe for ℓ up to the root of any u32-sized tree).
    fn pow(&self, l: u32) -> u64 {
        (self.b as u64).saturating_pow(l)
    }

    /// Highest level at which machine `id` is active:
    /// `level(id, b) = max{ ℓ : id mod b^ℓ == 0 }`, capped at L.
    pub fn level_of(&self, id: MachineId) -> u32 {
        debug_assert!(id < self.m);
        if id == 0 {
            return self.levels;
        }
        let mut l = 0;
        while l < self.levels && (id as u64) % self.pow(l + 1) == 0 {
            l += 1;
        }
        l
    }

    /// Does node `(level, id)` exist in the tree?
    pub fn is_node(&self, level: u32, id: MachineId) -> bool {
        id < self.m && level <= self.level_of(id)
    }

    /// Parent machine id for a node entering level `level` (Algorithm 3.1:
    /// `parent(id, i) = b^i · ⌊id / b^i⌋`).
    pub fn parent(&self, id: MachineId, level: u32) -> MachineId {
        let p = self.pow(level);
        ((id as u64 / p) * p) as MachineId
    }

    /// Children (machine ids) of internal node `(level, id)` — the nodes at
    /// `level − 1` that send to it, including `id` itself (j = 0).
    pub fn children(&self, level: u32, id: MachineId) -> Vec<MachineId> {
        debug_assert!(level >= 1 && self.is_node(level, id));
        let step = self.pow(level - 1);
        (0..self.b as u64)
            .map(|j| id as u64 + j * step)
            .take_while(|&c| c < self.m as u64)
            .map(|c| c as MachineId)
            .collect()
    }

    /// All node ids active at `level` (ascending).
    pub fn nodes_at_level(&self, level: u32) -> Vec<MachineId> {
        let step = self.pow(level);
        (0..self.m as u64)
            .step_by(step.min(u64::from(u32::MAX)) as usize)
            .map(|id| id as MachineId)
            .collect()
    }

    /// Number of internal (accumulation) nodes in the whole tree.
    pub fn num_internal_nodes(&self) -> usize {
        (1..=self.levels).map(|l| self.nodes_at_level(l).len()).sum()
    }

    /// Maximum fan-in of any internal node — bounds the accumulation
    /// memory: a parent holds at most `fan_in · k` solution elements
    /// (`k·⌈m^{1/L}⌉` in Table 1).
    pub fn max_fan_in(&self) -> u32 {
        (1..=self.levels)
            .flat_map(|l| {
                self.nodes_at_level(l)
                    .into_iter()
                    .map(move |id| self.children(l, id).len() as u32)
            })
            .max()
            .unwrap_or(0)
    }

    /// Render the tree as text (Fig. 2 style), for `greedyml tree --show`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "T(m={}, L={}, b={}) — {} internal node(s)\n",
            self.m,
            self.levels,
            self.b,
            self.num_internal_nodes()
        ));
        for l in (0..=self.levels).rev() {
            out.push_str(&format!("level {l}: "));
            let nodes = self.nodes_at_level(l);
            let labels: Vec<String> = nodes.iter().map(|id| format!("({l},{id})")).collect();
            out.push_str(&labels.join(" "));
            out.push('\n');
        }
        out
    }
}

/// `ceil(log_b(m))` for integers (`m ≥ 1, b ≥ 2`).
fn ceil_log(m: u32, b: u32) -> u32 {
    let mut l = 0u32;
    let mut cap = 1u64;
    while cap < m as u64 {
        cap *= b as u64;
        l += 1;
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log_basics() {
        assert_eq!(ceil_log(1, 2), 0);
        assert_eq!(ceil_log(2, 2), 1);
        assert_eq!(ceil_log(8, 2), 3);
        assert_eq!(ceil_log(9, 2), 4);
        assert_eq!(ceil_log(8, 3), 2);
        assert_eq!(ceil_log(8, 8), 1);
        assert_eq!(ceil_log(27, 3), 3);
    }

    /// Fig. 2 top-left: m=8, b=2 → L=3.
    #[test]
    fn fig2_b2() {
        let t = AccumulationTree::new(8, 2);
        assert_eq!(t.levels(), 3);
        assert_eq!(t.nodes_at_level(0), (0..8).collect::<Vec<_>>());
        assert_eq!(t.nodes_at_level(1), vec![0, 2, 4, 6]);
        assert_eq!(t.nodes_at_level(2), vec![0, 4]);
        assert_eq!(t.nodes_at_level(3), vec![0]);
        assert_eq!(t.children(1, 6), vec![6, 7]);
        assert_eq!(t.children(3, 0), vec![0, 4]);
        assert_eq!(t.parent(7, 1), 6);
        assert_eq!(t.parent(6, 2), 4);
        assert_eq!(t.parent(4, 3), 0);
        assert_eq!(t.max_fan_in(), 2);
    }

    /// Fig. 2 top-right: m=8, b=3 → L=2; the last node in level 1 has
    /// only 2 children.
    #[test]
    fn fig2_b3() {
        let t = AccumulationTree::new(8, 3);
        assert_eq!(t.levels(), 2);
        assert_eq!(t.nodes_at_level(1), vec![0, 3, 6]);
        assert_eq!(t.children(1, 0), vec![0, 1, 2]);
        assert_eq!(t.children(1, 6), vec![6, 7], "truncated arity");
        assert_eq!(t.children(2, 0), vec![0, 3, 6]);
    }

    /// Fig. 2 bottom-left: m=8, b=4 → L=2; the root has 2 children.
    #[test]
    fn fig2_b4() {
        let t = AccumulationTree::new(8, 4);
        assert_eq!(t.levels(), 2);
        assert_eq!(t.nodes_at_level(1), vec![0, 4]);
        assert_eq!(t.children(2, 0), vec![0, 4]);
        assert_eq!(t.children(1, 4), vec![4, 5, 6, 7]);
    }

    /// Fig. 2 bottom-right: m=8, b=8 → RandGreeDI, L=1.
    #[test]
    fn fig2_b8_is_randgreedi() {
        let t = AccumulationTree::new(8, 8);
        assert_eq!(t.levels(), 1);
        assert_eq!(t.children(1, 0), (0..8).collect::<Vec<_>>());
        assert_eq!(t, AccumulationTree::randgreedi(8));
    }

    #[test]
    fn level_of_matches_definition() {
        let t = AccumulationTree::new(16, 2);
        // level(id) = trailing b-ary zeros, capped at L; id 0 → L.
        assert_eq!(t.level_of(0), 4);
        assert_eq!(t.level_of(1), 0);
        assert_eq!(t.level_of(2), 1);
        assert_eq!(t.level_of(4), 2);
        assert_eq!(t.level_of(8), 3);
        assert_eq!(t.level_of(12), 2);
    }

    #[test]
    fn parent_child_inverse_property() {
        use crate::check::{ensure, forall, pair, Gen};
        forall(
            "tree parent/child inverse",
            300,
            pair(Gen::u64(1..65), Gen::u64(2..9)),
            |&(m, b)| {
                let m = m as u32;
                let b = b as u32;
                if m == 1 {
                    return Ok(());
                }
                let t = AccumulationTree::new(m, b);
                for l in 1..=t.levels() {
                    for id in t.nodes_at_level(l) {
                        let kids = t.children(l, id);
                        ensure(!kids.is_empty(), format!("node ({l},{id}) childless"))?;
                        ensure(kids[0] == id, "first child must be the node itself")?;
                        for &c in &kids {
                            ensure(
                                t.parent(c, l) == id,
                                format!("parent({c},{l}) != {id} in T({m},{b})"),
                            )?;
                            ensure(t.is_node(l - 1, c), "child is not a node one level down")?;
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn every_leaf_reaches_root() {
        use crate::check::{ensure, forall, pair, Gen};
        forall(
            "leaf-to-root chain",
            200,
            pair(Gen::u64(1..129), Gen::u64(2..17)),
            |&(m, b)| {
                let (m, b) = (m as u32, b as u32);
                if m == 1 {
                    return Ok(());
                }
                let t = AccumulationTree::new(m, b);
                for leaf in 0..m {
                    let mut id = leaf;
                    for l in 1..=t.levels() {
                        id = t.parent(id, l);
                    }
                    ensure(id == 0, format!("leaf {leaf} ended at {id}, not root"))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn at_most_one_truncated_node_per_level() {
        use crate::check::{ensure, forall, pair, Gen};
        forall(
            "≤1 short-arity node per level",
            200,
            pair(Gen::u64(2..200), Gen::u64(2..9)),
            |&(m, b)| {
                let (m, b) = (m as u32, b as u32);
                let t = AccumulationTree::new(m, b);
                for l in 1..=t.levels() {
                    let short = t
                        .nodes_at_level(l)
                        .into_iter()
                        .filter(|&id| (t.children(l, id).len() as u32) < b)
                        .count();
                    ensure(
                        short <= 1,
                        format!("level {l} of T({m},{b}) has {short} short nodes"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn children_partition_level_below() {
        // The children sets of all nodes at level ℓ exactly partition the
        // nodes at level ℓ−1.
        for (m, b) in [(8u32, 2u32), (8, 3), (8, 4), (13, 3), (100, 4), (9, 2)] {
            let t = AccumulationTree::new(m, b);
            for l in 1..=t.levels() {
                let mut collected: Vec<u32> = t
                    .nodes_at_level(l)
                    .into_iter()
                    .flat_map(|id| t.children(l, id))
                    .collect();
                collected.sort_unstable();
                assert_eq!(collected, t.nodes_at_level(l - 1), "T({m},{b}) level {l}");
            }
        }
    }

    #[test]
    fn render_mentions_all_levels() {
        let t = AccumulationTree::new(8, 2);
        let s = t.render();
        for l in 0..=3 {
            assert!(s.contains(&format!("level {l}:")), "missing level {l} in:\n{s}");
        }
    }

    #[test]
    fn single_machine_tree() {
        let t = AccumulationTree::new(1, 2);
        assert_eq!(t.levels(), 0);
        assert_eq!(t.level_of(0), 0);
        assert_eq!(t.nodes_at_level(0), vec![0]);
        assert_eq!(t.num_internal_nodes(), 0);
    }
}
