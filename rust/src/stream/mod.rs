//! Streaming subsystem: sieve coresets for the dist layer and live-dataset
//! deltas for resident fleets.
//!
//! Two halves, both aimed at ROADMAP's "distributed streaming / dynamic
//! data" workload:
//!
//! * [`coreset`] — in `--coreset` mode every leaf runs the Sieve-Streaming
//!   pass ([`crate::greedy::sieve`]) over its shard and the multi-level
//!   accumulation tree operates on the resulting O(k·log(k)/ε) coresets
//!   instead of whole O(n/m) shards (Lucic et al., "Horizontally Scalable
//!   Submodular Maximization", PAPERS.md).  Accumulation bytes and peak
//!   worker memory shrink accordingly; the `(1/2 − ε)` certificate of the
//!   winning sieve survives because the coreset contains its solution.
//!
//! * [`delta`] / [`live`] — a live dataset evolves by
//!   [`crate::objective::PartitionDelta`]s (global-id inserts with data
//!   rows, plus deletes).  [`live::LiveProblem`] tracks the authoritative
//!   post-delta oracle and a monotone **epoch** counter; resident fleets
//!   are advanced in place over the wire-v6 `delta` frame instead of
//!   re-shipping shards, and the session/job layers key warm state by
//!   (dataset fingerprint, epoch) so a stale fleet can never serve
//!   pre-delta data.

pub mod coreset;
pub mod delta;
pub mod live;

pub use coreset::{coreset_size_bound, shard_coreset, CORESET_EPSILON};
pub use delta::{deltas_to_value, owner_of, parse_deltas, split_delta};
pub use live::LiveProblem;
