//! Leaf-level sieve coresets for the distributed tree.
//!
//! In coreset mode a leaf does not hand its whole shard to GREEDY: it runs
//! one Sieve-Streaming pass over the shard and keeps the union of every
//! sieve's candidate set ([`crate::greedy::sieve_coreset`]).  That union is
//! the machine's *coreset*: it contains the winning sieve's `(1/2 − ε)`
//! solution, it is at most [`coreset_size_bound`] elements, and it is the
//! only thing the machine ships up the accumulation tree.  Interior nodes
//! re-sieve the union of their children's coresets, so the invariant —
//! "every message is a coreset" — holds at every level, and the root's
//! greedy over its coreset is the answer.

use crate::constraint::Cardinality;
use crate::greedy::{sieve_coreset, SieveCoreset};
use crate::objective::Oracle;
use crate::ElemId;

/// Accuracy of the sieve threshold grid used by coreset mode.  Fixed (not
/// a knob): every node in the tree must build the same grid for the
/// re-sieve invariant to be meaningful, and 0.1 keeps the grid small while
/// staying well inside the empirical band the property tests pin.
pub const CORESET_EPSILON: f64 = 0.1;

/// Upper bound on a coreset's size: the sieve grid instantiates thresholds
/// `(1+ε)^j` inside `[m/(2k), 2km]` — at most `log_{1+ε}(4k²) + 1` of them,
/// plus one retained threshold beyond each edge as the max singleton `m`
/// grows — and each sieve commits at most `k` elements.  This is the
/// `O(k·log(k)/ε)` memory bound of Badanidiyuru et al. (KDD 2014), which
/// the property suite asserts against real instances.
pub fn coreset_size_bound(k: usize, epsilon: f64) -> usize {
    assert!(epsilon > 0.0 && epsilon < 1.0);
    let kf = k.max(1) as f64;
    let grid = ((4.0 * kf * kf).ln() / (1.0 + epsilon).ln()).ceil() + 3.0;
    (kf * grid) as usize
}

/// Sieve one shard (or one union of child coresets) down to its coreset
/// with the mode's fixed [`CORESET_EPSILON`].
pub fn shard_coreset(
    oracle: &dyn Oracle,
    k: usize,
    shard: &[ElemId],
    view: Option<&[ElemId]>,
) -> SieveCoreset {
    sieve_coreset(oracle, &Cardinality::new(k), shard, view, CORESET_EPSILON)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::greedy::greedy_lazy;
    use crate::objective::KCover;
    use std::sync::Arc;

    fn cover(n: usize, seed: u64) -> KCover {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: n,
                num_items: n / 2,
                mean_size: 6.0,
                zipf_s: 0.9,
            },
            seed,
        );
        KCover::new(Arc::new(data))
    }

    #[test]
    fn shard_coresets_respect_the_size_bound() {
        let o = cover(2000, 11);
        for k in [5usize, 15, 40] {
            let stream: Vec<u32> = (0..2000).collect();
            let cs = shard_coreset(&o, k, &stream, None);
            let bound = coreset_size_bound(k, CORESET_EPSILON);
            assert!(
                cs.elems.len() <= bound,
                "k={k}: coreset {} exceeds bound {bound}",
                cs.elems.len()
            );
            assert!(!cs.elems.is_empty());
        }
    }

    #[test]
    fn resieving_a_union_of_coresets_keeps_the_value_band() {
        // Two leaves sieve disjoint halves; the parent re-sieves the union
        // and runs greedy over its coreset — within the (1/2 − ε) band of
        // greedy over the whole ground set.
        let o = cover(1600, 4);
        let k = 18;
        let left: Vec<u32> = (0..800).collect();
        let right: Vec<u32> = (800..1600).collect();
        let a = shard_coreset(&o, k, &left, None);
        let b = shard_coreset(&o, k, &right, None);
        let mut union = a.elems.clone();
        union.extend_from_slice(&b.elems);
        let parent = shard_coreset(&o, k, &union, None);
        let bound = coreset_size_bound(k, CORESET_EPSILON);
        assert!(parent.elems.len() <= bound);

        let c = Cardinality::new(k);
        let over = greedy_lazy(&o, &c, &parent.elems, None);
        let all: Vec<u32> = (0..1600).collect();
        let full = greedy_lazy(&o, &c, &all, None);
        assert!(
            over.value >= (0.5 - CORESET_EPSILON) * full.value,
            "coreset value {} vs full {}",
            over.value,
            full.value
        );
    }

    #[test]
    fn bound_is_monotone_in_k() {
        let mut prev = 0;
        for k in 1..30 {
            let b = coreset_size_bound(k, CORESET_EPSILON);
            assert!(b >= prev, "bound not monotone at k={k}");
            prev = b;
        }
    }
}
