//! The delta-file grammar and per-machine delta splitting.
//!
//! A delta file is JSON: either a bare array of
//! [`PartitionDelta::to_value`] objects or `{"deltas": [...]}`.  Each entry
//! advances the dataset by one epoch, in order.  Example (modular family):
//!
//! ```json
//! [
//!   {
//!     "n_global": 8,
//!     "insert": { "n": 8, "elems": [6, 7],
//!                 "data": { "family": "modular", "weights": [1.5, 0.5] } },
//!     "delete": [2]
//!   }
//! ]
//! ```
//!
//! The coordinator never ships a global delta verbatim: it splits it into
//! per-machine *sub-deltas* — every machine receives the full delete list
//! (a worker ignores deletes it does not hold; some other machine owns
//! them) and exactly the inserts the deterministic [`owner_of`] tape
//! assigns to it.  The split is a function of `(seed, element id)` only,
//! so replaying the same delta file over the same seed always lands every
//! insert on the same machine — the coupling that makes an incremental
//! re-solve bit-identical to a cold run on the post-delta dataset.

use crate::objective::{PartitionDelta, PartitionOracle};
use crate::util::rng::Rng;
use crate::ElemId;
use serde_json::Value;

/// Parse a delta file (bare array or `{"deltas": [...]}`).
pub fn parse_deltas(text: &str) -> Result<Vec<PartitionDelta>, String> {
    let v: Value =
        serde_json::from_str(text).map_err(|e| format!("delta file: invalid JSON: {e}"))?;
    let arr = match &v {
        Value::Array(a) => a.as_slice(),
        Value::Object(o) => match o.get("deltas") {
            Some(Value::Array(a)) => a.as_slice(),
            _ => {
                return Err(
                    "delta file: object form needs a \"deltas\" array field".to_string()
                )
            }
        },
        _ => return Err("delta file: expected an array or {\"deltas\": [...]}".to_string()),
    };
    arr.iter()
        .enumerate()
        .map(|(i, d)| PartitionDelta::from_value(d).map_err(|e| format!("deltas[{i}]: {e}")))
        .collect()
}

/// Encode a delta sequence in the bare-array file form.
pub fn deltas_to_value(deltas: &[PartitionDelta]) -> Value {
    Value::Array(deltas.iter().map(|d| d.to_value()).collect())
}

/// The machine that owns inserted element `e` — an extension of the
/// paper's random tape `r_W` to elements born after the initial draw.
/// Depends only on `(seed, e)`, never on arrival order or machine load.
pub fn owner_of(e: ElemId, machines: u32, seed: u64) -> u32 {
    assert!(machines > 0, "need at least one machine");
    Rng::split(seed ^ 0xD17A_0000, e as u64).below(machines as u64) as u32
}

/// Split a global delta into one sub-delta per machine (see module docs).
pub fn split_delta(
    delta: &PartitionDelta,
    machines: u32,
    seed: u64,
) -> Result<Vec<PartitionDelta>, String> {
    delta.validate()?;
    let tmp = PartitionOracle::from_payload(&delta.insert)?;
    let mut per: Vec<Vec<ElemId>> = vec![Vec::new(); machines as usize];
    for &e in &delta.insert.elems {
        per[owner_of(e, machines, seed) as usize].push(e);
    }
    per.into_iter()
        .map(|ids| {
            Ok(PartitionDelta {
                n_global: delta.n_global,
                insert: tmp.extract(&ids)?,
                delete: delta.delete.clone(),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::{Oracle, Partitionable};

    fn sample_delta() -> PartitionDelta {
        // A 12-element modular ground set; the delta inserts the two newest
        // ids and deletes two old ones.
        let weights = crate::objective::Modular::new((0..12).map(|i| i as f64).collect());
        let insert = weights.partitionable().unwrap().extract_partition(&[10, 11]);
        PartitionDelta { n_global: 12, insert, delete: vec![3, 4] }
    }

    #[test]
    fn file_grammar_roundtrips_both_forms() {
        let deltas = vec![sample_delta()];
        let bare = serde_json::to_string(&deltas_to_value(&deltas)).unwrap();
        let wrapped = serde_json::to_string(&serde_json::json!({ "deltas": deltas_to_value(&deltas) }))
            .unwrap();
        for text in [bare, wrapped] {
            let parsed = parse_deltas(&text).unwrap();
            assert_eq!(parsed, deltas);
        }
    }

    #[test]
    fn malformed_files_are_rejected_with_context() {
        assert!(parse_deltas("not json").unwrap_err().contains("invalid JSON"));
        assert!(parse_deltas("{\"nope\": []}").unwrap_err().contains("deltas"));
        assert!(parse_deltas("42").unwrap_err().contains("expected an array"));
        let err = parse_deltas("[{\"n_global\": 1}]").unwrap_err();
        assert!(err.contains("deltas[0]"), "{err}");
    }

    #[test]
    fn ownership_is_a_pure_function_of_seed_and_id() {
        for e in 0..200u32 {
            let a = owner_of(e, 4, 7);
            assert_eq!(a, owner_of(e, 4, 7));
            assert!(a < 4);
        }
        // Different seeds shuffle assignments (coupling is per-seed).
        let moved = (0..200u32).filter(|&e| owner_of(e, 4, 7) != owner_of(e, 4, 8)).count();
        assert!(moved > 50, "only {moved} of 200 moved across seeds");
    }

    #[test]
    fn split_partitions_inserts_and_replicates_deletes() {
        let d = sample_delta();
        let subs = split_delta(&d, 3, 42).unwrap();
        assert_eq!(subs.len(), 3);
        let mut seen: Vec<ElemId> = Vec::new();
        for (m, sub) in subs.iter().enumerate() {
            assert_eq!(sub.n_global, d.n_global);
            assert_eq!(sub.delete, d.delete);
            for &e in &sub.insert.elems {
                assert_eq!(owner_of(e, 3, 42), m as u32);
            }
            seen.extend_from_slice(&sub.insert.elems);
        }
        seen.sort_unstable();
        let mut want = d.insert.elems.clone();
        want.sort_unstable();
        assert_eq!(seen, want, "sub-deltas must partition the insert set");
        // Sub-delta data rows match the global delta's rows.
        let tmp = PartitionOracle::from_payload(&d.insert).unwrap();
        for sub in &subs {
            if !sub.insert.is_empty() {
                let re = tmp.extract(&sub.insert.elems).unwrap();
                assert_eq!(re, sub.insert);
            }
        }
    }
}
