//! The coordinator's view of a live dataset: authoritative post-delta
//! oracle, monotone epoch counter, and the partition replay that couples
//! incremental fleet updates with cold from-scratch runs.
//!
//! A [`LiveProblem`] starts at epoch 0 as a full-ground-set
//! [`PartitionOracle`] built from any [`Partitionable`] objective.  Every
//! [`LiveProblem::apply`] advances the epoch by one: the oracle compacts
//! deletes and appends inserts exactly the way a worker's
//! [`PartitionOracle::apply_delta`] does, so the coordinator's dataset and
//! every machine's shard stay structurally identical — the property that
//! makes an incremental re-solve bit-identical to shipping the post-delta
//! dataset cold.

use std::collections::HashSet;

use crate::objective::{Oracle, PartitionDelta, PartitionOracle, PartitionPayload};
use crate::ElemId;

use super::delta::{owner_of, split_delta};

/// A dataset that evolves by [`PartitionDelta`]s, plus its epoch.
pub struct LiveProblem {
    oracle: PartitionOracle,
    n0: usize,
    epoch: u64,
    history: Vec<PartitionDelta>,
}

impl LiveProblem {
    /// Snapshot a base objective as the epoch-0 live dataset.
    pub fn new(base: &dyn Oracle) -> Result<Self, String> {
        let p = base.partitionable().ok_or_else(|| {
            format!(
                "{}: objective does not support partition shipping (required for live deltas)",
                base.name()
            )
        })?;
        let all: Vec<ElemId> = (0..base.n() as u32).collect();
        let oracle = PartitionOracle::from_payload(&p.extract_partition(&all))?;
        Ok(Self::from_oracle(oracle))
    }

    /// Adopt an already-built facade (possibly holding only part of its
    /// global id space) as the epoch-0 dataset.
    pub fn from_oracle(oracle: PartitionOracle) -> Self {
        let n0 = oracle.n();
        Self { oracle, n0, epoch: 0, history: Vec::new() }
    }

    /// Current epoch (number of deltas applied).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Ground-set size at epoch 0 (what the leaf random tape is drawn on).
    pub fn n0(&self) -> usize {
        self.n0
    }

    /// The authoritative post-delta oracle.  It is itself [`Partitionable`],
    /// so a cold run on the current dataset solves over exactly this.
    pub fn oracle(&self) -> &PartitionOracle {
        &self.oracle
    }

    /// Deltas applied so far, oldest first.
    pub fn history(&self) -> &[PartitionDelta] {
        &self.history
    }

    /// Apply one delta: compacts deletes, ingests inserts, bumps the epoch.
    pub fn apply(&mut self, delta: &PartitionDelta) -> Result<(), String> {
        self.oracle.apply_delta(delta)?;
        self.history.push(delta.clone());
        self.epoch += 1;
        Ok(())
    }

    /// Replay the delta history over the epoch-0 leaf partition `base`
    /// (drawn on `n0` elements): deletes drop out of whichever part held
    /// them, inserts append to the machine the [`owner_of`] tape assigns.
    /// Pure in `(base, seed, history)` — a warm fleet advanced in place and
    /// a cold fleet shipped from scratch agree on every machine's part.
    pub fn parts_for(&self, base: Vec<Vec<ElemId>>, seed: u64) -> Vec<Vec<ElemId>> {
        let machines = base.len() as u32;
        let mut parts = base;
        for d in &self.history {
            if !d.delete.is_empty() {
                let dels: HashSet<ElemId> = d.delete.iter().copied().collect();
                for p in parts.iter_mut() {
                    p.retain(|e| !dels.contains(e));
                }
            }
            for &e in &d.insert.elems {
                parts[owner_of(e, machines, seed) as usize].push(e);
            }
        }
        parts
    }

    /// Per-machine sub-deltas for one global delta (see
    /// [`super::delta::split_delta`]).
    pub fn sub_deltas(
        &self,
        delta: &PartitionDelta,
        machines: u32,
        seed: u64,
    ) -> Result<Vec<PartitionDelta>, String> {
        split_delta(delta, machines, seed)
    }

    /// Extract one machine's shard payload at the current epoch.
    pub fn shard(&self, part: &[ElemId]) -> Result<PartitionPayload, String> {
        self.oracle.extract(part)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::KCover;
    use crate::util::rng::RandomTape;
    use std::sync::Arc;

    fn cover(n: usize, seed: u64) -> KCover {
        let data = crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: n,
                num_items: n / 2,
                mean_size: 5.0,
                zipf_s: 0.8,
            },
            seed,
        );
        KCover::new(Arc::new(data))
    }

    /// A delta over `grown`: insert `fresh` (already present in `grown`'s
    /// ground set, beyond the live oracle's horizon), delete `dels`.
    fn delta_from(grown: &KCover, n_global: usize, fresh: &[ElemId], dels: &[ElemId]) -> PartitionDelta {
        let mut insert = grown.partitionable().unwrap().extract_partition(fresh);
        insert.n_global = n_global;
        PartitionDelta { n_global, insert, delete: dels.to_vec() }
    }

    #[test]
    fn apply_advances_epoch_and_tracks_survivors() {
        // The "grown" dataset has 70 sets; the live problem starts from the
        // first 60 and the delta brings in two of the last ten.
        let grown = cover(70, 5);
        let base_ids: Vec<ElemId> = (0..60).collect();
        let base = PartitionOracle::from_payload(
            &grown.partitionable().unwrap().extract_partition(&base_ids),
        )
        .unwrap();
        let mut live = LiveProblem::from_oracle(base);
        assert_eq!(live.epoch(), 0);
        assert_eq!(live.n0(), 70);

        let d = delta_from(&grown, 70, &[60, 61], &[2, 9]);
        live.apply(&d).unwrap();
        assert_eq!(live.epoch(), 1);
        assert_eq!(live.history().len(), 1);
        assert!(live.oracle().holds(60) && live.oracle().holds(61));
        assert!(!live.oracle().holds(2) && !live.oracle().holds(9));
    }

    #[test]
    fn replayed_parts_match_the_live_oracle_shard_for_shard() {
        let grown = cover(80, 7);
        let base_ids: Vec<ElemId> = (0..64).collect();
        let base = PartitionOracle::from_payload(
            &grown.partitionable().unwrap().extract_partition(&base_ids),
        )
        .unwrap();
        let mut live = LiveProblem::from_oracle(base);
        let seed = 42u64;
        let machines = 3u32;
        let tape = RandomTape::draw(64, machines, seed);
        let base_parts = tape.partition_of(&base_ids);

        let d1 = delta_from(&grown, 80, &[64, 65, 66], &[1, 30]);
        let d2 = delta_from(&grown, 80, &[70, 71], &[64, 5]);
        for d in [&d1, &d2] {
            // Worker-side path: split and apply sub-deltas to shard oracles.
            let parts_before = live.parts_for(base_parts.clone(), seed);
            let mut shards: Vec<PartitionOracle> = parts_before
                .iter()
                .map(|p| PartitionOracle::from_payload(&live.shard(p).unwrap()).unwrap())
                .collect();
            let subs = live.sub_deltas(d, machines, seed).unwrap();
            for (s, sub) in shards.iter_mut().zip(&subs) {
                s.apply_delta(sub).unwrap();
            }
            // Coordinator-side path: advance the live oracle and re-extract.
            live.apply(d).unwrap();
            let parts_after = live.parts_for(base_parts.clone(), seed);
            for (m, (s, part)) in shards.iter().zip(&parts_after).enumerate() {
                assert_eq!(s.held(), &part[..], "machine {m} part order diverged");
                let inc = s.extract(part).unwrap();
                let cold = live.shard(part).unwrap();
                assert_eq!(inc, cold, "machine {m} shard data diverged");
            }
        }
        assert_eq!(live.epoch(), 2);
    }

    #[test]
    fn non_partitionable_oracles_are_rejected() {
        struct Opaque;
        impl Oracle for Opaque {
            fn n(&self) -> usize {
                3
            }
            fn name(&self) -> &'static str {
                "opaque"
            }
            fn new_state<'a>(
                &'a self,
                _view: Option<&[ElemId]>,
            ) -> Box<dyn crate::objective::GainState + 'a> {
                unimplemented!("never evaluated in this test")
            }
            fn elem_bytes(&self, _e: ElemId) -> usize {
                8
            }
        }
        let err = LiveProblem::new(&Opaque).unwrap_err();
        assert!(err.contains("partition shipping"), "{err}");
    }
}
