//! Partition matroid constraint: the ground set is partitioned into groups
//! and at most `cap[g]` elements may be chosen from group `g`.
//!
//! This implements the paper's §7 future-work direction ("experiments for
//! other hereditary constraints, such as matroid ... constraints"): the
//! GREEDY algorithm is 1/2-approximate under matroids and both RandGreeDI
//! (α/2) and GreedyML (α/(L+1)) inherit from it — the ablation bench
//! `fig4_tree_params --constraint matroid` exercises this path.

use super::{Constraint, ConstraintState};
use crate::ElemId;

/// A partition matroid over groups with per-group capacities.
#[derive(Clone, Debug)]
pub struct PartitionMatroid {
    /// group id of each element (dense `0..n`).
    groups: Vec<u32>,
    /// capacity of each group.
    caps: Vec<u32>,
}

impl PartitionMatroid {
    /// Build from per-element group ids and per-group capacities.
    pub fn new(groups: Vec<u32>, caps: Vec<u32>) -> Self {
        let ngroups = caps.len() as u32;
        assert!(
            groups.iter().all(|&g| g < ngroups),
            "element group id out of range"
        );
        Self { groups, caps }
    }

    /// Uniform capacities: `n` elements hashed into `ngroups` round-robin,
    /// each group with capacity `cap`.
    pub fn round_robin(n: usize, ngroups: usize, cap: u32) -> Self {
        Self::new(
            (0..n).map(|e| (e % ngroups) as u32).collect(),
            vec![cap; ngroups],
        )
    }

    /// Group of an element.
    pub fn group_of(&self, e: ElemId) -> u32 {
        self.groups[e as usize]
    }
}

impl Constraint for PartitionMatroid {
    fn new_state(&self) -> Box<dyn ConstraintState> {
        Box::new(PmState {
            matroid: self.clone(),
            used: vec![0; self.caps.len()],
            remaining: self.caps.iter().map(|&c| c as usize).sum(),
        })
    }

    fn rank(&self) -> usize {
        // Rank = Σ min(cap_g, |group g|); the simple Σ cap_g upper bound is
        // fine for buffer sizing but we compute the exact rank for the BSP
        // model's `k`.
        let mut sizes = vec![0u32; self.caps.len()];
        for &g in &self.groups {
            sizes[g as usize] += 1;
        }
        self.caps
            .iter()
            .zip(&sizes)
            .map(|(&c, &s)| c.min(s) as usize)
            .sum()
    }

    fn name(&self) -> &'static str {
        "partition-matroid"
    }
}

struct PmState {
    matroid: PartitionMatroid,
    used: Vec<u32>,
    remaining: usize,
}

impl ConstraintState for PmState {
    #[inline]
    fn can_add(&self, e: ElemId) -> bool {
        let g = self.matroid.groups[e as usize] as usize;
        self.used[g] < self.matroid.caps[g]
    }

    fn commit(&mut self, e: ElemId) {
        let g = self.matroid.groups[e as usize] as usize;
        self.used[g] += 1;
        self.remaining -= 1;
    }

    fn full(&self) -> bool {
        self.remaining == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_group_caps() {
        // 6 elements, groups [0,0,1,1,2,2], caps [1,2,0].
        let m = PartitionMatroid::new(vec![0, 0, 1, 1, 2, 2], vec![1, 2, 0]);
        assert!(m.is_feasible(&[0, 2, 3]));
        assert!(!m.is_feasible(&[0, 1]), "group 0 capped at 1");
        assert!(!m.is_feasible(&[4]), "group 2 capped at 0");
        assert_eq!(m.rank(), 3);
        assert_eq!(m.group_of(3), 1);
    }

    #[test]
    fn rank_clips_to_group_sizes() {
        // Group 0 has 1 element but cap 5.
        let m = PartitionMatroid::new(vec![0, 1, 1], vec![5, 1]);
        assert_eq!(m.rank(), 2);
    }

    #[test]
    fn full_when_all_caps_hit() {
        let m = PartitionMatroid::round_robin(4, 2, 1);
        let mut st = m.new_state();
        st.commit(0);
        assert!(!st.full());
        st.commit(1);
        assert!(st.full());
        assert!(!st.can_add(2));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_bad_groups() {
        PartitionMatroid::new(vec![0, 7], vec![1]);
    }
}
