//! Hereditary constraint families (§2.1).
//!
//! A constraint family `C ⊆ 2^W` is *hereditary* when every subset of a
//! feasible set is feasible — the property Lemma 4.2/4.3 rely on (rejected
//! sets `O_c ⊆ OPT` stay feasible).  The paper's experiments use cardinality
//! constraints; §7 lists matroid constraints as future work, so we ship a
//! partition matroid too and the algorithms are generic over the trait.

use crate::ElemId;

pub mod cardinality;
pub mod partition_matroid;

pub use cardinality::Cardinality;
pub use partition_matroid::PartitionMatroid;

/// A hereditary constraint. Stateless description; mint per-solution states.
pub trait Constraint: Send + Sync {
    /// Fresh state for an empty solution.
    fn new_state(&self) -> Box<dyn ConstraintState>;

    /// An upper bound on |S| for any feasible S (used to pre-size buffers
    /// and by the BSP cost model as the paper's `k`).
    fn rank(&self) -> usize;

    /// Human-readable name.
    fn name(&self) -> &'static str;

    /// Check feasibility of a whole set from scratch.
    fn is_feasible(&self, solution: &[ElemId]) -> bool {
        let mut st = self.new_state();
        solution.iter().all(|&e| {
            if st.can_add(e) {
                st.commit(e);
                true
            } else {
                false
            }
        })
    }
}

/// Incremental feasibility state for one growing solution.
pub trait ConstraintState {
    /// Can `e` be added while staying feasible?
    fn can_add(&self, e: ElemId) -> bool;

    /// Record that `e` was added.
    fn commit(&mut self, e: ElemId);

    /// Is the solution saturated (no element could ever be added)?  Purely
    /// an optimization: lets GREEDY stop scanning early once |S| = k.
    fn full(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heredity_generic() {
        // Every prefix of a feasible commit sequence must stay feasible —
        // checked for both constraint families on random sequences.
        let mut rng = crate::util::rng::Rng::new(33);
        let card = Cardinality::new(4);
        let groups: Vec<u32> = (0..20).map(|e| e % 3).collect();
        let pm = PartitionMatroid::new(groups, vec![2, 1, 3]);
        let constraints: [&dyn Constraint; 2] = [&card, &pm];
        for c in constraints {
            for _ in 0..50 {
                let mut st = c.new_state();
                let mut sol: Vec<ElemId> = Vec::new();
                for _ in 0..10 {
                    let e = rng.below(20) as ElemId;
                    if !sol.contains(&e) && st.can_add(e) {
                        st.commit(e);
                        sol.push(e);
                    }
                }
                assert!(c.is_feasible(&sol), "{}: grown set infeasible", c.name());
                // Heredity: every subset obtained by dropping one element.
                for drop in 0..sol.len() {
                    let sub: Vec<ElemId> = sol
                        .iter()
                        .enumerate()
                        .filter(|&(i, _)| i != drop)
                        .map(|(_, &e)| e)
                        .collect();
                    assert!(c.is_feasible(&sub), "{}: subset infeasible", c.name());
                }
            }
        }
    }
}
