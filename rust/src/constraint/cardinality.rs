//! Cardinality constraint `C = {A ⊆ W : |A| ≤ k}` — the family used in all
//! of the paper's experiments.

use super::{Constraint, ConstraintState};
use crate::ElemId;

/// `|S| ≤ k`.
#[derive(Clone, Copy, Debug)]
pub struct Cardinality {
    k: usize,
}

impl Cardinality {
    /// Constraint with solution-size budget `k`.
    pub fn new(k: usize) -> Self {
        Self { k }
    }

    /// The budget.
    pub fn k(&self) -> usize {
        self.k
    }
}

impl Constraint for Cardinality {
    fn new_state(&self) -> Box<dyn ConstraintState> {
        Box::new(CardState { k: self.k, size: 0 })
    }

    fn rank(&self) -> usize {
        self.k
    }

    fn name(&self) -> &'static str {
        "cardinality"
    }
}

struct CardState {
    k: usize,
    size: usize,
}

impl ConstraintState for CardState {
    #[inline]
    fn can_add(&self, _e: ElemId) -> bool {
        self.size < self.k
    }

    fn commit(&mut self, _e: ElemId) {
        self.size += 1;
    }

    #[inline]
    fn full(&self) -> bool {
        self.size >= self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enforces_budget() {
        let c = Cardinality::new(2);
        let mut st = c.new_state();
        assert!(st.can_add(0) && !st.full());
        st.commit(0);
        st.commit(1);
        assert!(!st.can_add(2));
        assert!(st.full());
        assert!(c.is_feasible(&[5, 9]));
        assert!(!c.is_feasible(&[1, 2, 3]));
        assert_eq!(c.rank(), 2);
    }

    #[test]
    fn k_zero_rejects_everything() {
        let c = Cardinality::new(0);
        let st = c.new_state();
        assert!(!st.can_add(0));
        assert!(st.full());
        assert!(c.is_feasible(&[]));
    }
}
