//! A small property-based testing harness ("proptest-lite").
//!
//! `proptest`/`quickcheck` are unavailable offline, so this module provides
//! the subset we need: composable random generators over a seeded [`Rng`],
//! a `forall` runner that reports the seed and case number of a failure so
//! it can be replayed deterministically, and greedy input shrinking for the
//! common container shapes (vectors and scalars).
//!
//! Usage:
//! ```no_run
//! # // no_run: doctest binaries miss the xla_extension rpath at load time.
//! use greedyml::check::{forall, Gen};
//! forall("sum is commutative", 200, Gen::vec(Gen::u64(0..100), 0..20), |xs| {
//!     let mut rev = xs.clone();
//!     rev.reverse();
//!     let a: u64 = xs.iter().sum();
//!     let b: u64 = rev.iter().sum();
//!     if a == b { Ok(()) } else { Err(format!("{a} != {b}")) }
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::Range;

/// A generator of values of type `T` from a PRNG, plus a shrinker that
/// proposes smaller variants of a failing input.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Rng) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: 'static> Gen<T> {
    /// Build from closures.
    pub fn new(
        gen: impl Fn(&mut Rng) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Self { gen: Box::new(gen), shrink: Box::new(shrink) }
    }

    /// Generate one value.
    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.gen)(rng)
    }

    /// Shrink candidates for a failing value.
    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value (shrinking is lost across the mapping).
    pub fn map<U: 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |rng| f(self.sample(rng)), |_| Vec::new())
    }
}

impl Gen<u64> {
    /// Uniform u64 in `range`.
    pub fn u64(range: Range<u64>) -> Gen<u64> {
        assert!(!range.is_empty());
        let lo = range.start;
        let hi = range.end;
        Gen::new(
            move |rng| lo + rng.below(hi - lo),
            move |&v| {
                let mut out = Vec::new();
                if v > lo {
                    out.push(lo); // smallest
                    out.push(lo + (v - lo) / 2); // halfway down
                    out.push(v - 1);
                }
                out.dedup();
                out
            },
        )
    }
}

impl Gen<usize> {
    /// Uniform usize in `range`.
    pub fn usize(range: Range<usize>) -> Gen<usize> {
        Gen::u64(range.start as u64..range.end as u64).map_keep_shrink_usize()
    }
}

impl Gen<u64> {
    fn map_keep_shrink_usize(self) -> Gen<usize> {
        Gen::new(
            move |rng| self.sample(rng) as usize,
            |&v| {
                let mut out = Vec::new();
                if v > 0 {
                    out.push(0);
                    out.push(v / 2);
                    out.push(v - 1);
                }
                out.dedup();
                out
            },
        )
    }
}

impl Gen<f64> {
    /// Uniform f64 in `[lo, hi)`.
    pub fn f64(lo: f64, hi: f64) -> Gen<f64> {
        assert!(lo < hi);
        Gen::new(
            move |rng| lo + rng.f64() * (hi - lo),
            move |&v| {
                let mut out = Vec::new();
                if v != lo {
                    out.push(lo);
                    out.push(lo + (v - lo) / 2.0);
                }
                out
            },
        )
    }
}

impl Gen<bool> {
    /// Bernoulli(p).
    pub fn bool(p: f64) -> Gen<bool> {
        Gen::new(move |rng| rng.bool(p), |&v| if v { vec![false] } else { vec![] })
    }
}

impl<T: Clone + 'static> Gen<Vec<T>> {
    /// Vector with length drawn from `len` and elements from `elem`.
    pub fn vec(elem: Gen<T>, len: Range<usize>) -> Gen<Vec<T>> {
        assert!(!len.is_empty());
        let lo = len.start;
        let hi = len.end;
        let elem = std::rc::Rc::new(elem);
        let elem2 = elem.clone();
        Gen::new(
            move |rng| {
                let n = lo + rng.below((hi - lo) as u64) as usize;
                (0..n).map(|_| elem.sample(rng)).collect()
            },
            move |v: &Vec<T>| {
                let mut out: Vec<Vec<T>> = Vec::new();
                // Structural shrinks: drop halves, drop single elements.
                if v.len() > lo {
                    out.push(v[..lo].to_vec());
                    out.push(
                        v[..v.len() / 2].to_vec().into_iter().chain(std::iter::empty()).collect(),
                    );
                    if v.len() > 1 {
                        out.push(v[1..].to_vec());
                        out.push(v[..v.len() - 1].to_vec());
                    }
                }
                out.retain(|c| c.len() >= lo);
                // Element-wise shrinks on the first shrinkable position.
                for (i, x) in v.iter().enumerate() {
                    let cands = elem2.shrinks(x);
                    if !cands.is_empty() {
                        for c in cands {
                            let mut v2 = v.clone();
                            v2[i] = c;
                            out.push(v2);
                        }
                        break;
                    }
                }
                out
            },
        )
    }
}

/// Pair generator.
pub fn pair<A: 'static, B: 'static>(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
    Gen::new(move |rng| (a.sample(rng), b.sample(rng)), |_| Vec::new())
}

/// Outcome of a property check.
pub type PropResult = Result<(), String>;

/// Run `prop` on `cases` random inputs from `gen`.  On failure, shrink the
/// input greedily (up to 200 shrink steps) and panic with the seed, case
/// index and minimized counterexample.
pub fn forall<T: std::fmt::Debug + Clone + 'static>(
    name: &str,
    cases: usize,
    gen: Gen<T>,
    prop: impl Fn(&T) -> PropResult,
) {
    // Derive the seed from the property name so distinct properties explore
    // distinct inputs but every run of the suite is reproducible.
    let seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    forall_seeded(name, seed, cases, gen, prop)
}

/// [`forall`] with an explicit seed (replay a failure).
pub fn forall_seeded<T: std::fmt::Debug + Clone + 'static>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: Gen<T>,
    prop: impl Fn(&T) -> PropResult,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink.
            let mut best = input;
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < 200 {
                for cand in gen.shrinks(&best) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if steps >= 200 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (seed={seed}, case={case}):\n  \
                 counterexample (after {steps} shrink steps): {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Assert helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut hits = 0usize;
        // Can't capture &mut in Fn; use a Cell.
        let hits_cell = std::cell::Cell::new(0usize);
        forall("u64 in range", 300, Gen::u64(5..10), |&x| {
            hits_cell.set(hits_cell.get() + 1);
            ensure((5..10).contains(&x), format!("{x} out of range"))
        });
        hits += hits_cell.get();
        assert_eq!(hits, 300);
    }

    #[test]
    fn vec_gen_respects_len() {
        forall("vec len", 200, Gen::vec(Gen::u64(0..3), 2..7), |v| {
            ensure((2..7).contains(&v.len()), format!("len {}", v.len()))
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_name() {
        forall("always fails", 10, Gen::u64(0..100), |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property: all values < 50. Counterexample should shrink toward 50.
        let result = std::panic::catch_unwind(|| {
            forall("lt 50", 500, Gen::u64(0..1000), |&x| {
                ensure(x < 50, format!("{x} >= 50"))
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // The minimized counterexample should be well below the initial
        // random failure (typically exactly 50 via halving).
        let after = msg.split("shrink steps): ").nth(1).unwrap();
        let value: u64 = after.split_whitespace().next().unwrap().parse().unwrap();
        assert!(value <= 99, "shrunk value {value} not small: {msg}");
    }

    #[test]
    fn f64_and_bool_gens() {
        forall("f64 range", 200, Gen::f64(-1.0, 1.0), |&x| {
            ensure((-1.0..1.0).contains(&x), format!("{x}"))
        });
        forall("bool const", 50, Gen::bool(0.0), |&b| ensure(!b, "true from p=0"));
    }

    #[test]
    fn pair_gen() {
        forall("pair", 100, pair(Gen::u64(0..4), Gen::u64(10..14)), |&(a, b)| {
            ensure(a < 4 && (10..14).contains(&b), format!("{a},{b}"))
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let g = Gen::u64(0..1_000_000);
            let mut rng = Rng::new(seed);
            (0..20).map(|_| g.sample(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }
}
