//! The gateway daemon: a network front door for the job service.
//!
//! The paper's deployment story is a long-lived service answering a
//! stream of maximization queries against one resident corpus.  PR 6/7
//! built everything *behind* that door — resident-shard sessions
//! ([`crate::dist`]), the warm [`SessionPool`](crate::algo::SessionPool)
//! and the [`JobQueue`](super::JobQueue) with its solution cache and
//! admission control.  This module is the door itself:
//!
//! * `greedyml gateway --bind <addr>` runs [`run_gateway`] — an accept
//!   loop speaking a small length-prefixed job protocol (the same
//!   4-byte-LE + JSON framing as the worker wire,
//!   [`crate::dist::wire`]), scheduling admitted jobs onto a bounded
//!   worker-thread pool that drives **one shared** [`JobQueue`]: jobs
//!   from different clients run concurrently on warm fleets, arbitrate
//!   one admission budget, and share one solution cache.
//! * `greedyml submit --gateway <addr>` is the matching client
//!   ([`GatewayClient`]): it streams job results back as they complete,
//!   not in submission order.
//!
//! The protocol is specified prose-first in `docs/gateway-protocol.md`;
//! the `gateway_doc_stays_in_lockstep_with_the_codec` test fails if a
//! message variant exists in one place but not the other.
//!
//! Message flow (one connection = one client; results interleave):
//!
//! ```text
//! client → gateway              gateway → client
//! ----------------              ----------------
//! Hello{version}                Welcome{version} | Error{message}
//! ── per job, pipelined ───────────────────────────────────────────────
//! Submit{job}                   Accepted{id} | Rejected{id,reason}
//!                               … then exactly one terminal frame per
//!                               accepted id, in completion order:
//!                               Result{id,solution,value,warm,cached,
//!                                      faults}
//!                               | Rejected{id,reason}   (admission)
//!                               | Failed{id,error}
//! Delta{spec,delta}             DeltaOk{epoch} | Error{message}
//! Stats                         Stats{counters}
//! ── end ──────────────────────────────────────────────────────────────
//! EOF                           (connection closes; queued jobs finish)
//! ```
//!
//! A job is `Accepted` the moment its spec parses — *before* admission
//! control, which runs on a worker thread and may still answer
//! `Rejected` (over budget) as the job's terminal frame.  A worker-fleet
//! fault inside one job is that job's problem alone: the pool evicts the
//! poisoned fleet, the job retries or fails per its `on_fault` policy,
//! and every other in-flight job keeps its own fleet and its own answer.

use super::jobs::Submission;
use super::{BuiltProblem, JobQueue};
use crate::algo::{dataset_fingerprint, DistConfig};
use crate::dist::wire::{read_frame, write_frame};
use crate::dist::{BackendSpec, CoresetSpec, FaultSpec, ShipSpec, WireSpec};
use crate::objective::PartitionDelta;
use crate::stream::LiveProblem;
use crate::metrics::{GatewayCounters, GatewaySnapshot};
use crate::tree::AccumulationTree;
use crate::util::config::Config;
use crate::ElemId;
use serde_json::{json, Value};
use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Gateway-protocol version, checked by the `Hello`/`Welcome` handshake
/// as the very first exchange on every connection.  Bump whenever a
/// frame is added, removed, or changes field semantics: a gateway from a
/// different build must refuse a client it cannot faithfully serve
/// instead of desyncing mid-stream.  Independent of the worker wire's
/// [`crate::dist::wire::PROTOCOL_VERSION`] — the two protocols evolve
/// separately.
///
/// * v1 — initial release: hello/submit/stats requests.
/// * v2 — `submit` jobs carry a `wire` field (worker frame encoding,
///   `--wire json|binary`).
/// * v3 — live datasets: `submit` jobs carry `epoch` and `coreset`
///   fields; a `delta` request applies a [`PartitionDelta`] to the
///   daemon's resident corpus and is answered by `delta_ok` with the new
///   epoch.
pub const GATEWAY_PROTOCOL_VERSION: u32 = 3;

/// A client must complete the handshake within this window.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(30);

/// Idle cutoff after the handshake: a client holding a connection open
/// between batches is fine; a half-dead peer is reaped.
const IDLE_TIMEOUT: Duration = Duration::from_secs(6 * 3600);

/// Built problems kept resident, keyed by dataset fingerprint — clients
/// querying the same corpus share one oracle build.
const PROBLEM_CACHE: usize = 4;

/// Lock with poison recovery: one panicking connection or worker thread
/// must not brick the daemon's shared state (every guarded structure is
/// valid after any partial update).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn send(w: &mut impl Write, v: &Value) -> crate::Result<()> {
    write_frame(w, v).map(|_| ()).map_err(|e| anyhow::anyhow!(e))
}

/// One job as it crosses the wire: the problem spec (flat config text,
/// the same `key = value` shape workers rebuild from) plus every
/// `[jobs]`-surface run parameter.  This is deliberately the
/// [`JobBatch`](super::JobBatch) shape — engine knobs outside it
/// (greedy kind, partition scheme, §6.4 variants…) take their GreedyML
/// defaults, exactly as `greedyml submit` jobs do.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Client-chosen id, echoed on every frame about this job.
    pub id: u64,
    /// Flat problem spec (`dataset.*` / `problem.*` / `objective.*`,
    /// including this job's `problem.k`).
    pub spec: String,
    /// Random-tape seed.
    pub seed: u64,
    /// Fleet width.
    pub machines: u32,
    /// Accumulation-tree branching.
    pub branching: u32,
    /// Execution backend (`auto` | `thread` | `process` | `tcp`).
    pub backend: String,
    /// Ship mode (`auto` | `spec` | `partition`).
    pub ship: String,
    /// Worker daemons for the tcp backend (`None` = the gateway's
    /// `GREEDYML_HOSTS` environment).
    pub hosts: Option<Vec<String>>,
    /// Executor width (0 = auto).
    pub threads: u64,
    /// Machine-local evaluation views.
    pub local_view: bool,
    /// Worker-loss policy (`auto` | `fail` | `retry` | `degrade`).
    pub on_fault: String,
    /// Worker frame encoding (`auto` | `json` | `binary`).
    pub wire: String,
    /// Dataset epoch this job targets: 0 for a static corpus, the
    /// `delta_ok` epoch after applying deltas.  A job whose epoch trails
    /// the daemon's live corpus fails instead of answering stale.
    pub epoch: u64,
    /// Sieve-streaming coreset mode (`auto` | `on` | `off`).
    pub coreset: String,
}

fn backend_str(b: BackendSpec) -> &'static str {
    match b {
        BackendSpec::Auto => "auto",
        BackendSpec::Thread => "thread",
        BackendSpec::Process => "process",
        BackendSpec::Tcp => "tcp",
    }
}

fn ship_str(s: ShipSpec) -> &'static str {
    match s {
        ShipSpec::Auto => "auto",
        ShipSpec::Spec => "spec",
        ShipSpec::Partition => "partition",
    }
}

fn fault_str(f: FaultSpec) -> &'static str {
    match f {
        FaultSpec::Auto => "auto",
        FaultSpec::Fail => "fail",
        FaultSpec::Retry => "retry",
        FaultSpec::Degrade => "degrade",
    }
}

fn wire_str(w: WireSpec) -> &'static str {
    match w {
        WireSpec::Auto => "auto",
        WireSpec::Json => "json",
        WireSpec::Binary => "binary",
    }
}

fn coreset_str(c: CoresetSpec) -> &'static str {
    match c {
        CoresetSpec::Auto => "auto",
        CoresetSpec::Off => "off",
        CoresetSpec::On => "on",
    }
}

impl JobSpec {
    /// Build from an engine config (the `submit --gateway` client path:
    /// [`JobBatch::dist_config`](super::JobBatch::dist_config) output).
    /// Fails if the config has no problem spec attached.
    pub fn from_dist(id: u64, cfg: &DistConfig) -> crate::Result<Self> {
        let spec = match &cfg.problem {
            Some(s) => s.clone(),
            None => anyhow::bail!("job has no problem spec (DistConfig::problem)"),
        };
        Ok(Self {
            id,
            spec,
            seed: cfg.seed,
            machines: cfg.tree.machines(),
            branching: cfg.tree.branching(),
            backend: backend_str(cfg.backend).to_string(),
            ship: ship_str(cfg.ship).to_string(),
            hosts: cfg.hosts.clone(),
            threads: cfg.threads.unwrap_or(0) as u64,
            local_view: cfg.local_view,
            on_fault: fault_str(cfg.on_fault).to_string(),
            wire: wire_str(cfg.wire).to_string(),
            epoch: cfg.epoch,
            coreset: coreset_str(cfg.coreset).to_string(),
        })
    }

    /// The engine config this job asks for.  Validates every parsed
    /// field — a malformed spec is a polite `Rejected`, never a daemon
    /// panic.
    pub fn dist_config(&self) -> crate::Result<DistConfig> {
        let backend = BackendSpec::parse(&self.backend)
            .map_err(|e| anyhow::anyhow!("job {}: backend: {e}", self.id))?;
        let ship = ShipSpec::parse(&self.ship)
            .map_err(|e| anyhow::anyhow!("job {}: ship: {e}", self.id))?;
        let on_fault = FaultSpec::parse(&self.on_fault)
            .map_err(|e| anyhow::anyhow!("job {}: on_fault: {e}", self.id))?;
        let wire = WireSpec::parse(&self.wire)
            .map_err(|e| anyhow::anyhow!("job {}: wire: {e}", self.id))?;
        let coreset = CoresetSpec::parse(&self.coreset)
            .map_err(|e| anyhow::anyhow!("job {}: coreset: {e}", self.id))?;
        anyhow::ensure!(self.machines >= 1, "job {}: need at least one machine", self.id);
        anyhow::ensure!(
            self.branching >= 2 || self.machines == 1,
            "job {}: branching factor must be ≥ 2",
            self.id
        );
        Ok(DistConfig {
            backend,
            ship,
            hosts: self.hosts.clone(),
            problem: Some(self.spec.clone()),
            threads: match self.threads {
                0 => None,
                t => Some(t as usize),
            },
            local_view: self.local_view,
            on_fault,
            wire,
            coreset,
            epoch: self.epoch,
            ..DistConfig::greedyml(AccumulationTree::new(self.machines, self.branching), self.seed)
        })
    }

    fn to_value(&self) -> Value {
        json!({
            "id": self.id,
            "spec": self.spec,
            "seed": self.seed,
            "machines": self.machines,
            "branching": self.branching,
            "backend": self.backend,
            "ship": self.ship,
            "hosts": self.hosts,
            "threads": self.threads,
            "local_view": self.local_view,
            "on_fault": self.on_fault,
            "wire": self.wire,
            "epoch": self.epoch,
            "coreset": self.coreset,
        })
    }

    fn from_value(v: &Value) -> crate::Result<Self> {
        Ok(Self {
            id: u64_field(v, "id")?,
            spec: str_field(v, "spec")?.to_string(),
            seed: u64_field(v, "seed")?,
            machines: u64_field(v, "machines")? as u32,
            branching: u64_field(v, "branching")? as u32,
            backend: str_field(v, "backend")?.to_string(),
            ship: str_field(v, "ship")?.to_string(),
            hosts: hosts_field(v)?,
            threads: u64_field(v, "threads")?,
            local_view: bool_field(v, "local_view")?,
            on_fault: str_field(v, "on_fault")?.to_string(),
            wire: str_field(v, "wire")?.to_string(),
            epoch: u64_field(v, "epoch")?,
            coreset: str_field(v, "coreset")?.to_string(),
        })
    }
}

/// Client → gateway requests.
#[derive(Clone, Debug, PartialEq)]
pub enum ToGateway {
    /// Connection handshake: the client announces its
    /// [`GATEWAY_PROTOCOL_VERSION`] as the very first frame.  The
    /// gateway replies [`FromGateway::Welcome`] on a match and
    /// [`FromGateway::Error`] (then closes) on a mismatch.
    Hello {
        /// The client's [`GATEWAY_PROTOCOL_VERSION`].
        version: u32,
    },
    /// Submit one job.  Answered immediately with
    /// [`FromGateway::Accepted`] (spec parsed; the job is queued) or
    /// [`FromGateway::Rejected`] (malformed); every accepted job later
    /// gets exactly one terminal frame.
    Submit(JobSpec),
    /// Apply one dataset delta to the daemon's resident corpus (the one
    /// `spec` fingerprints).  Answered with [`FromGateway::DeltaOk`]
    /// carrying the corpus's new epoch; a malformed delta is a
    /// connection-level [`FromGateway::Error`].  Subsequent
    /// [`ToGateway::Submit`] jobs at the new epoch run against the
    /// post-delta data — on warm fleets, workers patch their resident
    /// shards in place instead of re-shipping them.
    Delta {
        /// Flat problem spec identifying the corpus (dataset fingerprint).
        spec: String,
        /// The diff: global-id inserts with data rows, plus deletes.
        delta: PartitionDelta,
    },
    /// Ask for the daemon's live counters ([`FromGateway::Stats`]).
    Stats,
}

/// Gateway → client replies.
#[derive(Clone, Debug, PartialEq)]
pub enum FromGateway {
    /// Handshake reply: the gateway's [`GATEWAY_PROTOCOL_VERSION`].
    Welcome {
        /// The gateway's [`GATEWAY_PROTOCOL_VERSION`].
        version: u32,
    },
    /// The job's spec parsed and it is queued for scheduling.
    Accepted {
        /// The client-chosen job id.
        id: u64,
    },
    /// The job will not run: malformed spec (immediate) or refused by
    /// admission control (terminal, after `Accepted`).
    Rejected {
        /// The client-chosen job id.
        id: u64,
        /// Why the job was refused.
        reason: String,
    },
    /// Terminal: the job completed.  `warm`: ran on a reused resident
    /// fleet; `cached`: answered from the solution cache (no worker was
    /// touched); `faults`: human-readable fault accounting, empty for a
    /// clean run — non-empty with dropped machines marks a **degraded**
    /// answer (see `docs/failure-model.md`).
    Result {
        /// The client-chosen job id.
        id: u64,
        /// The solution element ids.
        solution: Vec<ElemId>,
        /// f(solution) — bit-exact across the wire (ryu).
        value: f64,
        /// Whether a warm fleet served the run.
        warm: bool,
        /// Whether the solution cache answered without running.
        cached: bool,
        /// Fault summary (empty = fault-free).
        faults: String,
    },
    /// Terminal: the job errored in flight (after admission, after the
    /// pool's own retry policy gave up).  The daemon survives; other
    /// jobs are untouched.
    Failed {
        /// The client-chosen job id.
        id: u64,
        /// The error chain.
        error: String,
    },
    /// A [`ToGateway::Delta`] applied cleanly; the corpus now serves the
    /// returned epoch.  Jobs submitted at this epoch see the post-delta
    /// data (and invalidate any older cached solutions for the corpus).
    DeltaOk {
        /// The corpus's dataset epoch after the delta.
        epoch: u64,
    },
    /// The daemon's live counters.
    Stats(GatewaySnapshot),
    /// Connection-level failure (handshake refusal, unreadable frame).
    /// The gateway closes the connection after sending it.
    Error {
        /// What went wrong.
        message: String,
    },
}

impl ToGateway {
    /// Encode as a JSON frame body.
    pub fn to_value(&self) -> Value {
        match self {
            Self::Hello { version } => json!({ "t": "hello", "version": version }),
            Self::Submit(job) => json!({ "t": "submit", "job": job.to_value() }),
            Self::Delta { spec, delta } => {
                json!({ "t": "delta", "spec": spec, "delta": delta.to_value() })
            }
            Self::Stats => json!({ "t": "stats" }),
        }
    }

    /// Decode from a JSON frame body.
    pub fn from_value(v: &Value) -> crate::Result<Self> {
        match str_field(v, "t")? {
            "hello" => Ok(Self::Hello { version: u64_field(v, "version")? as u32 }),
            "submit" => Ok(Self::Submit(JobSpec::from_value(field(v, "job")?)?)),
            "delta" => Ok(Self::Delta {
                spec: str_field(v, "spec")?.to_string(),
                delta: PartitionDelta::from_value(field(v, "delta")?)
                    .map_err(|e| anyhow::anyhow!("field 'delta': {e}"))?,
            }),
            "stats" => Ok(Self::Stats),
            other => anyhow::bail!("unknown gateway request '{other}'"),
        }
    }
}

impl FromGateway {
    /// Encode as a JSON frame body.
    pub fn to_value(&self) -> Value {
        match self {
            Self::Welcome { version } => json!({ "t": "welcome", "version": version }),
            Self::Accepted { id } => json!({ "t": "accepted", "id": id }),
            Self::Rejected { id, reason } => {
                json!({ "t": "rejected", "id": id, "reason": reason })
            }
            Self::Result { id, solution, value, warm, cached, faults } => json!({
                "t": "result",
                "id": id,
                "solution": solution,
                "value": value,
                "warm": warm,
                "cached": cached,
                "faults": faults,
            }),
            Self::Failed { id, error } => json!({ "t": "failed", "id": id, "error": error }),
            Self::DeltaOk { epoch } => json!({ "t": "delta_ok", "epoch": epoch }),
            Self::Stats(s) => json!({
                "t": "stats",
                "queued": s.queued,
                "running": s.running,
                "completed": s.completed,
                "warm": s.warm,
                "cached": s.cached,
                "rejected": s.rejected,
                "failed": s.failed,
                "faulted": s.faulted,
                "submitted": s.submitted,
                "sessions": s.sessions,
                "init_bytes": s.init_bytes,
            }),
            Self::Error { message } => json!({ "t": "error", "message": message }),
        }
    }

    /// Decode from a JSON frame body.
    pub fn from_value(v: &Value) -> crate::Result<Self> {
        match str_field(v, "t")? {
            "welcome" => Ok(Self::Welcome { version: u64_field(v, "version")? as u32 }),
            "accepted" => Ok(Self::Accepted { id: u64_field(v, "id")? }),
            "rejected" => Ok(Self::Rejected {
                id: u64_field(v, "id")?,
                reason: str_field(v, "reason")?.to_string(),
            }),
            "result" => Ok(Self::Result {
                id: u64_field(v, "id")?,
                solution: elems_field(v, "solution")?,
                value: f64_field(v, "value")?,
                warm: bool_field(v, "warm")?,
                cached: bool_field(v, "cached")?,
                faults: str_field(v, "faults")?.to_string(),
            }),
            "failed" => Ok(Self::Failed {
                id: u64_field(v, "id")?,
                error: str_field(v, "error")?.to_string(),
            }),
            "delta_ok" => Ok(Self::DeltaOk { epoch: u64_field(v, "epoch")? }),
            "stats" => Ok(Self::Stats(GatewaySnapshot {
                queued: u64_field(v, "queued")?,
                running: u64_field(v, "running")?,
                completed: u64_field(v, "completed")?,
                warm: u64_field(v, "warm")?,
                cached: u64_field(v, "cached")?,
                rejected: u64_field(v, "rejected")?,
                failed: u64_field(v, "failed")?,
                faulted: u64_field(v, "faulted")?,
                submitted: u64_field(v, "submitted")?,
                sessions: u64_field(v, "sessions")?,
                init_bytes: u64_field(v, "init_bytes")?,
            })),
            "error" => Ok(Self::Error { message: str_field(v, "message")?.to_string() }),
            other => anyhow::bail!("unknown gateway reply '{other}'"),
        }
    }
}

// ---- field helpers ----------------------------------------------------

fn field<'a>(v: &'a Value, key: &str) -> crate::Result<&'a Value> {
    v.get(key)
        .ok_or_else(|| anyhow::anyhow!("frame missing field '{key}'"))
}

fn str_field<'a>(v: &'a Value, key: &str) -> crate::Result<&'a str> {
    field(v, key)?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
}

fn u64_field(v: &Value, key: &str) -> crate::Result<u64> {
    field(v, key)?
        .as_u64()
        .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a u64"))
}

fn f64_field(v: &Value, key: &str) -> crate::Result<f64> {
    field(v, key)?
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
}

fn bool_field(v: &Value, key: &str) -> crate::Result<bool> {
    field(v, key)?
        .as_bool()
        .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a bool"))
}

fn elems_field(v: &Value, key: &str) -> crate::Result<Vec<ElemId>> {
    field(v, key)?
        .as_array()
        .ok_or_else(|| anyhow::anyhow!("field '{key}' is not an array"))?
        .iter()
        .map(|e| {
            e.as_u64()
                .map(|x| x as ElemId)
                .ok_or_else(|| anyhow::anyhow!("field '{key}': non-integer element"))
        })
        .collect()
}

/// `hosts` is the one nullable field: absent or `null` means "defer to
/// the gateway's environment".
fn hosts_field(v: &Value) -> crate::Result<Option<Vec<String>>> {
    let arr = match v.get("hosts") {
        None | Some(Value::Null) => return Ok(None),
        Some(h) => match h.as_array() {
            Some(arr) => arr,
            None => anyhow::bail!("field 'hosts' is not an array"),
        },
    };
    let mut hosts = Vec::with_capacity(arr.len());
    for e in arr {
        match e.as_str() {
            Some(s) => hosts.push(s.to_string()),
            None => anyhow::bail!("field 'hosts': non-string entry"),
        }
    }
    Ok(Some(hosts))
}

// ---- client -----------------------------------------------------------

/// A connected gateway client: submit jobs, stream replies.  One
/// connection pipelines any number of jobs; [`GatewayClient::next`]
/// yields frames in the order the gateway wrote them (results arrive in
/// completion order, not submission order — match on the echoed id).
pub struct GatewayClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl GatewayClient {
    /// Connect and complete the version handshake.
    pub fn connect(addr: &str) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("cannot connect to gateway {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let reader = stream.try_clone()?;
        let mut client = Self { reader: BufReader::new(reader), writer: BufWriter::new(stream) };
        client.send(&ToGateway::Hello { version: GATEWAY_PROTOCOL_VERSION })?;
        match client.next()? {
            FromGateway::Welcome { .. } => Ok(client),
            FromGateway::Error { message } => {
                anyhow::bail!("gateway refused the handshake: {message}")
            }
            other => anyhow::bail!("expected welcome from the gateway, got {other:?}"),
        }
    }

    /// Submit one job (replies arrive via [`GatewayClient::next`]).
    pub fn submit(&mut self, job: &JobSpec) -> crate::Result<()> {
        self.send(&ToGateway::Submit(job.clone()))
    }

    /// Apply one dataset delta to the daemon's resident corpus.  The
    /// [`FromGateway::DeltaOk`] reply (via [`GatewayClient::next`])
    /// carries the new epoch; submit subsequent jobs at that epoch.
    /// Drain this corpus's in-flight results first — a job still queued
    /// at the old epoch fails once the delta lands.
    pub fn send_delta(&mut self, spec: &str, delta: &PartitionDelta) -> crate::Result<()> {
        self.send(&ToGateway::Delta { spec: spec.to_string(), delta: delta.clone() })
    }

    /// Ask for the daemon's counters (the reply arrives via
    /// [`GatewayClient::next`], after any frames already in flight).
    pub fn request_stats(&mut self) -> crate::Result<()> {
        self.send(&ToGateway::Stats)
    }

    /// The next gateway frame; an error if the gateway hung up.
    pub fn next(&mut self) -> crate::Result<FromGateway> {
        match read_frame(&mut self.reader).map_err(|e| anyhow::anyhow!(e))? {
            Some(v) => FromGateway::from_value(&v),
            None => anyhow::bail!("gateway closed the connection"),
        }
    }

    fn send(&mut self, msg: &ToGateway) -> crate::Result<()> {
        send(&mut self.writer, &msg.to_value())
    }
}

// ---- daemon -----------------------------------------------------------

/// `greedyml gateway` settings.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Listen address (`--bind`; `127.0.0.1:0` picks a free port and
    /// prints it).
    pub bind: String,
    /// Worker threads draining the job queue (`--workers`): the maximum
    /// number of jobs in flight at once.
    pub workers: usize,
    /// Per-machine admission budget in bytes (`--mem-budget`; `None` =
    /// admit everything).  Concurrent jobs arbitrate this one budget.
    pub mem_budget: Option<u64>,
    /// Solution-cache capacity in entries (`--cache-entries`).
    pub cache_entries: usize,
}

/// Everything the daemon's threads share.
struct Shared {
    queue: JobQueue,
    counters: GatewayCounters,
    /// Built problems by dataset fingerprint (LRU, capacity
    /// [`PROBLEM_CACHE`]): clients querying the same corpus share one
    /// resident oracle build.
    problems: Mutex<Vec<(String, Arc<BuiltProblem>)>>,
    /// Live datasets by fingerprint, created by the first `delta` frame
    /// against a corpus.  Each entry's own mutex serializes deltas and
    /// solves on that corpus (a solve holds it for the run), so an
    /// epoch-N job never races the delta producing epoch N + 1; distinct
    /// corpora stay concurrent.
    live: Mutex<Vec<(String, Arc<Mutex<LiveProblem>>)>>,
}

/// An admitted job on its way to a worker thread.
struct ScheduledJob {
    job: JobSpec,
    dist: DistConfig,
    writer: Arc<Mutex<BufWriter<TcpStream>>>,
}

/// Run the gateway daemon: bind, print exactly one
/// `greedyml gateway: listening on <addr>` banner on stdout, then serve
/// forever.  Connection- and job-level failures go to stderr; nothing a
/// client sends brings the daemon down.
pub fn run_gateway(gc: &GatewayConfig) -> crate::Result<()> {
    let listener = TcpListener::bind(&gc.bind)
        .map_err(|e| anyhow::anyhow!("cannot bind {}: {e}", gc.bind))?;
    let addr = listener.local_addr()?;
    println!("greedyml gateway: listening on {addr}");
    serve_loop(listener, gc.clone())
}

/// The accept loop over an already-bound listener (separated from
/// [`run_gateway`] so tests can bind an ephemeral port themselves).
fn serve_loop(listener: TcpListener, gc: GatewayConfig) -> crate::Result<()> {
    let shared = Arc::new(Shared {
        queue: JobQueue::with_cache_entries(gc.mem_budget, gc.cache_entries),
        counters: GatewayCounters::default(),
        problems: Mutex::new(Vec::new()),
        live: Mutex::new(Vec::new()),
    });
    let (tx, rx) = mpsc::channel::<ScheduledJob>();
    let rx = Arc::new(Mutex::new(rx));
    for _ in 0..gc.workers.max(1) {
        let shared = Arc::clone(&shared);
        let rx = Arc::clone(&rx);
        std::thread::spawn(move || worker_loop(&shared, &rx));
    }
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                let shared = Arc::clone(&shared);
                let tx = tx.clone();
                std::thread::spawn(move || {
                    if let Err(e) = serve_client(stream, &shared, &tx) {
                        eprintln!("greedyml gateway: client {peer}: {e:#}");
                    }
                });
            }
            Err(e) => {
                // A persistent accept failure (e.g. EMFILE) must degrade
                // to slow retries, not a hot stderr-spamming spin.
                eprintln!("greedyml gateway: accept: {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Serve one client connection: handshake, then requests until EOF.
/// The connection's writer is shared (behind a mutex) with the worker
/// threads finishing this client's jobs, so `Accepted`/`Stats` replies
/// interleave with `Result` frames — each frame is written atomically.
fn serve_client(
    stream: TcpStream,
    shared: &Shared,
    tx: &Sender<ScheduledJob>,
) -> crate::Result<()> {
    let _ = stream.set_nodelay(true);
    // Read timeout only until the handshake completes (SO_RCVTIMEO is a
    // property of the socket, shared with the cloned reader below).
    let _ = stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
    let reader = stream.try_clone()?;
    let mut input = BufReader::new(reader);
    let writer = Arc::new(Mutex::new(BufWriter::new(stream)));

    let first = read_frame(&mut input)
        .map_err(|e| anyhow::anyhow!(e))?
        .ok_or_else(|| anyhow::anyhow!("EOF before hello"))?;
    match ToGateway::from_value(&first)? {
        ToGateway::Hello { version } if version == GATEWAY_PROTOCOL_VERSION => {
            let welcome = FromGateway::Welcome { version: GATEWAY_PROTOCOL_VERSION };
            send(&mut *lock(&writer), &welcome.to_value())?;
            let _ = input.get_ref().set_read_timeout(Some(IDLE_TIMEOUT));
        }
        ToGateway::Hello { version } => {
            let message = format!(
                "client speaks gateway-protocol v{version}, this daemon speaks \
                 v{GATEWAY_PROTOCOL_VERSION} — deploy matching greedyml builds"
            );
            let refusal = FromGateway::Error { message: message.clone() };
            let _ = send(&mut *lock(&writer), &refusal.to_value());
            anyhow::bail!("{message}");
        }
        other => {
            let message = "expected hello as the first frame".to_string();
            let _ = send(&mut *lock(&writer), &FromGateway::Error { message }.to_value());
            anyhow::bail!("expected hello as the first frame, got {other:?}");
        }
    }

    while let Some(frame) = read_frame(&mut input).map_err(|e| anyhow::anyhow!(e))? {
        match ToGateway::from_value(&frame)? {
            ToGateway::Submit(job) => {
                let id = job.id;
                match job.dist_config() {
                    // Malformed spec: immediate terminal rejection,
                    // nothing is queued.
                    Err(e) => {
                        let reply = FromGateway::Rejected { id, reason: format!("{e:#}") };
                        send(&mut *lock(&writer), &reply.to_value())?;
                    }
                    Ok(dist) => {
                        // Accepted is on the wire *before* the job can
                        // possibly produce a terminal frame.
                        send(&mut *lock(&writer), &FromGateway::Accepted { id }.to_value())?;
                        shared.counters.queued.fetch_add(1, Relaxed);
                        let scheduled = ScheduledJob { job, dist, writer: Arc::clone(&writer) };
                        if tx.send(scheduled).is_err() {
                            shared.counters.queued.fetch_sub(1, Relaxed);
                            shared.counters.failed.fetch_add(1, Relaxed);
                            let error = "gateway worker pool is gone".to_string();
                            let reply = FromGateway::Failed { id, error };
                            send(&mut *lock(&writer), &reply.to_value())?;
                        }
                    }
                }
            }
            ToGateway::Delta { spec, delta } => match apply_delta(shared, &spec, &delta) {
                Ok(epoch) => {
                    send(&mut *lock(&writer), &FromGateway::DeltaOk { epoch }.to_value())?;
                }
                Err(e) => {
                    // A delta the daemon cannot apply leaves the client's
                    // view of the corpus undefined — refuse the
                    // connection rather than serve it stale answers.
                    let message = format!("delta: {e:#}");
                    let _ =
                        send(&mut *lock(&writer), &FromGateway::Error { message }.to_value());
                    anyhow::bail!("delta: {e:#}");
                }
            },
            ToGateway::Stats => {
                let mut snap = shared.counters.snapshot();
                snap.submitted = shared.queue.submitted();
                snap.sessions = shared.queue.pool().sessions_established();
                snap.init_bytes = shared.queue.pool().init_bytes_total();
                send(&mut *lock(&writer), &FromGateway::Stats(snap).to_value())?;
            }
            ToGateway::Hello { .. } => {
                let message = "unexpected hello after the handshake".to_string();
                let _ = send(&mut *lock(&writer), &FromGateway::Error { message }.to_value());
                anyhow::bail!("unexpected hello after the handshake");
            }
        }
    }
    Ok(())
}

/// One worker thread: pull admitted jobs, run them through the shared
/// queue, write the terminal frame back to the submitting connection.
/// Job failures are frames, never daemon exits.
fn worker_loop(shared: &Shared, rx: &Mutex<Receiver<ScheduledJob>>) {
    loop {
        // Hold the receiver lock only while *waiting* — jobs run with
        // every worker free to pick up the next one.
        let next = lock(rx).recv();
        let Ok(scheduled) = next else { return };
        shared.counters.queued.fetch_sub(1, Relaxed);
        shared.counters.running.fetch_add(1, Relaxed);
        let reply = run_one(shared, &scheduled);
        shared.counters.running.fetch_sub(1, Relaxed);
        match &reply {
            FromGateway::Result { warm, cached, faults, .. } => {
                shared.counters.completed.fetch_add(1, Relaxed);
                if *warm {
                    shared.counters.warm.fetch_add(1, Relaxed);
                }
                if *cached {
                    shared.counters.cached.fetch_add(1, Relaxed);
                }
                if !faults.is_empty() {
                    shared.counters.faulted.fetch_add(1, Relaxed);
                }
            }
            FromGateway::Rejected { .. } => {
                shared.counters.rejected.fetch_add(1, Relaxed);
            }
            _ => {
                shared.counters.failed.fetch_add(1, Relaxed);
            }
        }
        if let Err(e) = send(&mut *lock(&scheduled.writer), &reply.to_value()) {
            // The client hung up before its answer arrived; the job's
            // side effects (cache entry, warm fleet) are still useful.
            eprintln!("greedyml gateway: job {}: undeliverable result: {e:#}", scheduled.job.id);
        }
    }
}

/// Run one admitted job to its terminal frame.
fn run_one(shared: &Shared, scheduled: &ScheduledJob) -> FromGateway {
    let id = scheduled.job.id;
    let live = {
        let fp = dataset_fingerprint(&scheduled.job.spec);
        lock(&shared.live)
            .iter()
            .find(|(f, _)| *f == fp)
            .map(|(_, l)| Arc::clone(l))
    };
    let outcome = problem_for(shared, &scheduled.job.spec).and_then(|problem| match &live {
        Some(l) => shared.queue.submit_live(&problem, &scheduled.dist, Some(&*lock(l))),
        None => shared.queue.submit(&problem, &scheduled.dist),
    });
    match outcome {
        Ok(Submission::Ran { solution, value, warm, faults }) => {
            FromGateway::Result { id, solution, value, warm, cached: false, faults }
        }
        Ok(Submission::Cached { solution, value }) => FromGateway::Result {
            id,
            solution,
            value,
            warm: false,
            cached: true,
            faults: String::new(),
        },
        Ok(Submission::Rejected { reason }) => FromGateway::Rejected { id, reason },
        Err(e) => FromGateway::Failed { id, error: format!("{e:#}") },
    }
}

/// Apply one delta to the corpus `spec` fingerprints: find (or create,
/// on the first delta) its [`LiveProblem`], mutate the resident oracle
/// in place, and return the new epoch.  Holding the corpus's own lock
/// across the mutation means no solve observes a half-applied delta.
fn apply_delta(shared: &Shared, spec: &str, delta: &PartitionDelta) -> crate::Result<u64> {
    let problem = problem_for(shared, spec)?;
    let fp = dataset_fingerprint(spec);
    let entry = {
        let mut live = lock(&shared.live);
        match live.iter().find(|(f, _)| *f == fp) {
            Some((_, l)) => Arc::clone(l),
            None => {
                let fresh = LiveProblem::new(problem.oracle.as_ref())
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                let fresh = Arc::new(Mutex::new(fresh));
                live.push((fp, Arc::clone(&fresh)));
                fresh
            }
        }
    };
    let mut corpus = lock(&entry);
    corpus.apply(delta).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(corpus.epoch())
}

/// The resident problem for a job spec: LRU lookup by dataset
/// fingerprint, else build and insert.  The build happens outside the
/// lock (it can take seconds on a large corpus); a concurrent build of
/// the same corpus keeps the first copy inserted.
fn problem_for(shared: &Shared, spec: &str) -> crate::Result<Arc<BuiltProblem>> {
    let fp = dataset_fingerprint(spec);
    {
        let mut cache = lock(&shared.problems);
        if let Some(pos) = cache.iter().position(|(f, _)| *f == fp) {
            let entry = cache.remove(pos);
            let problem = Arc::clone(&entry.1);
            cache.push(entry); // most recently used
            return Ok(problem);
        }
    }
    let cfg = Config::parse(spec).map_err(|e| anyhow::anyhow!("job problem spec: {e}"))?;
    let built = Arc::new(super::build_problem(&cfg, None)?);
    let mut cache = lock(&shared.problems);
    if let Some((_, existing)) = cache.iter().find(|(f, _)| *f == fp) {
        return Ok(Arc::clone(existing));
    }
    cache.push((fp, Arc::clone(&built)));
    while cache.len() > PROBLEM_CACHE {
        cache.remove(0); // evict the coldest corpus
    }
    Ok(built)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::build_constraint;

    const SPEC: &str = "dataset.kind = retail\ndataset.n = 150\ndataset.seed = 2\n\
                        problem.k = 4\n";

    fn sample_job() -> JobSpec {
        JobSpec {
            id: 3,
            spec: SPEC.to_string(),
            seed: 1,
            machines: 4,
            branching: 2,
            backend: "thread".to_string(),
            ship: "auto".to_string(),
            hosts: Some(vec!["127.0.0.1:7401".to_string(), "127.0.0.1:7402".to_string()]),
            threads: 2,
            local_view: false,
            on_fault: "retry".to_string(),
            wire: "binary".to_string(),
            epoch: 0,
            coreset: "auto".to_string(),
        }
    }

    fn sample_delta() -> PartitionDelta {
        PartitionDelta {
            n_global: 12,
            insert: crate::objective::PartitionPayload {
                n_global: 12,
                elems: vec![10, 11],
                data: crate::objective::PartitionData::Modular { weights: vec![1.5, 2.0] },
            },
            delete: vec![3],
        }
    }

    fn sample_snapshot() -> GatewaySnapshot {
        GatewaySnapshot {
            queued: 1,
            running: 2,
            completed: 9,
            warm: 5,
            cached: 3,
            rejected: 1,
            failed: 0,
            faulted: 1,
            submitted: 11,
            sessions: 2,
            init_bytes: 4096,
        }
    }

    fn roundtrip_request(msg: ToGateway) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg.to_value()).unwrap();
        let v = read_frame(&mut buf.as_slice()).unwrap().expect("frame present");
        assert_eq!(ToGateway::from_value(&v).unwrap(), msg);
    }

    fn roundtrip_reply(msg: FromGateway) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg.to_value()).unwrap();
        let v = read_frame(&mut buf.as_slice()).unwrap().expect("frame present");
        assert_eq!(FromGateway::from_value(&v).unwrap(), msg);
    }

    /// One sample of every client → gateway request (the lockstep test
    /// derives the live tag set from this list — extend it when adding a
    /// variant).
    fn all_requests() -> Vec<ToGateway> {
        vec![
            ToGateway::Hello { version: GATEWAY_PROTOCOL_VERSION },
            ToGateway::Submit(sample_job()),
            ToGateway::Delta { spec: SPEC.to_string(), delta: sample_delta() },
            ToGateway::Stats,
        ]
    }

    /// One sample of every gateway → client reply (see [`all_requests`]).
    fn all_replies() -> Vec<FromGateway> {
        vec![
            FromGateway::Welcome { version: GATEWAY_PROTOCOL_VERSION },
            FromGateway::Accepted { id: 3 },
            FromGateway::Rejected { id: 3, reason: "over the admission budget".to_string() },
            FromGateway::Result {
                id: 3,
                solution: vec![9, 2, 511],
                value: 12.5,
                warm: true,
                cached: false,
                faults: "1 fault seen, 1 retry".to_string(),
            },
            FromGateway::Failed { id: 3, error: "worker fleet died".to_string() },
            FromGateway::DeltaOk { epoch: 2 },
            FromGateway::Stats(sample_snapshot()),
            FromGateway::Error { message: "expected hello as the first frame".to_string() },
        ]
    }

    #[test]
    fn requests_roundtrip() {
        for msg in all_requests() {
            roundtrip_request(msg);
        }
        // A job with no hosts crosses the wire as null and comes back None.
        roundtrip_request(ToGateway::Submit(JobSpec { hosts: None, ..sample_job() }));
    }

    #[test]
    fn replies_roundtrip() {
        for msg in all_replies() {
            roundtrip_reply(msg);
        }
    }

    /// Every `"t"` tag scanned out of a document (the prose spec quotes
    /// each frame's tag as `"t": "<tag>"`).
    fn doc_tags(doc: &str) -> std::collections::BTreeSet<String> {
        let mut tags = std::collections::BTreeSet::new();
        let needle = "\"t\": \"";
        let mut rest = doc;
        while let Some(pos) = rest.find(needle) {
            rest = &rest[pos + needle.len()..];
            if let Some(end) = rest.find('"') {
                tags.insert(rest[..end].to_string());
            }
        }
        tags
    }

    #[test]
    fn gateway_doc_stays_in_lockstep_with_the_codec() {
        // Keep `docs/gateway-protocol.md` honest: every message variant
        // the codec speaks must be named in the spec (as `"t": "<tag>"`),
        // the spec must not describe tags the codec does not speak, and
        // every variant must round-trip through its own frame.
        let doc = include_str!("../../../docs/gateway-protocol.md");
        let documented = doc_tags(doc);
        let mut live = std::collections::BTreeSet::new();
        for msg in all_requests() {
            live.insert(msg.to_value()["t"].as_str().unwrap().to_string());
            roundtrip_request(msg);
        }
        for msg in all_replies() {
            live.insert(msg.to_value()["t"].as_str().unwrap().to_string());
            roundtrip_reply(msg);
        }
        assert_eq!(
            live, documented,
            "docs/gateway-protocol.md and coordinator/gateway.rs disagree on the message \
             set (left = codec, right = doc) — update both together"
        );
    }

    #[test]
    fn stats_request_bytes_match_the_documented_hex_dump() {
        // The annotated hex dump in docs/gateway-protocol.md shows this
        // exact frame; if the encoding ever changes, the doc must change
        // with it.
        let mut buf = Vec::new();
        let written = write_frame(&mut buf, &ToGateway::Stats.to_value()).unwrap();
        assert_eq!(
            buf,
            [0x0d, 0x00, 0x00, 0x00, 0x01, 0x7b, 0x22, 0x74, 0x22, 0x3a, 0x22, 0x73, 0x74,
             0x61, 0x74, 0x73, 0x22, 0x7d],
            "Stats frame no longer matches the hex dump in docs/gateway-protocol.md"
        );
        assert_eq!(written, buf.len() as u64, "write_frame must report the on-wire size");
    }

    #[test]
    fn hello_frame_bytes_match_the_documented_hex_dump() {
        // Pinned at v3 like the doc's dump — a version bump must touch
        // the doc, this test, and GATEWAY_PROTOCOL_VERSION together.
        let mut buf = Vec::new();
        write_frame(&mut buf, &ToGateway::Hello { version: 3 }.to_value()).unwrap();
        assert_eq!(
            buf,
            [0x19, 0x00, 0x00, 0x00, 0x01, 0x7b, 0x22, 0x74, 0x22, 0x3a, 0x22, 0x68, 0x65,
             0x6c, 0x6c, 0x6f, 0x22, 0x2c, 0x22, 0x76, 0x65, 0x72, 0x73, 0x69, 0x6f, 0x6e,
             0x22, 0x3a, 0x33, 0x7d],
            "Hello frame no longer matches the hex dump in docs/gateway-protocol.md"
        );
    }

    #[test]
    fn f64_values_cross_the_wire_bit_exactly() {
        // Clients compare gateway values against thread-backend runs with
        // to_bits(); ryu's shortest representation must reproduce the
        // exact double.
        for v in [1.0 / 3.0, 1e-300, 123456789.123456789, f64::MIN_POSITIVE] {
            let msg = FromGateway::Result {
                id: 0,
                solution: vec![],
                value: v,
                warm: false,
                cached: false,
                faults: String::new(),
            };
            let mut buf = Vec::new();
            write_frame(&mut buf, &msg.to_value()).unwrap();
            let parsed = read_frame(&mut buf.as_slice()).unwrap().unwrap();
            match FromGateway::from_value(&parsed).unwrap() {
                FromGateway::Result { value, .. } => assert_eq!(value.to_bits(), v.to_bits()),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn malformed_frames_are_polite_errors() {
        assert!(ToGateway::from_value(&json!({ "t": "bogus" })).is_err());
        assert!(FromGateway::from_value(&json!({ "t": "result", "id": 1 })).is_err());
        assert!(ToGateway::from_value(&json!({ "version": 1 })).is_err(), "missing tag");
        let job = JobSpec { machines: 0, ..sample_job() };
        assert!(job.dist_config().is_err(), "zero machines rejects instead of panicking");
        let job = JobSpec { backend: "quantum".to_string(), ..sample_job() };
        assert!(job.dist_config().is_err(), "unknown backend rejects");
    }

    #[test]
    fn job_spec_survives_the_dist_config_roundtrip() {
        let job = sample_job();
        let dist = job.dist_config().unwrap();
        assert_eq!(dist.seed, 1);
        assert_eq!(dist.tree.machines(), 4);
        assert_eq!(dist.tree.branching(), 2);
        assert!(matches!(dist.backend, BackendSpec::Thread));
        assert!(matches!(dist.ship, ShipSpec::Auto));
        assert!(matches!(dist.on_fault, FaultSpec::Retry));
        assert_eq!(dist.threads, Some(2));
        assert_eq!(dist.problem.as_deref(), Some(SPEC));
        assert_eq!(JobSpec::from_dist(3, &dist).unwrap(), job);
    }

    #[test]
    fn gateway_serves_thread_backend_jobs_end_to_end() {
        // A live daemon on an ephemeral port: submit → accepted → result,
        // bit-identical to a direct thread-backend run; an identical
        // resubmission is served from the cache; stats reconcile.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let gc = GatewayConfig {
            bind: String::new(),
            workers: 2,
            mem_budget: None,
            cache_entries: 8,
        };
        std::thread::spawn(move || serve_loop(listener, gc));
        let mut client = GatewayClient::connect(&addr).unwrap();

        let job = JobSpec { id: 0, hosts: None, ..sample_job() };
        client.submit(&job).unwrap();
        assert_eq!(client.next().unwrap(), FromGateway::Accepted { id: 0 });
        let (solution, value) = match client.next().unwrap() {
            FromGateway::Result { id: 0, solution, value, cached: false, .. } => {
                (solution, value)
            }
            other => panic!("expected a fresh result, got {other:?}"),
        };

        let cfg = Config::parse(SPEC).unwrap();
        let problem = super::super::build_problem(&cfg, None).unwrap();
        let (constraint, _) = build_constraint(&cfg, problem.oracle.n()).unwrap();
        let direct = crate::algo::run_dist(
            problem.oracle.as_ref(),
            constraint.as_ref(),
            &job.dist_config().unwrap(),
        )
        .unwrap();
        assert_eq!(solution, direct.solution, "gateway solution matches the thread backend");
        assert_eq!(value.to_bits(), direct.value.to_bits(), "f(S) is bit-identical");

        client.submit(&JobSpec { id: 1, ..job.clone() }).unwrap();
        assert_eq!(client.next().unwrap(), FromGateway::Accepted { id: 1 });
        match client.next().unwrap() {
            FromGateway::Result { id: 1, value: v, cached: true, .. } => {
                assert_eq!(v.to_bits(), value.to_bits(), "cache replay is bit-identical");
            }
            other => panic!("expected a cached result, got {other:?}"),
        }

        client.request_stats().unwrap();
        match client.next().unwrap() {
            FromGateway::Stats(s) => {
                assert_eq!(s.completed, 2);
                assert_eq!(s.cached, 1);
                assert_eq!(s.submitted, 2);
                assert_eq!(s.queued, 0);
                assert_eq!(s.running, 0);
            }
            other => panic!("expected stats, got {other:?}"),
        }
    }

    #[test]
    fn deltas_advance_the_daemons_resident_corpus() {
        // Each delta frame bumps the corpus's epoch by one and answers
        // DeltaOk; the daemon and the connection both survive to apply
        // the next one.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let gc = GatewayConfig {
            bind: String::new(),
            workers: 1,
            mem_budget: None,
            cache_entries: 8,
        };
        std::thread::spawn(move || serve_loop(listener, gc));
        let mut client = GatewayClient::connect(&addr).unwrap();
        let cfg = Config::parse(SPEC).unwrap();
        let problem = super::super::build_problem(&cfg, None).unwrap();
        let p = problem.oracle.partitionable().unwrap();
        for (round, doomed) in [(1u64, 5u32), (2, 9)] {
            let delta = PartitionDelta {
                n_global: problem.oracle.n(),
                insert: p.extract_partition(&[]),
                delete: vec![doomed],
            };
            client.send_delta(SPEC, &delta).unwrap();
            assert_eq!(client.next().unwrap(), FromGateway::DeltaOk { epoch: round });
        }
    }

    #[test]
    fn malformed_submissions_reject_without_touching_the_daemon() {
        // A zero-machine job bounces immediately; the connection and the
        // daemon both survive to serve the next, valid job.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let gc = GatewayConfig {
            bind: String::new(),
            workers: 1,
            mem_budget: None,
            cache_entries: 8,
        };
        std::thread::spawn(move || serve_loop(listener, gc));
        let mut client = GatewayClient::connect(&addr).unwrap();
        let doomed = JobSpec { id: 7, machines: 0, hosts: None, ..sample_job() };
        client.submit(&doomed).unwrap();
        match client.next().unwrap() {
            FromGateway::Rejected { id: 7, reason } => {
                assert!(reason.contains("machine"), "{reason}");
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        let fine = JobSpec { id: 8, hosts: None, ..sample_job() };
        client.submit(&fine).unwrap();
        assert_eq!(client.next().unwrap(), FromGateway::Accepted { id: 8 });
        assert!(
            matches!(client.next().unwrap(), FromGateway::Result { id: 8, .. }),
            "the daemon still runs valid jobs"
        );
    }
}
