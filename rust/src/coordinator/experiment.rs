//! Experiment runner: a config describes a dataset, an objective, a
//! constraint and a list of algorithm variants; the runner executes them
//! all, computes relative function values against the strongest available
//! baseline, prints the paper-shaped table and optionally writes JSON.

use super::dataset::{build_problem, BuiltProblem};
use crate::algo::{
    greedi_config, run_dist, run_sequential, randgreedi::RandGreediOpts, DistConfig,
};
use crate::constraint::{Cardinality, Constraint, PartitionMatroid};
use crate::dist::{BackendSpec, CoresetSpec, FaultSpec, ShipSpec, WireSpec};
use crate::greedy::GreedyKind;
use crate::metrics::RunReport;
use crate::runtime::Engine;
use crate::tree::AccumulationTree;
use crate::util::config::Config;
use std::sync::Arc;

/// One algorithm variant to run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AlgoSpec {
    /// Sequential (lazy) GREEDY.
    Greedy,
    /// GreeDI with `m` machines (contiguous partition).
    GreeDi { m: u32 },
    /// RandGreeDI with `m` machines.
    RandGreedi { m: u32 },
    /// GreedyML over T(m, b).
    GreedyMl { m: u32, b: u32 },
}

impl AlgoSpec {
    /// Parse one spec token: `greedy`, `greedi:m`, `randgreedi:m`,
    /// `greedyml:m:b`.
    pub fn parse(tok: &str) -> crate::Result<Self> {
        let parts: Vec<&str> = tok.trim().split(':').collect();
        let num = |s: &str| -> crate::Result<u32> {
            crate::util::config::parse_u64(s)
                .map(|v| v as u32)
                .map_err(|m| anyhow::anyhow!("algo spec '{tok}': {m}"))
        };
        match parts.as_slice() {
            ["greedy"] => Ok(Self::Greedy),
            ["greedi", m] => Ok(Self::GreeDi { m: num(m)? }),
            ["randgreedi", m] => Ok(Self::RandGreedi { m: num(m)? }),
            ["greedyml", m, b] => Ok(Self::GreedyMl { m: num(m)?, b: num(b)? }),
            _ => anyhow::bail!(
                "bad algo spec '{tok}' (greedy | greedi:m | randgreedi:m | greedyml:m:b)"
            ),
        }
    }

    /// Report label.
    pub fn label(&self) -> String {
        match self {
            Self::Greedy => "Greedy".into(),
            Self::GreeDi { m } => format!("GreeDI(m={m})"),
            Self::RandGreedi { m } => format!("RG(m={m})"),
            Self::GreedyMl { m, b } => {
                let t = AccumulationTree::new(*m, *b);
                format!("GML(m={m},b={b},L={})", t.levels())
            }
        }
    }
}

/// A fully parsed experiment.
pub struct Experiment {
    /// Experiment name (reports).
    pub name: String,
    /// The dataset + oracle.
    pub problem: BuiltProblem,
    /// Constraint.
    pub constraint: Box<dyn Constraint>,
    /// Solution size (rank of the constraint, for reporting).
    pub k: usize,
    /// Algorithm variants in run order.
    pub algos: Vec<AlgoSpec>,
    /// Shared run options.
    pub seed: u64,
    /// Per-machine memory limit.
    pub mem_limit: Option<u64>,
    /// k-medoid local-objective scheme.
    pub local_view: bool,
    /// §6.4 added elements per accumulation.
    pub added_elements: usize,
    /// Executor width (`run.threads`; 0 or absent = auto).
    pub threads: Option<usize>,
    /// Execution backend for the distributed variants (`run.backend`
    /// config key / `--backend` flag / `GREEDYML_BACKEND`).
    pub backend: BackendSpec,
    /// Flat problem spec shipped to process/tcp-backend workers.
    pub problem_spec: String,
    /// How problems travel to process/tcp workers (`run.ship` config key
    /// / `--ship` flag / `GREEDYML_SHIP`): rebuild recipe or O(n/m)
    /// dataset shards.
    pub ship: ShipSpec,
    /// `greedyml serve` worker daemons for the tcp backend (`run.hosts`
    /// config key / `--hosts` flag; `None` defers to `GREEDYML_HOSTS`).
    pub hosts: Option<Vec<String>>,
    /// Worker-loss policy for remote backends (`run.on_fault` config key
    /// / `--on-fault` flag / `GREEDYML_ON_FAULT`): fail, retry, degrade.
    pub on_fault: FaultSpec,
    /// Frame encoding on the worker wire (`run.wire` config key /
    /// `--wire` flag / `GREEDYML_WIRE`): json or binary.
    pub wire: WireSpec,
    /// Sieve-streaming coreset mode (`run.coreset` config key /
    /// `--coreset` flag / `GREEDYML_CORESET`): leaf shards are filtered
    /// to O(k log n / ε) coresets before accumulation.
    pub coreset: CoresetSpec,
}

/// Build the constraint described by the `[problem]` section.  Shared by
/// the experiment runner and the process-backend worker, which rebuilds
/// the same constraint from the shipped problem spec.  Returns the
/// constraint and the solution-size parameter `k`.
pub fn build_constraint(cfg: &Config, n: usize) -> crate::Result<(Box<dyn Constraint>, usize)> {
    let k = cfg.u64_or("problem.k", 32)? as usize;
    let constraint: Box<dyn Constraint> = match cfg.str_or("problem.constraint", "cardinality") {
        "cardinality" => Box::new(Cardinality::new(k)),
        "matroid" => {
            let groups = cfg.u64_or("problem.groups", 4)? as usize;
            let cap = (k / groups).max(1) as u32;
            Box::new(PartitionMatroid::round_robin(n, groups, cap))
        }
        other => anyhow::bail!("unknown constraint '{other}'"),
    };
    Ok((constraint, k))
}

impl Experiment {
    /// Build from a config (see configs/ for examples).
    pub fn from_config(cfg: &Config, engine: Option<Arc<Engine>>) -> crate::Result<Self> {
        let problem = build_problem(cfg, engine)?;
        let (constraint, k) = build_constraint(cfg, problem.oracle.n())?;
        let algos = cfg
            .str_or("run.algos", "greedy, randgreedi:8, greedyml:8:2")
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(AlgoSpec::parse)
            .collect::<crate::Result<Vec<_>>>()?;
        anyhow::ensure!(!algos.is_empty(), "run.algos selected nothing");
        let mem_limit = match cfg.get("run.mem_limit") {
            None | Some("none") => None,
            Some(v) => Some(
                crate::util::config::parse_u64(v).map_err(|m| anyhow::anyhow!("mem_limit: {m}"))?,
            ),
        };
        let backend = BackendSpec::parse(cfg.str_or("run.backend", "auto"))
            .map_err(|e| anyhow::anyhow!("run.backend: {e}"))?;
        let ship = ShipSpec::parse(cfg.str_or("run.ship", "auto"))
            .map_err(|e| anyhow::anyhow!("run.ship: {e}"))?;
        let on_fault = FaultSpec::parse(cfg.str_or("run.on_fault", "auto"))
            .map_err(|e| anyhow::anyhow!("run.on_fault: {e}"))?;
        let wire = WireSpec::parse(cfg.str_or("run.wire", "auto"))
            .map_err(|e| anyhow::anyhow!("run.wire: {e}"))?;
        let coreset = CoresetSpec::parse(cfg.str_or("run.coreset", "auto"))
            .map_err(|e| anyhow::anyhow!("run.coreset: {e}"))?;
        Ok(Self {
            name: cfg.str_or("name", "experiment").to_string(),
            problem,
            constraint,
            k,
            algos,
            seed: cfg.u64_or("run.seed", 42)?,
            mem_limit,
            local_view: cfg.bool_or("run.local_view", false)?,
            added_elements: cfg.u64_or("run.added", 0)? as usize,
            threads: match cfg.u64_or("run.threads", 0)? {
                0 => None,
                t => Some(t as usize),
            },
            backend,
            ship,
            problem_spec: super::problem_spec(cfg),
            hosts: crate::dist::tcp::hosts_from_config(cfg, "run.hosts")?,
            on_fault,
            wire,
            coreset,
        })
    }

    /// Attach this experiment's backend settings to an engine config.
    fn with_backend(&self, mut cfg: DistConfig) -> DistConfig {
        cfg.backend = self.backend;
        cfg.problem = Some(self.problem_spec.clone());
        cfg.ship = self.ship;
        cfg.threads = cfg.threads.or(self.threads);
        cfg.hosts = self.hosts.clone();
        cfg.on_fault = self.on_fault;
        cfg.wire = self.wire;
        cfg.coreset = self.coreset;
        cfg
    }

    /// The full engine config for a tree-shaped run of this experiment
    /// (GreedyML, or the RandGreeDI/GreeDI argmax when
    /// `compare_all_children`), with run options and backend settings
    /// attached.  The CLI's `--trace` re-run uses this so the traced
    /// config can never diverge from the tabulated one.
    pub fn dist_config(
        &self,
        tree: AccumulationTree,
        compare_all_children: bool,
    ) -> DistConfig {
        self.with_backend(DistConfig {
            mem_limit: self.mem_limit,
            local_view: self.local_view,
            added_elements: self.added_elements,
            compare_all_children,
            threads: self.threads,
            ..DistConfig::greedyml(tree, self.seed)
        })
    }

    /// Run every variant. Failed runs (e.g. OOM — an *expected* outcome in
    /// the memory experiments) produce a report row with value 0 and are
    /// listed in `failures`.
    pub fn run(&self) -> (Vec<RunReport>, Vec<(String, String)>) {
        let oracle = self.problem.oracle.as_ref();
        let dataset = self.problem.summary.name.clone();
        let mut reports = Vec::new();
        let mut failures = Vec::new();
        let mut baseline: Option<f64> = None;

        for spec in &self.algos {
            let label = spec.label();
            let result: Result<RunReport, String> = match *spec {
                AlgoSpec::Greedy => {
                    let constraint = self.constraint.as_ref();
                    run_sequential(oracle, constraint, GreedyKind::Lazy, self.mem_limit)
                        .map(|out| RunReport {
                            algo: label.clone(),
                            dataset: dataset.clone(),
                            k: self.k,
                            machines: 1,
                            branching: 0,
                            levels: 0,
                            value: out.greedy.value,
                            rel_value_pct: None,
                            critical_calls: out.greedy.calls,
                            total_calls: out.greedy.calls,
                            comp_secs: out.secs,
                            comm_secs: 0.0,
                            peak_mem: out.peak_mem,
                            faults: None,
                        })
                        .map_err(|e| e.to_string())
                }
                AlgoSpec::GreeDi { m } => {
                    let cfg = self.with_backend(greedi_config(m, self.mem_limit));
                    run_dist(oracle, self.constraint.as_ref(), &cfg)
                        .map(|out| {
                            RunReport::from_outcome(&label, &dataset, self.k, &out, m, m, 1)
                        })
                        .map_err(|e| e.to_string())
                }
                AlgoSpec::RandGreedi { m } => {
                    let opts = RandGreediOpts {
                        mem_limit: self.mem_limit,
                        local_view: self.local_view,
                        added_elements: self.added_elements,
                        ..RandGreediOpts::new(m, self.seed)
                    };
                    let cfg = self.with_backend(opts.to_config());
                    run_dist(oracle, self.constraint.as_ref(), &cfg)
                        .map(|out| {
                            RunReport::from_outcome(&label, &dataset, self.k, &out, m, m, 1)
                        })
                        .map_err(|e| e.to_string())
                }
                AlgoSpec::GreedyMl { m, b } => {
                    let tree = AccumulationTree::new(m, b);
                    let cfg = self.dist_config(tree, false);
                    run_dist(oracle, self.constraint.as_ref(), &cfg)
                        .map(|out| {
                            RunReport::from_outcome(
                                &label,
                                &dataset,
                                self.k,
                                &out,
                                m,
                                b,
                                tree.levels(),
                            )
                        })
                        .map_err(|e| e.to_string())
                }
            };
            match result {
                Ok(report) => {
                    if baseline.is_none() && report.value > 0.0 {
                        baseline = Some(report.value);
                    }
                    let report = match baseline {
                        Some(b) => report.with_baseline(b),
                        None => report,
                    };
                    reports.push(report);
                }
                Err(msg) => failures.push((label, msg)),
            }
        }
        (reports, failures)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_spec_parsing() {
        assert_eq!(AlgoSpec::parse("greedy").unwrap(), AlgoSpec::Greedy);
        assert_eq!(AlgoSpec::parse("greedi:4").unwrap(), AlgoSpec::GreeDi { m: 4 });
        assert_eq!(AlgoSpec::parse(" randgreedi:16 ").unwrap(), AlgoSpec::RandGreedi { m: 16 });
        assert_eq!(
            AlgoSpec::parse("greedyml:32:2").unwrap(),
            AlgoSpec::GreedyMl { m: 32, b: 2 }
        );
        assert!(AlgoSpec::parse("nope").is_err());
        assert!(AlgoSpec::parse("greedyml:8").is_err());
        assert!(AlgoSpec::parse("randgreedi:x").is_err());
        assert!(AlgoSpec::parse("greedyml:8:2").unwrap().label().contains("L=3"));
    }

    #[test]
    fn full_experiment_runs_all_algos() {
        let cfg = Config::parse(
            "name = smoke\n\
             [dataset]\nkind = retail\nn = 300\nseed = 2\n\
             [problem]\nk = 8\n\
             [run]\nalgos = greedy, greedi:4, randgreedi:4, greedyml:4:2\nseed = 5\n",
        )
        .unwrap();
        let exp = Experiment::from_config(&cfg, None).unwrap();
        let (reports, failures) = exp.run();
        assert!(failures.is_empty(), "{failures:?}");
        assert_eq!(reports.len(), 4);
        // First successful run (Greedy) is the 100% baseline.
        assert!((reports[0].rel_value_pct.unwrap() - 100.0).abs() < 1e-9);
        for r in &reports[1..] {
            let rel = r.rel_value_pct.unwrap();
            assert!(rel > 50.0 && rel <= 110.0, "{}: rel {rel}", r.algo);
        }
    }

    #[test]
    fn oom_shows_up_as_failure_not_panic() {
        let cfg = Config::parse(
            "[dataset]\nkind = retail\nn = 400\n\
             [problem]\nk = 8\n\
             [run]\nalgos = greedy\nmem_limit = 1kb\n",
        )
        .unwrap();
        let exp = Experiment::from_config(&cfg, None).unwrap();
        let (reports, failures) = exp.run();
        assert!(reports.is_empty());
        assert_eq!(failures.len(), 1);
        assert!(failures[0].1.contains("out of memory"));
    }

    #[test]
    fn backend_key_parses_and_rejects_garbage() {
        let base = "[dataset]\nkind = retail\nn = 120\n[problem]\nk = 4\n[run]\nalgos = greedy\n";
        let exp = Experiment::from_config(&Config::parse(base).unwrap(), None).unwrap();
        assert_eq!(exp.backend, BackendSpec::Auto);
        assert_eq!(exp.hosts, None);
        assert!(exp.problem_spec.contains("dataset.kind = retail"));
        let threaded = format!("{base}backend = thread\n");
        let exp = Experiment::from_config(&Config::parse(&threaded).unwrap(), None).unwrap();
        assert_eq!(exp.backend, BackendSpec::Thread);
        let bogus = format!("{base}backend = quantum\n");
        assert!(Experiment::from_config(&Config::parse(&bogus).unwrap(), None).is_err());
    }

    #[test]
    fn hosts_key_parses_and_rejects_garbage() {
        let base = "[dataset]\nkind = retail\nn = 120\n[problem]\nk = 4\n[run]\nalgos = greedy\n";
        let hosted = format!("{base}backend = tcp\nhosts = 127.0.0.1:7401, 127.0.0.1:7402\n");
        let exp = Experiment::from_config(&Config::parse(&hosted).unwrap(), None).unwrap();
        assert_eq!(exp.backend, BackendSpec::Tcp);
        assert_eq!(
            exp.hosts,
            Some(vec!["127.0.0.1:7401".to_string(), "127.0.0.1:7402".to_string()])
        );
        let portless = format!("{base}hosts = localhost\n");
        let err = Experiment::from_config(&Config::parse(&portless).unwrap(), None).unwrap_err();
        assert!(err.to_string().contains("run.hosts"), "{err}");
    }

    #[test]
    fn matroid_constraint_selected() {
        let cfg = Config::parse(
            "[dataset]\nkind = retail\nn = 200\n\
             [problem]\nk = 8\nconstraint = matroid\ngroups = 4\n\
             [run]\nalgos = greedyml:4:2\n",
        )
        .unwrap();
        let exp = Experiment::from_config(&cfg, None).unwrap();
        let (reports, failures) = exp.run();
        assert!(failures.is_empty(), "{failures:?}");
        assert_eq!(reports.len(), 1);
        assert!(reports[0].value > 0.0);
    }
}
