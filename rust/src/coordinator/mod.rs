//! The coordinator: experiment configs → datasets → algorithm runs →
//! paper-shaped reports.  This is the layer the CLI, the examples and the
//! benches drive.

pub mod dataset;
pub mod experiment;
pub mod sweep;

pub use dataset::{build_problem, Backend, BuiltProblem};
pub use experiment::{AlgoSpec, Experiment};
pub use sweep::Sweep;

use crate::metrics::RunReport;

/// Render a report table (header + one row per run + failures).
pub fn render_table(reports: &[RunReport], failures: &[(String, String)]) -> String {
    let mut out = String::new();
    out.push_str(&RunReport::header());
    out.push('\n');
    for r in reports {
        out.push_str(&r.row());
        out.push('\n');
    }
    for (algo, msg) in failures {
        out.push_str(&format!("{algo:<14} FAILED: {msg}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_failures() {
        let t = render_table(&[], &[("RG(m=8)".into(), "machine 0 out of memory".into())]);
        assert!(t.contains("FAILED"));
        assert!(t.contains("out of memory"));
        assert!(t.lines().count() >= 2);
    }
}
