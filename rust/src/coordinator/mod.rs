//! The coordinator: experiment configs → datasets → algorithm runs →
//! paper-shaped reports.  This is the layer the CLI, the examples and the
//! benches drive.

pub mod dataset;
pub mod experiment;
pub mod gateway;
pub mod jobs;
pub mod sweep;

pub use dataset::{build_problem, Backend, BuiltProblem};
pub use experiment::{AlgoSpec, Experiment};
pub use gateway::{run_gateway, GatewayClient, GatewayConfig, JobSpec};
pub use jobs::{JobBatch, JobQueue, Submission};
pub use sweep::Sweep;

use crate::metrics::RunReport;
use crate::util::config::Config;

/// Serialize the problem-defining sections of a config (`dataset.*`,
/// `problem.*`, `objective.*`) into flat `key = value` text.  A
/// process-backend worker parses this to rebuild the same oracle and
/// constraint in its own address space — the generators are seeded, so the
/// rebuild is byte-identical.  Values that would not survive a reparse
/// verbatim (a `#` reads as a comment, surrounding quotes get stripped)
/// are quoted with whichever quote character they don't contain.
pub fn problem_spec(cfg: &Config) -> String {
    let mut out = String::new();
    for section in ["dataset", "problem", "objective"] {
        for (k, v) in cfg.section(section) {
            out.push_str(k);
            out.push_str(" = ");
            let needs_quoting = v.contains('#')
                || (v.len() >= 2
                    && ((v.starts_with('"') && v.ends_with('"'))
                        || (v.starts_with('\'') && v.ends_with('\''))));
            if needs_quoting && !v.contains('"') {
                out.push('"');
                out.push_str(v);
                out.push('"');
            } else if needs_quoting && !v.contains('\'') {
                out.push('\'');
                out.push_str(v);
                out.push('\'');
            } else {
                // Pathological (contains '#' plus both quote kinds):
                // shipped raw; the Ready{n} handshake catches a divergent
                // rebuild.
                out.push_str(v);
            }
            out.push('\n');
        }
    }
    out
}

/// Render a report table (header + one row per run + failures).
pub fn render_table(reports: &[RunReport], failures: &[(String, String)]) -> String {
    let mut out = String::new();
    out.push_str(&RunReport::header());
    out.push('\n');
    for r in reports {
        out.push_str(&r.row());
        out.push('\n');
    }
    for r in reports {
        if let Some(f) = &r.faults {
            out.push_str(&format!("{:<14} DEGRADED/FAULTS: {f}\n", r.algo));
        }
    }
    for (algo, msg) in failures {
        out.push_str(&format!("{algo:<14} FAILED: {msg}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_failures() {
        let t = render_table(&[], &[("RG(m=8)".into(), "machine 0 out of memory".into())]);
        assert!(t.contains("FAILED"));
        assert!(t.contains("out of memory"));
        assert!(t.lines().count() >= 2);
    }

    #[test]
    fn table_renders_fault_notes() {
        let mut r = crate::metrics::RunReport {
            algo: "GML(4,2)".into(),
            dataset: "retail".into(),
            k: 10,
            machines: 4,
            branching: 2,
            levels: 2,
            value: 12.0,
            rel_value_pct: None,
            critical_calls: 10,
            total_calls: 40,
            comp_secs: 0.1,
            comm_secs: 0.01,
            peak_mem: 1024,
            faults: Some("faults 1 retries 0 dropped [3] elements lost 120".into()),
        };
        let t = render_table(std::slice::from_ref(&r), &[]);
        assert!(t.contains("DEGRADED/FAULTS"), "{t}");
        assert!(t.contains("elements lost 120"), "{t}");
        r.faults = None;
        let t = render_table(&[r], &[]);
        assert!(!t.contains("DEGRADED"), "fault-free table stays clean:\n{t}");
    }

    #[test]
    fn problem_spec_roundtrips_through_config_parse() {
        let cfg = Config::parse(
            "name = x\n[dataset]\nkind = retail\nn = 300\n[problem]\nk = 8\n\
             [run]\nalgos = greedy\n[objective]\nkind = auto\n",
        )
        .unwrap();
        let spec = problem_spec(&cfg);
        assert!(spec.contains("dataset.kind = retail"));
        assert!(spec.contains("problem.k = 8"));
        assert!(spec.contains("objective.kind = auto"));
        assert!(!spec.contains("run.algos"), "run section must not ship to workers");
        let reparsed = Config::parse(&spec).unwrap();
        assert_eq!(reparsed.str("dataset.kind").unwrap(), "retail");
        assert_eq!(reparsed.u64("problem.k").unwrap(), 8);
        // Building from the spec yields the same problem.
        let a = build_problem(&cfg, None).unwrap();
        let b = build_problem(&reparsed, None).unwrap();
        assert_eq!(a.oracle.n(), b.oracle.n());
        assert_eq!(a.objective, b.objective);
    }

    #[test]
    fn problem_spec_quotes_values_a_reparse_would_mangle() {
        // A '#' in a path must not be read as a comment by the worker.
        let cfg = Config::parse(
            "[dataset]\nkind = edgelist\npath = \"data/graph#v2.txt\"\n",
        )
        .unwrap();
        assert_eq!(cfg.str("dataset.path").unwrap(), "data/graph#v2.txt");
        let spec = problem_spec(&cfg);
        let reparsed = Config::parse(&spec).unwrap();
        assert_eq!(reparsed.str("dataset.path").unwrap(), "data/graph#v2.txt");
    }
}
