//! The job-queue front door of the always-on submodular service.
//!
//! The canonical GreedyML workload is many queries against one dataset —
//! exemplar clustering and summarization sweeps vary `(k, seed,
//! constraint)` while the corpus stays fixed.  This module is the
//! coordinator-side counterpart of the resident-shard sessions in
//! [`crate::dist`]: a thread-shareable [`JobQueue`] that
//!
//! 1. answers repeat queries from a bounded **LRU solution cache**
//!    (keyed by the dataset fingerprint, the constraint spec and every
//!    result-determining run parameter) without touching a worker,
//! 2. refuses jobs whose estimated per-machine memory need exceeds the
//!    queue's **admission budget** *before* any shipping happens —
//!    reproducing the §6.2 "cannot even hold the data" regime as a
//!    polite rejection instead of a mid-run abort — and makes jobs that
//!    fit individually but not *together* wait for in-flight
//!    reservations to drain instead of bouncing them, and
//! 3. runs everything else through a [`SessionPool`], so consecutive
//!    jobs against the same dataset reuse one warm fleet and ship each
//!    partition shard exactly once.
//!
//! Every method takes `&self`: one queue serves concurrent submitters
//! (the gateway daemon's worker threads drive exactly this), with the
//! cache, the counters and the budget ledger guarded by one short-held
//! internal lock — never across a run.  `greedyml submit --config
//! <file>` drives a [`JobBatch`] (the `[jobs]` config section) through
//! one queue, which is the long-lived-coordinator deployment in
//! miniature: the fleet outlives every individual run.

use super::experiment::build_constraint;
use super::BuiltProblem;
use crate::algo::{dataset_fingerprint, run_dist_pooled_live, DistConfig, SessionPool};
use crate::dist::{BackendSpec, CoresetSpec, FaultSpec, ShipSpec, WireSpec};
use crate::objective::Oracle;
use crate::stream::LiveProblem;
use crate::tree::AccumulationTree;
use crate::util::config::Config;
use crate::ElemId;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Default capacity of the solution cache, in entries
/// (`jobs.cache_entries`).
pub const DEFAULT_CACHE_ENTRIES: usize = 256;

/// What the queue did with one submitted job.
#[derive(Clone, Debug)]
pub enum Submission {
    /// The job ran to completion (`warm`: on a reused resident session;
    /// `faults`: human-readable fault summary, empty for a clean run).
    Ran { solution: Vec<ElemId>, value: f64, warm: bool, faults: String },
    /// Served from the solution cache; no worker was touched.
    Cached { solution: Vec<ElemId>, value: f64 },
    /// Refused by admission control; no worker was touched.
    Rejected { reason: String },
}

impl Submission {
    /// The solution value, if the job produced one.
    pub fn value(&self) -> Option<f64> {
        match self {
            Self::Ran { value, .. } | Self::Cached { value, .. } => Some(*value),
            Self::Rejected { .. } => None,
        }
    }

    /// One-word status for tables and logs.
    pub fn status(&self) -> &'static str {
        match self {
            Self::Ran { warm: true, .. } => "warm",
            Self::Ran { warm: false, .. } => "cold",
            Self::Cached { .. } => "cached",
            Self::Rejected { .. } => "rejected",
        }
    }
}

#[derive(Clone)]
struct CachedSolution {
    solution: Vec<ElemId>,
    value: f64,
}

/// One cached solution plus the identity needed to invalidate it: the
/// dataset fingerprint and the epoch the job ran at, so a live-dataset
/// delta can purge exactly the entries it stales.
struct CacheEntry {
    key: u64,
    fingerprint: String,
    epoch: u64,
    hit: CachedSolution,
}

/// Everything the queue mutates, behind one short-held lock.
struct QueueState {
    /// LRU order: front = coldest, back = most recently used.
    cache: Vec<CacheEntry>,
    /// Bytes reserved by admitted jobs still in flight (budget ledger).
    in_flight: u64,
    submitted: u64,
    cache_hits: u64,
    rejected: u64,
    failed: u64,
}

/// A job queue over one warm [`SessionPool`], with a bounded LRU
/// solution cache and memory-budget admission control, shareable across
/// submitter threads.  See the module docs.
pub struct JobQueue {
    pool: SessionPool,
    state: Mutex<QueueState>,
    /// Signalled whenever an in-flight reservation is returned.
    space: Condvar,
    /// Per-machine admission budget in bytes (`None` = admit everything).
    mem_budget: Option<u64>,
    /// Solution-cache capacity in entries (0 disables caching).
    cache_entries: usize,
}

impl Default for JobQueue {
    fn default() -> Self {
        Self::new(None)
    }
}

/// Admission-budget bytes held by one in-flight job.  Dropping it — on
/// completion, failure or panic alike — returns the bytes to the ledger
/// and wakes every submitter waiting for space.
struct Reservation<'a> {
    queue: &'a JobQueue,
    estimate: u64,
}

impl Drop for Reservation<'_> {
    fn drop(&mut self) {
        let mut st = self.queue.state();
        st.in_flight = st.in_flight.saturating_sub(self.estimate);
        self.queue.space.notify_all();
    }
}

impl JobQueue {
    /// A queue with the given per-machine admission budget and the
    /// default cache capacity ([`DEFAULT_CACHE_ENTRIES`]).
    pub fn new(mem_budget: Option<u64>) -> Self {
        Self::with_cache_entries(mem_budget, DEFAULT_CACHE_ENTRIES)
    }

    /// A queue with an explicit solution-cache capacity (`0` disables
    /// caching entirely — every submission runs).
    pub fn with_cache_entries(mem_budget: Option<u64>, cache_entries: usize) -> Self {
        Self {
            pool: SessionPool::new(),
            state: Mutex::new(QueueState {
                cache: Vec::new(),
                in_flight: 0,
                submitted: 0,
                cache_hits: 0,
                rejected: 0,
                failed: 0,
            }),
            space: Condvar::new(),
            mem_budget,
            cache_entries,
        }
    }

    /// The internal lock, recovering from poisoning: a submitter panic
    /// must not brick a long-lived daemon's queue (counters and the LRU
    /// list are valid after any partial update).
    fn state(&self) -> MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Submit one job: cache lookup → admission control → a run on the
    /// warm pool.  `cfg.problem` must carry the job's problem spec (it
    /// defines the constraint and the cache identity); config-built jobs
    /// ([`JobBatch::dist_config`]) always attach it.
    ///
    /// Under a budget, a job whose estimate exceeds it outright is
    /// rejected; a job that fits the budget but not the *remaining*
    /// space (other submitters' reservations) blocks until in-flight
    /// jobs return their bytes, then runs.  Concurrent submitters thus
    /// compete for one ledger instead of overcommitting the fleet.
    pub fn submit(&self, problem: &BuiltProblem, cfg: &DistConfig) -> crate::Result<Submission> {
        self.submit_live(problem, cfg, None)
    }

    /// [`JobQueue::submit`] against a live dataset: the run evaluates
    /// `live`'s post-delta oracle (not the batch's epoch-0 problem), the
    /// pool may advance a one-epoch-stale warm fleet in place instead of
    /// re-establishing ([`run_dist_pooled_live`]), and every cached
    /// solution this dataset produced at an earlier epoch is purged — a
    /// delta invalidates it.  `cfg.epoch` must equal `live`'s epoch.
    pub fn submit_live(
        &self,
        problem: &BuiltProblem,
        cfg: &DistConfig,
        live: Option<&LiveProblem>,
    ) -> crate::Result<Submission> {
        let spec = cfg
            .problem
            .as_deref()
            .ok_or_else(|| anyhow::anyhow!("job has no problem spec (DistConfig::problem)"))?;
        let fingerprint = dataset_fingerprint(spec);
        let oracle: &dyn Oracle = match live {
            Some(l) => l.oracle(),
            None => problem.oracle.as_ref(),
        };
        let key = job_key(cfg, spec, oracle.n());
        {
            let mut st = self.state();
            st.submitted += 1;
            if let Some(l) = live {
                st.cache
                    .retain(|e| e.fingerprint != fingerprint || e.epoch >= l.epoch());
            }
            if let Some(pos) = st.cache.iter().position(|e| e.key == key) {
                let entry = st.cache.remove(pos);
                let hit = entry.hit.clone();
                st.cache.push(entry); // most recently used
                st.cache_hits += 1;
                return Ok(Submission::Cached { solution: hit.solution, value: hit.value });
            }
        }
        let spec_cfg =
            Config::parse(spec).map_err(|e| anyhow::anyhow!("job problem spec: {e}"))?;
        let (constraint, k) = build_constraint(&spec_cfg, oracle.n())?;
        let _reservation = match self.mem_budget {
            None => None,
            Some(budget) => {
                let estimate = admission_estimate(problem, cfg, k);
                if estimate > budget {
                    self.state().rejected += 1;
                    return Ok(Submission::Rejected {
                        reason: format!(
                            "estimated {estimate} bytes per machine exceeds the \
                             {budget}-byte admission budget (≈{} shard elements + \
                             {}×{k} fan-in solution elements); raise jobs.mem_budget, \
                             add machines, or deepen the tree",
                            shard_elems(problem, cfg),
                            fan_in(cfg),
                        ),
                    });
                }
                let mut st = self.state();
                while estimate > budget.saturating_sub(st.in_flight) {
                    st = self.space.wait(st).unwrap_or_else(PoisonError::into_inner);
                }
                st.in_flight += estimate;
                Some(Reservation { queue: self, estimate })
            }
        };
        let run = run_dist_pooled_live(oracle, constraint.as_ref(), cfg, &self.pool, live)
            .map_err(|e| {
                self.state().failed += 1;
                anyhow::anyhow!(e)
            })?;
        let out = run.outcome;
        let faults =
            (!out.faults.is_empty()).then(|| out.faults.to_string()).unwrap_or_default();
        // A *degraded* solution (machines dropped mid-run) is feasible but
        // not this job's canonical answer — never cache it, so a repeat
        // submission recomputes against a healthy fleet.
        if self.cache_entries > 0 && out.faults.machines_dropped.is_empty() {
            let mut st = self.state();
            st.cache.retain(|e| e.key != key);
            st.cache.push(CacheEntry {
                key,
                fingerprint,
                epoch: cfg.epoch,
                hit: CachedSolution { solution: out.solution.clone(), value: out.value },
            });
            while st.cache.len() > self.cache_entries {
                st.cache.remove(0); // evict the coldest
            }
        }
        Ok(Submission::Ran { solution: out.solution, value: out.value, warm: run.warm, faults })
    }

    /// Jobs submitted (including cached and rejected ones).
    pub fn submitted(&self) -> u64 {
        self.state().submitted
    }

    /// Jobs answered from the solution cache.
    pub fn cache_hits(&self) -> u64 {
        self.state().cache_hits
    }

    /// Jobs refused by admission control.
    pub fn rejected(&self) -> u64 {
        self.state().rejected
    }

    /// Jobs that errored in flight (after admission, after the pool's own
    /// retry policy gave up).
    pub fn failed(&self) -> u64 {
        self.state().failed
    }

    /// Solutions currently cached (≤ the configured capacity).
    pub fn cache_len(&self) -> usize {
        self.state().cache.len()
    }

    /// The warm fleet store (init-byte and warm/cold counters live there).
    pub fn pool(&self) -> &SessionPool {
        &self.pool
    }
}

/// FNV-1a over the canonical job identity: the dataset fingerprint, the
/// `problem.*` constraint keys, and every run parameter that changes the
/// result.  Two configs that would produce bit-identical outcomes hash
/// identically; anything result-relevant that differs (k, seed, tree
/// shape, argmax semantics…) lands in a different slot.
fn job_key(cfg: &DistConfig, spec: &str, n: usize) -> u64 {
    let problem_keys: String = match Config::parse(spec) {
        Ok(c) => c.section("problem").map(|(k, v)| format!("{k}={v}\n")).collect(),
        Err(_) => spec.to_string(),
    };
    let canon = format!(
        "fp={fp}\n{problem_keys}n={n}\nkind={kind:?}\nseed={seed}\nm={m}\nb={b}\n\
         scheme={scheme:?}\nlocal_view={lv}\nadded={added}\ncompare={cmp}\n\
         epoch={epoch}\ncoreset={coreset}\n",
        fp = dataset_fingerprint(spec),
        n = n,
        kind = cfg.kind,
        seed = cfg.seed,
        m = cfg.tree.machines(),
        b = cfg.tree.branching(),
        scheme = cfg.partition,
        lv = cfg.local_view,
        added = cfg.added_elements,
        cmp = cfg.compare_all_children,
        // A delta re-solve must never replay a pre-delta answer, and a
        // sieve-filtered run is a different result from a full one.
        epoch = cfg.epoch,
        coreset = cfg.coreset.resolve().unwrap_or(false),
    );
    let mut h: u64 = 0xcbf29ce484222325;
    for byte in canon.as_bytes() {
        h ^= u64::from(*byte);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Elements the largest leaf shard holds: ⌈n/m⌉ plus the §6.4 added
/// elements a deepest-path machine bakes into its resident shard.
fn shard_elems(problem: &BuiltProblem, cfg: &DistConfig) -> u64 {
    let n = problem.oracle.n() as u64;
    let m = u64::from(cfg.tree.machines()).max(1);
    n.div_ceil(m) + (cfg.added_elements as u64) * u64::from(cfg.tree.levels())
}

/// Solution sets an accumulator holds in one superstep: its own plus
/// (b − 1) retiring children's — b in total, each at most k elements.
fn fan_in(cfg: &DistConfig) -> u64 {
    u64::from(cfg.tree.branching().max(2))
}

/// Per-machine memory the job is estimated to need, in bytes: the
/// largest resident shard plus one superstep's fan-in of k-element
/// solutions (shipped with their data under partition shipping), at the
/// per-element cost probed from a real one-element shard.  Deliberately
/// coarse — admission control guards against the §6.2 regime where the
/// data alone overwhelms a machine, not against kilobyte-level drift;
/// the in-run [`MemoryMeter`](crate::dist::MemoryMeter) stays the
/// precise enforcer.
fn admission_estimate(problem: &BuiltProblem, cfg: &DistConfig, k: usize) -> u64 {
    let per_elem = probe_elem_bytes(problem);
    (shard_elems(problem, cfg) + fan_in(cfg) * k as u64) * per_elem
}

/// Serialized bytes of a one-element shard of this problem — an upper
/// bound on marginal per-element cost (it carries the payload framing
/// too).  Non-partitionable oracles fall back to a flat guess.
fn probe_elem_bytes(problem: &BuiltProblem) -> u64 {
    const FALLBACK: u64 = 64;
    match problem.oracle.partitionable() {
        Some(p) if problem.oracle.n() > 0 => {
            let payload = p.extract_partition(&[0]);
            serde_json::to_string(&payload.to_value())
                .map(|s| s.len() as u64)
                .unwrap_or(FALLBACK)
                .max(1)
        }
        _ => FALLBACK,
    }
}

/// The `[jobs]` config section: a batch of `(k, seed)` queries against
/// one dataset, plus the fleet they run on.  `greedyml submit` drives
/// this through a [`JobQueue`].
pub struct JobBatch {
    /// k values to query (`jobs.ks`, required).
    pub ks: Vec<usize>,
    /// Tape seeds (`jobs.seeds`, default `42`).  The batch is the
    /// cartesian product seeds × ks, seed-major — all of one seed's ks
    /// run back-to-back so partition-shipped sessions stay warm.
    pub seeds: Vec<u64>,
    /// Fleet width (`jobs.machines`, default 8).
    pub machines: u32,
    /// Accumulation-tree branching (`jobs.branching`, default 2).
    pub branching: u32,
    /// Execution backend (`jobs.backend`, default auto).
    pub backend: BackendSpec,
    /// Ship mode (`jobs.ship`, default auto).
    pub ship: ShipSpec,
    /// Worker daemons for the tcp backend (`jobs.hosts`).
    pub hosts: Option<Vec<String>>,
    /// Machine-local evaluation views (`jobs.local_view`, default false).
    pub local_view: bool,
    /// Executor width (`jobs.threads`; 0 or absent = auto).
    pub threads: Option<usize>,
    /// Admission budget in bytes (`jobs.mem_budget`, e.g. `64mb`;
    /// absent = admit everything).
    pub mem_budget: Option<u64>,
    /// Solution-cache capacity in entries (`jobs.cache_entries`,
    /// default [`DEFAULT_CACHE_ENTRIES`]; 0 disables caching).
    pub cache_entries: usize,
    /// Worker-loss policy for remote backends (`jobs.on_fault`, default
    /// auto → `GREEDYML_ON_FAULT` → fail).
    pub on_fault: FaultSpec,
    /// Frame encoding on the worker wire (`jobs.wire`, default auto →
    /// `GREEDYML_WIRE` → json).  Deliberately *not* part of the job
    /// cache key ([`job_key`]): results are bit-identical across modes.
    pub wire: WireSpec,
    /// Sieve-coreset leaves (`jobs.coreset` / `--coreset`, default auto
    /// → `GREEDYML_CORESET` → off).  Unlike `wire` this *is* part of
    /// the cache key: a coreset run answers with a different value.
    pub coreset: CoresetSpec,
}

impl JobBatch {
    /// Parse the `[jobs]` section.
    pub fn from_config(cfg: &Config) -> crate::Result<Self> {
        let ks = cfg
            .u64_list("jobs.ks")?
            .into_iter()
            .map(|k| k as usize)
            .collect::<Vec<_>>();
        anyhow::ensure!(!ks.is_empty(), "jobs.ks is empty");
        let seeds = match cfg.get("jobs.seeds") {
            None => vec![42],
            Some(_) => cfg.u64_list("jobs.seeds")?,
        };
        anyhow::ensure!(!seeds.is_empty(), "jobs.seeds is empty");
        let backend = BackendSpec::parse(cfg.str_or("jobs.backend", "auto"))
            .map_err(|e| anyhow::anyhow!("jobs.backend: {e}"))?;
        let ship = ShipSpec::parse(cfg.str_or("jobs.ship", "auto"))
            .map_err(|e| anyhow::anyhow!("jobs.ship: {e}"))?;
        let mem_budget = match cfg.get("jobs.mem_budget") {
            None | Some("none") => None,
            Some(v) => Some(
                crate::util::config::parse_u64(v)
                    .map_err(|m| anyhow::anyhow!("jobs.mem_budget: {m}"))?,
            ),
        };
        let on_fault = FaultSpec::parse(cfg.str_or("jobs.on_fault", "auto"))
            .map_err(|e| anyhow::anyhow!("jobs.on_fault: {e}"))?;
        let wire = WireSpec::parse(cfg.str_or("jobs.wire", "auto"))
            .map_err(|e| anyhow::anyhow!("jobs.wire: {e}"))?;
        let coreset = CoresetSpec::parse(cfg.str_or("jobs.coreset", "auto"))
            .map_err(|e| anyhow::anyhow!("jobs.coreset: {e}"))?;
        Ok(Self {
            ks,
            seeds,
            machines: cfg.u64_or("jobs.machines", 8)? as u32,
            branching: cfg.u64_or("jobs.branching", 2)? as u32,
            backend,
            ship,
            hosts: crate::dist::tcp::hosts_from_config(cfg, "jobs.hosts")?,
            local_view: cfg.bool_or("jobs.local_view", false)?,
            threads: match cfg.u64_or("jobs.threads", 0)? {
                0 => None,
                t => Some(t as usize),
            },
            mem_budget,
            cache_entries: cfg.u64_or("jobs.cache_entries", DEFAULT_CACHE_ENTRIES as u64)?
                as usize,
            on_fault,
            wire,
            coreset,
        })
    }

    /// Every `(seed, k)` job in submission order (seed-major).
    pub fn jobs(&self) -> Vec<(u64, usize)> {
        let mut out = Vec::with_capacity(self.seeds.len() * self.ks.len());
        for &seed in &self.seeds {
            for &k in &self.ks {
                out.push((seed, k));
            }
        }
        out
    }

    /// The engine config of one job.  The job's `problem.k` is appended
    /// to the shipped spec (later keys win), so remote workers rebuild
    /// the constraint this job actually runs.
    pub fn dist_config(&self, cfg: &Config, k: usize, seed: u64) -> DistConfig {
        let spec = format!("{}problem.k = {k}\n", super::problem_spec(cfg));
        DistConfig {
            backend: self.backend,
            ship: self.ship,
            hosts: self.hosts.clone(),
            problem: Some(spec),
            threads: self.threads,
            local_view: self.local_view,
            on_fault: self.on_fault,
            wire: self.wire,
            coreset: self.coreset,
            ..DistConfig::greedyml(
                AccumulationTree::new(self.machines, self.branching),
                seed,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::build_problem;

    fn retail_config(n: usize) -> Config {
        Config::parse(&format!(
            "[dataset]\nkind = retail\nn = {n}\nseed = 2\n[problem]\nk = 6\n\
             [jobs]\nks = 4, 6\nseeds = 1, 2\nmachines = 4\n"
        ))
        .unwrap()
    }

    #[test]
    fn batch_parses_the_jobs_section() {
        let cfg = retail_config(200);
        let batch = JobBatch::from_config(&cfg).unwrap();
        assert_eq!(batch.ks, vec![4, 6]);
        assert_eq!(batch.seeds, vec![1, 2]);
        assert_eq!(batch.machines, 4);
        assert_eq!(batch.branching, 2);
        assert_eq!(batch.cache_entries, DEFAULT_CACHE_ENTRIES);
        assert_eq!(batch.jobs(), vec![(1, 4), (1, 6), (2, 4), (2, 6)]);
        assert!(JobBatch::from_config(&Config::parse("[jobs]\nks = \n").unwrap()).is_err());
        let capped = Config::parse("[jobs]\nks = 4\ncache_entries = 3\n").unwrap();
        assert_eq!(JobBatch::from_config(&capped).unwrap().cache_entries, 3);
    }

    #[test]
    fn repeat_jobs_hit_the_solution_cache() {
        let cfg = retail_config(200);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let queue = JobQueue::new(None);
        let dist = batch.dist_config(&cfg, 4, 1);
        let first = queue.submit(&problem, &dist).unwrap();
        let again = queue.submit(&problem, &dist).unwrap();
        let (Submission::Ran { solution: a, value: va, .. },
             Submission::Cached { solution: b, value: vb }) = (first, again)
        else {
            panic!("expected Ran then Cached");
        };
        assert_eq!(a, b);
        assert_eq!(va.to_bits(), vb.to_bits(), "cache replay is bit-identical");
        assert_eq!(queue.cache_hits(), 1);
        assert_eq!(queue.submitted(), 2);
    }

    #[test]
    fn distinct_jobs_do_not_collide_in_the_cache() {
        let cfg = retail_config(200);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let queue = JobQueue::new(None);
        for (seed, k) in batch.jobs() {
            let sub = queue.submit(&problem, &batch.dist_config(&cfg, k, seed)).unwrap();
            assert!(matches!(sub, Submission::Ran { .. }), "each distinct job runs");
        }
        assert_eq!(queue.cache_hits(), 0);
        let k4 = queue
            .submit(&problem, &batch.dist_config(&cfg, 4, 1))
            .unwrap();
        match k4 {
            Submission::Cached { solution, .. } => assert!(solution.len() <= 4),
            other => panic!("expected a cache hit, got {other:?}"),
        }
    }

    #[test]
    fn cache_evicts_least_recently_used_entry() {
        // Capacity 1: the second distinct job evicts the first, so the
        // first runs again on re-submission while the second stays hot.
        let cfg = retail_config(200);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let queue = JobQueue::with_cache_entries(None, 1);
        let a = batch.dist_config(&cfg, 4, 1);
        let b = batch.dist_config(&cfg, 6, 1);
        assert!(matches!(queue.submit(&problem, &a).unwrap(), Submission::Ran { .. }));
        assert!(matches!(queue.submit(&problem, &b).unwrap(), Submission::Ran { .. }));
        assert_eq!(queue.cache_len(), 1, "capacity bounds the cache");
        assert!(
            matches!(queue.submit(&problem, &b).unwrap(), Submission::Cached { .. }),
            "most recent entry stays hot"
        );
        assert!(
            matches!(queue.submit(&problem, &a).unwrap(), Submission::Ran { .. }),
            "evicted entry reruns"
        );
    }

    #[test]
    fn cache_hits_refresh_recency() {
        // Capacity 2: touching A makes B the coldest, so a third job
        // evicts B and A survives.
        let cfg = retail_config(200);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let queue = JobQueue::with_cache_entries(None, 2);
        let a = batch.dist_config(&cfg, 4, 1);
        let b = batch.dist_config(&cfg, 6, 1);
        let c = batch.dist_config(&cfg, 4, 2);
        queue.submit(&problem, &a).unwrap();
        queue.submit(&problem, &b).unwrap();
        assert!(matches!(queue.submit(&problem, &a).unwrap(), Submission::Cached { .. }));
        queue.submit(&problem, &c).unwrap(); // evicts b, the coldest
        assert!(matches!(queue.submit(&problem, &a).unwrap(), Submission::Cached { .. }));
        assert!(
            matches!(queue.submit(&problem, &b).unwrap(), Submission::Ran { .. }),
            "the coldest entry was evicted"
        );
    }

    #[test]
    fn zero_cache_entries_disables_caching() {
        let cfg = retail_config(200);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let queue = JobQueue::with_cache_entries(None, 0);
        let dist = batch.dist_config(&cfg, 4, 1);
        assert!(matches!(queue.submit(&problem, &dist).unwrap(), Submission::Ran { .. }));
        assert!(
            matches!(queue.submit(&problem, &dist).unwrap(), Submission::Ran { .. }),
            "nothing is ever cached at capacity 0"
        );
        assert_eq!(queue.cache_len(), 0);
        assert_eq!(queue.cache_hits(), 0);
    }

    #[test]
    fn admission_control_rejects_before_touching_workers() {
        let cfg = retail_config(400);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let mut queue = JobQueue::new(Some(16));
        let sub = queue.submit(&problem, &batch.dist_config(&cfg, 4, 1)).unwrap();
        match sub {
            Submission::Rejected { reason } => {
                assert!(reason.contains("admission budget"), "{reason}");
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        assert_eq!(queue.rejected(), 1);
        assert_eq!(queue.pool().jobs_run(), 0, "no worker was touched");
        // A rejected job is not cached: raising the budget lets it run.
        queue.mem_budget = Some(u64::MAX);
        let sub = queue.submit(&problem, &batch.dist_config(&cfg, 4, 1)).unwrap();
        assert!(matches!(sub, Submission::Ran { .. }), "re-submission after raise runs");
    }

    #[test]
    fn submission_status_words() {
        let ran =
            Submission::Ran { solution: vec![], value: 1.0, warm: true, faults: String::new() };
        assert_eq!(ran.status(), "warm");
        assert!(ran.value().is_some());
        let rej = Submission::Rejected { reason: "x".into() };
        assert_eq!(rej.status(), "rejected");
        assert!(rej.value().is_none());
    }

    #[test]
    fn estimate_exactly_at_budget_is_admitted() {
        // Admission rejects on `estimate > budget`: a job that needs the
        // whole budget and not a byte more must run, not bounce — the
        // boundary belongs to the user.
        let cfg = retail_config(200);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let dist = batch.dist_config(&cfg, 4, 1);
        let estimate = admission_estimate(&problem, &dist, 4);
        let queue = JobQueue::new(Some(estimate));
        let sub = queue.submit(&problem, &dist).unwrap();
        assert!(matches!(sub, Submission::Ran { .. }), "estimate == budget admits");
        assert_eq!(queue.rejected(), 0);
        let tight = JobQueue::new(Some(estimate - 1));
        let sub = tight.submit(&problem, &dist).unwrap();
        assert!(matches!(sub, Submission::Rejected { .. }), "one byte less rejects");
    }

    #[test]
    fn zero_budget_rejects_everything_without_workers() {
        let cfg = retail_config(200);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let queue = JobQueue::new(Some(0));
        for (seed, k) in batch.jobs() {
            let sub = queue.submit(&problem, &batch.dist_config(&cfg, k, seed)).unwrap();
            assert!(matches!(sub, Submission::Rejected { .. }));
        }
        assert_eq!(queue.rejected(), 4);
        assert_eq!(queue.pool().jobs_run(), 0, "no worker was ever touched");
        assert_eq!(queue.cache_hits(), 0, "rejected jobs are never cached");
    }

    #[test]
    fn cache_keys_distinguish_constraint_specs() {
        // Two jobs identical in every engine parameter but the constraint
        // spec (cardinality vs matroid over the same k) must not share a
        // cache slot — the constraint lives only in the problem text.
        let cfg = retail_config(200);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let card = batch.dist_config(&cfg, 4, 1);
        let matroid = DistConfig {
            problem: Some(format!(
                "{}problem.constraint = matroid\nproblem.groups = 2\n",
                card.problem.as_deref().unwrap()
            )),
            ..card.clone()
        };
        let n = problem.oracle.n();
        assert_ne!(
            job_key(&card, card.problem.as_deref().unwrap(), n),
            job_key(&matroid, matroid.problem.as_deref().unwrap(), n),
            "constraint keys are part of the cache identity"
        );
        let queue = JobQueue::new(None);
        let first = queue.submit(&problem, &card).unwrap();
        let second = queue.submit(&problem, &matroid).unwrap();
        assert!(matches!(first, Submission::Ran { .. }));
        assert!(matches!(second, Submission::Ran { .. }), "no false cache hit");
        assert_eq!(queue.cache_hits(), 0);
    }

    #[test]
    fn epoch_and_coreset_join_the_cache_identity() {
        // A delta re-solve (same dataset, bumped epoch) and a sieve-run
        // (coreset on) are different answers — neither may replay the
        // epoch-0 full-greedy cache entry.
        let cfg = retail_config(200);
        let batch = JobBatch::from_config(&cfg).unwrap();
        let base = batch.dist_config(&cfg, 4, 1);
        let spec = base.problem.clone().unwrap();
        let bumped = DistConfig { epoch: 1, ..base.clone() };
        assert_ne!(job_key(&base, &spec, 200), job_key(&bumped, &spec, 200));
        let sieved =
            DistConfig { coreset: crate::dist::CoresetSpec::On, ..base.clone() };
        assert_ne!(job_key(&base, &spec, 200), job_key(&sieved, &spec, 200));
    }

    #[test]
    fn counters_reconcile_over_a_mixed_sequence() {
        let cfg = retail_config(200);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let dist = batch.dist_config(&cfg, 4, 1);
        let mut queue = JobQueue::new(None);
        queue.submit(&problem, &dist).unwrap(); // ran
        queue.submit(&problem, &dist).unwrap(); // cached
        queue.mem_budget = Some(0);
        queue.submit(&problem, &batch.dist_config(&cfg, 6, 1)).unwrap(); // rejected
        queue.submit(&problem, &dist).unwrap(); // cached — cache precedes admission
        queue.mem_budget = None;
        queue.submit(&problem, &batch.dist_config(&cfg, 6, 1)).unwrap(); // ran
        assert_eq!(queue.submitted(), 5);
        assert_eq!(queue.cache_hits(), 2);
        assert_eq!(queue.rejected(), 1);
        assert_eq!(queue.failed(), 0);
        assert_eq!(
            queue.submitted(),
            queue.cache_hits() + queue.rejected() + queue.failed() + 2,
            "every submission is accounted exactly once (2 ran)"
        );
    }

    #[test]
    fn concurrent_submitters_share_one_queue() {
        // Two threads drive distinct jobs through one &JobQueue — the
        // gateway worker pool in miniature.  Counters reconcile and each
        // job's solution is immediately replayable from the cache.
        let cfg = retail_config(200);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let queue = JobQueue::new(None);
        let firsts = std::thread::scope(|scope| {
            let handles = [(1u64, 4usize), (2, 6)].map(|(seed, k)| {
                let (queue, problem, batch, cfg) = (&queue, &problem, &batch, &cfg);
                scope.spawn(move || {
                    let dist = batch.dist_config(cfg, k, seed);
                    let sub = queue.submit(problem, &dist).unwrap();
                    match sub {
                        Submission::Ran { value, .. } => (dist, value),
                        other => panic!("expected Ran, got {other:?}"),
                    }
                })
            });
            handles.map(|h| h.join().unwrap())
        });
        assert_eq!(queue.submitted(), 2);
        assert_eq!(queue.cache_hits(), 0);
        assert_eq!(queue.failed(), 0);
        for (dist, value) in &firsts {
            match queue.submit(&problem, dist).unwrap() {
                Submission::Cached { value: v, .. } => {
                    assert_eq!(v.to_bits(), value.to_bits(), "cache replay is bit-identical");
                }
                other => panic!("expected Cached, got {other:?}"),
            }
        }
    }

    #[test]
    fn budget_arbitration_waits_instead_of_rejecting() {
        // A budget that fits exactly one job at a time: two concurrent
        // submitters must serialize on the ledger — both complete, none
        // is rejected, the fleet is never overcommitted.
        let cfg = retail_config(200);
        let problem = build_problem(&cfg, None).unwrap();
        let batch = JobBatch::from_config(&cfg).unwrap();
        let a = batch.dist_config(&cfg, 4, 1);
        let b = batch.dist_config(&cfg, 6, 2);
        let budget = admission_estimate(&problem, &a, 4).max(admission_estimate(&problem, &b, 6));
        let queue = JobQueue::new(Some(budget));
        std::thread::scope(|scope| {
            for dist in [&a, &b] {
                let (queue, problem) = (&queue, &problem);
                scope.spawn(move || {
                    let sub = queue.submit(problem, dist).unwrap();
                    assert!(matches!(sub, Submission::Ran { .. }), "admitted after waiting");
                });
            }
        });
        assert_eq!(queue.rejected(), 0, "fitting jobs wait for space, never bounce");
        assert_eq!(queue.failed(), 0);
        assert_eq!(queue.submitted(), 2);
    }
}
