//! Dataset construction from experiment configs: synthetic generator
//! presets matching the paper's testbed (Table 2) plus file loaders for
//! real data.

use crate::data::gen;
use crate::data::{CsrGraph, DatasetSummary, ItemsetCollection, VectorSet};
use crate::objective::{KCover, KDominatingSet, KMedoid, Oracle};
use crate::runtime::{Engine, KCoverPjrt, KMedoidPjrt};
use crate::util::config::Config;
use std::sync::Arc;

/// Which gain-evaluation backend to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure Rust oracles.
    Cpu,
    /// AOT Pallas kernels through PJRT.
    Pjrt,
}

/// A built dataset + oracle, ready to run.
pub struct BuiltProblem {
    /// The oracle (CPU or PJRT-backed).
    pub oracle: Arc<dyn Oracle>,
    /// Table 2-style dataset summary.
    pub summary: DatasetSummary,
    /// Objective label for reports.
    pub objective: &'static str,
}

/// Build the dataset + oracle described by the `[dataset]` / `[objective]`
/// sections of `cfg`. `engine` is required when `objective.backend = pjrt`.
pub fn build_problem(cfg: &Config, engine: Option<Arc<Engine>>) -> crate::Result<BuiltProblem> {
    let kind = cfg.str_or("dataset.kind", "road");
    let seed = cfg.u64_or("dataset.seed", 1)?;
    let objective = cfg.str_or("objective.kind", "auto");
    let backend = match cfg.str_or("objective.backend", "cpu") {
        "cpu" => Backend::Cpu,
        "pjrt" => Backend::Pjrt,
        other => anyhow::bail!("objective.backend '{other}' (cpu|pjrt)"),
    };

    match kind {
        "road" | "belgium" => {
            let n = cfg.u64_or("dataset.n", 1 << 14)? as usize;
            let params = if kind == "belgium" {
                gen::RoadParams::belgium_like(n)
            } else {
                gen::RoadParams::usa_like(n)
            };
            let g = Arc::new(gen::road(params, seed));
            graph_problem(cfg, g, kind, objective)
        }
        "rmat" | "friendster" => {
            let scale = cfg.u64_or("dataset.scale", 14)? as u32;
            let g = Arc::new(gen::rmat(gen::RmatParams::friendster_like(scale), seed));
            graph_problem(cfg, g, kind, objective)
        }
        "ba" => {
            let n = cfg.u64_or("dataset.n", 1 << 14)? as usize;
            let attach = cfg.u64_or("dataset.attach", 3)? as usize;
            let g = Arc::new(gen::barabasi_albert(n, attach, seed));
            graph_problem(cfg, g, kind, objective)
        }
        "edgelist" => {
            let path = cfg.str("dataset.path")?;
            let g = Arc::new(CsrGraph::load_edge_list(path)?);
            graph_problem(cfg, g, path, objective)
        }
        "transactions" | "webdocs" | "kosarak" | "retail" => {
            let n = cfg.u64_or("dataset.n", 4000)? as usize;
            let params = match kind {
                "webdocs" => gen::TransactionParams::webdocs_like(n),
                "kosarak" => gen::TransactionParams::kosarak_like(n),
                "retail" => gen::TransactionParams::retail_like(n),
                _ => gen::TransactionParams {
                    num_sets: n,
                    num_items: cfg.u64_or("dataset.items", n as u64 / 4)? as usize,
                    mean_size: cfg.f64_or("dataset.mean_size", 8.0)?,
                    zipf_s: cfg.f64_or("dataset.zipf", 1.0)?,
                },
            };
            let data = Arc::new(gen::transactions(params, seed));
            cover_problem(data, kind, backend, engine)
        }
        "fimi" => {
            let path = cfg.str("dataset.path")?;
            let data = Arc::new(ItemsetCollection::load_fimi(path)?);
            cover_problem(data, path, backend, engine)
        }
        "gaussian" | "tiny_imagenet" => {
            let n = cfg.u64_or("dataset.n", 2048)? as usize;
            let dim = cfg.u64_or("dataset.dim", 128)? as usize;
            let params = if kind == "tiny_imagenet" {
                gen::GaussianParams::tiny_imagenet_like(n, dim)
            } else {
                gen::GaussianParams {
                    n,
                    dim,
                    classes: cfg.u64_or("dataset.classes", 16)? as usize,
                    noise: cfg.f64_or("dataset.noise", 0.35)?,
                }
            };
            let (vs, _labels) = gen::gaussian_mixture(params, seed);
            medoid_problem(Arc::new(vs), kind, backend, engine)
        }
        "fvecs" => {
            let path = cfg.str("dataset.path")?;
            let mut vs = VectorSet::load_fvecs(path)?;
            vs.normalize_rows();
            medoid_problem(Arc::new(vs), path, backend, engine)
        }
        other => anyhow::bail!("unknown dataset.kind '{other}'"),
    }
}

fn graph_problem(
    cfg: &Config,
    g: Arc<CsrGraph>,
    name: &str,
    objective: &str,
) -> crate::Result<BuiltProblem> {
    anyhow::ensure!(
        matches!(objective, "auto" | "kdom"),
        "graph datasets serve the k-dominating-set objective, got '{objective}'"
    );
    let summary = DatasetSummary::of_graph(name, &g);
    let closed = cfg.bool_or("objective.closed", false)?;
    let oracle: Arc<dyn Oracle> = if closed {
        Arc::new(KDominatingSet::closed(g))
    } else {
        Arc::new(KDominatingSet::new(g))
    };
    Ok(BuiltProblem { oracle, summary, objective: "k-dominating-set" })
}

fn cover_problem(
    data: Arc<ItemsetCollection>,
    name: &str,
    backend: Backend,
    engine: Option<Arc<Engine>>,
) -> crate::Result<BuiltProblem> {
    let summary = DatasetSummary::of_itemsets(name, &data);
    let oracle: Arc<dyn Oracle> = match backend {
        Backend::Cpu => Arc::new(KCover::new(data)),
        Backend::Pjrt => {
            let engine =
                engine.ok_or_else(|| anyhow::anyhow!("pjrt backend needs loaded artifacts"))?;
            Arc::new(KCoverPjrt::new(data, engine)?)
        }
    };
    Ok(BuiltProblem { oracle, summary, objective: "k-cover" })
}

fn medoid_problem(
    vs: Arc<VectorSet>,
    name: &str,
    backend: Backend,
    engine: Option<Arc<Engine>>,
) -> crate::Result<BuiltProblem> {
    let summary = DatasetSummary::of_vectors(name, &vs);
    let oracle: Arc<dyn Oracle> = match backend {
        Backend::Cpu => Arc::new(KMedoid::new(vs)),
        Backend::Pjrt => {
            let engine =
                engine.ok_or_else(|| anyhow::anyhow!("pjrt backend needs loaded artifacts"))?;
            Arc::new(KMedoidPjrt::new(vs, engine)?)
        }
    };
    Ok(BuiltProblem { oracle, summary, objective: "k-medoid" })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(text: &str) -> Config {
        Config::parse(text).unwrap()
    }

    #[test]
    fn builds_each_synthetic_kind() {
        for (text, objective) in [
            ("[dataset]\nkind = road\nn = 256\n", "k-dominating-set"),
            ("[dataset]\nkind = rmat\nscale = 8\n", "k-dominating-set"),
            ("[dataset]\nkind = ba\nn = 300\nattach = 2\n", "k-dominating-set"),
            ("[dataset]\nkind = retail\nn = 200\n", "k-cover"),
            ("[dataset]\nkind = gaussian\nn = 64\ndim = 8\nclasses = 4\n", "k-medoid"),
        ] {
            let p = build_problem(&cfg(text), None).unwrap();
            assert_eq!(p.objective, objective, "{text}");
            assert!(p.oracle.n() > 0);
            assert_eq!(p.summary.n, p.oracle.n());
        }
    }

    #[test]
    fn pjrt_backend_without_engine_errors() {
        let c = cfg("[dataset]\nkind = retail\nn = 50\n[objective]\nbackend = pjrt\n");
        assert!(build_problem(&c, None).is_err());
    }

    #[test]
    fn unknown_kind_errors() {
        assert!(build_problem(&cfg("[dataset]\nkind = nope\n"), None).is_err());
        let c = cfg("[dataset]\nkind = road\nn = 64\n[objective]\nkind = kmedoid\n");
        assert!(build_problem(&c, None).is_err(), "graph + kmedoid mismatch");
    }

    #[test]
    fn file_loaders_roundtrip() {
        let dir = std::env::temp_dir();
        let edge = dir.join("greedyml_test_edges.txt");
        std::fs::write(&edge, "0 1\n1 2\n").unwrap();
        let c = cfg(&format!("[dataset]\nkind = edgelist\npath = {}\n", edge.display()));
        let p = build_problem(&c, None).unwrap();
        assert_eq!(p.oracle.n(), 3);
        std::fs::remove_file(&edge).ok();

        let fimi = dir.join("greedyml_test.fimi");
        std::fs::write(&fimi, "1 2 3\n2 4\n").unwrap();
        let c = cfg(&format!("[dataset]\nkind = fimi\npath = {}\n", fimi.display()));
        let p = build_problem(&c, None).unwrap();
        assert_eq!(p.oracle.n(), 2);
        std::fs::remove_file(&fimi).ok();
    }
}
