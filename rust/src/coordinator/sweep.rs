//! Parameter sweeps: run an experiment grid (k values × algorithm variants)
//! and emit long-form rows — the driver behind the figure benches and the
//! `greedyml sweep` subcommand.  Results aggregate with geometric means per
//! the paper's reporting convention (§6).

use super::experiment::AlgoSpec;
use super::BuiltProblem;
use crate::algo::{greedi_config, run_dist_pooled, run_sequential, DistConfig, SessionPool};
use crate::constraint::Cardinality;
use crate::dist::{BackendSpec, CoresetSpec, FaultReport, FaultSpec, ShipSpec, WireSpec};
use crate::greedy::GreedyKind;
use crate::metrics::RunReport;
use crate::tree::AccumulationTree;
use crate::util::config::Config;
use crate::util::stats::geomean;

/// A sweep: the cartesian product of k values and algorithm variants on one
/// problem.
pub struct Sweep {
    /// k values to sweep.
    pub ks: Vec<usize>,
    /// Algorithm variants.
    pub algos: Vec<AlgoSpec>,
    /// Repetitions with distinct tape seeds (paper: six, geomean reported).
    pub reps: u64,
    /// Base seed.
    pub seed: u64,
    /// Per-machine memory limit.
    pub mem_limit: Option<u64>,
    /// k-medoid local-view scheme.
    pub local_view: bool,
    /// Execution backend for the distributed variants.
    pub backend: BackendSpec,
    /// How problems travel to process/tcp workers (`sweep.ship` config
    /// key / `--ship` flag / `GREEDYML_SHIP`).
    pub ship: ShipSpec,
    /// Flat problem spec shipped to process/tcp-backend workers.
    pub problem_spec: String,
    /// `greedyml serve` worker daemons for the tcp backend (`sweep.hosts`
    /// config key / `--hosts` flag; `None` defers to `GREEDYML_HOSTS`).
    pub hosts: Option<Vec<String>>,
    /// Worker-loss policy for remote backends (`sweep.on_fault` config
    /// key / `--on-fault` flag / `GREEDYML_ON_FAULT`).
    pub on_fault: FaultSpec,
    /// Frame encoding on the worker wire (`sweep.wire` config key /
    /// `--wire` flag / `GREEDYML_WIRE`).
    pub wire: WireSpec,
    /// Sieve-streaming coreset mode (`sweep.coreset` config key /
    /// `--coreset` flag / `GREEDYML_CORESET`).
    pub coreset: CoresetSpec,
}

impl Sweep {
    /// Parse from the `[sweep]` section of a config:
    /// `ks = 100, 200`, `algos = …`, `reps = 3`.
    pub fn from_config(cfg: &Config) -> crate::Result<Self> {
        let ks = cfg
            .u64_list("sweep.ks")?
            .into_iter()
            .map(|k| k as usize)
            .collect::<Vec<_>>();
        anyhow::ensure!(!ks.is_empty(), "sweep.ks is empty");
        let algos = cfg
            .str("sweep.algos")?
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(AlgoSpec::parse)
            .collect::<crate::Result<Vec<_>>>()?;
        let mem_limit = match cfg.get("sweep.mem_limit") {
            None | Some("none") => None,
            Some(v) => Some(
                crate::util::config::parse_u64(v)
                    .map_err(|m| anyhow::anyhow!("sweep.mem_limit: {m}"))?,
            ),
        };
        let backend = BackendSpec::parse(cfg.str_or("sweep.backend", "auto"))
            .map_err(|e| anyhow::anyhow!("sweep.backend: {e}"))?;
        let ship = ShipSpec::parse(cfg.str_or("sweep.ship", "auto"))
            .map_err(|e| anyhow::anyhow!("sweep.ship: {e}"))?;
        let on_fault = FaultSpec::parse(cfg.str_or("sweep.on_fault", "auto"))
            .map_err(|e| anyhow::anyhow!("sweep.on_fault: {e}"))?;
        let wire = WireSpec::parse(cfg.str_or("sweep.wire", "auto"))
            .map_err(|e| anyhow::anyhow!("sweep.wire: {e}"))?;
        let coreset = CoresetSpec::parse(cfg.str_or("sweep.coreset", "auto"))
            .map_err(|e| anyhow::anyhow!("sweep.coreset: {e}"))?;
        Ok(Self {
            ks,
            algos,
            reps: cfg.u64_or("sweep.reps", 3)?,
            seed: cfg.u64_or("sweep.seed", 42)?,
            mem_limit,
            local_view: cfg.bool_or("sweep.local_view", false)?,
            backend,
            ship,
            problem_spec: super::problem_spec(cfg),
            hosts: crate::dist::tcp::hosts_from_config(cfg, "sweep.hosts")?,
            on_fault,
            wire,
            coreset,
        })
    }

    /// Attach this sweep's backend settings to an engine config.  The
    /// sweep varies `k` and always runs a cardinality constraint — append
    /// both to the spec (later keys win) so process/tcp workers rebuild
    /// the constraint the cell actually runs.
    fn with_backend(&self, mut dist: DistConfig, k: usize) -> DistConfig {
        dist.backend = self.backend;
        dist.problem = Some(format!(
            "{}problem.constraint = cardinality\nproblem.k = {k}\n",
            self.problem_spec
        ));
        dist.ship = self.ship;
        dist.hosts = self.hosts.clone();
        dist.on_fault = self.on_fault;
        dist.wire = self.wire;
        dist.coreset = self.coreset;
        dist
    }

    /// Run the grid. Each (k, algo) cell is repeated `reps` times with
    /// seeds `seed + r`; values/calls/times are geomean-aggregated into one
    /// report row. Failed cells (OOM) are returned separately.
    ///
    /// All cells share one [`SessionPool`]: on the process/tcp backends
    /// the dataset ships to the fleet once and every grid cell is a job
    /// against the resident shards, so a p-point sweep pays 1×shard of
    /// Init traffic instead of p×shard.  (Cells that pin different shard
    /// splits — different rep seeds under partition shipping — establish
    /// their own sessions, as they must.)
    pub fn run(&self, problem: &BuiltProblem) -> (Vec<RunReport>, Vec<(String, String)>) {
        let oracle = problem.oracle.as_ref();
        let pool = SessionPool::new();
        let mut reports = Vec::new();
        let mut failures = Vec::new();
        for &k in &self.ks {
            let constraint = Cardinality::new(k);
            let baseline = run_sequential(oracle, &constraint, GreedyKind::Lazy, None)
                .map(|s| s.greedy.value)
                .unwrap_or(0.0);
            for spec in &self.algos {
                let label = spec.label();
                let mut vals = Vec::new();
                let mut calls = Vec::new();
                let mut comps = Vec::new();
                let mut comms = Vec::new();
                let mut peak = 0u64;
                let mut fault_notes: Vec<String> = Vec::new();
                let mut failed = None;
                let (m, b, l) = match *spec {
                    AlgoSpec::Greedy => (1, 0, 0),
                    AlgoSpec::GreeDi { m } | AlgoSpec::RandGreedi { m } => (m, m, 1),
                    AlgoSpec::GreedyMl { m, b } => (m, b, AccumulationTree::new(m, b).levels()),
                };
                for r in 0..self.reps {
                    let result = match *spec {
                        AlgoSpec::Greedy => {
                            run_sequential(oracle, &constraint, GreedyKind::Lazy, self.mem_limit)
                                .map(|s| {
                                    (
                                        s.greedy.value,
                                        s.greedy.calls,
                                        s.secs,
                                        0.0,
                                        s.peak_mem,
                                        FaultReport::default(),
                                    )
                                })
                                .map_err(|e| e.to_string())
                        }
                        AlgoSpec::GreeDi { m } => {
                            let cfg = self.with_backend(greedi_config(m, self.mem_limit), k);
                            run_dist_pooled(oracle, &constraint, &cfg, &pool)
                                .map(|o| {
                                    (
                                        o.value,
                                        o.critical_calls,
                                        o.comp_secs,
                                        o.comm_secs,
                                        o.peak_mem(),
                                        o.faults,
                                    )
                                })
                                .map_err(|e| e.to_string())
                        }
                        AlgoSpec::RandGreedi { m } => {
                            let opts = crate::algo::randgreedi::RandGreediOpts {
                                mem_limit: self.mem_limit,
                                local_view: self.local_view,
                                ..crate::algo::randgreedi::RandGreediOpts::new(m, self.seed + r)
                            };
                            let cfg = self.with_backend(opts.to_config(), k);
                            run_dist_pooled(oracle, &constraint, &cfg, &pool)
                                .map(|o| {
                                    (
                                        o.value,
                                        o.critical_calls,
                                        o.comp_secs,
                                        o.comm_secs,
                                        o.peak_mem(),
                                        o.faults,
                                    )
                                })
                                .map_err(|e| e.to_string())
                        }
                        AlgoSpec::GreedyMl { m, b } => {
                            let cfg = self.with_backend(
                                DistConfig {
                                    mem_limit: self.mem_limit,
                                    local_view: self.local_view,
                                    ..DistConfig::greedyml(
                                        AccumulationTree::new(m, b),
                                        self.seed + r,
                                    )
                                },
                                k,
                            );
                            run_dist_pooled(oracle, &constraint, &cfg, &pool)
                                .map(|o| {
                                    (
                                        o.value,
                                        o.critical_calls,
                                        o.comp_secs,
                                        o.comm_secs,
                                        o.peak_mem(),
                                        o.faults,
                                    )
                                })
                                .map_err(|e| e.to_string())
                        }
                    };
                    match result {
                        Ok((v, c, comp, comm, p, faults)) => {
                            vals.push(v.max(1e-12));
                            calls.push(c.max(1) as f64);
                            comps.push(comp.max(1e-9));
                            comms.push(comm.max(1e-12));
                            peak = peak.max(p);
                            if !faults.is_empty() {
                                fault_notes.push(format!("rep {r}: {faults}"));
                            }
                        }
                        Err(e) => {
                            failed = Some(e);
                            break;
                        }
                    }
                }
                match failed {
                    Some(e) => failures.push((format!("{label} k={k}"), e)),
                    None => {
                        let report = RunReport {
                            algo: label,
                            dataset: problem.summary.name.clone(),
                            k,
                            machines: m,
                            branching: b,
                            levels: l,
                            value: geomean(&vals),
                            rel_value_pct: None,
                            critical_calls: geomean(&calls) as u64,
                            total_calls: 0,
                            comp_secs: geomean(&comps),
                            comm_secs: geomean(&comms),
                            peak_mem: peak,
                            faults: (!fault_notes.is_empty()).then(|| fault_notes.join("; ")),
                        }
                        .with_baseline(baseline);
                        reports.push(report);
                    }
                }
            }
        }
        (reports, failures)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::build_problem;

    #[test]
    fn sweep_parses_and_runs_grid() {
        let cfg = Config::parse(
            "[dataset]\nkind = retail\nn = 400\nseed = 2\n\
             [sweep]\nks = 4, 8\nalgos = randgreedi:4, greedyml:4:2\nreps = 2\nseed = 9\n",
        )
        .unwrap();
        let problem = build_problem(&cfg, None).unwrap();
        let sweep = Sweep::from_config(&cfg).unwrap();
        let (reports, failures) = sweep.run(&problem);
        assert!(failures.is_empty(), "{failures:?}");
        assert_eq!(reports.len(), 4, "2 ks × 2 algos");
        for r in &reports {
            assert!(r.value > 0.0);
            let rel = r.rel_value_pct.unwrap();
            assert!(rel > 40.0 && rel <= 105.0, "{}: {rel}", r.algo);
        }
    }

    #[test]
    fn sweep_reports_oom_cells() {
        let cfg = Config::parse(
            "[dataset]\nkind = retail\nn = 400\nseed = 2\n\
             [sweep]\nks = 8\nalgos = randgreedi:4\nreps = 1\nmem_limit = 1kb\n",
        )
        .unwrap();
        let problem = build_problem(&cfg, None).unwrap();
        let sweep = Sweep::from_config(&cfg).unwrap();
        let (reports, failures) = sweep.run(&problem);
        assert!(reports.is_empty());
        assert_eq!(failures.len(), 1);
    }

    #[test]
    fn bad_configs_error() {
        let cfg = Config::parse("[sweep]\nks = \nalgos = greedy\n").unwrap();
        assert!(Sweep::from_config(&cfg).is_err());
        let cfg = Config::parse("[sweep]\nks = 4\nalgos = bogus\n").unwrap();
        assert!(Sweep::from_config(&cfg).is_err());
    }
}
