//! The k-medoid (exemplar-based clustering) objective (§4.2, §6.4).
//!
//! Given vectors and dissimilarity `d(u, v)` (Euclidean distance), the loss
//! `L(S) = (1/|V'|) Σ_{u∈V'} min_{v∈S} d(u, v)` is turned into a monotone
//! submodular maximization via `f(S) = L({e₀}) − L(S ∪ {e₀})` with the
//! auxiliary element `e₀` = the all-zeros vector (the paper's choice).
//!
//! The evaluation view `V'` matters here: in the distributed experiments
//! each machine evaluates `f` against only its local vectors
//! (Mirzasoleiman et al., Thm 10), so [`Oracle::new_state`] accepts the
//! local element list.  Candidates are always global ids.
//!
//! Per-call cost is `n'·δ` (δ = dim): each gain query scans the view and
//! computes one distance per element — this is the compute-intensive
//! objective the paper accelerates least well at the root (km images
//! accumulate there), and the one our Pallas/PJRT kernel accelerates
//! (`runtime::kmedoid_pjrt`).

use super::{GainState, Oracle};
use crate::data::vectors::VectorSet;
use crate::ElemId;
use std::sync::Arc;

/// k-medoid oracle over a vector set.
#[derive(Clone)]
pub struct KMedoid {
    data: Arc<VectorSet>,
}

impl KMedoid {
    /// Wrap a (preprocessed) vector set.
    pub fn new(data: Arc<VectorSet>) -> Self {
        Self { data }
    }

    /// The underlying vectors.
    pub fn data(&self) -> &Arc<VectorSet> {
        &self.data
    }

    /// Distance to the auxiliary element e₀ (all zeros) = L2 norm.
    fn d0(&self, i: usize) -> f64 {
        self.data.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

impl Oracle for KMedoid {
    fn n(&self) -> usize {
        self.data.len()
    }

    fn name(&self) -> &'static str {
        "k-medoid"
    }

    fn new_state<'a>(&'a self, view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        let view: Vec<ElemId> = match view {
            Some(v) => v.to_vec(),
            None => (0..self.data.len() as ElemId).collect(),
        };
        // mind_i starts at d(i, e0): the loss of the {e0}-only solution.
        let mind: Vec<f64> = view.iter().map(|&i| self.d0(i as usize)).collect();
        let base_loss_sum: f64 = mind.iter().sum();
        Box::new(KMedoidState {
            oracle: self,
            view,
            mind,
            base_loss_sum,
            solution: Vec::new(),
        })
    }

    fn elem_bytes(&self, _e: ElemId) -> usize {
        self.data.elem_bytes()
    }
}

struct KMedoidState<'a> {
    oracle: &'a KMedoid,
    view: Vec<ElemId>,
    /// Current min distance of each view element to S ∪ {e₀}.
    mind: Vec<f64>,
    /// Σ_i d(i, e₀) — the loss sum of the base solution {e₀}.
    base_loss_sum: f64,
    solution: Vec<ElemId>,
}

impl KMedoidState<'_> {
    #[inline]
    fn nv(&self) -> f64 {
        self.view.len().max(1) as f64
    }
}

impl GainState for KMedoidState<'_> {
    fn value(&self) -> f64 {
        // f(S) = L({e0}) − L(S ∪ {e0}) = (base − Σ mind) / n'.
        (self.base_loss_sum - self.mind.iter().sum::<f64>()) / self.nv()
    }

    fn gain(&self, e: ElemId) -> f64 {
        // §Perf P1: lane-parallel f32 distance (dist_sq_fast) plus sqrt
        // elision — once mind has shrunk, most candidates fail the
        // d² < mind² test and never pay the sqrt.
        let data = &self.oracle.data;
        let cand = data.row(e as usize);
        let mut acc = 0.0f64;
        for (idx, &i) in self.view.iter().enumerate() {
            let m = self.mind[idx];
            if m <= 0.0 {
                continue;
            }
            let d2 = crate::data::vectors::dist_sq_fast(data.row(i as usize), cand);
            if d2 < m * m {
                acc += m - d2.sqrt();
            }
        }
        acc / self.nv()
    }

    fn commit(&mut self, e: ElemId) {
        let data = &self.oracle.data;
        let cand = data.row(e as usize);
        for (idx, &i) in self.view.iter().enumerate() {
            let m = self.mind[idx];
            let d2 = crate::data::vectors::dist_sq_fast(data.row(i as usize), cand);
            if d2 < m * m {
                self.mind[idx] = d2.sqrt();
            }
        }
        self.solution.push(e);
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, _e: ElemId) -> u64 {
        // n'·δ (Table 1, k-medoid row).
        (self.view.len() * self.oracle.data.dim()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::testutil;

    fn small() -> KMedoid {
        // Four 2-d points.
        let vs = VectorSet::from_flat(vec![1.0, 0.0, 0.0, 1.0, -1.0, 0.0, 3.0, 4.0], 2).unwrap();
        KMedoid::new(Arc::new(vs))
    }

    #[test]
    fn value_matches_definition() {
        let o = small();
        // L({e0}) = mean of norms = (1 + 1 + 1 + 5)/4 = 2.
        // For S = {0}: distances of each point to point0=(1,0):
        //   d(0)=0, d(1)=sqrt2, d(2)=2, d(3)=sqrt(20); min with d0.
        let f0 = o.eval(&[0]);
        let l_e0 = 2.0;
        let mind = [0.0, 2f64.sqrt().min(1.0), 1.0_f64.min(2.0), 20f64.sqrt().min(5.0)];
        let expected = l_e0 - mind.iter().sum::<f64>() / 4.0;
        assert!((f0 - expected).abs() < 1e-9, "{f0} vs {expected}");
        assert_eq!(o.eval(&[]), 0.0);
    }

    #[test]
    fn monotone_submodular_incremental() {
        let (vs, _) = crate::data::gen::gaussian_mixture(
            crate::data::gen::GaussianParams { n: 12, dim: 6, classes: 3, noise: 0.4 },
            8,
        );
        let o = KMedoid::new(Arc::new(vs));
        let mut rng = crate::util::rng::Rng::new(6);
        testutil::check_submodular(&o, &mut rng, 30);
        testutil::check_incremental(&o, &mut rng);
    }

    #[test]
    fn local_view_restricts_evaluation() {
        let o = small();
        let st_full = o.new_state(None);
        let st_local = o.new_state(Some(&[3]));
        // Candidate 3 zeroes out the loss of view {3} entirely: gain = d0(3) = 5.
        assert!((st_local.gain(3) - 5.0).abs() < 1e-9);
        assert!(st_full.gain(3) < 5.0, "full view dilutes the gain");
        // call_cost reflects view size.
        assert_eq!(st_local.call_cost(0), 2);
        assert_eq!(st_full.call_cost(0), 8);
    }

    #[test]
    fn empty_view_is_safe() {
        let o = small();
        let mut st = o.new_state(Some(&[]));
        assert_eq!(st.value(), 0.0);
        assert_eq!(st.gain(1), 0.0);
        st.commit(1);
        assert_eq!(st.value(), 0.0);
    }
}
