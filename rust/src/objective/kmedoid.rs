//! The k-medoid (exemplar-based clustering) objective (§4.2, §6.4).
//!
//! Given vectors and dissimilarity `d(u, v)` (Euclidean distance), the loss
//! `L(S) = (1/|V'|) Σ_{u∈V'} min_{v∈S} d(u, v)` is turned into a monotone
//! submodular maximization via `f(S) = L({e₀}) − L(S ∪ {e₀})` with the
//! auxiliary element `e₀` = the all-zeros vector (the paper's choice).
//!
//! The evaluation view `V'` matters here: in the distributed experiments
//! each machine evaluates `f` against only its local vectors
//! (Mirzasoleiman et al., Thm 10), so [`Oracle::new_state`] accepts the
//! local element list.  Candidates are always global ids.
//!
//! Per-call cost is `n'·δ` (δ = dim): each gain query scans the view and
//! computes one distance per element — this is the compute-intensive
//! objective the paper accelerates least well at the root (km images
//! accumulate there).  The CPU state therefore overrides `gain_batch` with
//! a cache-blocked tile kernel (§Perf P6): distances via the norm trick
//! `‖u−v‖² = ‖u‖² + ‖v‖² − 2·u·v` over precomputed row norms, candidate
//! register-blocking through [`crate::data::vectors::dot4_fast`], and the
//! existing `mind` sqrt-elision pruning.  The Pallas/PJRT kernel
//! (`runtime::kmedoid_pjrt`) is the accelerator-side counterpart.

use super::problem::{PartitionData, PartitionPayload, Partitionable};
use super::{GainState, Oracle};
use crate::data::vectors::{dot4_fast, dot_fast, VectorSet};
use crate::ElemId;
use std::sync::Arc;

/// View rows per cache tile of the blocked gain kernel (§Perf P6): at the
/// paper's δ = 128 a tile is 64 × 128 × 4 B = 32 KB of X rows, small enough
/// to stay L1/L2-hot across the whole candidate slice.
const VIEW_TILE: usize = 64;

/// k-medoid oracle over a vector set.
#[derive(Clone)]
pub struct KMedoid {
    data: Arc<VectorSet>,
}

impl KMedoid {
    /// Wrap a (preprocessed) vector set.
    pub fn new(data: Arc<VectorSet>) -> Self {
        Self { data }
    }

    /// The underlying vectors.
    pub fn data(&self) -> &Arc<VectorSet> {
        &self.data
    }
}

impl Oracle for KMedoid {
    fn n(&self) -> usize {
        self.data.len()
    }

    fn name(&self) -> &'static str {
        "k-medoid"
    }

    fn new_state<'a>(&'a self, view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        let view: Vec<ElemId> = match view {
            Some(v) => v.to_vec(),
            None => (0..self.data.len() as ElemId).collect(),
        };
        let norms = self.data.norms_sq();
        // mind_i starts at d(i, e0) = ‖x_i‖: the loss of the {e0}-only
        // solution (e0 is the all-zeros auxiliary element).
        let mind: Vec<f64> = view.iter().map(|&i| norms[i as usize].sqrt()).collect();
        let base_loss_sum: f64 = mind.iter().sum();
        Box::new(KMedoidState {
            oracle: self,
            norms,
            view,
            mind,
            base_loss_sum,
            solution: Vec::new(),
        })
    }

    fn elem_bytes(&self, _e: ElemId) -> usize {
        self.data.elem_bytes()
    }

    fn partitionable(&self) -> Option<&dyn Partitionable> {
        Some(self)
    }
}

impl Partitionable for KMedoid {
    fn extract_partition(&self, elems: &[ElemId]) -> PartitionPayload {
        PartitionPayload {
            n_global: self.data.len(),
            elems: elems.to_vec(),
            data: PartitionData::Vectors {
                dim: self.data.dim(),
                flat: self.data.gather_flat(elems),
            },
        }
    }

    fn needs_local_view(&self) -> bool {
        // f(S) scans the evaluation view; without the §6.4 machine-local
        // scheme a shard cannot reproduce the full-dataset objective.
        true
    }
}

struct KMedoidState<'a> {
    oracle: &'a KMedoid,
    /// Cached ‖x_i‖² for every row (norm-trick kernel input).
    norms: &'a [f64],
    view: Vec<ElemId>,
    /// Current min distance of each view element to S ∪ {e₀}.
    mind: Vec<f64>,
    /// Σ_i d(i, e₀) — the loss sum of the base solution {e₀}.
    base_loss_sum: f64,
    solution: Vec<ElemId>,
}

impl KMedoidState<'_> {
    #[inline]
    fn nv(&self) -> f64 {
        self.view.len().max(1) as f64
    }

    /// Norm-trick squared distance: ‖x − c‖² = ‖x‖² + ‖c‖² − 2·x·c, clamped
    /// at zero (f32 cancellation can go a hair negative for coincident
    /// points, and sqrt of that would be NaN).
    #[inline]
    fn d2(ni: f64, cn: f64, dot: f64) -> f64 {
        (ni + cn - 2.0 * dot).max(0.0)
    }

    /// §Perf P6 core: add each candidate's un-normalized gain
    /// Σ_i max(0, mind_i − d(i, c)) into `acc`.
    ///
    /// Blocking: view tiles outer so a 32 KB block of X rows stays cache-hot
    /// across the whole candidate slice, candidates register-blocked in
    /// fours inside ([`dot4_fast`] reuses each X element across the four).
    /// Per candidate, view elements accumulate in index order with one f64
    /// accumulator per (candidate, tile) — the order depends only on the
    /// view, never on chunking or thread count, and the per-candidate lane
    /// math of `dot4_fast` equals `dot_fast`, so every path through this
    /// kernel (single gain, serial batch, executor-chunked batch) is
    /// bit-identical.
    fn accumulate_gains(&self, es: &[ElemId], acc: &mut [f64]) {
        debug_assert_eq!(es.len(), acc.len());
        let data = &self.oracle.data;
        let norms = self.norms;
        let nview = self.view.len();
        let mut t = 0;
        while t < nview {
            let tend = (t + VIEW_TILE).min(nview);
            let mut c = 0;
            while c + 4 <= es.len() {
                let (e0, e1, e2, e3) = (es[c], es[c + 1], es[c + 2], es[c + 3]);
                let r0 = data.row(e0 as usize);
                let r1 = data.row(e1 as usize);
                let r2 = data.row(e2 as usize);
                let r3 = data.row(e3 as usize);
                let cn = [
                    norms[e0 as usize],
                    norms[e1 as usize],
                    norms[e2 as usize],
                    norms[e3 as usize],
                ];
                let mut s = [0.0f64; 4];
                for idx in t..tend {
                    let m = self.mind[idx];
                    if m <= 0.0 {
                        continue;
                    }
                    let i = self.view[idx] as usize;
                    let x = data.row(i);
                    let ni = norms[i];
                    let dots = dot4_fast(x, r0, r1, r2, r3);
                    let mm = m * m;
                    for j in 0..4 {
                        let d2 = Self::d2(ni, cn[j], dots[j]);
                        if d2 < mm {
                            s[j] += m - d2.sqrt();
                        }
                    }
                }
                for j in 0..4 {
                    acc[c + j] += s[j];
                }
                c += 4;
            }
            while c < es.len() {
                let e = es[c] as usize;
                let cand = data.row(e);
                let cn = norms[e];
                let mut s = 0.0f64;
                for idx in t..tend {
                    let m = self.mind[idx];
                    if m <= 0.0 {
                        continue;
                    }
                    let i = self.view[idx] as usize;
                    let d2 = Self::d2(norms[i], cn, dot_fast(data.row(i), cand));
                    if d2 < m * m {
                        s += m - d2.sqrt();
                    }
                }
                acc[c] += s;
                c += 1;
            }
            t = tend;
        }
    }
}

impl GainState for KMedoidState<'_> {
    fn value(&self) -> f64 {
        // f(S) = L({e0}) − L(S ∪ {e0}) = (base − Σ mind) / n'.
        (self.base_loss_sum - self.mind.iter().sum::<f64>()) / self.nv()
    }

    fn gain(&self, e: ElemId) -> f64 {
        // A one-candidate tile of the blocked kernel, so the lazy heap's
        // single refreshes agree bit-for-bit with the batched initial scan.
        let mut acc = [0.0f64];
        self.accumulate_gains(&[e], &mut acc);
        acc[0] / self.nv()
    }

    fn gain_batch(&self, es: &[ElemId], out: &mut Vec<f64>) {
        out.clear();
        out.resize(es.len(), 0.0);
        self.accumulate_gains(es, out);
        let nv = self.nv();
        for g in out.iter_mut() {
            *g /= nv;
        }
    }

    fn commit(&mut self, e: ElemId) {
        // Fused on the same norm-trick kernel as the gain scan: the d² a
        // commit writes into `mind` is the exact value the next gain query
        // would compare against, so sqrt-elision pruning stays lossless.
        let data = &self.oracle.data;
        let cand = data.row(e as usize);
        let cn = self.norms[e as usize];
        for (idx, &i) in self.view.iter().enumerate() {
            let m = self.mind[idx];
            let d2 = Self::d2(self.norms[i as usize], cn, dot_fast(data.row(i as usize), cand));
            if d2 < m * m {
                self.mind[idx] = d2.sqrt();
            }
        }
        self.solution.push(e);
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, _e: ElemId) -> u64 {
        // n'·δ (Table 1, k-medoid row).
        (self.view.len() * self.oracle.data.dim()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::testutil;

    fn small() -> KMedoid {
        // Four 2-d points.
        let vs = VectorSet::from_flat(vec![1.0, 0.0, 0.0, 1.0, -1.0, 0.0, 3.0, 4.0], 2).unwrap();
        KMedoid::new(Arc::new(vs))
    }

    #[test]
    fn value_matches_definition() {
        let o = small();
        // L({e0}) = mean of norms = (1 + 1 + 1 + 5)/4 = 2.
        // For S = {0}: distances of each point to point0=(1,0):
        //   d(0)=0, d(1)=sqrt2, d(2)=2, d(3)=sqrt(20); min with d0.
        let f0 = o.eval(&[0]);
        let l_e0 = 2.0;
        let mind = [0.0, 2f64.sqrt().min(1.0), 1.0_f64.min(2.0), 20f64.sqrt().min(5.0)];
        let expected = l_e0 - mind.iter().sum::<f64>() / 4.0;
        assert!((f0 - expected).abs() < 1e-9, "{f0} vs {expected}");
        assert_eq!(o.eval(&[]), 0.0);
    }

    #[test]
    fn monotone_submodular_incremental() {
        let (vs, _) = crate::data::gen::gaussian_mixture(
            crate::data::gen::GaussianParams { n: 12, dim: 6, classes: 3, noise: 0.4 },
            8,
        );
        let o = KMedoid::new(Arc::new(vs));
        let mut rng = crate::util::rng::Rng::new(6);
        testutil::check_submodular(&o, &mut rng, 30);
        testutil::check_incremental(&o, &mut rng);
    }

    #[test]
    fn local_view_restricts_evaluation() {
        let o = small();
        let st_full = o.new_state(None);
        let st_local = o.new_state(Some(&[3]));
        // Candidate 3 zeroes out the loss of view {3} entirely: gain = d0(3) = 5.
        assert!((st_local.gain(3) - 5.0).abs() < 1e-9);
        assert!(st_full.gain(3) < 5.0, "full view dilutes the gain");
        // call_cost reflects view size.
        assert_eq!(st_local.call_cost(0), 2);
        assert_eq!(st_full.call_cost(0), 8);
    }

    #[test]
    fn tiled_batch_matches_single_gains_bitwise() {
        // > VIEW_TILE elements so the kernel crosses tile boundaries, and a
        // candidate count that exercises both the 4-block and the scalar
        // remainder; a couple of commits make `mind` pruning non-trivial.
        let (vs, _) = crate::data::gen::gaussian_mixture(
            crate::data::gen::GaussianParams { n: 150, dim: 19, classes: 5, noise: 0.4 },
            12,
        );
        let o = KMedoid::new(Arc::new(vs));
        let mut st = o.new_state(None);
        st.commit(7);
        st.commit(101);
        let es: Vec<ElemId> = (0..149).collect();
        let mut batch = Vec::new();
        st.gain_batch(&es, &mut batch);
        for (i, &e) in es.iter().enumerate() {
            assert_eq!(
                st.gain(e).to_bits(),
                batch[i].to_bits(),
                "elem {e}: single vs batched tile kernel"
            );
        }
        // Chunked evaluation (what the executor does) merges identically.
        let mut chunked = Vec::new();
        for chunk in es.chunks(64) {
            let mut part = Vec::new();
            st.gain_batch(chunk, &mut part);
            chunked.extend(part);
        }
        assert_eq!(
            batch.iter().map(|g| g.to_bits()).collect::<Vec<_>>(),
            chunked.iter().map(|g| g.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn empty_view_is_safe() {
        let o = small();
        let mut st = o.new_state(Some(&[]));
        assert_eq!(st.value(), 0.0);
        assert_eq!(st.gain(1), 0.0);
        st.commit(1);
        assert_eq!(st.value(), 0.0);
    }
}
