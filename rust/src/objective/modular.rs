//! A modular (additive) objective: `f(S) = Σ_{e∈S} w_e` with `w ≥ 0`.
//!
//! Modular functions are the degenerate boundary of submodularity (equality
//! in the diminishing-returns inequality) and GREEDY is *exactly optimal*
//! for them under a cardinality constraint — which makes this the ideal
//! calibration oracle for the test suite: any algorithm bug that loses
//! elements or miscounts gains shows up as a hard equality failure.

use super::problem::{PartitionData, PartitionPayload, Partitionable};
use super::{GainState, Oracle};
use crate::ElemId;

/// Modular objective with fixed non-negative weights.
#[derive(Clone, Debug)]
pub struct Modular {
    weights: Vec<f64>,
}

impl Modular {
    /// Build from weights (must be non-negative for monotonicity).
    pub fn new(weights: Vec<f64>) -> Self {
        assert!(weights.iter().all(|&w| w >= 0.0), "weights must be non-negative");
        Self { weights }
    }

    /// Random weights in [0, 1).
    pub fn random(n: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        Self::new((0..n).map(|_| rng.f64()).collect())
    }

    /// Weight of one element.
    pub fn weight(&self, e: ElemId) -> f64 {
        self.weights[e as usize]
    }
}

impl Oracle for Modular {
    fn n(&self) -> usize {
        self.weights.len()
    }

    fn name(&self) -> &'static str {
        "modular"
    }

    fn new_state<'a>(&'a self, _view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        Box::new(ModularState { weights: &self.weights, value: 0.0, solution: Vec::new() })
    }

    fn elem_bytes(&self, _e: ElemId) -> usize {
        16 // id + weight
    }

    fn partitionable(&self) -> Option<&dyn Partitionable> {
        Some(self)
    }
}

impl Partitionable for Modular {
    fn extract_partition(&self, elems: &[ElemId]) -> PartitionPayload {
        PartitionPayload {
            n_global: self.weights.len(),
            elems: elems.to_vec(),
            data: PartitionData::Modular {
                weights: elems.iter().map(|&e| self.weights[e as usize]).collect(),
            },
        }
    }
}

struct ModularState<'a> {
    weights: &'a [f64],
    value: f64,
    solution: Vec<ElemId>,
}

impl GainState for ModularState<'_> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&self, e: ElemId) -> f64 {
        // Re-adding an element gains nothing (sets, not multisets).
        if self.solution.contains(&e) {
            0.0
        } else {
            self.weights[e as usize]
        }
    }

    fn commit(&mut self, e: ElemId) {
        if !self.solution.contains(&e) {
            self.value += self.weights[e as usize];
            self.solution.push(e);
        }
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, _e: ElemId) -> u64 {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::testutil;

    #[test]
    fn additive() {
        let o = Modular::new(vec![1.0, 2.0, 4.0]);
        assert_eq!(o.eval(&[0, 2]), 5.0);
        assert_eq!(o.eval(&[]), 0.0);
        assert_eq!(o.eval(&[1, 1]), 2.0, "duplicates ignored");
    }

    #[test]
    fn submodular_and_incremental() {
        let o = Modular::random(10, 3);
        let mut rng = crate::util::rng::Rng::new(1);
        testutil::check_submodular(&o, &mut rng, 40);
        testutil::check_incremental(&o, &mut rng);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn rejects_negative_weights() {
        Modular::new(vec![1.0, -0.5]);
    }
}
