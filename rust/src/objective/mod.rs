//! Submodular objective oracles.
//!
//! The algorithms only touch objectives through two traits:
//!
//! * [`Oracle`] — a monotone submodular function `f : 2^V → R+` over a dense
//!   ground set `0..n`, able to mint incremental evaluation states.
//! * [`GainState`] — one solution-in-progress: query marginal gains
//!   `f(S ∪ {e}) − f(S)` (the paper's unit of computation — every gain query
//!   is one "function call" in Table 1 and all §6 plots), and commit chosen
//!   elements.
//!
//! States support an optional *evaluation view*: the k-medoid experiments
//! (§6.4) compute the objective w.r.t. only the data local to a machine
//! (Mirzasoleiman et al., Thm 10 justifies this), so a state can be bound
//! to a subset of the dataset while candidates stay global.

use crate::ElemId;

pub mod facility;
pub mod kcover;
pub mod kdominate;
pub mod kmedoid;
pub mod modular;
pub mod problem;
pub mod wcover;

pub use facility::FacilityLocation;
pub use kcover::KCover;
pub use kdominate::KDominatingSet;
pub use kmedoid::KMedoid;
pub use modular::Modular;
pub use problem::{
    PartitionData, PartitionDecoder, PartitionDelta, PartitionOracle, PartitionPayload,
    Partitionable,
};
pub use wcover::WeightedCover;

/// A monotone submodular objective over ground set `0..n`.
pub trait Oracle: Send + Sync {
    /// Ground-set size.
    fn n(&self) -> usize;

    /// Human-readable name (reports).
    fn name(&self) -> &'static str;

    /// Fresh empty-solution state.  `view` restricts the *evaluation*
    /// dataset (not the candidate universe): `None` evaluates against the
    /// full dataset; `Some(elems)` against that subset (k-medoid local
    /// objective).  Objectives that don't distinguish (coverage) ignore it.
    fn new_state<'a>(&'a self, view: Option<&[ElemId]>) -> Box<dyn GainState + 'a>;

    /// Bytes needed to hold / communicate element `e` (solution shipping
    /// and memory accounting; §4.2 Communication Complexity).
    fn elem_bytes(&self, e: ElemId) -> usize;

    /// Evaluate `f(S)` from scratch (convenience; costs |S| gain queries).
    fn eval(&self, solution: &[ElemId]) -> f64 {
        let mut st = self.new_state(None);
        for &e in solution {
            st.commit(e);
        }
        st.value()
    }

    /// Partition-shipping hook ([`problem`]): oracles whose dataset can be
    /// sliced into serde-stable shards return themselves as a
    /// [`Partitionable`].  The default `None` means the oracle only
    /// travels as a rebuild recipe (`--ship spec`) — the PJRT-backed
    /// oracles stay there because their data lives in AOT device buffers.
    fn partitionable(&self) -> Option<&dyn Partitionable> {
        None
    }
}

/// An in-progress solution with incremental marginal-gain queries.
///
/// States are `Send + Sync`: the read-only scan methods (`gain`,
/// `gain_batch`) fan out across executor workers
/// ([`crate::dist::pool::par_gain_batch`]), while `commit` keeps `&mut`
/// exclusivity on the submitting thread.
pub trait GainState: Send + Sync {
    /// Current `f(S)`.
    fn value(&self) -> f64;

    /// Marginal gain `f(S ∪ {e}) − f(S)`. Pure (does not mutate).
    fn gain(&self, e: ElemId) -> f64;

    /// Add `e` to the solution.
    fn commit(&mut self, e: ElemId);

    /// Elements committed so far, in commit order.
    fn solution(&self) -> &[ElemId];

    /// Abstract cost of one `gain` query in the BSP model (the paper's
    /// per-call cost: δ for coverage functions, n'·δ for k-medoid).
    fn call_cost(&self, e: ElemId) -> u64;

    /// Batched gains; the CPU k-medoid state overrides this with the
    /// cache-blocked tile kernel, and the PJRT-accelerated state pushes the
    /// whole candidate tile through the AOT kernel.  Implementations must
    /// keep each candidate's gain independent of the batch it arrives in —
    /// the executor splits batches into fixed-size chunks across threads
    /// and relies on the merged vector being bit-identical.
    fn gain_batch(&self, es: &[ElemId], out: &mut Vec<f64>) {
        out.clear();
        out.extend(es.iter().map(|&e| self.gain(e)));
    }

    /// Whether the executor may split one `gain_batch` across worker
    /// threads (`dist::pool::par_gain_batch`).  Pure CPU states say yes;
    /// the PJRT states opt out — their launches funnel through one engine
    /// mutex (chunking would only multiply padded kernel launches) and the
    /// device-to-host readback is not internally thread-safe.
    fn parallel_scan(&self) -> bool {
        true
    }
}

/// Shared test helpers: generic submodularity / monotonicity checks used by
/// every objective's test module and by the property suite.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Check monotonicity and the diminishing-returns property on random
    /// chains X ⊆ Y and elements w ∉ Y.
    pub fn check_submodular(oracle: &dyn Oracle, rng: &mut crate::util::rng::Rng, trials: usize) {
        let n = oracle.n();
        assert!(n >= 3, "need a few elements");
        for _ in 0..trials {
            // Random Y, random X ⊂ Y, random w ∉ Y.
            let mut elems: Vec<ElemId> = (0..n as u32).collect();
            rng.shuffle(&mut elems);
            let ylen = 1 + rng.below((n - 1) as u64) as usize;
            let (yset, rest) = elems.split_at(ylen.min(n - 1));
            let xlen = rng.below(yset.len() as u64 + 1) as usize;
            let xset = &yset[..xlen];
            let w = rest[0];

            let f = |s: &[ElemId]| oracle.eval(s);
            let fy = f(yset);
            let fx = f(xset);
            assert!(
                fx <= fy + 1e-6,
                "{}: monotonicity violated f(X)={fx} > f(Y)={fy}",
                oracle.name()
            );
            let gain_x = f(&[xset, &[w]].concat()) - fx;
            let gain_y = f(&[yset, &[w]].concat()) - fy;
            assert!(
                gain_x >= gain_y - 1e-6,
                "{}: submodularity violated: gain at X {gain_x} < gain at Y {gain_y}",
                oracle.name()
            );
        }
    }

    /// Check that incremental gains match from-scratch evaluation along a
    /// random insertion order.
    pub fn check_incremental(oracle: &dyn Oracle, rng: &mut crate::util::rng::Rng) {
        let n = oracle.n();
        let mut elems: Vec<ElemId> = (0..n as u32).collect();
        rng.shuffle(&mut elems);
        let take = elems.len().min(8);
        let mut st = oracle.new_state(None);
        let mut sol: Vec<ElemId> = Vec::new();
        for &e in &elems[..take] {
            let want = oracle.eval(&[&sol[..], &[e]].concat()) - oracle.eval(&sol);
            let got = st.gain(e);
            assert!(
                (want - got).abs() < 1e-6,
                "{}: incremental gain {got} != batch {want} at |S|={}",
                oracle.name(),
                sol.len()
            );
            st.commit(e);
            sol.push(e);
            assert!(
                (st.value() - oracle.eval(&sol)).abs() < 1e-6,
                "{}: value drift after commit",
                oracle.name()
            );
        }
        assert_eq!(st.solution(), &sol[..]);
    }
}
